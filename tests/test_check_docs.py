"""Doc-CI plumbing (scripts/check_docs.py): fence extraction rules and
the documented files actually containing executable blocks.  Executing
the blocks is the CI ``docs`` job; this keeps the extractor honest in
tier-1 without paying the snippet runtimes."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))
from check_docs import default_files, extract_blocks  # noqa: E402

SAMPLE = """\
intro text
```python
x = 1
```
```bash
echo not python
```
```python no-run
this_would_crash(
```
```text
nope
```
```python
y = x + 1
```
"""


def test_extracts_only_runnable_python_blocks():
    blocks = extract_blocks(SAMPLE)
    assert [src for _, src in blocks] == ["x = 1", "y = x + 1"]
    # line numbers point INTO the block (1-indexed markdown lines)
    assert blocks[0][0] == 3
    assert blocks[1][0] == 15


def test_unterminated_fence_does_not_hang_or_crash():
    blocks = extract_blocks("```python\nx = 1")
    assert blocks == [(2, "x = 1")]


def test_plain_fence_without_language_ignored():
    assert extract_blocks("```\nnot code\n```\n") == []


def test_documented_files_exist_with_executable_blocks():
    files = default_files()
    names = {os.path.basename(f) for f in files}
    assert {"README.md", "serving.md", "quantization.md"} <= names
    for f in files:
        with open(f) as fh:
            assert extract_blocks(fh.read()), \
                f"{f} has no executable python block"
