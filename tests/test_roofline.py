"""Roofline machinery: trip-count-aware HLO cost parsing vs XLA's
aggregate on unrolled graphs; collective parsing; term derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the roofline/dist subsystem is not present in every checkout yet; skip
# cleanly instead of failing collection
RL = pytest.importorskip("repro.dist.roofline")
analyze = pytest.importorskip("repro.dist.hlo_cost").analyze
# Compiled.cost_analysis() returns [dict] on some jax versions (0.4.x
# CPU) and dict on others; normalize through the shared shim.
from repro.dist.hlo_cost import xla_cost_dict


def _scan_fn(x, ws):
    def body(h, w):
        return jnp.tanh(h @ w), None
    y, _ = jax.lax.scan(body, x, ws)
    return y.sum()


def _unrolled_fn(x, ws):
    for i in range(8):
        x = jnp.tanh(x @ ws[i])
    return x.sum()


@pytest.fixture(scope="module")
def compiled_pair():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    return (jax.jit(_scan_fn).lower(x, ws).compile(),
            jax.jit(_unrolled_fn).lower(x, ws).compile())


def test_xla_cost_analysis_misses_trip_count(compiled_pair):
    """Documents WHY hlo_cost exists: XLA counts scan bodies once."""
    c_scan, c_unr = compiled_pair
    f_scan = xla_cost_dict(c_scan)["flops"]
    f_unr = xla_cost_dict(c_unr)["flops"]
    assert f_scan < f_unr / 4


def test_parsed_flops_match_unrolled(compiled_pair):
    c_scan, c_unr = compiled_pair
    expect = 2 * 128 * 256 * 256 * 8
    for c in compiled_pair:
        got = analyze(c.as_text())["flops"]
        assert abs(got - expect) / expect < 0.02, got


def test_parsed_bytes_reasonable(compiled_pair):
    """Slice-aware bytes: within 2x of XLA's unrolled accounting."""
    c_scan, c_unr = compiled_pair
    xla_b = xla_cost_dict(c_unr)["bytes accessed"]
    got = analyze(c_scan.as_text())["bytes accessed"]
    assert 0.5 * xla_b < got < 2.0 * xla_b


def test_collective_bytes_regex():
    hlo = """
ENTRY %main (a: f32[256]) -> f32[256] {
  %a = f32[256]{0} parameter(0)
  %ar = f32[256]{0} all-reduce(%a), replica_groups={}
  %ag.1 = bf16[2,128]{1,0} all-gather(%x), dimensions={0}
  ROOT %r = f32[256]{0} add(%ar, %ar)
}
"""
    c = analyze(hlo)
    assert c["collective_bytes"] == 256 * 4 + 2 * 128 * 2
    assert c["collective_count"] == 2


def test_collectives_inside_loops_are_trip_multiplied():
    """A collective inside a scanned layer fires once per trip."""
    hlo = """
%body (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  %g = f32[64]{0} get-tuple-element(%p), index=1
  %ar = f32[64]{0} all-reduce(%g), replica_groups={}
  ROOT %t = (s32[], f32[64]{0}) tuple(%i, %ar)
}
%cond (p: (s32[], f32[64])) -> pred[] {
  %p = (s32[], f32[64]{0}) parameter(0)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64]{0} parameter(0)
  %t0 = (s32[], f32[64]{0}) tuple(%z, %a)
  %w = (s32[], f32[64]{0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[64]{0} get-tuple-element(%w), index=1
}
"""
    c = analyze(hlo)
    assert c["collective_count"] == 5
    assert c["collective_bytes"] == 5 * 64 * 4


def test_roofline_terms_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"total": 50e9 * 0.5, "count": 3}
    t = RL.roofline_terms(cost, coll, model_flops=197e12 / 2)
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 2.0) < 1e-6
    assert abs(t["collective_s"] - 0.5) < 1e-6
    assert t["bottleneck"] == "memory"
    assert abs(t["useful_flops_ratio"] - 0.5) < 1e-6
    assert abs(t["mfu_bound"] - 0.25) < 1e-6
