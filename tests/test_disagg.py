"""repro.serve.disagg: DisaggEngine behind the LLMEngine surface --
bit-identical token streams vs the single-process engine under loadgen
traces (sync + async pumps), cancellation semantics, worker balancing,
disagg metrics, and cross-process worker pools."""
import jax
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import init_params
from repro.serve import (EnginePump, LLMEngine, SamplingParams,
                         StepBudgetExhausted)
from repro.serve.disagg import (DisaggEngine, WorkerSpec,
                                generate_disagg)
from repro.serve.loadgen import (ClusteredArrivals, SLO,
                                 SharedPrefixChat, RAGLongPrompt, Trace,
                                 TraceEvent, WorkloadMix, run)


@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# both worlds must share chunking/limits: chunked prefill is numerics
_KNOBS = dict(max_batch=2, max_len=48, prefill_chunk=8)


def _mono(cfg, params):
    return LLMEngine(params, cfg, **_KNOBS)


def _disagg(cfg, params, **kw):
    kw = {**_KNOBS, **kw}
    kw.setdefault("prefill_workers", 1)
    kw.setdefault("decode_workers", 2)
    return DisaggEngine(params, cfg, **kw)


def _clustered_trace(vocab, n=10, seed=3, cancel_fraction=0.0):
    mix = WorkloadMix(
        [(2, SharedPrefixChat(n_prefixes=3, prefix_len=8,
                              suffix_len=(1, 2), max_tokens=(2, 4))),
         (1, RAGLongPrompt(prompt_len=(10, 14), max_tokens=(1, 3)))],
        cancel_fraction=cancel_fraction)
    return mix.build(n_requests=n, vocab_size=vocab, seed=seed,
                     arrivals=ClusteredArrivals(n_clusters=3,
                                                gap_s=0.5,
                                                spread_s=0.001))


# ---------------------------------------------------------------------------
# bit-identity vs the single-process engine
# ---------------------------------------------------------------------------

def test_disagg_streams_bit_identical_greedy_and_seeded(setup):
    """The acceptance bar: greedy and seeded-sampled streams through
    the split pipeline match LLMEngine token-for-token."""
    cfg, params = setup
    events = [
        TraceEvent(t=0.000, request_id="greedy", prompt=(1, 2, 3, 4),
                   max_tokens=5, seed=11),
        TraceEvent(t=0.001, request_id="samp",
                   prompt=(9, 8, 7, 6, 5, 4, 3), max_tokens=4,
                   temperature=0.8, top_k=16, seed=12),
        TraceEvent(t=0.002, request_id="one", prompt=(5,),
                   max_tokens=3, seed=13),
        TraceEvent(t=0.003, request_id="nuc",
                   prompt=tuple(t % cfg.vocab_size
                                for t in range(20, 32)),
                   max_tokens=4, temperature=0.7, top_p=0.9, seed=14),
    ]
    tr = Trace(events=events, name="bitident")
    rm = run(_mono(cfg, params), tr, pump="sync", time_scale=0.0,
             warmup=False)
    with _disagg(cfg, params) as eng:
        rd = run(eng, tr, pump="sync", time_scale=0.0, warmup=False)
        mj = eng.metrics_json()
    assert rd["token_streams"] == rm["token_streams"]
    # the one-token prompt had no prefix to ship
    assert mj["disagg"]["transport"]["direct_admits"] == 1
    assert mj["disagg"]["transport"]["transfers"] == 3
    assert mj["disagg"]["decode"]["snapshot_restores"] == 3


def test_disagg_clustered_burst_trace_matches_llmengine(setup):
    cfg, params = setup
    tr = _clustered_trace(cfg.vocab_size)
    rm = run(_mono(cfg, params), tr, pump="sync", time_scale=0.0,
             warmup=False)
    with _disagg(cfg, params) as eng:
        rd = run(eng, tr, pump="sync", time_scale=0.0, warmup=False)
        mj = eng.metrics_json()
    assert rd["token_streams"] == rm["token_streams"]
    assert rd["completed"] == len(tr)
    d = mj["disagg"]
    assert d["transport"]["transfers"] == len(tr)
    assert d["transport"]["bytes"] > 0
    assert d["transport"]["latency_ms"]["n"] == len(tr)
    assert d["decode"]["snapshot_restores"] == len(tr)
    # snapshot restores made these zero-prefill seats on decode workers
    assert d["decode"]["fallback_prefill_dispatches"] == 0
    assert d["prefill"]["dispatches"] > 0


def test_disagg_async_pump_matches_sync(setup):
    """loadgen's async EnginePump drives a DisaggEngine unchanged and
    explicit per-event seeds keep the streams timing-invariant."""
    cfg, params = setup
    tr = _clustered_trace(cfg.vocab_size, n=8, seed=5)
    with _disagg(cfg, params) as es:
        rs = run(es, tr, pump="sync", time_scale=0.0, warmup=False)
    with _disagg(cfg, params) as ea:
        ra = run(ea, tr, SLO(ttft_p99_ms=600_000.0), pump="async",
                 time_scale=0.0, warmup=False)
        assert ea.scheduler.outstanding() == []
    assert ra["token_streams"] == rs["token_streams"]
    assert ra["slo"]["ok"] is True
    assert ra["steps"] > 0 and ra["occupancy_mean"] > 0


def test_disagg_warmup_path_and_metrics_sections(setup):
    cfg, params = setup
    tr = _clustered_trace(cfg.vocab_size, n=4, seed=7)
    with _disagg(cfg, params, decode_workers=1) as eng:
        r = run(eng, tr, pump="sync", time_scale=0.0, warmup=True)
        mj = eng.metrics_json()
    assert r["completed"] == len(tr)
    d = mj["disagg"]
    assert d["mode"] == "thread"
    assert d["prefill"]["workers"] == 1
    assert d["decode"]["workers"] == 1
    assert 0 < d["decode"]["occupancy_mean"] <= 1.0
    assert d["admission"]["plan"]["max_batch"] >= 1
    # worker dispatch counters merged into the engine section
    assert mj["engine"]["prefill_dispatches"] > 0
    assert mj["engine"]["decode_steps"] > 0
    assert mj["engine"]["prefix_restores"] > 0


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_disagg_cancellation_token_deterministic(setup):
    cfg, params = setup
    events = [
        TraceEvent(t=0.000, request_id="keep0", prompt=(1, 2, 3, 4),
                   max_tokens=6, seed=1),
        TraceEvent(t=0.001, request_id="cq", prompt=(5, 6, 7),
                   max_tokens=6, seed=2, cancel_after_tokens=0),
        TraceEvent(t=0.002, request_id="cd", prompt=(8, 9, 10, 11),
                   max_tokens=6, seed=3, cancel_after_tokens=2),
        TraceEvent(t=0.003, request_id="keep1", prompt=(4, 3, 2, 1, 5),
                   max_tokens=4, seed=4),
    ]
    tr = Trace(events=events, name="cancel")
    rm = run(_mono(cfg, params), tr, pump="sync", time_scale=0.0,
             warmup=False)
    with _disagg(cfg, params) as eng:
        rd = run(eng, tr, pump="sync", time_scale=0.0, warmup=False)
        assert eng.scheduler.outstanding() == []
        mj = eng.metrics_json()
    assert rd["token_streams"] == rm["token_streams"]
    assert rd["token_streams"]["cq"] == []
    assert len(rd["token_streams"]["cd"]) == 2          # exactly k
    assert rd["cancelled"] == 2 and rd["completed"] == 2
    assert mj["engine"]["requests_cancelled"] == 2


def test_disagg_cancel_api_edges(setup):
    cfg, params = setup
    with _disagg(cfg, params, decode_workers=1) as eng:
        assert eng.cancel("nope") is False
        st = eng.add_request([1, 2, 3], SamplingParams(max_tokens=4))
        # still queued: cancelled before any worker saw it
        assert eng.cancel(st.request_id) is True
        assert st.finished and list(st.token_ids) == []
        assert eng.cancel(st.request_id) is False       # already done
        st2 = eng.add_request([1, 2, 3, 4],
                              SamplingParams(max_tokens=8))
        eng.step()                                      # admitted
        assert eng.cancel(st2.request_id) is True
        assert not eng.has_unfinished()
        assert eng.step() == []                         # strict no-op


# ---------------------------------------------------------------------------
# engine surface / topology
# ---------------------------------------------------------------------------

def test_disagg_balances_across_decode_workers(setup):
    cfg, params = setup
    with _disagg(cfg, params, decode_workers=2) as eng:
        sp = SamplingParams(max_tokens=3)
        for i in range(4):
            eng.add_request([1 + i, 2, 3, 4], sp)
        eng.step()
        # least-loaded placement: 4 admits over 2x2 slots fill both
        assert [len(s) for s in eng._assigned] == [2, 2]
        eng.run()
        occ = eng.metrics_json()["disagg"]["decode"][
            "per_worker_occupancy"]
    assert len(occ) == 2 and all(o > 0 for o in occ)


def test_disagg_rejects_bad_arguments(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="worker"):
        DisaggEngine(None, setup[0], prefill_workers=0)
    with _disagg(cfg, params, decode_workers=1) as eng:
        eng.add_request([1, 2], SamplingParams(max_tokens=2),
                        request_id="dup")
        with pytest.raises(ValueError, match="duplicate"):
            eng.add_request([3, 4], SamplingParams(max_tokens=2),
                            request_id="dup")
        eng.run()
    with pytest.raises(ValueError, match="role"):
        WorkerSpec(role="embed", cfg=cfg, params=params)


def test_disagg_run_budget_exhaustion(setup):
    cfg, params = setup
    with _disagg(cfg, params, decode_workers=1) as eng:
        st = eng.add_request([1, 2, 3], SamplingParams(max_tokens=6))
        with pytest.raises(StepBudgetExhausted, match="unfinished"):
            eng.run(max_steps=2)
        assert not st.finished
        eng.run()                   # resumes cleanly
        assert st.finished and len(st.token_ids) == 6
        assert eng.metrics_json()["engine"]["run_budget_exhausted"] == 1


def test_disagg_stream_iteration_under_pump(setup):
    cfg, params = setup
    with _disagg(cfg, params, decode_workers=1) as eng:
        with EnginePump(eng) as pump:
            st = pump.add_request([1, 2, 3, 4],
                                  SamplingParams(max_tokens=5))
            toks = list(st.stream)
            assert toks == list(st.token_ids) and len(toks) == 5
        assert eng.scheduler.outstanding() == []


def test_generate_disagg_matches_engine_generate(setup):
    cfg, params = setup
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5]]
    outs = generate_disagg(params, cfg, prompts, max_new_tokens=4,
                           max_len=48)
    assert len(outs) == 3 and all(len(o) == 4 for o in outs)
    with pytest.raises(ValueError, match="empty"):
        generate_disagg(params, cfg, [])


# ---------------------------------------------------------------------------
# process mode (real worker processes, spawn)
# ---------------------------------------------------------------------------

def test_disagg_process_mode_bit_identical(setup):
    """1 prefill + 1 decode worker in their own spawned processes:
    snapshots cross a real process boundary and the streams still match
    the in-process engine exactly."""
    cfg, params = setup
    events = [
        TraceEvent(t=0.000, request_id="g", prompt=(1, 2, 3, 4),
                   max_tokens=3, seed=41),
        TraceEvent(t=0.001, request_id="s", prompt=(7, 6, 5, 4, 3),
                   max_tokens=3, temperature=0.9, top_k=8, seed=42),
    ]
    tr = Trace(events=events, name="proc")
    rm = run(_mono(cfg, params), tr, pump="sync", time_scale=0.0,
             warmup=False)
    with _disagg(cfg, params, decode_workers=1,
                 mode="process") as eng:
        rd = run(eng, tr, pump="sync", time_scale=0.0, warmup=False)
        mj = eng.metrics_json()
    assert rd["token_streams"] == rm["token_streams"]
    d = mj["disagg"]
    assert d["mode"] == "process"
    assert d["transport"]["transfers"] == 2
    assert d["transport"]["bytes"] > 0
    assert d["decode"]["snapshot_restores"] == 2
