"""W4A8 int4 path (PR 8): nibble pack/unpack round-trip, the
kernels-backend routing for ``quamba-w4a8``, the structured backend
fallback warning, and pre-v2 (unpacked) artifact load compatibility.

The int4-matmul-vs-qdq and kernels-forward-vs-qdq parity checks that
used to live here were consolidated into the single tolerance-pinned
matrix in ``test_parity_matrix.py``."""
import dataclasses
import json
import os
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.kernels import ops as kops
from repro.models import forward, init_params
from repro.models.mamba import use_kernel_backend
from repro.models.quantize import (backend_fallback_reason, make_qctx,
                                   reset_backend_fallback_warnings)
from repro.quant.recipe import (BackendFallbackWarning, get_spec,
                                pack_int4, quantize_weight, unpack_int4,
                                uses_kernel_backend)

jax.config.update("jax_platform_name", "cpu")

# one representative arch per registered family
FAMILY_ARCHS = {
    "mamba": "mamba-130m",
    "dense": "llama3-8b",
    "moe": "qwen3-moe-30b-a3b",
    "hybrid": "zamba2-1.2b",
    "ssm": "xlstm-1.3b",
    "audio": "whisper-medium",
    "vlm": "paligemma-3b",
}

W4_KERNELS = dataclasses.replace(get_spec("quamba-w4a8"),
                                 backend="kernels")


def _calib_batches(cfg, b=2, l=32, n=2, seed=7):
    if cfg.family == "audio":
        key = jax.random.PRNGKey(seed)
        return [{"frames": jax.random.normal(key, (b, 24, cfg.d_model)),
                 "tokens": jax.random.randint(key, (b, 8), 0,
                                              cfg.vocab_size)}
                for _ in range(n)]
    if cfg.family == "vlm":
        key = jax.random.PRNGKey(seed)
        return [{"patches": jax.random.normal(
                     key, (b, cfg.prefix_len, cfg.d_model)),
                 "tokens": jax.random.randint(key, (b, l - cfg.prefix_len),
                                              0, cfg.vocab_size)}
                for _ in range(n)]
    return list(eval_batches(cfg.vocab_size, b, l, n, seed=seed))


def _w4_artifact(arch, spec=None):
    cfg = scale_down(get_config(arch), layers=2, width=64, vocab=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = _calib_batches(cfg)
    spec = spec or get_spec("quamba-w4a8")
    return cfg, api.Quantizer(cfg, spec).calibrate(calib).quantize(params)


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 3), (2, 2), (7, 5), (64, 48),
                                   (129, 257), (5,), (8,)])
def test_pack_unpack_round_trip(shape):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(-8, 8, size=shape).astype(np.int8))
    packed = pack_int4(q)
    assert packed.dtype == jnp.int8
    assert packed.shape == (-(-shape[0] // 2),) + shape[1:]
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(packed, shape[0])), np.asarray(q))


def test_pack_layout_low_nibble_is_even_row():
    q = jnp.asarray([[-8], [7], [3]], jnp.int8)        # odd K: zero pad
    packed = np.asarray(pack_int4(q))
    assert packed.shape == (2, 1)
    assert packed[0, 0] & 0xF == (-8) & 0xF            # byte0 lo = row 0
    assert (packed[0, 0] >> 4) & 0xF == 7              # byte0 hi = row 1
    assert packed[1, 0] & 0xF == 3                     # byte1 lo = row 2
    assert (packed[1, 0] >> 4) & 0xF == 0              # pad nibble is 0
    # unpadded unpack keeps the zero row (harmless for matmul)
    assert np.asarray(unpack_int4(pack_int4(q))).shape == (4, 1)


def test_pack_unpack_vmaps_over_stacked_layers():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.integers(-8, 8, size=(3, 65, 10)).astype(np.int8))
    packed = jax.vmap(pack_int4)(q)
    got = jax.vmap(lambda p: unpack_int4(p, 65))(packed)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(q))


def test_quantize_weight_storage_contract():
    w = jax.random.normal(jax.random.PRNGKey(2), (33, 17))
    w4 = get_spec("quamba-w4a8")
    packed = quantize_weight(w, w4)
    assert set(packed) == {"qw4", "s_w"} and packed["qw4"].shape == (17, 17)
    pinned = quantize_weight(w, w4, storage="int8")
    assert set(pinned) == {"qw", "s_w"}
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(packed["qw4"], 33)), np.asarray(pinned["qw"]))
    # int8 specs never pack
    assert "qw" in quantize_weight(w, get_spec("quamba"))
    with pytest.raises(ValueError, match="storage"):
        quantize_weight(w, w4, storage="int4")


# ---------------------------------------------------------------------------
# int4_matmul: bit-exact vs int8_matmul on the unpacked values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(3, 7, 5), (16, 64, 48), (5, 129, 33)])
def test_int4_matmul_matches_int8_matmul_bit_exact(mkn):
    m, k, n = mkn
    rng = np.random.default_rng(3)
    qx = jnp.asarray(rng.integers(-128, 128, (m, k)).astype(np.int8))
    q = jnp.asarray(rng.integers(-8, 8, (k, n)).astype(np.int8))
    bias = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
    packed = pack_int4(q)
    for kw in ({}, {"apply_silu": True}, {"s_out": 0.05}):
        y4 = kops.int4_matmul(qx, packed, 0.01, 0.1, bias, **kw)
        y8 = kops.int8_matmul(qx, q, 0.01, 0.1, bias, **kw)
        np.testing.assert_array_equal(np.asarray(y4), np.asarray(y8))


def test_int4_matmul_rejects_wrong_layout():
    qx = jnp.zeros((2, 8), jnp.int8)
    with pytest.raises(ValueError, match="packed rows"):
        kops.int4_matmul(qx, jnp.zeros((8, 3), jnp.int8), 1.0, 1.0)
    with pytest.raises(ValueError, match="bk must be even"):
        kops.int4_matmul(qx, jnp.zeros((4, 3), jnp.int8), 1.0, 1.0, bk=3)


# (test_int4_matmul_parity_vs_qdq_all_family_sites moved to the
# consolidated matrix: test_parity_matrix.py::test_matmul_parity_kernel_vs_qdq)


# ---------------------------------------------------------------------------
# qdq execution with packed weights (all families)
# ---------------------------------------------------------------------------

def _unpack_qdata(qdata):
    """Rewrite every {"qw4"} leaf to the equivalent unpacked {"qw"}."""
    def walk(tree):
        if isinstance(tree, dict):
            if "qw4" in tree:
                packed = tree["qw4"]
                flat = packed.reshape((-1,) + packed.shape[-2:])
                qw = jax.vmap(unpack_int4)(flat).reshape(
                    packed.shape[:-2] + (2 * packed.shape[-2],
                                         packed.shape[-1]))
                return {"qw": qw, "s_w": tree["s_w"]}
            return {k: walk(v) for k, v in tree.items()}
        return tree
    return walk(qdata)


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_w4a8_qdq_forward_identical_packed_vs_unpacked(family):
    """The packed storage is execution-transparent: the qdq forward over
    {"qw4"} leaves is bit-identical to the same qdata unpacked (the
    pre-v2 layout), for every architecture family."""
    cfg, qm = _w4_artifact(FAMILY_ARCHS[family])
    batch = _calib_batches(cfg, seed=21)[0]
    lg_packed, _ = forward(qm.params, cfg, batch, qctx=qm.qctx())
    legacy = _unpack_qdata(qm.qdata)
    # padded rows unpack to zeros beyond the true K; trim to match params
    qctx_legacy = make_qctx(qm.spec, legacy)
    lg_unpacked, _ = forward(qm.params, cfg, batch, qctx=qctx_legacy)
    np.testing.assert_array_equal(np.asarray(lg_packed),
                                  np.asarray(lg_unpacked))


# ---------------------------------------------------------------------------
# kernels-backend routing + parity (the PR-8 acceptance bar)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def w4_kernels_setup():
    return _w4_artifact("mamba-130m", spec=W4_KERNELS)


def test_w4a8_spec_uses_kernel_backend():
    assert uses_kernel_backend(W4_KERNELS)
    assert backend_fallback_reason(W4_KERNELS, None) is None


# (test_w4a8_kernels_matches_qdq_oracle_1e6 moved to the consolidated
# matrix: test_parity_matrix.py::test_forward_parity_kernels_vs_qdq)


def test_w4a8_routes_matmuls_to_int4_kernel(w4_kernels_setup, monkeypatch):
    cfg, qm = w4_kernels_setup
    counts = {"int4_matmul": 0, "int8_matmul": 0}
    for name in counts:
        orig = getattr(kops, name)

        def wrap(*a, __o=orig, __n=name, **kw):
            counts[__n] += 1
            return __o(*a, **kw)

        monkeypatch.setattr(kops, name, wrap)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(6), (2, 16),
                                          0, cfg.vocab_size)}
    forward(qm.params, cfg, batch, qctx=qm.qctx())
    # no qdq fallback and no int8 matmul for matmul sites: W4A8 means
    # every projection runs on the nibble-packed kernel
    assert counts["int4_matmul"] > 0
    assert counts["int8_matmul"] == 0, counts


def test_w4a8_weight_bytes_halved(w4_kernels_setup):
    _, qm = w4_kernels_setup
    lay = qm.qdata["qw"]["layers"]
    for site in ("in_proj", "x_proj", "dt_proj", "out_proj"):
        packed = np.asarray(lay[site]["qw4"])
        k = qm.params["layers"][site].shape[-2]
        assert packed.shape[-2] == -(-k // 2)


# ---------------------------------------------------------------------------
# structured fallback warning + describe()
# ---------------------------------------------------------------------------

def test_fallback_warning_names_reason_and_is_structured(w4_kernels_setup):
    cfg, qm = w4_kernels_setup
    legacy = _unpack_qdata(qm.qdata)
    # the warning is once-per-process-per-reason; earlier tests in this
    # process may already have consumed the unpacked-4-bit reason
    reset_backend_fallback_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        make_qctx(qm.spec, legacy)
    assert len(rec) == 1
    w = rec[0].message
    assert isinstance(w, BackendFallbackWarning)
    assert w.requested == "kernels" and w.effective == "qdq"
    assert "unpacked 4-bit" in w.reason
    # block-level routing agrees with the warning
    ctx = make_qctx(qm.spec, legacy)
    lay = {"mode": "quant", "spec": ctx["spec"],
           "scales": jax.tree.map(lambda a: a[0], ctx["scales"]["layers"]),
           "qw": jax.tree.map(lambda a: a[0], ctx["qw"]["layers"])}
    assert not use_kernel_backend(lay)


def test_no_warning_when_kernels_request_is_honored(w4_kernels_setup):
    _, qm = w4_kernels_setup
    with warnings.catch_warnings():
        warnings.simplefilter("error", BackendFallbackWarning)
        qm.qctx()                                   # packed: no fallback
        qm.qctx(backend="qdq")                      # qdq request: silent


def test_describe_surfaces_effective_backend(w4_kernels_setup):
    _, qm = w4_kernels_setup
    d = qm.describe()
    assert d["requested_backend"] == "kernels"
    assert d["effective_backend"] == "kernels"
    assert d["backend_fallback_reason"] is None
    assert d["w_bits"] == 4 and d["a_bits"] == 8
    # a qdq-backend spec reports qdq with the request reason
    cfg, qm_qdq = _w4_artifact("mamba-130m")
    d2 = qm_qdq.describe()
    assert d2["effective_backend"] == "qdq"
    # quarot can never feed the kernels
    quarot = dataclasses.replace(get_spec("quarot"), backend="kernels")
    assert "quarot" in backend_fallback_reason(quarot, None)


# ---------------------------------------------------------------------------
# pre-PR-8 (format v1, unpacked) artifact compatibility
# ---------------------------------------------------------------------------

def _write_v1_artifact(tmp_path, qm):
    """A faithful pre-PR-8 artifact: unpacked w4 leaves, format v1 meta
    without the v2 backend fields or the soft_edge spec knob."""
    legacy = dataclasses.replace(qm, qdata=_unpack_qdata(qm.qdata))
    path = os.path.join(str(tmp_path), "legacy")
    legacy.save(path)
    meta_p = os.path.join(path, "quantized_model.json")
    meta = json.load(open(meta_p))
    meta["format_version"] = 1
    meta["spec"].pop("soft_edge", None)
    for key in ("effective_backend", "backend_fallback_reason"):
        meta.pop(key, None)
    json.dump(meta, open(meta_p, "w"))
    return path


def test_pre_pr8_artifact_loads_and_runs_on_qdq(tmp_path, w4_kernels_setup):
    cfg, qm = w4_kernels_setup
    path = _write_v1_artifact(tmp_path, qm)
    qm2 = api.load(path)
    assert "qw" in qm2.qdata["qw"]["layers"]["in_proj"]   # unpacked
    d = qm2.describe()
    assert d["effective_backend"] == "qdq"
    assert "unpacked 4-bit" in d["backend_fallback_reason"]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(7),
                                              (2, 16), 0, cfg.vocab_size)}
        lg_old, _ = forward(qm2.params, cfg, batch, qctx=qm2.qctx())
    # and its numerics equal the packed artifact's qdq oracle
    lg_new, _ = forward(qm.params, cfg, batch, qctx=qm.qctx(backend="qdq"))
    np.testing.assert_array_equal(np.asarray(lg_old), np.asarray(lg_new))


def test_future_format_version_refused(tmp_path, w4_kernels_setup):
    _, qm = w4_kernels_setup
    path = os.path.join(str(tmp_path), "future")
    qm.save(path)
    meta_p = os.path.join(path, "quantized_model.json")
    meta = json.load(open(meta_p))
    meta["format_version"] = 99
    json.dump(meta, open(meta_p, "w"))
    with pytest.raises(ValueError, match="format_version"):
        api.load(path)


# ---------------------------------------------------------------------------
# Quamba-SE soft-edge activation policy
# ---------------------------------------------------------------------------

def test_soft_edge_scale_sits_between_percentile_and_amax():
    cfg = scale_down(get_config("mamba-130m"), layers=2, width=64,
                     vocab=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = _calib_batches(cfg)
    stats = api.calibration_stats(cfg, params, calib)
    base = get_spec("quamba-w4a8")
    se = get_spec("quamba-w4a8-se")
    assert se.soft_edge == 0.25
    q_hard = api.Quantizer(cfg, base).with_stats(stats).quantize(params)
    q_soft = api.Quantizer(cfg, se).with_stats(stats).quantize(params)
    s_hard = np.asarray(q_hard.qdata["scales"]["layers"]["x"])
    s_soft = np.asarray(q_soft.qdata["scales"]["layers"]["x"])
    from repro.quant.observers import stats_scale
    s_amax = np.asarray(stats_scale(stats["layers"]["x"]))
    assert np.all(s_soft >= s_hard - 1e-12)
    assert np.all(s_soft <= s_amax + 1e-12)
    np.testing.assert_allclose(s_soft, 0.75 * s_hard + 0.25 * s_amax,
                               rtol=1e-6)
    # non-percentile sites are untouched by the policy
    np.testing.assert_array_equal(
        np.asarray(q_hard.qdata["scales"]["layers"]["in"]),
        np.asarray(q_soft.qdata["scales"]["layers"]["in"]))


def test_soft_edge_validation():
    with pytest.raises(ValueError, match="soft_edge"):
        dataclasses.replace(get_spec("quamba"), soft_edge=1.5).validate()
    dataclasses.replace(get_spec("quamba"), soft_edge=1.0).validate()
