"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (per-kernel allclose against ref.py)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.causal_conv1d import causal_conv1d
from repro.kernels.hadamard_quant import hadamard_quant
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rmsnorm_quant import rmsnorm_quant
from repro.kernels.selective_scan import selective_scan
from repro.quant import quantizers as Q

RNG = np.random.default_rng(0)


def _i8(*shape):
    return jnp.asarray(RNG.integers(-128, 128, shape, dtype=np.int8))


def _f32(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (100, 300, 170),
                                   (256, 128, 384), (33, 257, 65)])
def test_int8_matmul_shapes(m, k, n):
    qx, qw = _i8(m, k), _i8(k, n)
    bias = _f32(n)
    got = int8_matmul(qx, qw, 0.01, 0.02, bias)
    want = ref.int8_matmul_ref(qx, qw, 0.01, 0.02, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_int8_matmul_dtypes(out_dtype):
    qx, qw = _i8(64, 64), _i8(64, 64)
    got = int8_matmul(qx, qw, 0.01, 0.02, out_dtype=out_dtype)
    assert got.dtype == out_dtype


def test_int8_matmul_silu_int8_out():
    qx, qw = _i8(64, 128), _i8(128, 64)
    got = int8_matmul(qx, qw, 0.01, 0.02, s_out=0.05, apply_silu=True)
    want = Q.quantize(jax.nn.silu(
        ref.int8_matmul_ref(qx, qw, 0.01, 0.02)), 0.05)
    assert got.dtype == jnp.int8
    # allow off-by-one from rounding at the fp boundary
    assert np.abs(np.asarray(got, np.int32)
                  - np.asarray(want, np.int32)).max() <= 1


# ---------------------------------------------------------------------------
# rmsnorm + residual + quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d", [(4, 64), (100, 512), (257, 384)])
def test_rmsnorm_quant(t, d):
    x, r, w = _f32(t, d), _f32(t, d), _f32(d)
    q1, r1 = rmsnorm_quant(x, r, w, 0.02)
    q2, r2 = ref.rmsnorm_quant_ref(x, r, w, 0.02)
    assert np.abs(np.asarray(q1, np.int32)
                  - np.asarray(q2, np.int32)).max() <= 1
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-5)


# ---------------------------------------------------------------------------
# hadamard quant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 512, 768, 2048, 2560])
def test_hadamard_quant_sizes(n):
    y = _f32(64, n)
    got = hadamard_quant(y, 0.03)
    want = ref.hadamard_quant_ref(y, 0.03)
    match = (np.asarray(got) == np.asarray(want)).mean()
    assert match > 0.9999, match


# ---------------------------------------------------------------------------
# causal conv1d
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,l,d,w", [(1, 8, 32, 4), (2, 37, 96, 4),
                                     (3, 64, 256, 2)])
def test_causal_conv(b, l, d, w):
    qx, qw = _i8(b, l, d), _i8(w, d)
    bias = _f32(d)
    state = _i8(b, w - 1, d)
    y1, s1 = causal_conv1d(qx, qw, bias, 0.02, 0.01, state=state)
    y2, s2 = ref.causal_conv1d_ref(qx, qw, bias, 0.02, 0.01, state=state)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert np.array_equal(np.asarray(s1), np.asarray(s2))


def test_causal_conv_chunked_equals_full():
    """Carrying the int8 tail state across chunks == one full pass."""
    b, l, d, w = 2, 64, 32, 4
    qx, qw = _i8(b, l, d), _i8(w, d)
    bias = _f32(d)
    full, _ = causal_conv1d(qx, qw, bias, 0.02, 0.01)
    st = None
    parts = []
    for i in range(0, l, 16):
        y, st = causal_conv1d(qx[:, i:i + 16], qw, bias, 0.02, 0.01,
                              state=st)
        parts.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(parts, 1)),
                               np.asarray(full), atol=1e-4)


# ---------------------------------------------------------------------------
# selective scan (the paper's core kernel)
# ---------------------------------------------------------------------------

def _scan_inputs(b, l, d, n, seed=0):
    rng = np.random.default_rng(seed)
    u = rng.normal(size=(b, l, d)).astype(np.float32) * 0.5
    dt = np.abs(rng.normal(size=(b, l, d))).astype(np.float32) * 0.1
    a = -np.abs(rng.normal(size=(d, n))).astype(np.float32)
    bm = rng.normal(size=(b, l, n)).astype(np.float32)
    cm = rng.normal(size=(b, l, n)).astype(np.float32)
    dr = rng.normal(size=d).astype(np.float32)
    z = rng.normal(size=(b, l, d)).astype(np.float32)
    qs, scales = {}, {}
    for name, arr in [("u", u), ("dt", dt), ("A", a), ("B", bm),
                      ("C", cm)]:
        s = float(Q.symmetric_scale(jnp.asarray(arr)))
        scales[name] = s
        qs[name] = Q.quantize(jnp.asarray(arr), s)
    svec = jnp.asarray([scales[k] for k in ("u", "dt", "A", "B", "C")],
                       jnp.float32)
    return qs, scales, svec, jnp.asarray(dr), jnp.asarray(z)


@pytest.mark.parametrize("b,l,d,n,chunk,bd", [
    (1, 16, 32, 8, 16, 32),
    (2, 100, 192, 16, 32, 64),
    (2, 64, 128, 16, 128, 256),   # chunk > L, block > D
    (1, 33, 96, 4, 8, 32),        # ragged L
])
def test_selective_scan_shapes(b, l, d, n, chunk, bd):
    qs, scales, svec, dr, z = _scan_inputs(b, l, d, n, seed=l)
    y1, h1 = selective_scan(qs["u"], qs["dt"], qs["A"], qs["B"], qs["C"],
                            svec, dr, z=z, chunk=chunk, block_d=bd)
    y2, h2 = ref.selective_scan_quant_ref(
        qs["u"], qs["dt"], qs["A"], qs["B"], qs["C"], scales, dr, z=z,
        return_state=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-3,
                               atol=1e-3)


def test_selective_scan_state_carry():
    """h0 in, h_last out: chunked prefill equals one full scan."""
    b, l, d, n = 1, 64, 64, 8
    qs, scales, svec, dr, _ = _scan_inputs(b, l, d, n, seed=9)
    y_full, h_full = selective_scan(qs["u"], qs["dt"], qs["A"], qs["B"],
                                    qs["C"], svec, dr, chunk=32,
                                    block_d=64)
    h = None
    ys = []
    for i in range(0, l, 16):
        sl = lambda a: a[:, i:i + 16]
        y, h = selective_scan(sl(qs["u"]), sl(qs["dt"]), qs["A"],
                              sl(qs["B"]), sl(qs["C"]), svec, dr, h0=h,
                              chunk=16, block_d=64)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=1e-3, atol=1e-3)


@given(st.integers(1, 3), st.sampled_from([8, 24, 64]),
       st.sampled_from([32, 64]), st.sampled_from([4, 16]))
@settings(max_examples=8, deadline=None)
def test_selective_scan_property(b, l, d, n):
    qs, scales, svec, dr, z = _scan_inputs(b, l, d, n, seed=b * l + d)
    y1, _ = selective_scan(qs["u"], qs["dt"], qs["A"], qs["B"], qs["C"],
                           svec, dr, z=z, chunk=16, block_d=32)
    y2 = ref.selective_scan_quant_ref(qs["u"], qs["dt"], qs["A"], qs["B"],
                                      qs["C"], scales, dr, z=z)
    assert np.allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                       atol=2e-3)


# ---------------------------------------------------------------------------
# fused single-token scan step (decode TPOT kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,d,n,bd", [(1, 32, 8, 32), (3, 192, 16, 64),
                                      (2, 96, 4, 256)])
def test_selective_scan_step_kernel(b, d, n, bd):
    from repro.kernels.scan_step import selective_scan_step
    rng = np.random.default_rng(b * d)
    arrs = {
        "u": rng.normal(size=(b, d)).astype(np.float32) * 0.5,
        "dt": np.abs(rng.normal(size=(b, d))).astype(np.float32) * 0.1,
        "A": -np.abs(rng.normal(size=(d, n))).astype(np.float32),
        "B": rng.normal(size=(b, n)).astype(np.float32),
        "C": rng.normal(size=(b, n)).astype(np.float32),
    }
    qs, sc = {}, {}
    for k, a in arrs.items():
        s = float(Q.symmetric_scale(jnp.asarray(a)))
        sc[k] = s
        qs[k] = Q.quantize(jnp.asarray(a), s)
    svec = jnp.asarray([sc[k] for k in ("u", "dt", "A", "B", "C")],
                       jnp.float32)
    dres = jnp.asarray(rng.normal(size=d).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    h = jnp.asarray(rng.normal(size=(b, d, n)).astype(np.float32))
    y1, h1 = selective_scan_step(qs["u"], qs["dt"], qs["A"], qs["B"],
                                 qs["C"], svec, dres, h, z=z, block_d=bd)
    dq = {k: qs[k].astype(jnp.float32) * sc[k] for k in qs}
    y2, h2 = ref.selective_scan_step_ref(h, dq["u"], dq["dt"], dq["A"],
                                         dq["B"], dq["C"], dres, z=z)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-5,
                               atol=1e-5)


def test_scan_step_matches_sequence_kernel_l1():
    """The fused step kernel == the sequence kernel at L=1."""
    from repro.kernels.scan_step import selective_scan_step
    qs, scales, svec, dr, z = _scan_inputs(2, 1, 64, 8, seed=21)
    h0 = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 64, 8)).astype(np.float32))
    y_seq, h_seq = selective_scan(qs["u"], qs["dt"], qs["A"], qs["B"],
                                  qs["C"], svec, dr, z=z, h0=h0,
                                  chunk=1, block_d=64)
    y_st, h_st = selective_scan_step(
        qs["u"][:, 0], qs["dt"][:, 0], qs["A"], qs["B"][:, 0],
        qs["C"][:, 0], svec, dr, h0, z=z[:, 0], block_d=64)
    np.testing.assert_allclose(np.asarray(y_st), np.asarray(y_seq[:, 0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_st), np.asarray(h_seq),
                               rtol=1e-5, atol=1e-5)



@pytest.mark.parametrize("b,d,n,k", [(1, 32, 8, 1), (2, 64, 8, 4),
                                     (2, 96, 16, 8)])
def test_scan_verify_matches_k_sequential_steps(b, d, n, k):
    """The multi-token verify kernel == k sequential step-kernel calls,
    and its per-step state snapshots are the rollback points (PR-7
    acceptance bar: parity <= 1e-6)."""
    from repro.kernels.scan_step import (selective_scan_step,
                                         selective_scan_verify)
    rng = np.random.default_rng(d + k)
    arrs = {
        "u": rng.normal(size=(b, k, d)).astype(np.float32) * 0.5,
        "dt": np.abs(rng.normal(size=(b, k, d))).astype(np.float32) * 0.1,
        "A": -np.abs(rng.normal(size=(d, n))).astype(np.float32),
        "B": rng.normal(size=(b, k, n)).astype(np.float32),
        "C": rng.normal(size=(b, k, n)).astype(np.float32),
    }
    qs, sc = {}, {}
    for name, a in arrs.items():
        s = float(Q.symmetric_scale(jnp.asarray(a)))
        sc[name] = s
        qs[name] = Q.quantize(jnp.asarray(a), s)
    svec = jnp.asarray([sc[name] for name in ("u", "dt", "A", "B", "C")],
                       jnp.float32)
    dres = jnp.asarray(rng.normal(size=d).astype(np.float32))
    z = jnp.asarray(rng.normal(size=(b, k, d)).astype(np.float32))
    h0 = jnp.asarray(rng.normal(size=(b, d, n)).astype(np.float32))

    y_v, h_steps = selective_scan_verify(qs["u"], qs["dt"], qs["A"],
                                         qs["B"], qs["C"], svec, dres,
                                         h0, z=z, block_d=64)
    assert y_v.shape == (b, k, d) and h_steps.shape == (b, k, d, n)
    h = h0
    for i in range(k):
        y_i, h = selective_scan_step(qs["u"][:, i], qs["dt"][:, i],
                                     qs["A"], qs["B"][:, i], qs["C"][:, i],
                                     svec, dres, h, z=z[:, i], block_d=64)
        np.testing.assert_allclose(np.asarray(y_v[:, i]), np.asarray(y_i),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(h_steps[:, i]),
                                   np.asarray(h), rtol=1e-6, atol=1e-6)


def test_scan_verify_m1_equals_step():
    """M=1 verify degenerates to the single-token step kernel exactly."""
    from repro.kernels.scan_step import (selective_scan_step,
                                         selective_scan_verify)
    qs, scales, svec, dr, z = _scan_inputs(2, 1, 64, 8, seed=42)
    h0 = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, 64, 8)).astype(np.float32))
    y_v, h_v = selective_scan_verify(qs["u"], qs["dt"], qs["A"], qs["B"],
                                     qs["C"], svec, dr, h0, z=z,
                                     block_d=64)
    y_s, h_s = selective_scan_step(qs["u"][:, 0], qs["dt"][:, 0], qs["A"],
                                   qs["B"][:, 0], qs["C"][:, 0], svec, dr,
                                   h0, z=z[:, 0], block_d=64)
    np.testing.assert_array_equal(np.asarray(y_v[:, 0]), np.asarray(y_s))
    np.testing.assert_array_equal(np.asarray(h_v[:, 0]), np.asarray(h_s))


# ---------------------------------------------------------------------------
# quantized SSD scan (Mamba-2 kernel, MXU-matmul formulation)
# ---------------------------------------------------------------------------

def _ssd_kernel_inputs(b, l, h, hd, n, seed=7):
    from repro.models.ssd import ssd_chunked
    rng = np.random.default_rng(seed)
    arrs = {
        "x": rng.normal(size=(b, l, h, hd)).astype(np.float32) * 0.5,
        "dt": (np.abs(rng.normal(size=(b, l, h))) * 0.2
               ).astype(np.float32),
        "A": (-np.abs(rng.normal(size=h)) - 0.1).astype(np.float32),
        "B": rng.normal(size=(b, l, n)).astype(np.float32),
        "C": rng.normal(size=(b, l, n)).astype(np.float32),
    }
    dres = rng.normal(size=h).astype(np.float32)
    qs, sc = {}, {}
    for k, a in arrs.items():
        s = float(Q.symmetric_scale(jnp.asarray(a)))
        sc[k] = s
        qs[k] = Q.quantize(jnp.asarray(a), s)
    svec = jnp.asarray([sc[k] for k in ("x", "dt", "A", "B", "C")],
                       jnp.float32)
    dq = {k: jnp.asarray(np.asarray(qs[k]).astype(np.float32) * sc[k])
          for k in qs}
    return qs, svec, dq, jnp.asarray(dres)


@pytest.mark.parametrize("b,l,h,hd,n,chunk", [
    (1, 32, 2, 8, 8, 16),
    (2, 96, 3, 8, 16, 32),
    (1, 33, 1, 4, 4, 16),     # ragged L
])
def test_ssd_scan_kernel(b, l, h, hd, n, chunk):
    from repro.kernels.ssd_scan import ssd_scan
    from repro.models.ssd import ssd_chunked
    qs, svec, dq, dres = _ssd_kernel_inputs(b, l, h, hd, n, seed=l)
    y_k, s_k = ssd_scan(qs["x"], qs["dt"], qs["A"], qs["B"], qs["C"],
                        svec, dres, chunk=chunk)
    y_r, s_r = ssd_chunked(dq["x"], dq["dt"], dq["A"], dq["B"], dq["C"],
                           dres, chunk=l, return_state=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-3, atol=2e-3)


def test_ssd_scan_kernel_state_carry():
    from repro.kernels.ssd_scan import ssd_scan
    qs, svec, dq, dres = _ssd_kernel_inputs(1, 64, 2, 8, 8, seed=3)
    y_full, s_full = ssd_scan(qs["x"], qs["dt"], qs["A"], qs["B"],
                              qs["C"], svec, dres, chunk=16)
    h0 = None
    ys = []
    for i in range(0, 64, 32):
        sl = lambda a: a[:, i:i + 32]
        y, h0 = ssd_scan(sl(qs["x"]), sl(qs["dt"]), qs["A"],
                         sl(qs["B"]), sl(qs["C"]), svec, dres, h0=h0,
                         chunk=16)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(s_full),
                               rtol=2e-3, atol=2e-3)
