"""repro.serve.loadgen: deterministic workload/trace generation, trace
replay through the sync and async pumps (bit-identical token streams),
token-deterministic cancellation under load, SLO gating, and the
engine's run-budget guard."""
import json
import random
import warnings

import jax
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import init_params
from repro.serve import (EnginePump, LLMEngine, SamplingParams,
                         StepBudgetExhausted)
from repro.serve.loadgen import (SLO, BurstyArrivals, ClusteredArrivals,
                                 RAGLongPrompt, SharedPrefixChat, Trace,
                                 TraceEvent, UniformArrivals,
                                 WorkloadMix, run, validate_prompts)
from repro.serve.metrics import stats_ms


# ---------------------------------------------------------------------------
# workload models + traces (pure python, no engine)
# ---------------------------------------------------------------------------

def _mix(cancel_fraction=0.0):
    return WorkloadMix(
        [(3, SharedPrefixChat(n_prefixes=4, prefix_len=8,
                              suffix_len=(1, 2), max_tokens=(2, 4))),
         (1, RAGLongPrompt(prompt_len=(10, 16), max_tokens=(1, 2)))],
        cancel_fraction=cancel_fraction)


def test_trace_build_is_deterministic_and_roundtrips(tmp_path):
    t1 = _mix(0.25).build(n_requests=20, vocab_size=64, seed=5)
    t2 = _mix(0.25).build(n_requests=20, vocab_size=64, seed=5)
    assert (json.dumps(t1.to_json(), sort_keys=True)
            == json.dumps(t2.to_json(), sort_keys=True))
    t3 = _mix(0.25).build(n_requests=20, vocab_size=64, seed=6)
    assert t3.to_json() != t1.to_json()          # the seed matters
    p = t1.save(str(tmp_path / "trace.json"))
    assert Trace.load(p).to_json() == t1.to_json()
    # every request carries an explicit sampling seed: replayed streams
    # must not depend on admission order (the engine's seedless salt)
    assert all(e.seed is not None for e in t1.events)
    assert 0 < t1.n_cancelled < len(t1)


def test_trace_rejects_bad_schedules():
    e = TraceEvent(t=0.0, request_id="a", prompt=(1, 2))
    with pytest.raises(ValueError, match="duplicate"):
        Trace(events=[e, TraceEvent(t=1.0, request_id="a",
                                    prompt=(3, 4))])
    with pytest.raises(ValueError, match="negative"):
        Trace(events=[TraceEvent(t=-0.5, request_id="b",
                                 prompt=(1,))])
    with pytest.raises(ValueError, match="version"):
        Trace.from_json({"version": 99, "events": []})


def test_trace_events_sorted_by_arrival():
    tr = Trace(events=[TraceEvent(t=2.0, request_id="b", prompt=(1,)),
                       TraceEvent(t=1.0, request_id="a", prompt=(2,))])
    assert [e.request_id for e in tr.events] == ["a", "b"]
    assert tr.span_s == 2.0


def test_validate_prompts_catches_misfit_traces():
    tr = Trace(events=[TraceEvent(t=0.0, request_id="a",
                                  prompt=(1, 2, 63), max_tokens=4)])
    validate_prompts(tr, vocab_size=64, max_len=16)
    with pytest.raises(ValueError, match="out-of-vocab"):
        validate_prompts(tr, vocab_size=32)
    with pytest.raises(ValueError, match="max_len"):
        validate_prompts(tr, vocab_size=64, max_len=5)
    empty = Trace(events=[TraceEvent(t=0.0, request_id="e",
                                     prompt=())])
    with pytest.raises(ValueError, match="empty"):
        validate_prompts(empty, vocab_size=64)


def test_shared_prefix_reuse_is_zipf_skewed():
    wl = SharedPrefixChat(n_prefixes=6, prefix_len=8, zipf_a=1.3)
    mix = WorkloadMix([(1, wl)])
    tr = mix.build(n_requests=120, vocab_size=64, seed=0,
                   arrivals=UniformArrivals(span_s=1.0))
    counts = {}
    for e in tr.events:
        counts[e.prompt[:8]] = counts.get(e.prompt[:8], 0) + 1
    assert len(counts) > 1                  # more than one prefix used
    ranked = sorted(counts.values(), reverse=True)
    # a hot head and a long tail -- the prefix-cache-stress shape
    assert ranked[0] >= 3 * ranked[-1]
    assert sum(ranked) == 120


def test_bursty_arrivals_deterministic_sorted_positive():
    arr = BurstyArrivals(rate=30, burst_rate=120, on_s=0.05, off_s=0.1)
    a = arr.times(random.Random(3), 50)
    b = arr.times(random.Random(3), 50)
    assert a == b and len(a) == 50
    assert all(t > 0 for t in a) and a == sorted(a)
    with pytest.raises(ValueError):
        BurstyArrivals(rate=0)
    with pytest.raises(ValueError):
        BurstyArrivals(on_s=0)


def test_clustered_and_uniform_arrivals_shapes():
    times = ClusteredArrivals(n_clusters=3, gap_s=2.0,
                              spread_s=0.01).times(None, 7)
    assert len(times) == 7 and times == sorted(times)
    # ceil(7/3) = 3 per cluster: bursts at 0, 2, 4 with tiny spreads
    assert times[0] == 0.0 and times[3] == 2.0 and times[6] == 4.0
    assert times[2] - times[0] == pytest.approx(0.02)
    with pytest.raises(ValueError):
        ClusteredArrivals(n_clusters=0)
    u = UniformArrivals(span_s=3.0).times(None, 4)
    assert u == [0.0, 1.0, 2.0, 3.0]
    assert UniformArrivals(span_s=1.0).times(None, 1) == [0.0]


def test_mix_validation_and_weighting():
    with pytest.raises(ValueError, match="at least one"):
        WorkloadMix([])
    with pytest.raises(ValueError, match="weights"):
        WorkloadMix([(0, RAGLongPrompt())])
    with pytest.raises(ValueError, match="cancel_fraction"):
        WorkloadMix([(1, RAGLongPrompt())], cancel_fraction=1.5)
    tr = _mix().build(n_requests=80, vocab_size=64, seed=1)
    counts = tr.meta["component_counts"]
    # 3:1 weighting: chat must clearly dominate
    assert counts["chat"] > counts.get("rag", 0) > 0
    assert tr.n_cancelled == 0


def test_slo_goodput_bounds_and_tail_gates():
    slo = SLO(ttft_ms=100.0, ttft_p99_ms=200.0, tpot_p95_ms=50.0)
    assert slo.good(80.0, None) and not slo.good(150.0, None)
    assert not slo.good(None, None)          # no first token: not good
    ok = {"ttft_ms": {"p95": 90.0, "p99": 150.0},
          "tpot_ms": {"p95": 40.0}}
    assert slo.check(ok) == []
    bad = {"ttft_ms": {"p95": 90.0, "p99": 250.0},
           "tpot_ms": {"p95": 60.0}}
    v = slo.check(bad)
    assert len(v) == 2 and any("p99" in s for s in v)
    # absent stats count as violations, not silent passes
    assert slo.check({"ttft_ms": None, "tpot_ms": None})
    assert SLO(ttft_p99_ms=1.0).to_json() == {"ttft_p99_ms": 1.0}


def test_stats_ms_includes_p99():
    s = stats_ms([i / 1000.0 for i in range(1, 101)])
    assert set(s) == {"mean", "p50", "p95", "p99", "max", "n"}
    assert s["p50"] <= s["p95"] <= s["p99"] <= s["max"]
    assert stats_ms([]) is None


# ---------------------------------------------------------------------------
# engine integration (small mamba; one module-scoped param set)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("prefill_chunk", 8)
    return LLMEngine(params, cfg, **kw)


def _trace(vocab):
    events = [
        TraceEvent(t=0.000, request_id="a", prompt=(1, 2, 3, 4),
                   max_tokens=5, seed=11),
        TraceEvent(t=0.001, request_id="b",
                   prompt=(9, 8, 7, 6, 5, 4, 3), max_tokens=4,
                   temperature=0.8, top_k=16, seed=12),
        TraceEvent(t=0.002, request_id="c", prompt=(5, 5, 5),
                   max_tokens=3, seed=13),
        TraceEvent(t=0.004, request_id="d",
                   prompt=tuple(t % vocab for t in range(20, 32)),
                   max_tokens=4, temperature=0.7, top_p=0.9, seed=14),
    ]
    return Trace(events=events, name="t4")


def test_sync_replay_bit_identical_streams_and_schedule(setup):
    cfg, params = setup
    tr = _trace(cfg.vocab_size)
    r1 = run(_engine(cfg, params), tr, pump="sync", time_scale=0.0,
             warmup=False)
    r2 = run(_engine(cfg, params), tr, pump="sync", time_scale=0.0,
             warmup=False)
    assert r1["token_streams"] == r2["token_streams"]
    assert r1["schedule"] == r2["schedule"]
    assert all(len(r1["token_streams"][e.request_id]) == e.max_tokens
               for e in tr.events)
    assert r1["steps_before_last_arrival"] == 0
    assert r1["completed"] == 4 and r1["cancelled"] == 0


def test_async_pump_matches_sync_streams_and_drains_clean(setup):
    cfg, params = setup
    tr = _trace(cfg.vocab_size)
    eng_s = _engine(cfg, params)
    rs = run(eng_s, tr, pump="sync", time_scale=0.0, warmup=False)
    eng_a = _engine(cfg, params)
    ra = run(eng_a, tr, SLO(ttft_p99_ms=600_000.0), pump="async",
             time_scale=0.0, warmup=False)
    ra2 = run(_engine(cfg, params), tr, pump="async", time_scale=0.0,
              warmup=False)
    # explicit per-request seeds make streams batch-mix invariant, so
    # async timing noise cannot change a single token
    assert ra["token_streams"] == rs["token_streams"]
    assert ra["token_streams"] == ra2["token_streams"]
    assert eng_a.scheduler.outstanding() == []
    assert eng_s.scheduler.outstanding() == []
    assert ra["slo"]["ok"] is True
    assert ra["steps"] > 0 and ra["occupancy_mean"] > 0


def test_cancellation_under_load_token_deterministic(setup):
    cfg, params = setup
    events = [
        TraceEvent(t=0.000, request_id="keep0", prompt=(1, 2, 3, 4),
                   max_tokens=6, seed=1),
        # k=0: cancelled atomically with submission, while QUEUED
        TraceEvent(t=0.001, request_id="cq", prompt=(5, 6, 7),
                   max_tokens=6, seed=2, cancel_after_tokens=0),
        # k=2: cancelled from its own on_token callback mid-DECODE
        TraceEvent(t=0.002, request_id="cd", prompt=(8, 9, 10, 11),
                   max_tokens=6, seed=3, cancel_after_tokens=2),
        TraceEvent(t=0.003, request_id="keep1", prompt=(4, 3, 2, 1, 5),
                   max_tokens=4, seed=4),
    ]
    tr = Trace(events=events, name="cancel")
    assert tr.n_cancelled == 2
    eng_s = _engine(cfg, params)
    rs = run(eng_s, tr, pump="sync", time_scale=0.0, warmup=False)
    eng_a = _engine(cfg, params)
    ra = run(eng_a, tr, pump="async", time_scale=0.0, warmup=False)
    for r, eng in ((rs, eng_s), (ra, eng_a)):
        assert r["token_streams"]["cq"] == []
        assert len(r["token_streams"]["cd"]) == 2       # exactly k
        assert len(r["token_streams"]["keep0"]) == 6
        assert len(r["token_streams"]["keep1"]) == 4
        assert r["cancelled"] == 2 and r["completed"] == 2
        # no slot leaks: queue and slot table fully drained
        assert eng.scheduler.outstanding() == []
        assert eng.scheduler.live() == []
    assert rs["token_streams"] == ra["token_streams"]
    mj = eng_a.metrics_json()
    assert mj["engine"]["requests_cancelled"] == 2


def test_cancelled_requests_do_not_perturb_survivors(setup):
    """The survivors' streams must be bit-identical whether or not the
    cancelled requests ever existed (batched sampler key isolation)."""
    cfg, params = setup
    keep = [TraceEvent(t=0.0, request_id="keep0", prompt=(1, 2, 3, 4),
                       max_tokens=5, seed=21, temperature=0.9,
                       top_k=8),
            TraceEvent(t=0.002, request_id="keep1",
                       prompt=(4, 3, 2, 1, 5), max_tokens=4, seed=22)]
    noise = [TraceEvent(t=0.001, request_id=f"x{i}",
                        prompt=(6 + i, 7, 8), max_tokens=6,
                        seed=30 + i, cancel_after_tokens=i % 3)
             for i in range(4)]
    r_with = run(_engine(cfg, params), Trace(events=keep + noise),
                 pump="sync", time_scale=0.0, warmup=False)
    r_solo = run(_engine(cfg, params), Trace(events=list(keep)),
                 pump="sync", time_scale=0.0, warmup=False)
    for rid in ("keep0", "keep1"):
        assert r_with["token_streams"][rid] \
            == r_solo["token_streams"][rid]


def test_run_budget_exhaustion_raises_warns_and_resumes(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    st = eng.add_request([1, 2, 3], SamplingParams(max_tokens=6))
    with pytest.raises(StepBudgetExhausted, match="unfinished"):
        eng.run(max_steps=2)
    assert eng.metrics.run_budget_exhausted == 1
    assert not st.finished and len(st.token_ids) == 2
    with pytest.warns(RuntimeWarning, match="exhausted"):
        eng.run(max_steps=1, on_exhaust="warn")
    assert eng.metrics.run_budget_exhausted == 2
    eng.run()                       # consistent state: resumes cleanly
    assert st.finished and len(st.token_ids) == 6
    mj = eng.metrics_json()
    assert mj["engine"]["run_budget_exhausted"] == 2
    with pytest.raises(ValueError, match="on_exhaust"):
        eng.run(on_exhaust="ignore")
    # a drained engine never trips the guard, even with max_steps=0
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.run(max_steps=0)


def test_stream_iteration_under_running_pump(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    with EnginePump(eng) as pump:
        st = pump.add_request([1, 2, 3, 4],
                              SamplingParams(max_tokens=5))
        toks = list(st.stream)      # consumer blocks; pump thread steps
        assert toks == list(st.token_ids) and len(toks) == 5
        st2 = pump.add_request([5, 6, 7],
                               SamplingParams(max_tokens=3, seed=9))
        assert pump.drain(timeout=60.0)
        assert len(st2.token_ids) == 3
    assert pump.steps > 0 and len(pump.samples) == pump.steps
    assert eng.scheduler.outstanding() == []
    with pytest.raises(RuntimeError, match="already started"):
        with EnginePump(eng) as p2:
            p2.start()


def test_runner_rejects_bad_arguments(setup):
    cfg, params = setup
    tr = _trace(cfg.vocab_size)
    eng = _engine(cfg, params)
    with pytest.raises(ValueError, match="pump"):
        run(eng, tr, pump="turbo")
    with pytest.raises(ValueError, match="time_scale"):
        run(eng, tr, time_scale=-1.0)
    with pytest.raises(ValueError, match="no events"):
        run(eng, Trace(events=[]))
    with pytest.raises(ValueError, match="max_len"):
        run(_engine(cfg, params, max_len=8), tr, pump="sync")
