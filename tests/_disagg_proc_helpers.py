"""Spawn targets for the disagg cross-process tests.

These live outside the test modules on purpose: a ``multiprocessing``
spawn child re-imports the module that defines its target, and the
test modules import the conftest-installed ``hypothesis`` fallback,
which only exists in the parent interpreter.
"""
from repro.serve.disagg.transport import pack_snapshot, unpack_snapshot


def child_roundtrip(conn, blob):
    """Unpack in a fresh interpreter, repack, ship back."""
    try:
        tree = unpack_snapshot(blob)
        conn.send(("ok", pack_snapshot(tree)))
    except Exception as e:  # pragma: no cover - diagnostic path
        conn.send(("err", f"{type(e).__name__}: {e}"))
    finally:
        conn.close()
