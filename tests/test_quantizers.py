"""Unit + property tests for the quantizer primitives (paper §3.2, §F)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import quantizers as Q

jax.config.update("jax_platform_name", "cpu")


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.1, 100.0))
@settings(max_examples=25, deadline=None)
def test_qdq_error_bounded_by_half_step(seed, scale_mag):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32) * scale_mag)
    s = Q.symmetric_scale(x)
    err = jnp.abs(Q.qdq(x, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_quantize_range(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=512).astype(np.float32) * 10)
    q = Q.quantize(x, Q.symmetric_scale(x))
    assert q.dtype == jnp.int8
    assert int(q.min()) >= -128 and int(q.max()) <= 127


def test_percentile_scale_smaller_under_outliers():
    rng = np.random.default_rng(0)
    x = rng.normal(size=100_000).astype(np.float32)
    x[::1000] *= 50.0                       # 0.1% outliers (paper Fig. 12)
    xj = jnp.asarray(x)
    s_mm = float(Q.symmetric_scale(xj))
    s_p = float(Q.percentile_scale(xj, 99.9))
    assert s_p < s_mm / 5
    # bulk error must improve (the paper's central observation for x)
    bulk = np.abs(x) < s_p * 127
    e_mm = np.abs(np.asarray(Q.qdq(xj, s_mm)) - x)[bulk].mean()
    e_p = np.abs(np.asarray(Q.qdq(xj, s_p)) - x)[bulk].mean()
    assert e_p < e_mm / 5


def test_dynamic_equals_static_with_same_scale():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=128).astype(np.float32))
    assert np.allclose(np.asarray(Q.dynamic_qdq(x)),
                       np.asarray(Q.qdq(x, Q.symmetric_scale(x))))


def test_log2_preserves_small_values_better():
    rng = np.random.default_rng(2)
    x = np.abs(rng.normal(size=10_000)).astype(np.float32) * 0.01
    x[0] = 100.0                            # one huge outlier
    xj = jnp.asarray(x)
    uni = np.asarray(Q.qdq(xj, Q.symmetric_scale(xj)))
    log2 = np.asarray(Q.log2_qdq(xj))
    small = x < 0.05
    rel_uni = np.abs(uni[small] - x[small]).mean()
    rel_log = np.abs(log2[small] - x[small]).mean()
    assert rel_log < rel_uni


def test_asymmetric_handles_shifted_distributions():
    rng = np.random.default_rng(3)
    x = jnp.asarray((rng.normal(size=4096) * 0.1 + 5.0).astype(np.float32))
    s, zp = Q.asymmetric_qparams(x)
    err_asym = float(jnp.abs(Q.qdq_asymmetric(x, s, zp) - x).mean())
    err_sym = float(jnp.abs(Q.qdq(x, Q.symmetric_scale(x)) - x).mean())
    assert err_asym < err_sym


@given(st.integers(1, 7))
@settings(max_examples=7, deadline=None)
def test_per_channel_no_worse_than_per_tensor(seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    w[:, 0] *= 50                            # one hot channel
    wj = jnp.asarray(w)
    s_pc = Q.per_channel_scale(wj, axis=1)
    e_pc = float(jnp.abs(Q.qdq(wj, s_pc) - wj).mean())
    e_pt = float(jnp.abs(Q.qdq(wj, Q.symmetric_scale(wj)) - wj).mean())
    assert e_pc <= e_pt + 1e-7
