"""Tests for the public quantization facade (``repro.api``): artifact
save/load round-trip, Quantizer-vs-legacy-path parity for every preset,
the site-map registry, QuantSpec validation, and the int8 KV-cache path."""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.models import forward, init_params
from repro.models.quantize import make_qctx, quantize_model
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import PRESETS, QuantSpec, get_spec
from repro.quant.sitemap import SiteMap, get_site_map, registered_families

jax.config.update("jax_platform_name", "cpu")

FAMILIES = ("mamba", "dense", "moe", "hybrid", "ssm", "audio", "vlm")


def _mamba_setup():
    cfg = scale_down(get_config("mamba-130m"), layers=2, width=64,
                     vocab=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = list(eval_batches(cfg.vocab_size, 2, 32, 2, seed=7))
    return cfg, params, calib


@pytest.fixture(scope="module")
def mamba_setup():
    return _mamba_setup()


def _tree_items(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def _assert_trees_identical(a, b, what=""):
    fa, fb = _tree_items(a), _tree_items(b)
    assert len(fa) == len(fb), what
    for (pa, la), (pb, lb) in zip(fa, fb):
        assert pa == pb, (what, pa, pb)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=f"{what} {pa}")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_all_config_families_resolve_to_a_site_map():
    for fam in FAMILIES:
        sm = get_site_map(fam)
        assert isinstance(sm, SiteMap)
        assert sm.sections
    assert set(FAMILIES) <= set(registered_families())


def test_unknown_family_raises_keyerror():
    with pytest.raises(KeyError):
        get_site_map("not-a-family")


# ---------------------------------------------------------------------------
# frozen reference: the hand-wired mamba recipe (pre-registry seed code).
# The declarative site-map walker must reproduce it bit-exactly -- this
# keeps the parity suite meaningful now that quantize_model itself walks
# the registry.
# ---------------------------------------------------------------------------

def _reference_mamba_quantize(params, stats, spec):
    from repro.quant import quantizers as Q
    from repro.quant import recipe as qrecipe
    from repro.quant.baselines import fold_smoothing, smoothquant_factors
    from repro.quant.observers import stats_scale

    stats_l = stats["layers"]

    def _scale(site, pct=100.0):
        s = stats_scale(stats_l[site], percentile=pct)
        if spec.soft_edge > 0.0 and pct < 100.0:
            # Quamba-SE soft edge: blend the clip toward the abs-max
            s_max = stats_scale(stats_l[site])
            s = (1.0 - spec.soft_edge) * s + spec.soft_edge * s_max
        return s

    _qw = lambda w, fold=False, storage="auto": jax.vmap(
        lambda wi: qrecipe.quantize_weight(
            wi, spec, fold_hadamard_axis=0 if fold else None,
            storage=storage))(w)

    p = dict(params["layers"])
    if spec.method == "smoothquant":
        def fold_one(norm, w_in, cmax_in):
            s1 = smoothquant_factors(cmax_in, w_in, spec.smooth_alpha)
            norm, w_in = fold_smoothing(norm, w_in, s1)
            return norm, w_in, jnp.maximum(jnp.max(cmax_in / s1),
                                           1e-8) / 127.0
        p["norm"], p["in_proj"], s_in = jax.vmap(fold_one)(
            p["norm"], p["in_proj"], stats_l["in"]["cmax"])
        s_x = _scale("x")
    else:
        s_in = _scale("in")
        # one scale for the SSM input AND x_proj: the kernel dataflow
        # feeds the SSM input's int8 tensor straight into the x_proj
        # matmul, so the sites must share a grid.  Under quarot the SSM
        # input is quantized in the rotated domain (x_had) and the
        # unrotated tensor keeps its minmax scale.
        s_x = _scale("x", 100.0 if spec.method == "quarot"
                     else spec.x_percentile)
    scales = {
        "in": s_in, "conv_in": _scale("conv_in"), "x": s_x,
        "x_had": _scale("x_had"), "dt_low": _scale("dt_low"),
        "dt": _scale("dt"), "B": _scale("B"), "C": _scale("C"),
        "y": _scale("y"), "y_had": _scale("y_had"),
        "A": jax.vmap(lambda a: Q.symmetric_scale(-jnp.exp(a)))(
            p["A_log"]),
        "in_proj": s_in,
        "x_proj": s_x,
        "dt_proj": _scale("dt_low"), "out_proj": _scale("y"),
        "out_proj_had": _scale("y_had"),
    }
    qw = {
        "in_proj": _qw(p["in_proj"]), "x_proj": _qw(p["x_proj"]),
        "dt_proj": _qw(p["dt_proj"]), "out_proj": _qw(p["out_proj"]),
        "out_proj_had": _qw(p["out_proj"], fold=True),
        # int8 taps for the fused conv kernel (backend="kernels"), taken
        # from the *original* weights (the in-place fake-quant below uses
        # the same symmetric scale, so qw * s_w == the fake-quant taps);
        # storage stays one-value-per-byte even under w4 (conv reads int8)
        "conv_w": _qw(p["conv_w"], storage="int8"),
        # A = -exp(A_log) quantized once for the int8 scan kernels
        "A": {"qw": jax.vmap(lambda a, s: Q.quantize(-jnp.exp(a), s))(
            p["A_log"], scales["A"])},
    }
    p["conv_w"] = jax.vmap(lambda w: Q.qdq(
        w, Q.symmetric_scale(w, bits=spec.w_bits), bits=spec.w_bits))(
        p["conv_w"])
    new_params = dict(params)
    new_params["layers"] = p
    return new_params, {"scales": {"layers": scales},
                        "qw": {"layers": qw}}


def test_site_map_walker_matches_frozen_reference(mamba_setup):
    cfg, params, calib = mamba_setup
    stats = api.calibration_stats(cfg, params, calib)
    for name, spec in PRESETS.items():
        if spec is None:
            continue
        ref_p, ref_q = _reference_mamba_quantize(params, stats, spec)
        got_p, got_q = quantize_model(params, stats, cfg, spec)
        _assert_trees_identical(ref_q, got_q, f"ref qdata[{name}]")
        _assert_trees_identical(ref_p, got_p, f"ref params[{name}]")


def _reference_decoder_quantize(params, stats, spec, use_moe=False):
    """Frozen hand-wired decoder recipe (seed ``_decoder_layer``)."""
    from repro.quant import quantizers as Q
    from repro.quant import recipe as qrecipe
    from repro.quant.baselines import smoothquant_factors
    from repro.quant.observers import stats_scale

    stats_l = stats["layers"]
    _scale = lambda site: stats_scale(stats_l[site])
    _qw = lambda w: jax.vmap(
        lambda wi: qrecipe.quantize_weight(wi, spec))(w)

    p = dict(params["layers"])
    if spec.method == "smoothquant":
        def fold_one(ln1, wq, wk, wv, cmax):
            s = smoothquant_factors(cmax, wq, spec.smooth_alpha)
            sh = (-1, 1)
            return (ln1 / s, wq * s.reshape(sh), wk * s.reshape(sh),
                    wv * s.reshape(sh))
        attn = dict(p["attn"])
        p["ln1"], attn["wq"], attn["wk"], attn["wv"] = jax.vmap(fold_one)(
            p["ln1"], p["attn"]["wq"], p["attn"]["wk"], p["attn"]["wv"],
            stats_l["attn_in"]["cmax"])
        p["attn"] = attn
    s_in, s_o = _scale("attn_in"), _scale("o_in")
    scales = {"attn": {"wq": s_in, "wk": s_in, "wv": s_in, "wo": s_o}}
    qw = {"attn": {k: _qw(p["attn"][k])
                   for k in ("wq", "wk", "wv", "wo")}}
    if use_moe:
        def wqdq(w):
            return Q.qdq(w, Q.symmetric_scale(w, bits=spec.w_bits),
                         bits=spec.w_bits)
        moe = dict(p["moe"])
        for key in ("wi", "wo"):
            flat = moe[key].reshape((-1,) + moe[key].shape[-2:])
            moe[key] = jax.vmap(wqdq)(flat).reshape(moe[key].shape)
        p["moe"] = moe
        scales["moe"], qw["moe"] = {}, {}
    else:
        scales["mlp"] = {"mlp_wi": _scale("mlp_in"),
                         "mlp_wo": _scale("down_in")}
        qw["mlp"] = {"mlp_wi": _qw(p["mlp"]["wi"]),
                     "mlp_wo": _qw(p["mlp"]["wo"])}
    new_params = dict(params)
    new_params["layers"] = p
    return new_params, {"scales": {"layers": scales},
                        "qw": {"layers": qw}}


@pytest.mark.parametrize("arch,use_moe", [("llama3-8b", False),
                                          ("qwen3-moe-30b-a3b", True)])
def test_site_map_walker_matches_frozen_decoder_reference(arch, use_moe):
    cfg = scale_down(get_config(arch), layers=2, width=64, vocab=128)
    params = init_params(jax.random.PRNGKey(3), cfg)
    calib = list(eval_batches(cfg.vocab_size, 2, 16, 2, seed=13))
    stats = api.calibration_stats(cfg, params, calib)
    for name, spec in PRESETS.items():
        if spec is None:
            continue
        ref_p, ref_q = _reference_decoder_quantize(params, stats, spec,
                                                   use_moe=use_moe)
        got_p, got_q = quantize_model(params, stats, cfg, spec)
        _assert_trees_identical(ref_q, got_q, f"{arch} qdata[{name}]")
        _assert_trees_identical(ref_p, got_p, f"{arch} params[{name}]")


# ---------------------------------------------------------------------------
# facade vs legacy path parity (every preset)
# ---------------------------------------------------------------------------

def test_quantizer_matches_legacy_path_for_every_preset(mamba_setup):
    cfg, params, calib = mamba_setup
    # legacy chain, shared calibration
    stats = run_calibration(
        lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"}),
        params, calib)
    for name, spec in PRESETS.items():
        qm = api.Quantizer(cfg, name).with_stats(stats).quantize(params)
        if spec is None:                       # fp pass-through
            assert qm.qdata is None and qm.qctx() is None
            continue
        legacy_params, legacy_qdata = quantize_model(params, stats, cfg,
                                                     spec)
        _assert_trees_identical(legacy_qdata, qm.qdata, f"qdata[{name}]")
        _assert_trees_identical(legacy_params, qm.params,
                                f"params[{name}]")
        # the artifact's qctx is the legacy make_qctx
        legacy_ctx = make_qctx(spec, legacy_qdata)
        ctx = qm.qctx()
        assert ctx["mode"] == legacy_ctx["mode"] == "quant"
        assert ctx["spec"] == legacy_ctx["spec"]


def test_quantizer_calibrate_chain_matches_with_stats(mamba_setup):
    cfg, params, calib = mamba_setup
    qm1 = api.Quantizer(cfg, "quamba").calibrate(calib).quantize(params)
    stats = api.calibration_stats(cfg, params, calib)
    qm2 = api.Quantizer(cfg, "quamba").with_stats(stats).quantize(params)
    _assert_trees_identical(qm1.qdata, qm2.qdata, "calibrate-chain")


def test_quantize_one_shot_helper(mamba_setup):
    cfg, params, calib = mamba_setup
    qm = api.quantize(params, cfg, calib, spec="static")
    logits, _ = qm.forward(calib[0])
    assert logits.shape == (*calib[0]["tokens"].shape, cfg.vocab_size)
    loss, metrics = qm.loss(calib[0])
    assert np.isfinite(float(loss)) and "ce_loss" in metrics


def test_quantizer_requires_calibration(mamba_setup):
    cfg, params, _ = mamba_setup
    with pytest.raises(ValueError, match="calibration"):
        api.Quantizer(cfg, "quamba").quantize(params)


# ---------------------------------------------------------------------------
# artifact save / load
# ---------------------------------------------------------------------------

def test_save_load_round_trip_bit_exact(tmp_path, mamba_setup):
    cfg, params, calib = mamba_setup
    qm = api.Quantizer(cfg, "quamba").calibrate(calib).quantize(params)
    path = os.path.join(str(tmp_path), "artifact")
    qm.save(path)
    qm2 = api.load(path)
    assert qm2.spec == qm.spec
    assert qm2.cfg == qm.cfg
    _assert_trees_identical(qm.qdata, qm2.qdata, "qdata")
    _assert_trees_identical(qm.params, qm2.params, "params")
    # int8 payloads stay int8 through the round trip
    q_leaf = qm2.qdata["qw"]["layers"]["in_proj"]["qw"]
    assert np.asarray(q_leaf).dtype == np.int8
    # and the loaded artifact still runs
    lg1, _ = qm.forward(calib[0])
    lg2, _ = qm2.forward(calib[0])
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-6, atol=1e-6)


def test_save_is_atomic_and_overwrites(tmp_path, mamba_setup):
    cfg, params, calib = mamba_setup
    qm = api.Quantizer(cfg, "static").calibrate(calib).quantize(params)
    path = os.path.join(str(tmp_path), "artifact")
    qm.save(path)
    qm.save(path)                               # second save must not fail
    assert api.load(path).spec == qm.spec


def test_fp_artifact_save_load(tmp_path, mamba_setup):
    cfg, params, calib = mamba_setup
    qm = api.Quantizer(cfg, "fp").quantize(params)
    path = os.path.join(str(tmp_path), "fp_artifact")
    qm.save(path)
    qm2 = api.load(path)
    assert qm2.spec is None and qm2.qdata is None
    _assert_trees_identical(qm.params, qm2.params, "fp params")


# ---------------------------------------------------------------------------
# QuantSpec validation (explicit raises, not bare asserts)
# ---------------------------------------------------------------------------

def test_quantspec_validate_raises_value_error():
    with pytest.raises(ValueError, match="method"):
        QuantSpec(method="nope").validate()
    with pytest.raises(ValueError, match="w_bits"):
        QuantSpec(w_bits=3).validate()
    with pytest.raises(ValueError, match="a_bits"):
        QuantSpec(a_bits=16).validate()
    QuantSpec().validate()                      # default is valid


# ---------------------------------------------------------------------------
# int8 KV cache (QuantSpec.quantize_kv_cache -> Engine)
# ---------------------------------------------------------------------------

def test_quantize_kv_cache_flag_reaches_engine():
    cfg = scale_down(get_config("llama3-8b"), layers=2, width=64,
                     vocab=128)
    params = init_params(jax.random.PRNGKey(1), cfg)
    calib = list(eval_batches(cfg.vocab_size, 2, 16, 2, seed=11))
    spec = get_spec("quamba-kv8")
    assert spec.quantize_kv_cache
    qm = api.Quantizer(cfg, spec).calibrate(calib).quantize(params)
    eng = qm.engine(max_batch=2, max_len=32)
    assert eng.cache_dtype == jnp.int8
    assert eng.state["caches"]["k"].dtype == jnp.int8
    assert "k_s" in eng.state["caches"]
    # decode through the int8 cache produces sane tokens
    outs = qm.generate([[1, 2, 3], [5, 6]], max_new_tokens=4, max_len=32)
    assert all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_int8_kv_cache_close_to_fp_cache():
    cfg = scale_down(get_config("llama3-8b"), layers=2, width=64,
                     vocab=128)
    params = init_params(jax.random.PRNGKey(2), cfg)
    from repro.models import decode_step, init_decode_state
    toks = jnp.asarray([3, 9], jnp.int32)
    state_fp = init_decode_state(cfg, 2, 16, cache_dtype=jnp.float32)
    state_q = init_decode_state(cfg, 2, 16, cache_dtype=jnp.int8)
    for _ in range(3):
        lg_fp, state_fp = decode_step(params, cfg, state_fp, toks)
        lg_q, state_q = decode_step(params, cfg, state_q, toks)
    # per-entry int8 quantization: logits track the fp-cache path closely
    np.testing.assert_allclose(np.asarray(lg_q), np.asarray(lg_fp),
                               rtol=0.1, atol=0.15)


def test_engine_default_cache_stays_fp(mamba_setup):
    cfg, params, calib = mamba_setup
    qm = api.Quantizer(cfg, "quamba").calibrate(calib).quantize(params)
    eng = qm.engine(max_batch=2, max_len=16)
    assert eng.cache_dtype == jnp.float32      # mamba: no KV cache anyway


# ---------------------------------------------------------------------------
# legacy shim still works (existing callers)
# ---------------------------------------------------------------------------

def test_legacy_free_functions_still_importable(mamba_setup):
    cfg, params, calib = mamba_setup
    stats = run_calibration(
        lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"}),
        params, calib)
    spec = get_spec("quamba")
    qp, qd = quantize_model(params, stats, cfg, spec)
    qctx = make_qctx(spec, qd)
    lg, _ = forward(qp, cfg, calib[0], qctx=qctx)
    assert np.all(np.isfinite(np.asarray(lg)))
