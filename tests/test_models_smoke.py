"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and finiteness, plus
decode/forward consistency (deliverable f)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, scale_down
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, loss_fn)
from repro.optim.adamw import OptimConfig
from repro.train.step import init_train_state, make_train_step

ALL_ARCHS = ASSIGNED_ARCHS + ["mamba-130m"]


def _batch(cfg, key, b=2, l=32):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (b, 24, cfg.d_model)),
                "tokens": jax.random.randint(key, (b, 8), 0,
                                             cfg.vocab_size),
                "targets": jax.random.randint(key, (b, 8), 0,
                                              cfg.vocab_size)}
    if cfg.family == "vlm":
        lt = l - cfg.prefix_len
        return {"patches": jax.random.normal(
                    key, (b, cfg.prefix_len, cfg.d_model)),
                "tokens": jax.random.randint(key, (b, lt), 0,
                                             cfg.vocab_size),
                "targets": jax.random.randint(key, (b, lt), 0,
                                              cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (b, l), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (b, l), 0,
                                          cfg.vocab_size)}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = scale_down(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape[0] == batch["tokens"].shape[0]
    assert logits.shape[1] == batch["tokens"].shape[1]
    assert logits.shape[2] == cfg.vocab_size
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = scale_down(get_config(arch))
    key = jax.random.PRNGKey(1)
    state = init_train_state(key, cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(total_steps=10),
                                   remat=True))
    state2, metrics = step(state, _batch(cfg, key))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = scale_down(get_config(arch))
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    b, l = 2, 8
    toks = jax.random.randint(key, (b, l), 0, cfg.vocab_size)
    state = init_decode_state(cfg, b, 32, cache_dtype=jnp.float32)
    if cfg.family == "audio":
        frames = jax.random.normal(key, (b, 16, cfg.d_model))
        logits_full, _ = forward(params, cfg,
                                 {"frames": frames, "tokens": toks})
        # build enc_out the way forward does
        from repro.models import common as C
        from repro.models.model import _scan_blocks
        from repro.models.transformer import (encoder_layer,
                                              sinusoidal_positions)
        x = frames.astype(jnp.float32) + sinusoidal_positions(
            16, cfg.d_model)[None]
        enc, _ = _scan_blocks(
            lambda lp, h, q: encoder_layer(lp, cfg, h, qctx=q), x,
            params["enc_layers"], None, "enc")
        state["enc_out"] = C.rmsnorm(enc, params["enc_norm"],
                                     cfg.norm_eps)
    elif cfg.family == "vlm":
        pytest.skip("vlm prefix prefill is exercised in serving tests")
    else:
        logits_full, _ = forward(params, cfg, {"tokens": toks})
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    outs = []
    for i in range(l):
        lg, state = step(params, state, toks[:, i])
        outs.append(lg)
    err = float(jnp.abs(logits_full - jnp.stack(outs, 1)).max())
    scale = float(jnp.abs(logits_full).max())
    assert err <= 1e-3 * max(scale, 1.0), (err, scale)


def test_long_context_applicability():
    """long_500k runs for SSM/hybrid archs and is skipped for pure
    attention (DESIGN.md §Arch-applicability)."""
    from repro.configs import LONG_500K, cell_supported
    runnable = {a for a in ASSIGNED_ARCHS
                if cell_supported(get_config(a), LONG_500K)[0]}
    assert runnable == {"zamba2-1.2b", "xlstm-1.3b"}
