"""End-to-end system tests: the full paper pipeline on a small model.

train -> calibrate -> quantize -> evaluate -> serve, all through the
public API.  Accuracy-ordering claims on trained models live in the
benchmark harness (they need more training steps than a unit test
budget); here we assert the pipeline's invariants.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.data import batches, eval_batches
from repro.models import forward, loss_fn
from repro.models.quantize import make_qctx, quantize_model
from repro.optim import OptimConfig
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import get_spec
from repro.serve import generate
from repro.train import init_train_state, make_train_step


@pytest.fixture(scope="module")
def pipeline():
    cfg = scale_down(get_config("mamba-130m"))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=2e-3, warmup_steps=10, total_steps=60)))
    losses = []
    for b in batches(cfg.vocab_size, 8, 64, seed=11, num_steps=40):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    params = state["params"]
    calib = eval_batches(cfg.vocab_size, 4, 64, 4, seed=777)
    stats = run_calibration(
        lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"}),
        params, calib)
    return cfg, params, stats, losses


def _ppl(cfg, params, qctx=None):
    evalb = eval_batches(cfg.vocab_size, 8, 64, 3, seed=999)
    f = jax.jit(lambda p, b: loss_fn(p, cfg, b, qctx=qctx)[0])
    return math.exp(float(np.mean([float(f(params, b)) for b in evalb])))


def test_training_learned_structure(pipeline):
    cfg, params, stats, losses = pipeline
    assert losses[-1] < losses[0] - 0.3
    # eval ppl far below uniform (the corpus-graph consistency invariant)
    assert _ppl(cfg, params) < cfg.vocab_size / 2


def test_quantized_ppl_close_to_fp(pipeline):
    cfg, params, stats, _ = pipeline
    fp = _ppl(cfg, params)
    spec = get_spec("quamba")
    qp, qd = quantize_model(params, stats, cfg, spec)
    q = _ppl(cfg, qp, make_qctx(spec, qd))
    assert q < fp * 1.3, (fp, q)


def test_quamba_no_worse_than_static(pipeline):
    cfg, params, stats, _ = pipeline
    vals = {}
    for m in ("quamba", "static"):
        spec = get_spec(m)
        qp, qd = quantize_model(params, stats, cfg, spec)
        vals[m] = _ppl(cfg, qp, make_qctx(spec, qd))
    assert vals["quamba"] <= vals["static"] * 1.02


def test_quantized_generation_end_to_end(pipeline):
    cfg, params, stats, _ = pipeline
    spec = get_spec("quamba")
    qp, qd = quantize_model(params, stats, cfg, spec)
    outs = generate(qp, cfg, [[1, 2], [3]], max_new_tokens=5,
                    qctx=make_qctx(spec, qd), max_len=32)
    assert len(outs) == 2 and all(len(o) == 5 for o in outs)
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_calibration_stats_structure(pipeline):
    cfg, params, stats, _ = pipeline
    layer_stats = stats["layers"]
    for site in ("in", "x", "y", "y_had", "dt", "B", "C"):
        assert site in layer_stats, site
        assert layer_stats[site]["amax"].shape == (cfg.n_layers,)
