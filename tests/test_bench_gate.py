"""scripts/compare_bench.py forward compatibility: unknown keys, missing
metrics, and non-numeric values must skip, never crash the gate."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "scripts"))
from compare_bench import GATED, RENAMES, gate  # noqa: E402

pytestmark = pytest.mark.serve

BASE = {
    "tpot_quamba_kernels_ms": 0.1,
    "prefill_chunked_tokens_per_s": 5000.0,
    "engine_prefill": {"prefill_dispatches": 8},
    "serve": {"ttft_ms": {"mean": 40.0, "p95": 80.0},
              "prefix_cache": {"ttft_ms_hit": {"mean": 10.0},
                               "ttft_ms_miss": {"mean": 40.0},
                               "hit_rate": 0.8},
              "spec_decode": {"tokens_per_s": 200.0,
                              "acceptance_rate": 0.95},
              "loadgen": {"ttft_ms": {"p99": 500.0},
                          "goodput_requests": 11},
              "disagg": {"ttft_ms": {"p95": 120.0},
                         "transfers": 16,
                         "streams_match_single_process": True}},
}


def test_identical_passes():
    assert gate(BASE, dict(BASE), 0.25) == []


def test_unknown_and_extra_keys_ignored():
    cur = dict(BASE)
    cur["brand_new_metric"] = {"deeply": {"nested": [1, 2, 3]}}
    cur["serve"] = dict(BASE["serve"], queue_depth_series=[3, 2, 1],
                        occupancy_mean=0.9)
    prev = dict(BASE)
    prev["only_in_prev"] = "whatever"
    assert gate(prev, cur, 0.25) == []


def test_missing_metric_skips_not_raises():
    prev = {"tpot_quamba_kernels_ms": 0.1}     # pre-PR-4 artifact: no
    cur = dict(BASE)                           # serve section at all
    assert gate(prev, cur, 0.25) == []
    assert gate({}, cur, 0.25) == []
    assert gate(cur, {}, 0.25) == []


def test_non_numeric_values_skip():
    prev = dict(BASE, tpot_quamba_kernels_ms="fast")
    cur = dict(BASE, serve={"ttft_ms": {"mean": None}})
    assert gate(prev, cur, 0.25) == []
    # a dict where a float is expected (schema drift) also skips
    cur2 = dict(BASE, tpot_quamba_kernels_ms={"mean": 100.0})
    assert gate(BASE, cur2, 0.25) == []
    # a non-numeric LEGACY value behind the rename fallback also skips
    old = {"tpot_quamba_kernels_us": "fast"}
    assert gate(old, BASE, 0.25) == []


def test_regression_detected_and_improvement_passes():
    worse = {
        "tpot_quamba_kernels_ms": 0.14,              # +40% (lower better)
        "prefill_chunked_tokens_per_s": 3000.0,      # -40% (higher better)
        "engine_prefill": {"prefill_dispatches": 9},  # any increase fails
        "serve": {"ttft_ms": {"mean": 60.0},          # +50%
                  # hit TTFT gets a loose 100% threshold (small-sample
                  # wall clock); +400% = the cache stopped hitting
                  "prefix_cache": {"ttft_ms_hit": {"mean": 50.0}}},
    }
    failures = gate(BASE, worse, 0.25)
    assert len(failures) == 5
    assert any("serve.ttft_ms.mean" in f for f in failures)
    assert any("serve.prefix_cache.ttft_ms_hit.mean" in f
               for f in failures)
    better = {
        "tpot_quamba_kernels_ms": 0.05,
        "prefill_chunked_tokens_per_s": 9000.0,
        "engine_prefill": {"prefill_dispatches": 3},
        "serve": {"ttft_ms": {"mean": 10.0},
                  "prefix_cache": {"ttft_ms_hit": {"mean": 5.0}}},
    }
    assert gate(BASE, better, 0.25) == []


def test_small_wobble_within_tolerance_passes():
    cur = dict(BASE, tpot_quamba_kernels_ms=0.12,
               serve={"ttft_ms": {"mean": 48.0},     # 20% < 25%
                      # 2x on the ms-scale hit TTFT is runner wobble,
                      # not a cache regression: within its 100% band
                      "prefix_cache": {"ttft_ms_hit": {"mean": 19.9}}})
    assert gate(BASE, cur, 0.25) == []


def test_tpot_rename_fallback_bridges_old_baselines():
    """PR-7 renamed tpot_quamba_kernels_us -> _ms: a pre-rename
    baseline (only *_us, microseconds) must still gate against a
    post-rename artifact (only *_ms) -- compared in ms via RENAMES."""
    assert RENAMES["tpot_quamba_kernels_ms"] == (
        "tpot_quamba_kernels_us", 1e-3)
    old = {"tpot_quamba_kernels_us": 100.0}          # 0.1 ms
    new = {"tpot_quamba_kernels_ms": 0.1}
    assert gate(old, new, 0.25) == []                # same speed: clean
    assert gate(new, old, 0.25) == []                # rollback direction
    slow = {"tpot_quamba_kernels_ms": 0.2}           # +100% across rename
    failures = gate(old, slow, 0.25)
    assert len(failures) == 1
    assert "tpot_quamba_kernels_ms" in failures[0]
    # the canonical key wins when both are present (alias is ignored)
    both = {"tpot_quamba_kernels_ms": 0.1,
            "tpot_quamba_kernels_us": 999999.0}
    assert gate(both, new, 0.25) == []


def test_producer_alias_dropped_but_renames_bridge_kept():
    """The one-release tpot_quamba_kernels_us producing alias is gone
    from pr_speed; the gate's RENAMES bridge stays until archived
    baselines roll over, so a post-removal artifact (no *_us key
    anywhere) still gates against a pre-rename baseline."""
    src_path = os.path.join(os.path.dirname(__file__), "..",
                            "benchmarks", "pr_speed.py")
    with open(src_path) as f:
        src = f.read()
    assert "tpot_quamba_kernels_us" not in src
    assert "deprecations" not in src
    assert RENAMES["tpot_quamba_kernels_ms"] == (
        "tpot_quamba_kernels_us", 1e-3)
    old = {"tpot_quamba_kernels_us": 100.0}          # pre-rename: 0.1 ms
    assert gate(old, BASE, 0.25) == []               # same speed: clean
    slow = dict(BASE, tpot_quamba_kernels_ms=0.2)    # +100% across it
    failures = gate(old, slow, 0.25)
    assert len(failures) == 1
    assert "tpot_quamba_kernels_ms" in failures[0]


def test_disagg_ttft_tail_gated():
    """serve.disagg.ttft_ms.p95 is gated (lower is better) with the
    loose small-sample 100% band; pre-disagg baselines skip."""
    by_key = {k: (hb, ov) for k, hb, ov in GATED}
    assert by_key["serve.disagg.ttft_ms.p95"] == (False, 1.0)
    wobble = dict(BASE, serve=dict(
        BASE["serve"], disagg={"ttft_ms": {"p95": 238.0}}))
    assert gate(BASE, wobble, 0.25) == []            # <2x: wobble band
    slow = dict(BASE, serve=dict(
        BASE["serve"], disagg={"ttft_ms": {"p95": 300.0}}))
    failures = gate(BASE, slow, 0.25)
    assert len(failures) == 1
    assert "serve.disagg.ttft_ms.p95" in failures[0]
    pre = dict(BASE, serve={k: v for k, v in BASE["serve"].items()
                            if k != "disagg"})
    assert gate(pre, BASE, 0.25) == []               # old baseline
    assert gate(BASE, pre, 0.25) == []               # rollback direction


def test_spec_decode_throughput_gated():
    """PR-7: serve.spec_decode.tokens_per_s is gated (higher is
    better) with a 50% threshold -- higher-is-better regressions cap
    at 100%, so the usual loose 100% band could never fire.  A >2x
    throughput collapse (the fused verify path silently falling back
    to per-token decode) fails the gate; 2x runner wobble passes."""
    by_key = {k: (hb, ov) for k, hb, ov in GATED}
    assert by_key["serve.spec_decode.tokens_per_s"] == (True, 0.5)
    collapsed = dict(BASE, serve=dict(
        BASE["serve"], spec_decode={"tokens_per_s": 40.0}))
    failures = gate(BASE, collapsed, 0.25)
    assert len(failures) == 1
    assert "serve.spec_decode.tokens_per_s" in failures[0]
    wobble = dict(BASE, serve=dict(
        BASE["serve"], spec_decode={"tokens_per_s": 101.0}))
    assert gate(BASE, wobble, 0.25) == []
    # pre-PR-7 baseline without the section skips cleanly
    pre = dict(BASE, serve={"ttft_ms": {"mean": 40.0}})
    assert gate(pre, BASE, 0.25) == []
    assert gate(BASE, pre, 0.25) == []


def test_dispatch_count_zero_tolerance():
    cur = {"engine_prefill": {"prefill_dispatches": 9}}
    prev = {"engine_prefill": {"prefill_dispatches": 8}}
    failures = gate(prev, cur, 0.25)
    assert len(failures) == 1 and "prefill_dispatches" in failures[0]


def test_gated_covers_serve_ttft():
    assert any(k == "serve.ttft_ms.mean" for k, _, _ in GATED)
    assert any(k == "serve.prefix_cache.ttft_ms_hit.mean"
               for k, _, _ in GATED)


def test_gated_covers_tail_latency_keys():
    """PR-6: the gate watches the p95/p99 TAILS, with the loose
    small-sample threshold (100%), not the default 25%."""
    by_key = {k: (hb, ov) for k, hb, ov in GATED}
    assert by_key["serve.ttft_ms.p95"] == (False, 1.0)
    assert by_key["serve.loadgen.ttft_ms.p99"] == (False, 1.0)
    # doubling is wobble-tolerated; 2.5x is a caught regression
    cur = dict(BASE, serve={"ttft_ms": {"p95": 155.0},
                            "loadgen": {"ttft_ms": {"p99": 1250.0}}})
    failures = gate(BASE, cur, 0.25)
    assert len(failures) == 1
    assert "serve.loadgen.ttft_ms.p99" in failures[0]


def test_run_meta_stamp_is_ignored_by_the_gate():
    """PR-6: BENCH_PR.json carries a top-level run_meta provenance
    stamp (git commit, timestamp, backend); the gate must skip it in
    both directions -- new artifact vs old baseline and rollback."""
    stamped = dict(BASE, run_meta={
        "git_commit": "deadbeef", "timestamp_utc": "2026-01-01T00:00:00",
        "backend": "cpu", "device_kind": "cpu", "jax_version": "0.4.37"})
    assert gate(BASE, stamped, 0.25) == []
    assert gate(stamped, BASE, 0.25) == []
    # two stamped artifacts with DIFFERENT metadata still compare clean
    other = dict(stamped, run_meta={"git_commit": "cafef00d",
                                    "backend": "tpu"})
    assert gate(stamped, other, 0.25) == []


def test_pre_pr6_artifact_without_loadgen_skips():
    old = dict(BASE, serve={"ttft_ms": {"mean": 40.0}})  # no loadgen,
    assert gate(old, BASE, 0.25) == []                   # no p95
    assert gate(BASE, old, 0.25) == []


def test_prefix_cache_keys_tolerated_by_old_and_new_gates():
    """Forward/backward compat for the serve.prefix_cache section: a
    pre-PR-5 artifact (no section at all), a null TTFT split (a run
    where nothing hit), and extra unknown cache keys all skip."""
    pre_pr5 = {k: v for k, v in BASE.items() if k != "serve"}
    pre_pr5["serve"] = {"ttft_ms": {"mean": 40.0}}
    assert gate(pre_pr5, BASE, 0.25) == []       # new keys, old baseline
    assert gate(BASE, pre_pr5, 0.25) == []       # rollback direction
    no_hits = dict(BASE, serve={
        "ttft_ms": {"mean": 40.0},
        "prefix_cache": {"ttft_ms_hit": None, "hit_rate": None,
                         "brand_new_counter": [1, 2]}})
    assert gate(BASE, no_hits, 0.25) == []
    assert gate(no_hits, BASE, 0.25) == []


def test_w4a8_keys_gated_and_ratio_zero_tolerance():
    """PR 8: the w4a8 kernels TPOT is gated at the default band and the
    matmul weight-bytes ratio -- a deterministic storage fact -- fails
    on ANY growth; pre-PR-8 baselines without the section skip."""
    by_key = {k: (hb, ov) for k, hb, ov in GATED}
    assert by_key["w4a8.tpot_kernels_ms"] == (False, None)
    assert by_key["w4a8.matmul_weight_bytes_ratio"] == (False, 0.0)
    prev = dict(BASE, w4a8={"tpot_kernels_ms": 5.0,
                            "matmul_weight_bytes_ratio": 0.5})
    same = dict(BASE, w4a8={"tpot_kernels_ms": 5.0,
                            "matmul_weight_bytes_ratio": 0.5})
    assert gate(prev, same, 0.25) == []
    unpacked = dict(BASE, w4a8={"tpot_kernels_ms": 5.0,
                                "matmul_weight_bytes_ratio": 0.51})
    failures = gate(prev, unpacked, 0.25)
    assert any("matmul_weight_bytes_ratio" in f for f in failures)
    assert gate(BASE, prev, 0.25) == []          # pre-PR-8 baseline
    assert gate(prev, BASE, 0.25) == []          # rollback direction
