"""Data pipeline: determinism, restart consistency, learnable structure."""
import numpy as np

from repro.data import CorpusSpec, MarkovCorpus, batches


def test_deterministic_given_seed_and_step():
    a = list(batches(100, 4, 16, seed=3, num_steps=3))
    b = list(batches(100, 4, 16, seed=3, num_steps=3))
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x["tokens"]),
                              np.asarray(y["tokens"]))


def test_restart_resumes_exact_stream():
    full = list(batches(100, 4, 16, seed=5, num_steps=6))
    tail = list(batches(100, 4, 16, seed=5, start_step=3, num_steps=3))
    for x, y in zip(full[3:], tail):
        assert np.array_equal(np.asarray(x["tokens"]),
                              np.asarray(y["tokens"]))


def test_targets_shifted_by_one():
    (b,) = list(batches(50, 2, 8, seed=1, num_steps=1))
    corpus = MarkovCorpus(CorpusSpec(50, seed=1234))
    toks = np.asarray(b["tokens"])
    tgts = np.asarray(b["targets"])
    # target[t] is the sampled successor of token[t]
    assert np.array_equal(toks[:, 1:], tgts[:, :-1])


def test_bigram_structure_is_learnable():
    """Successors come from a b-sized table: conditional entropy is far
    below the unigram entropy."""
    corpus = MarkovCorpus(CorpusSpec(1000, branching=8, seed=0))
    rng = np.random.default_rng(0)
    seq = corpus.sample(rng, 1, 50_000)[0]
    # each token has at most 8 distinct successors
    succ = {}
    for a, b in zip(seq[:-1], seq[1:]):
        succ.setdefault(int(a), set()).add(int(b))
    assert max(len(v) for v in succ.values()) <= 8
