"""Checkpoint atomicity, integrity, retention, corruption fallback."""
import os
import shutil

import numpy as np
import jax.numpy as jnp
import pytest

from repro.train import checkpoint as ckpt


@pytest.fixture()
def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path, tree):
    ckpt.save(str(tmp_path), 10, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 10
    assert np.allclose(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == np.int32


def test_retention(tmp_path, tree):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_tmp_litter_ignored_and_gced(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree)
    litter = tmp_path / "step_00000002.tmp-999"
    litter.mkdir()
    (litter / "arr_00000.npy").write_bytes(b"junk")
    assert ckpt.latest_step(str(tmp_path)) == 1       # tmp ignored
    ckpt.save(str(tmp_path), 3, tree)                  # gc happens
    assert not litter.exists()


def test_corruption_falls_back(tmp_path, tree):
    ckpt.save(str(tmp_path), 1, tree, keep=5)
    ckpt.save(str(tmp_path), 2, tree, keep=5)
    # corrupt newest
    d = tmp_path / "step_00000002"
    f = d / "arr_00000.npy"
    f.write_bytes(f.read_bytes()[:-4] + b"\x00\x00\x00\x00")
    restored, step = ckpt.restore_any(str(tmp_path), tree)
    assert step == 1


def test_restore_missing_raises(tmp_path, tree):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), tree)
