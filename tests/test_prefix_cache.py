"""Prefix state cache (``repro.serve.cache``): longest-prefix-match
correctness, LRU/byte-budget eviction, cache-on/off token-stream
equivalence through the engine, and metrics hit-rate math."""
import numpy as np
import jax
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import init_params
from repro.serve import (CacheAwareScheduler, LLMEngine, Request,
                         RequestStatus, SamplingParams, StateCache,
                         make_scheduler)
from repro.serve.cache import prefix_hash, rolling_hashes, tree_nbytes
from repro.serve.request import RequestState


def _state(n_floats: int):
    """A fake slot-state tree of a known byte size (4 bytes/elem)."""
    return {"h": np.arange(n_floats, dtype=np.float32)}


# ---------------------------------------------------------------------------
# pure cache semantics (no engine, no jax compiles)
# ---------------------------------------------------------------------------

def test_rolling_hash_prefix_identity():
    toks = [3, 1, 4, 1, 5, 9, 2, 6]
    hs = rolling_hashes(toks)
    assert len(hs) == len(toks) + 1
    for k in range(len(toks) + 1):
        assert hs[k] == prefix_hash(toks[:k])
    # token order matters (not a bag-of-tokens hash)
    assert prefix_hash([1, 2]) != prefix_hash([2, 1])


def test_longest_prefix_match_and_collision_guard():
    c = StateCache(byte_budget=1 << 20)
    c.insert([1, 2], _state(4))
    c.insert([1, 2, 3, 4], _state(4))
    c.insert([9, 9], _state(4))
    # longest usable prefix wins; covering len(prompt) - 1 tokens makes
    # it a FULL hit (only the last token is left to feed the decoder)
    e = c.lookup([1, 2, 3, 4, 5])
    assert e is not None and e.tokens == (1, 2, 3, 4)
    # the full-length entry is NOT usable for its own prompt (the last
    # token must stay as the first decode input) -> shorter match
    e = c.lookup([1, 2, 3, 4])
    assert e is not None and e.tokens == (1, 2)
    # same length, different tokens: token equality is checked, so a
    # would-be hash-bucket probe can never return the wrong state
    assert c.lookup([5, 6, 7]) is None
    assert c.lookup([2]) is None            # limit 0: nothing to reuse
    assert [1, 2] in c and [1, 3] not in c
    s = c.stats()
    assert s["hits"] == 1 and s["partial_hits"] == 1
    assert s["misses"] == 2
    assert s["hit_rate"] == pytest.approx(0.5)
    assert s["tokens_reused"] == 4 + 2


def test_peek_len_has_no_side_effects():
    c = StateCache(byte_budget=1 << 20)
    c.insert([1, 2, 3], _state(4))
    assert c.peek_len([1, 2, 3, 4]) == 3
    assert c.peek_len([1, 2, 3]) == 0       # limit is len-1
    assert c.peek_len([7]) == 0
    s = c.stats()
    assert s["hits"] == s["partial_hits"] == s["misses"] == 0


def test_lru_byte_budget_eviction():
    c = StateCache(byte_budget=3 * 16)      # room for three 16B entries
    c.insert([1], _state(4))
    c.insert([2], _state(4))
    c.insert([3], _state(4))
    assert len(c) == 3 and c.bytes_in_use == 48
    c.lookup([1, 99])                       # refresh [1]: now [2] is LRU
    c.insert([4], _state(4))                # over budget -> evict [2]
    assert [2] not in c and [1] in c and [3] in c and [4] in c
    assert c.bytes_in_use == 48 and c.stats()["evicted"] == 1
    # an entry bigger than the whole budget is rejected, not thrashed
    assert not c.insert([5, 6], _state(1000))
    assert c.stats()["rejected"] == 1 and len(c) == 3
    # zero budget disables insertion entirely
    off = StateCache(byte_budget=0)
    assert not off.insert([1], _state(1))
    assert off.lookup([1, 2]) is None


def test_reinsert_refreshes_not_duplicates():
    c = StateCache(byte_budget=1 << 20)
    assert c.insert([1, 2], _state(4))
    assert not c.insert([1, 2], _state(4))  # already cached: LRU bump
    assert len(c) == 1 and c.stats()["inserted"] == 1


def test_tree_nbytes_counts_dtype_width():
    assert tree_nbytes({"a": np.zeros((3,), np.float32)}) == 12
    assert tree_nbytes({"a": np.zeros((3,), np.int8),
                        "b": {"c": np.zeros((2, 2), np.float32)}}) == 19


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------

def test_cache_aware_scheduler_orders_hits_first():
    sched = make_scheduler("cache-aware", 1)
    assert isinstance(sched, CacheAwareScheduler)
    states = []
    for rid, cached in (("a", 0), ("b", 5), ("c", 5), ("d", 2)):
        st = RequestState(Request([1, 2], SamplingParams(),
                                  request_id=rid))
        st.cached_len = cached
        sched.add(st)
        states.append(st)
    order = [sched._pick().request_id for _ in range(4)]
    assert order == ["b", "c", "d", "a"]    # longest first, FCFS ties


# ---------------------------------------------------------------------------
# engine integration (small mamba; one module-scoped param set)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, prompts, prefix_cache_mb, **kw):
    eng = LLMEngine(params, cfg, max_batch=2, max_len=64,
                    prefill_chunk=4, prefix_cache_mb=prefix_cache_mb,
                    **kw)
    states = [eng.add_request(list(p),
                              SamplingParams(max_tokens=4, seed=i)
                              if i % 2 else
                              SamplingParams(max_tokens=4))
              for i, p in enumerate(prompts)]
    eng.run()
    return [list(s.token_ids) for s in states], eng


def test_cache_on_off_token_streams_identical(setup):
    """Same seeds => identical outputs with the cache on and off, over
    full hits, partial hits, and misses (greedy + sampled mixed)."""
    cfg, params = setup
    shared = [(3 * i) % cfg.vocab_size for i in range(17)]
    prompts = [shared + [5],            # cold miss (fills the cache)
               shared + [5],            # full hit (identical prompt)
               shared[:8] + [9, 2],     # partial hit at the 8-boundary
               [7, 7]]                  # miss (nothing shared)
    off, _ = _run(cfg, params, prompts, None)
    on, eng = _run(cfg, params, prompts, 64)
    assert on == off
    s = eng.prefix_cache.stats()
    assert s["hits"] >= 1 and s["partial_hits"] >= 1 and s["misses"] >= 1
    assert eng.counters["prefix_restores"] == \
        s["hits"] + s["partial_hits"]


def test_full_hit_skips_prefill_dispatches(setup):
    cfg, params = setup
    prompt = [(2 * i + 1) % cfg.vocab_size for i in range(9)]
    eng = LLMEngine(params, cfg, max_batch=1, max_len=64,
                    prefill_chunk=4, prefix_cache_mb=64)
    eng.add_request(list(prompt), SamplingParams(max_tokens=2))
    eng.run()
    cold_dispatches = eng.counters["prefill_dispatches"]
    assert cold_dispatches > 0
    st = eng.add_request(list(prompt), SamplingParams(max_tokens=2))
    eng.step()                               # admission + first decode
    # full hit: restored straight past PREFILLING, zero new dispatches
    assert eng.counters["prefill_dispatches"] == cold_dispatches
    assert st.cached_len == len(prompt) - 1
    assert st.status is RequestStatus.DECODING
    eng.run()
    assert len(st.token_ids) == 2


def test_tiny_budget_degrades_to_miss_with_correct_outputs(setup):
    cfg, params = setup
    shared = [(3 * i) % cfg.vocab_size for i in range(9)]
    prompts = [shared + [5], shared + [5]]
    off, _ = _run(cfg, params, prompts, None)
    on, eng = _run(cfg, params, prompts, 1e-4)   # ~100B: nothing fits
    assert on == off
    s = eng.prefix_cache.stats()
    assert s["rejected"] > 0 and s["hits"] == 0 and len(
        eng.prefix_cache) == 0


def test_cache_aware_admission_serves_hits_first(setup):
    cfg, params = setup
    shared = [(5 * i + 1) % cfg.vocab_size for i in range(9)]
    eng = LLMEngine(params, cfg, max_batch=1, max_len=64,
                    prefill_chunk=4, prefix_cache_mb=64)
    assert isinstance(eng.scheduler, CacheAwareScheduler)  # default
    eng.add_request(list(shared), SamplingParams(max_tokens=1),
                    request_id="cold")
    eng.run()
    # queue a miss BEFORE a hit: cache-aware admission flips the order
    eng.add_request([9, 8, 7], SamplingParams(max_tokens=1),
                    request_id="miss")
    eng.add_request(list(shared), SamplingParams(max_tokens=1),
                    request_id="hit")
    finish_order = []
    while eng.has_unfinished():
        finish_order += [o.request_id for o in eng.step() if o.finished]
    assert finish_order == ["hit", "miss"]


def test_metrics_hit_rate_and_ttft_split_with_fake_clock(setup):
    cfg, params = setup
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    shared = [(3 * i + 2) % cfg.vocab_size for i in range(9)]
    eng = LLMEngine(params, cfg, max_batch=1, max_len=64,
                    prefill_chunk=4, prefix_cache_mb=64, clock=clock)
    eng.add_request(list(shared), SamplingParams(max_tokens=2))
    eng.run()
    for _ in range(2):
        eng.add_request(list(shared), SamplingParams(max_tokens=2))
    eng.run()
    mj = eng.metrics_json()
    pc = mj["prefix_cache"]
    assert pc["hits"] == 2 and pc["misses"] == 1
    assert pc["hit_rate"] == pytest.approx(2 / 3)
    assert pc["full_hit_rate"] == pytest.approx(2 / 3)
    assert pc["ttft_ms_hit"]["n"] == 2 and pc["ttft_ms_miss"]["n"] == 1
    # the fake clock ticks once per metrics event: a hit request sees
    # submit -> schedule -> first token (2 ticks of TTFT); the miss
    # also pays one tick per decoded-but-queued step before it -- the
    # split just has to be internally consistent and finite
    assert pc["ttft_ms_hit"]["mean"] > 0
    assert pc["ttft_ms_miss"]["mean"] > 0
    reqs = list(mj["requests"].values())
    assert sorted(r["cached_tokens"] for r in reqs) == \
        [0, len(shared) - 1, len(shared) - 1]


def test_partial_hit_resumes_and_extends_prefix_chain(setup):
    cfg, params = setup
    base = [(7 * i + 3) % cfg.vocab_size for i in range(13)]
    eng = LLMEngine(params, cfg, max_batch=1, max_len=64,
                    prefill_chunk=4, prefix_cache_mb=64)
    eng.add_request(base[:9], SamplingParams(max_tokens=1))
    eng.run()                              # snapshots at 4 and 8
    assert base[:8] in eng.prefix_cache
    eng.add_request(list(base), SamplingParams(max_tokens=1))
    eng.run()                              # resumes at 8, snapshots 12
    s = eng.prefix_cache.stats()
    assert s["partial_hits"] == 1
    assert base[:12] in eng.prefix_cache   # the chain grew
    st = eng.add_request(base[:12] + [1], SamplingParams(max_tokens=1))
    eng.run()                              # ...and is itself a full hit
    assert eng.prefix_cache.stats()["hits"] >= 1
    assert st.cached_len == 12


# ---------------------------------------------------------------------------
# copy-on-write snapshot sharing (PR-7): concurrent restores of one
# cached prefix must share device buffers, never deep-copy them
# ---------------------------------------------------------------------------

def test_lookup_returns_the_cached_tree_by_reference():
    """Repeated lookups hand out the SAME leaves -- the COW contract's
    cache-side half (insert stores by reference, lookup never copies)."""
    c = StateCache(byte_budget=1 << 20)
    tree = _state(8)
    c.insert([1, 2, 3], tree)
    e1 = c.lookup([1, 2, 3, 9])
    e2 = c.lookup([1, 2, 3, 7])
    assert e1 is e2
    assert e1.state["h"] is tree["h"]      # stored by reference
    assert e2.state["h"] is e1.state["h"]  # shared across lookups


def test_promotion_pays_one_device_put_across_concurrent_hits():
    """A spilled prefix hit by N concurrent requests crosses the
    host->device boundary ONCE; every later hit shares the promoted
    tree by reference."""
    moves = {"to_host": 0, "to_device": 0}

    def to_host(t):
        moves["to_host"] += 1
        return t

    def to_device(t):
        moves["to_device"] += 1
        return t

    c = StateCache(byte_budget=2 * 32, spill_byte_budget=1 << 20,
                   to_host=to_host, to_device=to_device)
    c.insert([1, 1], _state(8))            # 32 B each: budget fits 2
    c.insert([2, 2], _state(8))
    c.insert([3, 3], _state(8))            # evicts+spills [1, 1]
    assert moves["to_host"] == 1 and c.stats()["spills"] == 1

    entries = [c.lookup([1, 1, i]) for i in range(4)]
    assert all(e is not None for e in entries)
    assert moves["to_device"] == 1          # one promotion, not four
    assert c.stats()["promotions"] == 1
    first = entries[0]
    assert all(e is first for e in entries)
    assert all(e.state["h"] is first.state["h"] for e in entries)


def test_concurrent_restores_share_state_and_leave_entry_intact(setup):
    """Engine-level COW: a batch of same-prefix requests restores the
    one cached snapshot N times, decodes past it, and the cached entry
    still replays bit-identically afterwards (restores read the shared
    tree; advancing a slot builds new arrays)."""
    cfg, params = setup
    shared = [(3 * i + 1) % cfg.vocab_size for i in range(9)]
    eng = LLMEngine(params, cfg, max_batch=2, max_len=64,
                    prefill_chunk=4, prefix_cache_mb=64)
    cold = eng.add_request(shared + [5], SamplingParams(max_tokens=4))
    eng.run()
    entry = eng.prefix_cache.lookup(shared + [5])
    assert entry is not None and entry.tokens == tuple(shared)
    leaves_before = jax.tree.leaves(entry.state)

    hot = [eng.add_request(shared + [5], SamplingParams(max_tokens=4),
                           request_id=f"hot{i}") for i in range(3)]
    eng.run()
    # every hot request restored the SAME tree (no per-restore copy):
    # the entry still holds the exact leaf objects from before...
    leaves_after = jax.tree.leaves(
        eng.prefix_cache.lookup(shared + [5]).state)
    assert all(a is b for a, b in zip(leaves_before, leaves_after))
    # ...and decoding from the shared snapshot never corrupted it:
    # streams are bit-identical to the cold request's
    assert all(list(h.token_ids) == list(cold.token_ids) for h in hot)
    assert eng.counters["prefix_restores"] == 3      # one per hot seat
    assert eng.prefix_cache.stats()["promotions"] == 0
