"""Speculative draft-and-verify decoding (``repro.serve.spec`` +
``EngineCore.decode_spec`` + ``LLMEngine._spec_step``): greedy streams
bit-identical to vanilla for any draft, Leviathan rejection sampling
distribution-identical to target sampling, O(1) rollback parity,
mid-verify cancellation, prefix-cache interaction, and the
``SpecConfig`` validation surface."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.configs.base import ModelConfig
from repro.models import (decode_step, init_decode_state, init_params,
                          select_verify_state, supports_verify,
                          verify_step)
from repro.serve import LLMEngine, SamplingParams, SpecConfig
from repro.serve.spec import resolve_draft, spec_acceptance

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def draft_setup(setup):
    """A genuinely different (smaller, randomly initialised) draft."""
    cfg, _ = setup
    dc = scale_down(get_config("mamba-130m"), layers=1, width=32,
                    vocab=cfg.vocab_size)
    dparams = init_params(jax.random.PRNGKey(7), dc)
    return dc, dparams


def _streams(cfg, params, spec, prompts, sps, **kw):
    eng = LLMEngine(params, cfg, max_batch=4, max_len=96,
                    prefill_chunk=8, speculative=spec, **kw)
    sts = [eng.add_request(list(p), sp, request_id=f"r{i}")
           for i, (p, sp) in enumerate(zip(prompts, sps))]
    eng.run()
    return [list(s.token_ids) for s in sts], eng


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_spec_config_validation(setup, draft_setup):
    cfg, params = setup
    dc, dparams = draft_setup
    with pytest.raises(ValueError, match="k must be >= 1"):
        SpecConfig(k=0)
    # self-draft with explicit weights is a contradiction
    with pytest.raises(ValueError, match="draft_params must be None"):
        resolve_draft(SpecConfig(draft="self", draft_params={}),
                      cfg, params, None)
    # a *named* draft that resolves to the target degenerates to self
    dcfg, dp, _, is_self = resolve_draft(
        SpecConfig(draft=cfg.name), cfg, params, None)
    assert is_self and dcfg is cfg and dp is params
    # a different model needs weights (the engine never loads ckpts)
    with pytest.raises(ValueError, match="draft_params"):
        resolve_draft(SpecConfig(draft="mamba-370m"), cfg, params, None)
    with pytest.raises(ValueError, match="draft_params"):
        resolve_draft(SpecConfig(draft=dc), cfg, params, None)
    # vocab mismatch can never verify token-by-token
    bad = scale_down(get_config("mamba-130m"), layers=1, width=32,
                     vocab=cfg.vocab_size * 2)
    with pytest.raises(ValueError, match="vocab"):
        resolve_draft(SpecConfig(draft=bad, draft_params=dparams),
                      cfg, params, None)


def test_unsupported_family_raises():
    cfg = scale_down(get_config("llama3-8b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert not supports_verify(cfg)
    with pytest.raises(ValueError, match="speculative"):
        LLMEngine(params, cfg, max_batch=2, max_len=64,
                  speculative=SpecConfig(k=2))


# ---------------------------------------------------------------------------
# greedy bit-identity: spec streams == vanilla streams, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_greedy_bit_identity_self_draft(setup, k):
    cfg, params = setup
    prompts = [[(3 * i + j) % cfg.vocab_size for j in range(5 + i)]
               for i in range(3)]
    sps = [SamplingParams(max_tokens=12)] * 3
    van, _ = _streams(cfg, params, None, prompts, sps)
    spec, eng = _streams(cfg, params, SpecConfig(draft="self", k=k),
                         prompts, sps)
    assert spec == van
    sd = eng.metrics_json()["spec_decode"]
    assert sd["acceptance_rate"] == pytest.approx(1.0)
    assert sd["k"] == k and sd["draft"] == "self"
    # self-draft accepts everything: 12 tokens in ceil(12 / (k+1)) rounds
    assert eng.counters["spec_rounds"] <= -(-12 // (k + 1)) + 1


def test_greedy_bit_identity_distinct_draft(setup, draft_setup):
    """Greedy verification guarantees the emitted stream for ANY draft
    -- even an untrained one that disagrees most of the time."""
    cfg, params = setup
    dc, dparams = draft_setup
    prompts = [[(5 * i + j) % cfg.vocab_size for j in range(6)]
               for i in range(2)]
    sps = [SamplingParams(max_tokens=10)] * 2
    van, _ = _streams(cfg, params, None, prompts, sps)
    spec, eng = _streams(
        cfg, params, SpecConfig(draft=dc, draft_params=dparams, k=4),
        prompts, sps)
    assert spec == van
    sd = eng.metrics_json()["spec_decode"]
    assert 0.0 <= sd["acceptance_rate"] <= 1.0
    assert sd["rolled_back_tokens"] == \
        sd["drafted_tokens"] - sd["accepted_tokens"]
    # the distinct draft prefilled through its own path
    assert eng.counters["draft_prefill_dispatches"] > 0


def test_mixed_greedy_and_sampled_batch(setup):
    """Greedy and sampled rows coexist in one verify round; the greedy
    rows still match vanilla bit for bit."""
    cfg, params = setup
    prompts = [[1 + i, 2, 3, 4] for i in range(4)]
    sps = [SamplingParams(max_tokens=8),
           SamplingParams(max_tokens=8, temperature=0.9, top_k=16,
                          seed=3),
           SamplingParams(max_tokens=8),
           SamplingParams(max_tokens=8, temperature=1.2, top_p=0.9,
                          seed=11)]
    van, _ = _streams(cfg, params, None, prompts, sps)
    spec, eng = _streams(cfg, params, SpecConfig(draft="self", k=3),
                         prompts, sps)
    assert spec[0] == van[0] and spec[2] == van[2]   # greedy rows
    assert all(len(s) == 8 for s in spec)            # sampled: right len
    sd = eng.metrics_json()["spec_decode"]
    assert 0.0 < sd["acceptance_rate"] <= 1.0


# ---------------------------------------------------------------------------
# distribution identity: the emitted marginal IS the target distribution
# ---------------------------------------------------------------------------

def test_rejection_sampling_marginal_matches_target():
    """Leviathan acceptance: for draft d ~ q accepted iff
    u*q(d) < p(d), else resampled from norm(max(p-q, 0)), the marginal
    of the emitted token is exactly p.  Checked empirically against the
    true p with many trials batched down the B axis (k=1)."""
    v = 8
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(v)).astype(np.float32)
    q = rng.dirichlet(np.ones(v)).astype(np.float32)
    n = 20_000
    logits = jnp.asarray(np.log(p))[None, None, :].repeat(n, 0)
    logits = jnp.concatenate([logits, logits], axis=1)  # (n, 2, v): k=1
    drafts = jnp.asarray(rng.choice(v, size=(n, 1), p=q))
    qprobs = jnp.asarray(q)[None, None, :].repeat(n, 0)
    keys = jax.random.split(jax.random.PRNGKey(42), n)
    temps = jnp.ones((n,), jnp.float32)
    n_acc, extra, _ = spec_acceptance(
        logits, drafts.astype(jnp.int32), qprobs, keys, temps,
        jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.float32),
        truncate=False)
    emitted = np.where(np.asarray(n_acc) == 1,
                       np.asarray(drafts)[:, 0], np.asarray(extra))
    emp = np.bincount(emitted, minlength=v) / n
    # total-variation distance ~ O(1/sqrt(n)) for a faithful sampler
    assert 0.5 * np.abs(emp - p).sum() < 0.02
    # sanity: acceptance rate == sum(min(p, q)) in expectation
    acc = float(np.mean(np.asarray(n_acc)))
    assert acc == pytest.approx(np.minimum(p, q).sum(), abs=0.02)


def test_identical_p_q_always_accepts_and_bonus_flows():
    """p == q accepts every draft (u in [0,1) and u*q < p never fails)
    and the full-accept bonus samples from the last distribution."""
    v, n, k = 6, 4_000, 3
    rng = np.random.default_rng(1)
    p = rng.dirichlet(np.ones(v)).astype(np.float32)
    logits = jnp.asarray(np.log(p))[None, None, :].repeat(n, 0) \
        .repeat(k + 1, 1)
    drafts = jnp.asarray(rng.choice(v, size=(n, k), p=p), jnp.int32)
    qprobs = jnp.asarray(p)[None, None, :].repeat(n, 0).repeat(k, 1)
    keys = jax.random.split(jax.random.PRNGKey(5), n)
    n_acc, extra, _ = spec_acceptance(
        logits, drafts, qprobs, keys, jnp.ones((n,), jnp.float32),
        jnp.zeros((n,), jnp.int32), jnp.ones((n,), jnp.float32),
        truncate=False)
    assert np.all(np.asarray(n_acc) == k)
    emp = np.bincount(np.asarray(extra), minlength=v) / n
    assert 0.5 * np.abs(emp - p).sum() < 0.03


def test_seeded_sampled_spec_streams_are_reproducible(setup):
    """Same seeds => identical spec streams run to run (the draft and
    target PRNG lanes are deterministic); different draft k changes
    rounds, not determinism."""
    cfg, params = setup
    prompts = [[2, 4, 6, 8]] * 2
    sps = [SamplingParams(max_tokens=10, temperature=0.8, seed=s)
           for s in (0, 1)]
    a, _ = _streams(cfg, params, SpecConfig(draft="self", k=4),
                    prompts, sps)
    b, _ = _streams(cfg, params, SpecConfig(draft="self", k=4),
                    prompts, sps)
    assert a == b
    assert a[0] != a[1]          # different seeds actually differ


# ---------------------------------------------------------------------------
# rollback: select_verify_state(j) == j+1 sequential decode steps
# ---------------------------------------------------------------------------

def test_rollback_snapshots_match_sequential_decode(setup):
    cfg, params = setup
    b, m = 2, 5
    fed = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (b, m)),
        jnp.int32)
    state0 = init_decode_state(cfg, b, 64)
    logits_v, steps = verify_step(params, cfg, state0, fed)
    state = state0
    for j in range(m):
        lg, state = decode_step(params, cfg, state, fed[:, j])
        np.testing.assert_array_equal(np.asarray(logits_v[:, j]),
                                      np.asarray(lg))
        snap = select_verify_state(cfg, steps,
                                   jnp.full((b,), j, jnp.int32))
        for a, bb in zip(jax.tree.leaves(snap), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


# ---------------------------------------------------------------------------
# engine semantics: cancellation, prefix cache, metrics
# ---------------------------------------------------------------------------

def test_mid_verify_cancellation_drops_block_remainder(setup):
    """A cancel fired from an on_token callback in the middle of a
    committed block stops emission at that token; the engine stays
    consistent and later requests are unaffected."""
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=2, max_len=96,
                    prefill_chunk=8,
                    speculative=SpecConfig(draft="self", k=4))
    got = []

    def on_token(tok):
        got.append(tok)
        if len(got) == 2:                    # mid-block (k+1 == 5)
            eng.cancel("victim")

    st = eng.add_request([1, 2, 3], SamplingParams(max_tokens=50),
                         request_id="victim", on_token=on_token)
    eng.run()
    assert st.finished and st.finish_reason.value == "cancelled"
    assert list(st.token_ids) == got and len(got) == 2
    # the slot is free and a fresh request decodes normally
    st2 = eng.add_request([1, 2, 3], SamplingParams(max_tokens=6),
                          request_id="after")
    eng.run()
    assert len(st2.token_ids) == 6
    mj = eng.metrics_json()
    assert mj["engine"]["requests_cancelled"] == 1
    assert mj["requests"]["victim"]["generated"] == 2


def test_stop_token_truncates_block(setup):
    """A stop token inside a multi-token block finishes the request at
    the stop token; tokens after it in the block are dropped."""
    cfg, params = setup
    van, _ = _streams(cfg, params, None, [[3, 1, 4]],
                      [SamplingParams(max_tokens=40)])
    stop = van[0][2]                         # appears inside any block
    sps = [SamplingParams(max_tokens=40, stop_token_ids=(stop,))]
    van_stop, _ = _streams(cfg, params, None, [[3, 1, 4]], sps)
    spec, _ = _streams(cfg, params, SpecConfig(draft="self", k=7),
                       [[3, 1, 4]], sps)
    assert spec[0] == van_stop[0]            # truncated identically
    assert spec[0][-1] == stop
    assert len(spec[0]) < len(van[0])        # the block really truncated


def test_spec_with_prefix_cache_streams_identical(setup):
    """Speculative decode composes with the prefix cache: restored
    prefixes feed the verify path (and the self-draft's shared slot)
    with bit-identical results."""
    cfg, params = setup
    shared = [(2 * j + 1) % cfg.vocab_size for j in range(9)]
    prompts = [shared + [5], shared + [5], shared + [9]]
    sps = [SamplingParams(max_tokens=8)] * 3
    spec = SpecConfig(draft="self", k=4)
    off, _ = _streams(cfg, params, spec, prompts, sps)
    on, eng = _streams(cfg, params, spec, prompts, sps,
                       prefix_cache_mb=64)
    assert on == off
    s = eng.prefix_cache.stats()
    assert s["hits"] + s["partial_hits"] >= 1
    # and both match vanilla (greedy), cache or not
    van, _ = _streams(cfg, params, None, prompts, sps)
    assert on == van


def test_spec_metrics_json_section(setup):
    cfg, params = setup
    prompts = [[1, 2, 3], [4, 5, 6, 7]]
    sps = [SamplingParams(max_tokens=9),
           SamplingParams(max_tokens=9, temperature=0.7, seed=2)]
    _, eng = _streams(cfg, params, SpecConfig(draft="self", k=4),
                      prompts, sps)
    sd = eng.metrics_json()["spec_decode"]
    assert sd["k"] == 4 and sd["draft"] == "self"
    assert sd["rounds"] == eng.counters["spec_rounds"] > 0
    assert sd["drafted_tokens"] == sd["accepted_tokens"] \
        + sd["rolled_back_tokens"]
    assert 0.0 < sd["acceptance_rate"] <= 1.0
    spd = sd["per_request_speedup"]
    assert spd["n"] == 2 and spd["mean"] > 1.0   # self-draft: > 1 tok/round
    rm = eng.metrics_json()["requests"]["r0"]
    assert rm["spec_rounds"] > 0
    assert rm["spec_speedup"] == pytest.approx(
        rm["generated"] / rm["spec_rounds"])


def test_one_fused_dispatch_per_spec_round(setup):
    """The batching contract: a round's k+1 draft steps, verify,
    acceptance, and rollback are ONE ``_spec_fn`` dispatch -- never k
    separate draft launches.  Pinned by counting actual invocations."""
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=2, max_len=96,
                    prefill_chunk=8,
                    speculative=SpecConfig(draft="self", k=3))
    calls = 0
    inner = eng.core._spec_fn

    def counting(*a, **kw):
        nonlocal calls
        calls += 1
        return inner(*a, **kw)

    eng.core._spec_fn = counting
    st = eng.add_request([1, 2, 3, 4], SamplingParams(max_tokens=9))
    eng.run()
    assert len(st.token_ids) == 9
    c = eng.counters
    assert calls == c["spec_rounds"] == c["spec_dispatches"] > 0
    sd = eng.metrics_json()["spec_decode"]
    assert sd["dispatches"] == c["spec_dispatches"]
    # one live slot: exactly k drafted tokens ride each fused dispatch
    assert sd["drafted_tokens_per_dispatch"] == pytest.approx(3.0)
    # two live slots double the drafted tokens per dispatch, not the
    # dispatch count per round
    eng2 = LLMEngine(params, cfg, max_batch=2, max_len=96,
                     prefill_chunk=8,
                     speculative=SpecConfig(draft="self", k=3))
    for i in range(2):
        eng2.add_request([1 + i, 2, 3, 4],
                         SamplingParams(max_tokens=8))
    eng2.run()
    sd2 = eng2.metrics_json()["spec_decode"]
    assert sd2["dispatches"] == eng2.counters["spec_rounds"]
    assert sd2["drafted_tokens_per_dispatch"] == pytest.approx(6.0)


def test_vanilla_engine_has_no_spec_section(setup):
    cfg, params = setup
    _, eng = _streams(cfg, params, None, [[1, 2]],
                      [SamplingParams(max_tokens=2)])
    assert "spec_decode" not in eng.metrics_json()
    assert "spec_rounds" not in eng.counters
