"""Test-suite bootstrap.

Property tests use ``hypothesis`` when it is installed.  On machines
without it (this suite must collect and run everywhere), a tiny
deterministic stand-in is registered under the same import name: ``given``
replays each strategy's boundary values first and then seeded random
draws, so the property tests still execute as example-based tests with a
fixed, reproducible sample.
"""
from __future__ import annotations

import random
import sys
import types


def _install_hypothesis_fallback() -> None:
    try:
        import hypothesis  # noqa: F401  (the real thing is available)
        return
    except ImportError:
        pass

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw           # draw(rnd, i) -> value

        def draw(self, rnd, i):
            return self._draw(rnd, i)

    def integers(lo, hi):
        return _Strategy(lambda r, i: lo if i == 0 else
                         hi if i == 1 else r.randint(lo, hi))

    def floats(lo, hi):
        return _Strategy(lambda r, i: lo if i == 0 else
                         hi if i == 1 else r.uniform(lo, hi))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r, i: seq[i % len(seq)])

    def booleans():
        return _Strategy(lambda r, i: (False, True)[i % 2])

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper():
                # read at call time: works whether @settings is applied
                # above @given (stamps wrapper) or below (stamps fn)
                n = getattr(wrapper, "_fallback_max_examples",
                            getattr(fn, "_fallback_max_examples", 10))
                rnd = random.Random(0)
                for i in range(n):
                    fn(*[s.draw(rnd, i) for s in strategies])

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.__fallback__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_fallback()


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "serve: serving-layer tests (scheduler, request lifecycle, "
        "sampler, metrics) -- the CI `serve` job runs `-m serve`")
