"""Serving engine correctness vs standalone decode: continuous
batching, slot reuse, stop tokens, quantized serving.  The request
lifecycle/scheduling surface is covered in test_serve_lifecycle.py."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import decode_step, init_decode_state, init_params, forward
from repro.models.quantize import make_qctx, quantize_model
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import get_spec
from repro.serve import LLMEngine, SamplingParams, generate


def _greedy_ref(params, cfg, prompt, n, qctx=None):
    state = init_decode_state(cfg, 1, 64, cache_dtype=jnp.float32)
    lg = None
    for t in prompt:
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([t], jnp.int32), qctx=qctx)
    out = []
    for _ in range(n):
        nt = int(jnp.argmax(lg[0]))
        out.append(nt)
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([nt], jnp.int32), qctx=qctx)
    return out


@pytest.mark.parametrize("arch", ["mamba-130m", "granite-3-2b",
                                  "xlstm-1.3b"])
def test_engine_matches_standalone_greedy(arch):
    cfg = scale_down(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = [3, 1, 4]
    ref = _greedy_ref(params, cfg, prompt, 5)
    eng = LLMEngine(params, cfg, max_batch=2, max_len=64)
    s0 = eng.add_request(prompt, SamplingParams(max_tokens=5))
    eng.add_request([9], SamplingParams(max_tokens=2))   # interleaved
    eng.run()
    assert s0.token_ids == ref
    # reused slot must be clean
    s2 = eng.add_request(prompt, SamplingParams(max_tokens=5))
    eng.run()
    assert s2.token_ids == ref


def test_continuous_batching_throughput():
    """More requests than slots all complete."""
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(1), cfg)
    outs = generate(params, cfg, [[i + 1] for i in range(7)],
                    max_new_tokens=3, max_len=32)
    assert len(outs) == 7 and all(len(o) == 3 for o in outs)


def test_eos_stops_generation():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(2), cfg)
    ref = _greedy_ref(params, cfg, [5], 8)
    eos = ref[0]                              # first generated token
    eng = LLMEngine(params, cfg, max_batch=1, max_len=32)
    st = eng.add_request([5], SamplingParams(max_tokens=8,
                                             stop_token_ids=(eos,)))
    eng.run()
    assert st.token_ids == ref[:1]            # stops at eos inclusive


def test_quantized_serving_runs():
    """Quamba-quantized model through the engine (paper's deployment)."""
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(3), cfg)
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i),
                                             (2, 32), 0, cfg.vocab_size)}
               for i in range(2)]
    stats = run_calibration(
        lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"}),
        params, batches)
    spec = get_spec("quamba")
    qparams, qdata = quantize_model(params, stats, cfg, spec)
    qctx = make_qctx(spec, qdata)
    ref = _greedy_ref(qparams, cfg, [2, 7], 4, qctx=qctx)
    eng = LLMEngine(qparams, cfg, max_batch=2, max_len=32, qctx=qctx)
    st = eng.add_request([2, 7], SamplingParams(max_tokens=4))
    eng.run()
    assert st.token_ids == ref
