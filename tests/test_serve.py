"""Serving engine (legacy ``Engine`` shim surface): correctness vs
standalone decode, continuous batching, slot reuse, quantized serving.
The request-centric API is covered in test_serve_lifecycle.py."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import decode_step, init_decode_state, init_params, forward
from repro.models.quantize import make_qctx, quantize_model
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import get_spec
from repro.serve import Engine, Request, generate


def _greedy_ref(params, cfg, prompt, n, qctx=None):
    state = init_decode_state(cfg, 1, 64, cache_dtype=jnp.float32)
    lg = None
    for t in prompt:
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([t], jnp.int32), qctx=qctx)
    out = []
    for _ in range(n):
        nt = int(jnp.argmax(lg[0]))
        out.append(nt)
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([nt], jnp.int32), qctx=qctx)
    return out


@pytest.mark.parametrize("arch", ["mamba-130m", "granite-3-2b",
                                  "xlstm-1.3b"])
def test_engine_matches_standalone_greedy(arch):
    cfg = scale_down(get_config(arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = [3, 1, 4]
    ref = _greedy_ref(params, cfg, prompt, 5)
    eng = Engine(params, cfg, max_batch=2, max_len=64)
    r0 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    r1 = Request(uid=1, prompt=[9], max_new_tokens=2)   # interleaved
    eng.submit(r0)
    eng.submit(r1)
    eng.run()
    assert r0.output == ref
    # reused slot must be clean
    r2 = Request(uid=2, prompt=prompt, max_new_tokens=5)
    eng.submit(r2)
    eng.run()
    assert r2.output == ref


def test_continuous_batching_throughput():
    """More requests than slots all complete."""
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(1), cfg)
    outs = generate(params, cfg, [[i + 1] for i in range(7)],
                    max_new_tokens=3, max_len=32)
    assert len(outs) == 7 and all(len(o) == 3 for o in outs)


def test_eos_stops_generation():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(2), cfg)
    ref = _greedy_ref(params, cfg, [5], 8)
    eos = ref[0]                              # first generated token
    eng = Engine(params, cfg, max_batch=1, max_len=32)
    r = Request(uid=0, prompt=[5], max_new_tokens=8, eos_id=eos)
    eng.submit(r)
    eng.run()
    assert r.output == ref[:1]                # stops at eos inclusive


def test_quantized_serving_runs():
    """Quamba-quantized model through the engine (paper's deployment)."""
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(3), cfg)
    batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i),
                                             (2, 32), 0, cfg.vocab_size)}
               for i in range(2)]
    stats = run_calibration(
        lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"}),
        params, batches)
    spec = get_spec("quamba")
    qparams, qdata = quantize_model(params, stats, cfg, spec)
    qctx = make_qctx(spec, qdata)
    ref = _greedy_ref(qparams, cfg, [2, 7], 4, qctx=qctx)
    eng = Engine(qparams, cfg, max_batch=2, max_len=32, qctx=qctx)
    r = Request(uid=0, prompt=[2, 7], max_new_tokens=4)
    eng.submit(r)
    eng.run()
    assert r.output == ref
