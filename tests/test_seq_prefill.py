"""Universal sequence prefill (PR-7): ``models.prefill_step`` covers
every serving family -- mamba, dense (llama3), moe (qwen3-moe), and
hybrid (zamba2) -- with chunked prefill that is bit-identical to
per-token decoding and costs O(num_chunks) dispatches, not O(tokens).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import (decode_step, init_decode_state, init_params,
                          prefill_step, supports_seq_prefill)
from repro.models.model import SEQ_PREFILL_FAMILIES
from repro.serve import LLMEngine, SamplingParams
from repro.serve.core import EngineCore

jax.config.update("jax_platform_name", "cpu")

# one representative architecture per serving family the issue names
ARCHS = ["mamba-130m", "llama3-8b", "qwen3-moe-30b-a3b", "zamba2-1.2b"]


@pytest.fixture(scope="module", params=ARCHS)
def fam_setup(request):
    cfg = scale_down(get_config(request.param))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_families_cover_the_serving_archs():
    fams = {scale_down(get_config(a)).family for a in ARCHS}
    assert fams == {"mamba", "dense", "moe", "hybrid"}
    assert all(f in SEQ_PREFILL_FAMILIES for f in fams)
    assert all(supports_seq_prefill(scale_down(get_config(a)))
               for a in ARCHS)


def test_chunked_prefill_bitwise_matches_per_token(fam_setup):
    """State after prefilling L tokens is bit-identical however the
    tokens were chunked -- including one-token chunks, i.e. the decode
    path itself."""
    cfg, params = fam_setup
    L = 13
    toks = np.random.default_rng(cfg.n_layers + L).integers(
        0, cfg.vocab_size, (1, L))
    probe = jnp.asarray([toks[0, -1]], jnp.int32)

    def run(chunks):
        state = init_decode_state(cfg, 1, 48)
        c0 = 0
        for c in chunks:
            _, state = prefill_step(
                params, cfg, state, jnp.asarray(toks[:, c0:c0 + c],
                                                jnp.int32))
            c0 += c
        assert c0 == L
        # the probe decode exercises the state end to end (logits see
        # every leaf, incl. caches/conv taps the tree compare may
        # reorder)
        lg, state = decode_step(params, cfg, state, probe)
        return lg, state

    # the hybrid family's Mamba-2 (SSD) blocks batch their intra-chunk
    # matmuls, which reassociates fp adds vs the per-token recurrence:
    # ~1 ULP on raw tensors (greedy token STREAMS are still bit-equal
    # across chunkings -- asserted at engine level below); the other
    # families replay the exact per-token op sequence, so they must be
    # bitwise
    exact = cfg.family != "hybrid"

    def check(a, b):
        if exact:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)

    lg_tok, st_tok = run([1] * L)
    for chunks in ([L], [5, 5, 3], [4, 1, 8]):
        lg, st = run(chunks)
        check(lg, lg_tok)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_tok)):
            check(a, b)


def test_engine_prefill_dispatches_scale_with_chunks(fam_setup):
    """prefill_dispatches == len(chunk_plan), for every family: the
    engine prefills 16 prompt tokens in 4 chunks of 4, not 16 steps."""
    cfg, params = fam_setup
    prompt = [int(t) for t in np.arange(17) % cfg.vocab_size]
    eng = LLMEngine(params, cfg, max_batch=2, max_len=48,
                    prefill_chunk=4)
    eng.add_request(prompt, SamplingParams(max_tokens=2))
    eng.run()
    assert eng.counters["prefill_dispatches"] == \
        len(EngineCore._chunk_plan(16, 4)) == 4


def test_engine_streams_invariant_to_prefill_chunking(fam_setup):
    """Greedy streams are bit-identical across prefill chunk sizes
    (1-token chunks == the per-token path)."""
    cfg, params = fam_setup
    prompts = [[(3 * i + j) % cfg.vocab_size for j in range(7 + i)]
               for i in range(3)]

    def run(chunk):
        eng = LLMEngine(params, cfg, max_batch=2, max_len=48,
                        prefill_chunk=chunk)
        sts = [eng.add_request(list(p), SamplingParams(max_tokens=6))
               for p in prompts]
        eng.run()
        return [list(s.token_ids) for s in sts]

    per_token = run(1)
    for chunk in (4, 8, 64):
        assert run(chunk) == per_token
