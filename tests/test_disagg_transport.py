"""Snapshot transport wire format (``repro.serve.disagg.transport``):
round-trips over every ``SEQ_PREFILL_FAMILIES`` decode-state layout
(odd shapes, int8 KV entries, packed-w4 qdata), crc-corruption and
framing rejection, and cross-process restore equality through a
spawned interpreter."""
import multiprocessing as mp

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import init_decode_state
from repro.models.model import SEQ_PREFILL_FAMILIES
from repro.quant.recipe import pack_int4
from repro.serve.disagg import (SnapshotCorruption, pack_snapshot,
                                snapshot_equal, unpack_snapshot)
from repro.serve.disagg.transport import FORMAT, MAGIC

# one representative arch per sequence-prefill family; the assertion
# below keeps this table honest when families are added
FAMILY_ARCHS = {
    "mamba": "mamba-130m",
    "dense": "granite-3-2b",
    "moe": "granite-moe-1b-a400m",
    "vlm": "paligemma-3b",
    "hybrid": "zamba2-1.2b",
}


def test_family_table_covers_seq_prefill_families():
    assert set(FAMILY_ARCHS) == set(SEQ_PREFILL_FAMILIES)


def _fill(tree, seed=0):
    """Replace every leaf with deterministic non-trivial values (zero
    trees would hide byte-order/offset bugs)."""
    rng = np.random.default_rng(seed)
    return jax.tree_util.tree_map(
        lambda x: jnp.asarray(
            rng.integers(-100, 100, np.shape(x)).astype(
                np.asarray(x).dtype)), tree)


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_roundtrip_every_family_decode_state(family):
    cfg = scale_down(get_config(FAMILY_ARCHS[family]))
    assert cfg.family == family
    # int8 KV caches for the dense family (the Quamba deployment shape)
    cache_dtype = jnp.int8 if family == "dense" else jnp.float32
    state = _fill(init_decode_state(cfg, 1, 17, cache_dtype=cache_dtype))
    blob = pack_snapshot(state)
    back = unpack_snapshot(blob)
    assert snapshot_equal(state, back)
    # dtypes survive exactly: an int8 KV entry must come back int8,
    # not promoted to float
    if family == "dense":
        flat = jax.tree_util.tree_flatten(back)[0]
        assert any(np.asarray(leaf).dtype == np.int8 for leaf in flat)


def test_roundtrip_packed_w4_qdata_tree():
    """Packed int4 nibbles (odd leading dim -> padded pack) and their
    scales ride the same wire format unchanged."""
    w = jnp.asarray(np.random.default_rng(3).integers(
        -8, 8, (7, 5)).astype(np.int8))
    tree = {"qdata": pack_int4(w), "scale": jnp.float32(0.125),
            "shape": jnp.asarray([7, 5], jnp.int32)}
    back = unpack_snapshot(pack_snapshot(tree))
    assert snapshot_equal(tree, back)
    assert np.asarray(back["qdata"]).dtype == np.int8
    assert back["qdata"].shape == (4, 5)       # ceil(7/2) rows packed


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 9), st.integers(1, 11), st.integers(0, 3),
       st.sampled_from(["float32", "int8", "int32", "float16"]))
def test_roundtrip_property_odd_shapes(a, b, depth, dtype):
    """Property: any dict tree of odd-shaped leaves round-trips to
    bitwise-equal host arrays."""
    rng = np.random.default_rng(a * 100 + b * 10 + depth)
    leaf = rng.integers(-120, 120, (a, b)).astype(dtype)
    tree = {"pos": np.asarray([a], np.int32), "x": leaf}
    for d in range(depth):
        tree = {f"level{d}": tree,
                "extra": rng.integers(0, 5, (b,)).astype(dtype)}
    back = unpack_snapshot(pack_snapshot(tree))
    assert snapshot_equal(tree, back)


def test_roundtrip_scalar_and_empty_leaves():
    tree = {"s": np.float32(2.5), "z": np.zeros((0, 4), np.float32),
            "n": {"i": np.int32(-7)}}
    back = unpack_snapshot(pack_snapshot(tree))
    assert snapshot_equal(tree, back)
    assert unpack_snapshot(pack_snapshot({})) == {}


def test_crc_corruption_rejected():
    state = _fill(init_decode_state(
        scale_down(get_config("mamba-130m")), 1, 8))
    blob = bytearray(pack_snapshot(state))
    blob[-3] ^= 0x40                       # flip one payload bit
    with pytest.raises(SnapshotCorruption, match="crc32"):
        unpack_snapshot(bytes(blob))


def test_manifest_corruption_rejected():
    state = _fill(init_decode_state(
        scale_down(get_config("mamba-130m")), 1, 8))
    blob = pack_snapshot(state)
    with pytest.raises(SnapshotCorruption, match="magic"):
        unpack_snapshot(b"not-a-snapshot" + blob)
    with pytest.raises(SnapshotCorruption, match="truncated"):
        unpack_snapshot(blob[:len(blob) // 2])
    with pytest.raises(SnapshotCorruption, match="truncated"):
        unpack_snapshot(blob[:len(MAGIC) + 2])
    # advertised format must match exactly (no silent cross-version
    # reads between worker fleets)
    evil = blob.replace(FORMAT.encode(), b"snapshot-v9", 1)
    with pytest.raises(SnapshotCorruption, match="format"):
        unpack_snapshot(evil)


def test_snapshot_equal_detects_differences():
    a = {"x": np.arange(6, dtype=np.float32)}
    assert snapshot_equal(a, {"x": np.arange(6, dtype=np.float32)})
    assert not snapshot_equal(a, {"x": np.arange(6, dtype=np.float64)})
    assert not snapshot_equal(a, {"y": np.arange(6, dtype=np.float32)})
    b = {"x": np.arange(6, dtype=np.float32)}
    b["x"][0] = 99.0
    assert not snapshot_equal(a, b)


def test_cross_process_restore_equality():
    """A snapshot packed here, unpacked in a spawned process, repacked
    there, and unpacked back here is bitwise-identical -- the disagg
    worker boundary cannot perturb state."""
    cfg = scale_down(get_config("zamba2-1.2b"))
    state = _fill(init_decode_state(cfg, 1, 9), seed=7)
    blob = pack_snapshot(state)
    from _disagg_proc_helpers import child_roundtrip
    ctx = mp.get_context("spawn")
    parent, child = ctx.Pipe()
    proc = ctx.Process(target=child_roundtrip, args=(child, blob),
                       daemon=True)
    proc.start()
    child.close()
    assert parent.poll(300), "child never replied"
    kind, payload = parent.recv()
    proc.join(30)
    assert kind == "ok", payload
    assert payload == blob                 # byte-stable across repack
    assert snapshot_equal(state, unpack_snapshot(payload))
