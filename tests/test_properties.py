"""Property-based tests (PR 10): int4 packing round-trips and the
Quamba-SE soft-edge scale blend under randomized shapes and knobs.

Runs under real ``hypothesis`` when installed; otherwise the
deterministic fallback in ``conftest.py`` replays each strategy's
boundary values plus seeded random draws, so the properties execute
everywhere with a fixed sample.
"""
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.quant.quantizers import percentile_scale, symmetric_scale
from repro.quant.recipe import (get_spec, pack_int4, quantize_weight,
                                soft_edge_blend, unpack_int4)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# pack_int4 / unpack_int4
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 67), st.integers(1, 9), st.booleans(),
       st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_round_trip(k, n, two_d, seed):
    """Any int4 tensor (1-D or 2-D, odd or even K) survives the nibble
    pack bit-exactly, and the packed carrier is half the rows."""
    rng = np.random.default_rng(seed)
    shape = (k, n) if two_d else (k,)
    q = jnp.asarray(rng.integers(-8, 8, size=shape).astype(np.int8))
    packed = pack_int4(q)
    assert packed.dtype == jnp.int8
    assert packed.shape == (-(-k // 2),) + shape[1:]
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed, k)),
                                  np.asarray(q))
    # unpadded unpack keeps the zero row of an odd K (harmless for a
    # matmul: the matching activation column is absent)
    full = np.asarray(unpack_int4(packed))
    assert full.shape[0] == 2 * (-(-k // 2))
    if k % 2:
        np.testing.assert_array_equal(full[-1],
                                      np.zeros_like(full[-1]))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 65), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_quantize_weight_packed_equals_pinned_storage(k, n, seed):
    """The nibble-packed "auto" storage and the one-value-per-byte
    "int8" storage of the same w4 weight hold identical grid values."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    spec = get_spec("quamba-w4a8")
    packed = quantize_weight(w, spec)
    pinned = quantize_weight(w, spec, storage="int8")
    assert set(packed) == {"qw4", "s_w"}
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(packed["qw4"], k)),
        np.asarray(pinned["qw"]))
    np.testing.assert_array_equal(np.asarray(packed["s_w"]),
                                  np.asarray(pinned["s_w"]))


# ---------------------------------------------------------------------------
# soft-edge blend
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(1e-6, 10.0), st.floats(0.0, 10.0))
def test_soft_edge_blend_between_endpoints(lam, s_pct, spread):
    """The blend lands between the percentile clip and the abs-max
    scale for any lambda, hits the endpoints exactly at 0 and 1, and is
    monotone in lambda."""
    s_amax = s_pct + spread
    s = float(soft_edge_blend(jnp.float32(s_pct), jnp.float32(s_amax),
                              lam))
    eps = 1e-6 * (1.0 + s_amax)
    assert s_pct - eps <= s <= s_amax + eps
    if lam == 0.0:
        np.testing.assert_allclose(s, s_pct, rtol=1e-6)
    if lam == 1.0:
        np.testing.assert_allclose(s, s_amax, rtol=1e-6)
    s_hi = float(soft_edge_blend(jnp.float32(s_pct),
                                 jnp.float32(s_amax),
                                 min(1.0, lam + 0.125)))
    assert s_hi >= s - eps


@settings(max_examples=15, deadline=None)
@given(st.floats(90.0, 100.0), st.integers(0, 2 ** 31 - 1))
def test_soft_edge_blend_of_percentile_scales(p, seed):
    """With real tensors: the percentile scale never exceeds the
    abs-max scale (even at extreme p), so the blend is sandwiched --
    exactly the invariant the Quamba-SE preset relies on."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=2048).astype(np.float32))
    s_pct = float(percentile_scale(x, p=p))
    s_amax = float(symmetric_scale(x))
    assert s_pct <= s_amax + 1e-8
    for lam in (0.25, 0.5, 0.75):
        s = float(soft_edge_blend(jnp.float32(s_pct),
                                  jnp.float32(s_amax), lam))
        assert s_pct - 1e-8 <= s <= s_amax + 1e-8
