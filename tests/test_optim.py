"""Optimizer + gradient compression tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (OptimConfig, adamw_update, clip_by_global_norm,
                         compress_tree_with_feedback, cosine_lr,
                         init_error_state, init_opt_state)


def test_cosine_schedule_shape():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(0, 111, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # peak after warmup
    assert lrs[-1] <= 0.11                   # decays to min ratio
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    cn = float(jnp.linalg.norm(clipped["a"]))
    assert abs(cn - 1.0) < 1e-4


def test_adamw_moves_towards_minimum():
    cfg = OptimConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||^2
        params, opt, _ = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_error_feedback_compression_unbiased_over_time():
    """EF-int8 SGD on a quadratic converges like exact SGD."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=64).astype(np.float32))
    w = jnp.zeros(64)
    err = init_error_state({"w": w})["w"]
    for _ in range(300):
        g = 2 * (w - target) + 0.001 * rng.normal(size=64).astype(np.float32)
        (cg,), (err,) = (lambda t: (jax.tree.leaves(t[0]),
                                    jax.tree.leaves(t[1])))(
            compress_tree_with_feedback({"w": g}, {"w": err}))
        w = w - 0.05 * cg
    assert float(jnp.abs(w - target).max()) < 0.05


def test_compression_reduces_payload():
    from repro.optim.compression import compress
    g = jnp.asarray(np.random.default_rng(1).normal(size=1024),
                    jnp.float32)
    q, s = compress(g)
    assert q.dtype == jnp.int8 and q.nbytes == g.nbytes // 4
