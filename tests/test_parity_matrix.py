"""The qdq <-> kernels parity matrix (PR 10, satellite of the QAT PR).

One parametrized matrix replaces the ad-hoc parity checks that used to
be scattered across ``test_kernel_backend.py`` (full-forward kernels vs
oracle for quamba/static/out_had/in_per) and ``test_int4.py`` (the
int4-matmul site sweep and the w4a8 forward check):

* **forward rows** -- the mamba family is the only one with a kernels
  execution path, so the full-forward slab is mamba x every
  kernels-eligible preset: logits of ``backend="kernels"`` vs the same
  artifact's qdq oracle.
* **matmul rows** -- every OTHER family still exercises the kernels via
  its nibble-packed matmul sites: for each family x w4 preset, every
  packed site's ``int4_matmul`` output vs the dequantize-then-fp-matmul
  oracle.

Every cell reads its tolerance from the single ``TOL`` table below --
a parity regression means editing that table in review, not hunting a
constant through the suite.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.kernels import ops as kops
from repro.models import forward, init_params
from repro.quant.recipe import get_spec, unpack_int4, uses_kernel_backend

jax.config.update("jax_platform_name", "cpu")

FAMILY_ARCHS = {
    "mamba": "mamba-130m",
    "dense": "llama3-8b",
    "moe": "qwen3-moe-30b-a3b",
    "hybrid": "zamba2-1.2b",
    "ssm": "xlstm-1.3b",
    "audio": "whisper-medium",
    "vlm": "paligemma-3b",
}

# every preset the kernels backend can execute end to end (static
# scales, int8 activations, per-tensor weights)
FORWARD_PRESETS = ("quamba", "static", "in_per", "out_had", "smoothquant",
                   "quamba-w4a8", "quamba-w4a8-se")
MATMUL_PRESETS = ("quamba-w4a8", "quamba-w4a8-se")

# THE tolerance table: (row kind, preset) -> (rtol, atol).  The int8
# presets run activations through rmsnorm_quant/hadamard_quant requant
# chains whose fp-simulation differs at ~1e-5; the w4 presets' matmul
# path is a pure integer dot, so those cells pin two orders tighter.
TOL = {
    ("forward", "quamba"): (1e-4, 1e-4),
    ("forward", "static"): (1e-4, 1e-4),
    ("forward", "in_per"): (1e-4, 1e-4),
    ("forward", "out_had"): (1e-4, 1e-4),
    ("forward", "smoothquant"): (1e-4, 1e-4),
    ("forward", "quamba-w4a8"): (1e-6, 1e-6),
    ("forward", "quamba-w4a8-se"): (1e-6, 1e-6),
    ("matmul", "quamba-w4a8"): (1e-6, 1e-6),
    ("matmul", "quamba-w4a8-se"): (1e-6, 1e-6),
}


def _calib_batches(cfg, b=2, l=32, n=2, seed=7):
    if cfg.family == "audio":
        key = jax.random.PRNGKey(seed)
        return [{"frames": jax.random.normal(key, (b, 24, cfg.d_model)),
                 "tokens": jax.random.randint(key, (b, 8), 0,
                                              cfg.vocab_size)}
                for _ in range(n)]
    if cfg.family == "vlm":
        key = jax.random.PRNGKey(seed)
        return [{"patches": jax.random.normal(
                     key, (b, cfg.prefix_len, cfg.d_model)),
                 "tokens": jax.random.randint(key, (b, l - cfg.prefix_len),
                                              0, cfg.vocab_size)}
                for _ in range(n)]
    return list(eval_batches(cfg.vocab_size, b, l, n, seed=seed))


_SETUP_CACHE = {}


def _family_setup(family):
    """(cfg, params, stats): one calibration pass per family, shared by
    every preset column of that family's row."""
    if family not in _SETUP_CACHE:
        cfg = scale_down(get_config(FAMILY_ARCHS[family]), layers=2,
                         width=64, vocab=128)
        params = init_params(jax.random.PRNGKey(0), cfg)
        stats = api.calibration_stats(cfg, params, _calib_batches(cfg))
        _SETUP_CACHE[family] = (cfg, params, stats)
    return _SETUP_CACHE[family]


def _artifact(family, preset, backend=None):
    cfg, params, stats = _family_setup(family)
    spec = get_spec(preset)
    if backend is not None:
        spec = dataclasses.replace(spec, backend=backend)
    return cfg, api.Quantizer(cfg, spec).with_stats(stats) \
        .quantize(params)


# ---------------------------------------------------------------------------
# forward slab: mamba x kernels-eligible presets
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("preset", FORWARD_PRESETS)
def test_forward_parity_kernels_vs_qdq(preset):
    cfg, qm = _artifact("mamba", preset, backend="kernels")
    assert uses_kernel_backend(qm.spec), preset
    assert qm.describe()["effective_backend"] == "kernels"
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                          0, cfg.vocab_size)}
    lg_q, _ = forward(qm.params, cfg, batch, qctx=qm.qctx(backend="qdq"))
    lg_k, _ = forward(qm.params, cfg, batch, qctx=qm.qctx())
    rtol, atol = TOL[("forward", preset)]
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_q),
                               rtol=rtol, atol=atol,
                               err_msg=f"forward x {preset}")


# ---------------------------------------------------------------------------
# matmul slab: every family x w4 presets, every packed site
# ---------------------------------------------------------------------------

def _packed_sites(tree, path=""):
    """Yield (path, leaf) for every nibble-packed weight-site dict."""
    if isinstance(tree, dict):
        if "qw4" in tree:
            yield path, tree
        else:
            for k, v in tree.items():
                yield from _packed_sites(v, f"{path}/{k}")


@pytest.mark.parametrize("preset", MATMUL_PRESETS)
@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_matmul_parity_kernel_vs_qdq(family, preset):
    _, qm = _artifact(family, preset)
    sites = list(_packed_sites(qm.qdata["qw"]))
    assert sites, f"{family} x {preset}: no packed matmul sites?"
    rtol, atol = TOL[("matmul", preset)]
    rng = np.random.default_rng(4)
    for path, lin in sites:
        packed = np.asarray(lin["qw4"])
        packed2d = jnp.asarray(packed.reshape((-1,) + packed.shape[-2:])[0])
        s_w = float(np.asarray(lin["s_w"]).reshape(-1)[0])
        kp, n = packed2d.shape
        qx = jnp.asarray(rng.integers(-128, 128, (4, 2 * kp))
                         .astype(np.int8))
        s_x = 0.02
        got = np.asarray(kops.int4_matmul(qx, packed2d, s_x, s_w))
        dq = np.asarray(unpack_int4(packed2d)).astype(np.float32) * s_w
        want = (np.asarray(qx).astype(np.float32) * s_x) @ dq
        np.testing.assert_allclose(got, want, rtol=rtol, atol=atol,
                                   err_msg=f"{family}{path} x {preset}")


def test_tolerance_table_covers_exactly_the_matrix():
    """No orphan rows: every cell in the matrix has a pinned tolerance
    and every pinned tolerance corresponds to a cell that runs."""
    want = {("forward", p) for p in FORWARD_PRESETS} \
        | {("matmul", p) for p in MATMUL_PRESETS}
    assert set(TOL) == want
