"""Kernel-backed int8 execution (``QuantSpec.backend == "kernels"``):
routing through the Pallas kernels, parity against the fake-quant
oracle, prefill-then-decode equivalence, and the engine's chunked
prefill dispatch count."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.models import (decode_step, forward, init_decode_state,
                          init_params, prefill_step)
from repro.models.mamba import use_kernel_backend
from repro.quant.recipe import get_spec, uses_kernel_backend
from repro.serve import LLMEngine, Request, SamplingParams, generate

jax.config.update("jax_platform_name", "cpu")

KERNEL_OPS = ("rmsnorm_quant", "int8_matmul", "causal_conv1d",
              "selective_scan", "selective_scan_step", "hadamard_quant")


@pytest.fixture(scope="module")
def qsetup():
    cfg = scale_down(get_config("mamba-130m"), layers=2, width=64,
                     vocab=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = list(eval_batches(cfg.vocab_size, 2, 32, 2, seed=7))
    qm = api.Quantizer(cfg, "quamba-kernels").calibrate(calib) \
        .quantize(params)
    return cfg, qm


def _count_ops(monkeypatch):
    from repro.kernels import ops
    counts = {name: 0 for name in KERNEL_OPS}
    for name in KERNEL_OPS:
        orig = getattr(ops, name)

        def wrap(*a, __orig=orig, __name=name, **kw):
            counts[__name] += 1
            return __orig(*a, **kw)

        monkeypatch.setattr(ops, name, wrap)
    return counts


# ---------------------------------------------------------------------------
# spec / preset plumbing
# ---------------------------------------------------------------------------

def test_backend_flag_validation_and_preset():
    import dataclasses
    spec = get_spec("quamba-kernels")
    assert spec.backend == "kernels" and uses_kernel_backend(spec)
    assert not uses_kernel_backend(get_spec("quamba"))
    assert not uses_kernel_backend(get_spec("dynamic"))   # dynamic scales
    assert not uses_kernel_backend(get_spec("quarot"))    # rotate-back
    # w4a8 runs on the kernel backend since PR 8 (int4_matmul); a3 and
    # per-channel weights still keep the oracle
    w4 = dataclasses.replace(get_spec("quamba-w4a8"), backend="kernels")
    assert uses_kernel_backend(w4)
    assert not uses_kernel_backend(
        dataclasses.replace(w4, per_channel_w=True))
    bad = dataclasses.replace(spec, backend="nope")
    with pytest.raises(ValueError):
        bad.validate()


def _layer_qctx(qctx, layer=0):
    """The per-layer qctx the layer scan hands to each block."""
    sl = lambda t: jax.tree.map(lambda a: a[layer], t)
    return {"mode": "quant", "spec": qctx["spec"],
            "scales": sl(qctx["scales"]["layers"]),
            "qw": sl(qctx["qw"]["layers"])}


def test_qctx_backend_override(qsetup):
    _, qm = qsetup
    assert use_kernel_backend(_layer_qctx(qm.qctx()))
    assert not use_kernel_backend(_layer_qctx(qm.qctx(backend="qdq")))
    assert qm.qctx(backend="kernels")["spec"].backend == "kernels"
    # artifacts quantized before the kernel backend existed carry no
    # int8 conv taps -> graceful fallback to the qdq oracle
    legacy = _layer_qctx(qm.qctx())
    legacy["qw"] = {k: v for k, v in legacy["qw"].items()
                    if k != "conv_w"}
    assert not use_kernel_backend(legacy)


# ---------------------------------------------------------------------------
# routing: the kernel backend actually calls the Pallas kernels
# ---------------------------------------------------------------------------

def test_forward_routes_through_kernels(qsetup, monkeypatch):
    cfg, qm = qsetup
    counts = _count_ops(monkeypatch)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    forward(qm.params, cfg, batch, qctx=qm.qctx())
    for name in ("rmsnorm_quant", "int8_matmul", "causal_conv1d",
                 "selective_scan", "hadamard_quant"):
        assert counts[name] > 0, (name, counts)
    assert counts["selective_scan_step"] == 0


def test_decode_routes_through_step_kernel(qsetup, monkeypatch):
    cfg, qm = qsetup
    counts = _count_ops(monkeypatch)
    state = init_decode_state(cfg, 1, 32, cache_dtype=jnp.float32)
    decode_step(qm.params, cfg, state, jnp.asarray([3], jnp.int32),
                qctx=qm.qctx())
    assert counts["selective_scan_step"] > 0
    assert counts["selective_scan"] == 0
    for name in ("rmsnorm_quant", "int8_matmul", "causal_conv1d",
                 "hadamard_quant"):
        assert counts[name] > 0, (name, counts)


def test_qdq_backend_never_touches_kernels(qsetup, monkeypatch):
    cfg, qm = qsetup
    counts = _count_ops(monkeypatch)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    forward(qm.params, cfg, batch, qctx=qm.qctx(backend="qdq"))
    assert all(c == 0 for c in counts.values()), counts


# ---------------------------------------------------------------------------
# parity: the kernels-vs-qdq forward checks that used to live here
# (quamba + static/out_had/in_per) moved to the consolidated matrix in
# test_parity_matrix.py::test_forward_parity_kernels_vs_qdq, which
# covers every kernels-eligible preset with one pinned tolerance table.
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# prefill-then-decode equivalence (sequence forward with h_last carry
# must match per-token mamba_block_step stepping)
# ---------------------------------------------------------------------------

PROMPT = [3, 1, 4, 1, 5, 9, 2, 6]


def _step_all(params, cfg, prompt, qctx):
    state = init_decode_state(cfg, 1, 32, cache_dtype=jnp.float32)
    lg = None
    for t in prompt:
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([t], jnp.int32), qctx=qctx)
    return lg


def _prefill_then_step(params, cfg, prompt, qctx, chunk):
    state = init_decode_state(cfg, 1, 32, cache_dtype=jnp.float32)
    head = prompt[:-1]
    for c0 in range(0, len(head), chunk):
        toks = jnp.asarray([head[c0:c0 + chunk]], jnp.int32)
        _, state = prefill_step(params, cfg, state, toks, qctx=qctx)
    lg, _ = decode_step(params, cfg, state,
                        jnp.asarray([prompt[-1]], jnp.int32), qctx=qctx)
    return lg


@pytest.mark.parametrize("chunk", [3, 16])
def test_prefill_matches_stepping_fp(qsetup, chunk):
    cfg, qm = qsetup
    params = init_params(jax.random.PRNGKey(5), cfg)
    lg1 = _step_all(params, cfg, PROMPT, None)
    lg2 = _prefill_then_step(params, cfg, PROMPT, None, chunk)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg1),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ["qdq", "kernels"])
def test_prefill_matches_stepping_quant(qsetup, backend):
    cfg, qm = qsetup
    qctx = qm.qctx(backend=backend)
    lg1 = _step_all(qm.params, cfg, PROMPT, qctx)
    lg2 = _prefill_then_step(qm.params, cfg, PROMPT, qctx, chunk=4)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg1),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: chunked prefill dispatch count + correctness, input guards
# ---------------------------------------------------------------------------

def test_engine_prefill_is_chunked_not_per_token(qsetup):
    cfg, qm = qsetup
    eng = LLMEngine(qm.params, cfg, max_batch=2, max_len=32,
                    qctx=qm.qctx(), prefill_chunk=4)
    st = eng.add_request(PROMPT, SamplingParams(max_tokens=4))
    eng.run()
    # 7 prompt-head tokens, chunk=4 -> [4, 2, 1]: 3 dispatches, not 7
    assert eng.counters["prefill_dispatches"] == 3
    # and the result matches standalone per-token greedy decoding
    state = init_decode_state(cfg, 1, 32, cache_dtype=jnp.float32)
    lg = None
    for t in PROMPT:
        lg, state = decode_step(qm.params, cfg, state,
                                jnp.asarray([t], jnp.int32),
                                qctx=qm.qctx())
    ref = []
    for _ in range(4):
        nt = int(jnp.argmax(lg[0]))
        ref.append(nt)
        lg, state = decode_step(qm.params, cfg, state,
                                jnp.asarray([nt], jnp.int32),
                                qctx=qm.qctx())
    assert st.token_ids == ref


def test_chunk_plan_bounds_compiles_and_covers():
    for chunk in (1, 3, 4, 128):
        for n in (0, 1, 2, 5, 7, 127, 128, 255, 300):
            plan = LLMEngine._chunk_plan(n, chunk)
            assert sum(plan) == n
            # full chunks plus powers of two below chunk -> bounded
            # distinct shapes no matter the prompt-length mix
            assert all(s == chunk or (s < chunk and s & (s - 1) == 0)
                       for s in plan)


@pytest.mark.parametrize("spec_kw", [
    {"method": "dynamic"},
    {"input_quant": "dynamic"},
    {"input_quant": "log2"},
    {"input_quant": "asym_percentile"},
])
def test_engine_per_call_scales_keep_per_token_prefill(qsetup, spec_kw):
    cfg, qm = qsetup
    import dataclasses
    spec = dataclasses.replace(get_spec("quamba"), **spec_kw)
    qctx = {"mode": "quant", "spec": spec, **qm.qdata}
    eng = LLMEngine(qm.params, cfg, max_batch=1, max_len=32, qctx=qctx,
                    prefill_chunk=4)
    # per-call scales (dynamic method / per-tensor input_quant stats):
    # chunked prefill would see chunk-wide statistics, so the engine
    # must keep the per-token path
    assert eng._prefill_fn is None
    # the chunk-invariant default does use the sequence path
    eng2 = LLMEngine(qm.params, cfg, max_batch=1, max_len=32,
                     qctx=qm.qctx(), prefill_chunk=4)
    assert eng2._prefill_fn is not None


def test_generate_rejects_empty_inputs(qsetup):
    cfg, qm = qsetup
    with pytest.raises(ValueError, match="prompts is empty"):
        generate(qm.params, cfg, [])
    with pytest.raises(ValueError, match="prompts\\[1\\] is empty"):
        generate(qm.params, cfg, [[1], []])
    eng = LLMEngine(qm.params, cfg, max_batch=1, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.add_request(Request([]))
