"""Batched top-k/top-p sampler: mask semantics against a numpy
reference, support membership, and golden-distribution checks."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.serve.sampler import apply_top_k_top_p, sample, sample_batched

pytestmark = pytest.mark.serve


# ---------------------------------------------------------------------------
# numpy reference (independent implementation of the same semantics)
# ---------------------------------------------------------------------------

def _np_softmax(x):
    e = np.exp(x - np.max(x))
    return e / e.sum()


def _np_top_k_top_p(logits, k, p):
    """Reference mask: keep the top-k logits AND the nucleus (smallest
    prefix of the sorted distribution with mass >= p; a token stays
    while the mass before it is < p); rank 0 always survives."""
    v = len(logits)
    order = np.argsort(-logits, kind="stable")
    ranked = logits[order]
    k_eff = v if (k <= 0 or k > v) else k
    keep = np.arange(v) < k_eff
    probs = _np_softmax(ranked)
    cum = np.cumsum(probs)
    keep &= (cum - probs) < p
    keep[0] = True
    out = np.full(v, -np.inf, dtype=logits.dtype)
    out[order[keep]] = logits[order[keep]]
    return out


def _np_expected_dist(logits, temp, k, p):
    """Token distribution the sampler should draw from."""
    scaled = logits / max(temp, 1e-4)
    masked = _np_top_k_top_p(scaled, k, p)
    finite = np.isfinite(masked)
    probs = np.zeros_like(scaled)
    probs[finite] = _np_softmax(masked[finite])
    return probs


# ---------------------------------------------------------------------------
# mask semantics
# ---------------------------------------------------------------------------

def test_mask_matches_numpy_reference():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(6, 37)).astype(np.float32)
    top_k = np.array([0, 1, 3, 37, 100, 10], np.int32)
    top_p = np.array([1.0, 1.0, 0.5, 0.9, 0.3, 0.75], np.float32)
    got = np.asarray(apply_top_k_top_p(jnp.asarray(logits),
                                       jnp.asarray(top_k),
                                       jnp.asarray(top_p)))
    for b in range(6):
        ref = _np_top_k_top_p(logits[b], int(top_k[b]), float(top_p[b]))
        np.testing.assert_array_equal(np.isfinite(got[b]),
                                      np.isfinite(ref), err_msg=f"row {b}")
        fin = np.isfinite(ref)
        np.testing.assert_allclose(got[b][fin], ref[fin], rtol=1e-6,
                                   err_msg=f"row {b}")


def test_disabled_mask_is_identity():
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(3, 16)).astype(np.float32)
    got = np.asarray(apply_top_k_top_p(
        jnp.asarray(logits), jnp.zeros((3,), jnp.int32),
        jnp.ones((3,), jnp.float32)))
    np.testing.assert_allclose(got, logits, rtol=1e-6)


def test_tiny_top_p_keeps_exactly_the_argmax():
    rng = np.random.default_rng(2)
    logits = rng.normal(size=(4, 12)).astype(np.float32)
    got = np.asarray(apply_top_k_top_p(
        jnp.asarray(logits), jnp.zeros((4,), jnp.int32),
        jnp.full((4,), 1e-6, jnp.float32)))
    for b in range(4):
        fin = np.isfinite(got[b])
        assert fin.sum() == 1 and fin[np.argmax(logits[b])]


# ---------------------------------------------------------------------------
# sampling support + golden distribution
# ---------------------------------------------------------------------------

def _draws(logits_row, temp, k, p, n=4000, seed=0):
    """n independent draws via the batch dimension (one jitted call)."""
    logits = jnp.tile(jnp.asarray(logits_row)[None, :], (n, 1))
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    toks = sample_batched(keys, logits,
                          jnp.full((n,), temp, jnp.float32),
                          jnp.full((n,), k, jnp.int32),
                          jnp.full((n,), p, jnp.float32))
    return np.asarray(toks)


def test_top_k_support():
    rng = np.random.default_rng(3)
    row = rng.normal(size=17).astype(np.float32)
    allowed = set(np.argsort(-row)[:3].tolist())
    toks = _draws(row, temp=1.0, k=3, p=1.0, n=500)
    assert set(toks.tolist()) <= allowed


def test_top_p_support():
    rng = np.random.default_rng(4)
    row = rng.normal(size=17).astype(np.float32)
    probs = _np_expected_dist(row, 1.0, 0, 0.7)
    allowed = set(np.flatnonzero(probs > 0).tolist())
    toks = _draws(row, temp=1.0, k=0, p=0.7, n=500)
    assert set(toks.tolist()) <= allowed


@pytest.mark.parametrize("temp,k,p", [
    (1.0, 4, 1.0),        # pure top-k
    (1.0, 0, 0.85),       # pure nucleus
    (0.7, 5, 0.9),        # combined, sharpened
    (1.5, 0, 1.0),        # plain temperature
])
def test_golden_distribution(temp, k, p):
    """Empirical frequencies match the numpy-reference truncated
    distribution within ~4 sigma of the binomial sampling noise."""
    rng = np.random.default_rng(5)
    row = rng.normal(size=8).astype(np.float32)
    expect = _np_expected_dist(row, temp, k, p)
    n = 4000
    toks = _draws(row, temp, k, p, n=n, seed=42)
    freq = np.bincount(toks, minlength=len(row)) / n
    assert freq[expect == 0].sum() == 0.0       # support is exact
    tol = 4 * np.sqrt(np.maximum(expect * (1 - expect), 1e-12) / n)
    np.testing.assert_array_less(np.abs(freq - expect), tol + 1e-9)


def test_greedy_rows_ignore_sampling_config():
    rng = np.random.default_rng(6)
    logits = rng.normal(size=(5, 11)).astype(np.float32)
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    toks = sample_batched(keys, jnp.asarray(logits),
                          jnp.zeros((5,), jnp.float32),
                          jnp.full((5,), 2, jnp.int32),
                          jnp.full((5,), 0.5, jnp.float32))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.argmax(logits, axis=-1))


def test_mixed_greedy_and_sampled_rows():
    rng = np.random.default_rng(7)
    logits = rng.normal(size=(4, 9)).astype(np.float32)
    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    temps = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32)
    toks = np.asarray(sample_batched(
        keys, jnp.asarray(logits), temps,
        jnp.zeros((4,), jnp.int32), jnp.ones((4,), jnp.float32)))
    assert toks[0] == np.argmax(logits[0])
    assert toks[2] == np.argmax(logits[2])
    assert all(0 <= t < 9 for t in toks)


def test_same_key_same_draw():
    rng = np.random.default_rng(8)
    row = rng.normal(size=13).astype(np.float32)
    a = _draws(row, 1.0, 5, 0.9, n=16, seed=3)
    b = _draws(row, 1.0, 5, 0.9, n=16, seed=3)
    np.testing.assert_array_equal(a, b)


def test_legacy_sample_shim_greedy():
    rng = np.random.default_rng(9)
    logits = rng.normal(size=(3, 21)).astype(np.float32)
    toks = np.asarray(sample(jax.random.PRNGKey(0),
                             jnp.asarray(logits), 0.0))
    np.testing.assert_array_equal(toks, np.argmax(logits, axis=-1))
    # temperature path still yields valid in-range tokens
    toks = np.asarray(sample(jax.random.PRNGKey(0),
                             jnp.asarray(logits), 0.8))
    assert toks.shape == (3,) and all(0 <= t < 21 for t in toks)
