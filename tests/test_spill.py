"""Host-RAM spill tier of the prefix ``StateCache``: pure two-tier
semantics with injected tree movers, promote-on-hit, host-side LRU,
and end-to-end engine runs where evicted prefixes still hit (promoted
from host) with token streams bit-identical to cache-off."""
import numpy as np
import jax
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.models import init_params
from repro.serve import LLMEngine, SamplingParams, StateCache


def _state(n_floats: int):
    return {"h": np.arange(n_floats, dtype=np.float32)}


class _Movers:
    """Injected to_host/to_device pair that counts crossings."""

    def __init__(self):
        self.to_host_calls = 0
        self.to_device_calls = 0

    def to_host(self, tree):
        self.to_host_calls += 1
        return {k: np.asarray(v) for k, v in tree.items()}

    def to_device(self, tree):
        self.to_device_calls += 1
        return dict(tree)


def _cache(device_entries=2, spill_entries=16, mv=None):
    mv = mv or _Movers()
    return StateCache(byte_budget=device_entries * 16,
                      spill_byte_budget=spill_entries * 16,
                      to_host=mv.to_host, to_device=mv.to_device), mv


# ---------------------------------------------------------------------------
# pure two-tier semantics (no engine, no jax)
# ---------------------------------------------------------------------------

def test_eviction_spills_instead_of_dropping():
    c, mv = _cache(device_entries=2)
    c.insert([1], _state(4))
    c.insert([2], _state(4))
    c.insert([3], _state(4))          # over budget: [1] spills to host
    s = c.stats()
    assert s["entries"] == 2 and s["host_entries"] == 1
    assert s["spills"] == 1 and s["spilled_bytes"] == 16
    assert s["evicted"] == 1 and s["host_evicted"] == 0
    assert mv.to_host_calls == 1
    # the spilled prefix is still "in" the cache, both tiers visible
    assert [1] in c and [2] in c and [3] in c


def test_promote_on_lookup_restores_device_residency():
    c, mv = _cache(device_entries=2)
    for t in ([1], [2], [3]):         # [1] ends up spilled
        c.insert(t, _state(4))
    e = c.lookup([1, 99])             # host match -> promotion
    assert e is not None and e.tokens == (1,)
    s = c.stats()
    assert s["promotions"] == 1 and s["promoted_bytes"] == 16
    assert mv.to_device_calls == 1
    # promotion made room by re-spilling the device LRU ([2])
    assert s["entries"] == 2 and s["spills"] == 2
    assert c.lookup([2, 99]) is not None      # ...which still hits
    assert c.stats()["promotions"] == 2


def test_device_tier_wins_ties_no_promotion():
    c, mv = _cache(device_entries=2)
    c.insert([1], _state(4))
    assert c.lookup([1, 5]) is not None
    assert c.stats()["promotions"] == 0 and mv.to_device_calls == 0


def test_host_tier_lru_overflow_is_a_true_drop():
    c, _ = _cache(device_entries=1, spill_entries=2)
    for t in ([1], [2], [3], [4]):    # 3 spills into a 2-entry host tier
        c.insert(t, _state(4))
    s = c.stats()
    assert s["entries"] == 1 and s["host_entries"] == 2
    assert s["spills"] == 3 and s["host_evicted"] == 1
    assert [1] not in c               # the oldest spill fell off the end
    assert [2] in c and [3] in c and [4] in c
    assert c.lookup([1, 7]) is None   # a dropped entry is a real miss
    assert c.stats()["misses"] == 1


def test_reinsert_supersedes_stale_host_copy():
    c, _ = _cache(device_entries=1)
    c.insert([1], _state(4))
    c.insert([2], _state(4))          # [1] spills
    assert c.stats()["host_entries"] == 1
    fresh = _state(4)
    assert c.insert([1], fresh)       # fresh device copy...
    s = c.stats()
    assert s["host_entries"] == 1     # ...[2] spilled, stale [1] gone
    assert c.lookup([1, 9]).state is fresh
    assert c.stats()["promotions"] == 0


def test_spill_disabled_is_plain_lru():
    c = StateCache(byte_budget=16)    # no spill budget
    c.insert([1], _state(4))
    c.insert([2], _state(4))
    s = c.stats()
    assert s["spill_enabled"] is False
    assert s["evicted"] == 1 and s["spills"] == 0
    assert s["host_entries"] == 0 and [1] not in c


def test_clear_empties_both_tiers():
    c, _ = _cache(device_entries=1)
    c.insert([1], _state(4))
    c.insert([2], _state(4))
    c.clear()
    s = c.stats()
    assert s["entries"] == 0 and s["host_entries"] == 0
    assert c.bytes_in_use == 0 and c.host_bytes_in_use == 0
    assert c.lookup([1, 2]) is None


def test_peek_len_never_promotes():
    c, mv = _cache(device_entries=1)
    c.insert([1, 2], _state(4))
    c.insert([3, 4], _state(4))       # [1, 2] spills
    assert c.peek_len([1, 2, 9]) == 2
    assert c.stats()["promotions"] == 0 and mv.to_device_calls == 0


def test_engine_rejects_spill_without_device_cache():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="spill"):
        LLMEngine(params, cfg, max_batch=1, max_len=32,
                  prefix_cache_spill_mb=8)


# ---------------------------------------------------------------------------
# engine integration: eviction pressure + promote-on-hit, bit-identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _entry_mb(cfg, entries: float) -> float:
    di, ds, w = cfg.d_inner, cfg.d_state, cfg.conv_width
    return entries * cfg.n_layers * (di * ds + (w - 1) * di) * 4 / (1 << 20)


def _serve_rounds(cfg, params, n_prefixes=3, rounds=2, **cache_kw):
    """Round-robin over ``n_prefixes`` shared heads, twice: round 2
    re-visits prefixes that round 1's insertions evicted."""
    eng = LLMEngine(params, cfg, max_batch=2, max_len=48,
                    prefill_chunk=8, **cache_kw)
    streams = []
    for _ in range(rounds):
        for i in range(n_prefixes):
            head = [(7 * i + 3 * j + 1) % cfg.vocab_size
                    for j in range(17)]
            st = eng.add_request(head + [i + 1, 2],
                                 SamplingParams(max_tokens=3))
            eng.run()
            streams.append(list(st.token_ids))
    assert eng.scheduler.outstanding() == []
    return eng, streams


def test_spilled_prefixes_still_hit_streams_identical(setup):
    cfg, params = setup
    tiny = _entry_mb(cfg, 1.6)        # device holds ~1.6 snapshots
    eng_spill, s_spill = _serve_rounds(
        cfg, params, prefix_cache_mb=tiny, prefix_cache_spill_mb=32)
    _, s_off = _serve_rounds(cfg, params)
    _, s_dev = _serve_rounds(cfg, params, prefix_cache_mb=32)

    pc = eng_spill.metrics_json()["prefix_cache"]
    assert pc["spill_enabled"] and pc["evicted"] > 0
    assert pc["spills"] > 0 and pc["spilled_bytes"] > 0
    # round 2 hit prefixes the device tier had already evicted
    assert pc["promotions"] > 0 and pc["promoted_bytes"] > 0
    assert pc["hits"] + pc["partial_hits"] > 0
    # restore-from-host must not change a single token
    assert s_spill == s_off == s_dev


def test_eviction_readmission_churn_keeps_accounting_exact(setup):
    cfg, params = setup
    tiny = _entry_mb(cfg, 1.2)
    eng, _ = _serve_rounds(cfg, params, n_prefixes=4, rounds=3,
                           prefix_cache_mb=tiny,
                           prefix_cache_spill_mb=_entry_mb(cfg, 2.5))
    c = eng.prefix_cache
    # byte accounting stays exact under spill/promote/drop churn
    assert c.bytes_in_use == sum(e.nbytes for e in c._entries.values())
    assert c.host_bytes_in_use == sum(e.nbytes
                                      for e in c._host.values())
    assert c.bytes_in_use <= c.byte_budget
    assert c.host_bytes_in_use <= c.spill_byte_budget
    s = c.stats()
    assert s["spills"] > 0 and s["host_evicted"] > 0   # host churned too
    assert eng.scheduler.outstanding() == []
