"""QAT recovery pass (PR 10): STE gradient correctness and the
end-to-end recovery contract.

Three layers of guarantees:

1. Quantizer-level: the STE-composed ``qdq`` / ``quantize_weight(ste=
   True)`` forward is bit-identical to the integer round trip, and its
   gradients match finite differences.  The FD trick: stepping ``x`` by
   exactly one LSB (``h = scale``) shifts ``round(x/s)`` by exactly 1,
   so the *true* finite difference of the fake-quant equals the STE
   surrogate (1 inside the representable range, 0 in saturation) --
   away from the clip boundary the STE is not an approximation at the
   grid's own step size, it is exact.
2. Site-map level: for every registered trainable weight/fake-quant
   site of all 7 families, the site's actual tensor + scale pass the FD
   check, and ``jax.grad`` of the full QAT loss delivers a nonzero
   gradient to the site's fp parameter.  ``trainable=False`` provably
   blocks the gradient.
3. Pipeline level: the STE training forward equals the deployed PTQ qdq
   forward; ``Quantizer.finetune`` recovers >= 50% of the w4a4 PTQ
   eval-loss gap on the synthetic corpus; the finetuned artifact
   save/load round-trips and runs on the kernels backend.
"""
import dataclasses
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config, scale_down
from repro.data import batches, eval_batches
from repro.models import init_params, loss_fn
from repro.optim import OptimConfig
from repro.quant import quantizers as Q
from repro.quant.hadamard import fold_hadamard_into_weight
from repro.quant.recipe import (get_spec, kernel_backend_fallback_reason,
                                quantize_weight, unpack_int4)
from repro.quant.sitemap import (BlockSites, FakeQuantSite, ScaleSite,
                                 WeightSite, get_site_map, quantize_block,
                                 quantize_with_site_map,
                                 trainable_scale_overrides)
from repro.train.qat import (QATConfig, init_qat_state, make_qat_loss,
                             make_qat_step, qat_eval_loss,
                             qat_optim_config)
from repro.train.step import init_train_state, make_train_step

jax.config.update("jax_platform_name", "cpu")

FAMILY_ARCHS = {
    "mamba": "mamba-130m",
    "dense": "llama3-8b",
    "moe": "qwen3-moe-30b-a3b",
    "hybrid": "zamba2-1.2b",
    "ssm": "xlstm-1.3b",
    "audio": "whisper-medium",
    "vlm": "paligemma-3b",
}


def _batch(cfg, key, b=2, l=16):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (b, 24, cfg.d_model)),
                "tokens": jax.random.randint(key, (b, 8), 0,
                                             cfg.vocab_size),
                "targets": jax.random.randint(key, (b, 8), 0,
                                              cfg.vocab_size)}
    if cfg.family == "vlm":
        lt = max(l, cfg.prefix_len + 8) - cfg.prefix_len
        return {"patches": jax.random.normal(
                    key, (b, cfg.prefix_len, cfg.d_model)),
                "tokens": jax.random.randint(key, (b, lt), 0,
                                             cfg.vocab_size),
                "targets": jax.random.randint(key, (b, lt), 0,
                                              cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (b, l), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (b, l), 0,
                                          cfg.vocab_size)}


_FAMILY_CACHE = {}


def _family_setup(family):
    """(cfg, params, stats, batch) per family, built once per run."""
    if family not in _FAMILY_CACHE:
        cfg = scale_down(get_config(FAMILY_ARCHS[family]), layers=2,
                         width=64, vocab=128)
        params = init_params(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, jax.random.PRNGKey(3))
        stats = api.calibration_stats(cfg, params, [batch])
        _FAMILY_CACHE[family] = (cfg, params, stats, batch)
    return _FAMILY_CACHE[family]


# ---------------------------------------------------------------------------
# quantizer-level STE: forward bit-identity + gradients vs FD
# ---------------------------------------------------------------------------

def test_round_ste_value_and_gradient():
    x = jnp.asarray(np.random.default_rng(0).normal(size=64) * 3,
                    jnp.float32)
    np.testing.assert_array_equal(np.asarray(Q.round_ste(x)),
                                  np.asarray(jnp.round(x)))
    g = jax.grad(lambda v: jnp.sum(Q.round_ste(v)))(x)
    np.testing.assert_array_equal(np.asarray(g), np.ones(64, np.float32))


@pytest.mark.parametrize("bits", [4, 8])
def test_qdq_forward_bit_identical_to_integer_round_trip(bits):
    """The STE recomposition must not move the PTQ forward by one ulp."""
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32) * 2)
    s = 0.05
    got = Q.qdq(x, s, bits=bits)
    want = Q.dequantize(Q.quantize(x, s, bits=bits), s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [4, 8])
def test_qdq_grad_x_is_clipped_ste_and_matches_fd(bits):
    """FD with h = one LSB is *exact* for the fake-quant away from the
    clip boundary: round((x+s)/s) = round(x/s) + 1, so the secant slope
    is exactly 1 inside the range and exactly 0 in deep saturation --
    the STE surrogate coincides with the true finite difference."""
    qmax = 2.0 ** (bits - 1) - 1.0
    qmin = -(2.0 ** (bits - 1))
    s = 0.07
    rng = np.random.default_rng(5)
    z = rng.uniform(qmin * 1.6, qmax * 1.6, size=512)
    z = z[np.abs(z - np.round(z)) > 0.1]            # stay off round ties
    x = jnp.asarray((z * s).astype(np.float32))

    g = jax.grad(lambda v: jnp.sum(Q.qdq(v, s, bits=bits)))(x)
    g = np.asarray(g)
    inside = (z > qmin + 2.0) & (z < qmax - 2.0)
    saturated = (z < qmin - 2.0) | (z > qmax + 2.0)
    assert inside.any() and saturated.any()
    np.testing.assert_array_equal(g[inside], 1.0)
    np.testing.assert_array_equal(g[saturated], 0.0)

    fd = (np.asarray(Q.qdq(x + s, s, bits=bits))
          - np.asarray(Q.qdq(x - s, s, bits=bits))) / (2.0 * s)
    np.testing.assert_allclose(fd[inside], g[inside], atol=1e-4)
    np.testing.assert_allclose(fd[saturated], g[saturated], atol=1e-4)


@pytest.mark.parametrize("bits", [4, 8])
def test_qdq_grad_scale_matches_lsq_closed_form(bits):
    """d qdq/d s under the STE composition is the LSQ gradient:
    round(z) - z inside the range, qmax/qmin at saturation.  The
    saturated branch is genuinely linear in s (value = qmax * s), so FD
    verifies it directly."""
    qmax = 2.0 ** (bits - 1) - 1.0
    qmin = -(2.0 ** (bits - 1))
    s0 = 0.1
    rng = np.random.default_rng(6)
    z = rng.uniform(qmin * 1.5, qmax * 1.5, size=256)
    z = z[np.abs(z - np.round(z)) > 0.1]
    x = jnp.asarray((z * s0).astype(np.float32))

    g = float(jax.grad(
        lambda s: jnp.sum(Q.qdq(x, s, bits=bits)))(jnp.float32(s0)))
    zc = np.clip(z, qmin, qmax)
    expected = np.where(z > qmax, qmax,
                        np.where(z < qmin, qmin, np.round(zc) - zc))
    np.testing.assert_allclose(g, expected.sum(), rtol=1e-4)

    sat = jnp.asarray((z[z > qmax + 1.0] * s0).astype(np.float32))
    if sat.size:
        # float32 under the hood (x64 off): the 1/(2h) division turns
        # ulp-level sum noise into ~5e-5 relative, hence the tolerance
        h = 1e-4
        fd = (float(jnp.sum(Q.qdq(sat, s0 + h, bits=bits)))
              - float(jnp.sum(Q.qdq(sat, s0 - h, bits=bits)))) / (2 * h)
        np.testing.assert_allclose(fd, qmax * sat.size, rtol=1e-3)


def test_qdq_asymmetric_keeps_tie_breaking_and_clipped_ste():
    """The STE goes on the *inner* round -- round(x/s) + zp, not
    round(x/s + zp) -- because banker's rounding breaks otherwise:
    round(0.5) + 3 = 3 but round(0.5 + 3) = 4.  Exact half-LSB inputs
    pin the composition order."""
    s, zp = 0.25, 3.0
    x = jnp.asarray([0.125, -0.125, 0.375, 0.625, 1.0], jnp.float32)
    got = np.asarray(Q.qdq_asymmetric(x, s, zp, bits=8))
    q = np.clip(np.round(np.asarray(x) / s) + zp, -128, 127)
    np.testing.assert_array_equal(got, ((q - zp) * s).astype(np.float32))
    # the broken composition would disagree on the ties
    assert (np.round(np.asarray(x) / s + zp) != q).any()
    g = np.asarray(jax.grad(
        lambda v: jnp.sum(Q.qdq_asymmetric(v, s, zp, bits=8)))(x))
    np.testing.assert_array_equal(g, np.ones(5, np.float32))


@pytest.mark.parametrize("preset", ["quamba", "quamba-w4a8"])
def test_quantize_weight_ste_matches_int_path_and_passes_grad(preset):
    spec = get_spec(preset)
    w = jax.random.normal(jax.random.PRNGKey(2), (33, 17))
    ste = quantize_weight(w, spec, ste=True)
    ref = quantize_weight(w, spec, storage="int8")
    assert set(ste) == {"qw", "s_w"}              # float grid, never packed
    assert ste["qw"].dtype == w.dtype
    np.testing.assert_array_equal(np.asarray(ste["qw"]),
                                  np.asarray(ref["qw"]).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(ste["s_w"]),
                                  np.asarray(ref["s_w"]))
    # grad of the dequantized site w.r.t. the fp weight: s_w is frozen,
    # so d/dw sum(qw * s_w) = 1 everywhere inside the representable
    # range (the abs-max scale puts every value inside by construction)
    g = np.asarray(jax.grad(lambda v: jnp.sum(
        quantize_weight(v, spec, ste=True)["qw"]
        * quantize_weight(v, spec, ste=True)["s_w"]))(w))
    assert np.mean(np.abs(g - 1.0) < 1e-5) > 0.99


# ---------------------------------------------------------------------------
# site-map level: FD per registered site, grad flow, trainable=False
# ---------------------------------------------------------------------------

def _weight_site_tensors(site_map, params, spec):
    """Yield (label, tensor_2d, trainable) for every weight/fake-quant
    site: the actual (possibly Hadamard-folded) tensor the fake-quant
    sees, one layer slice."""
    def first_slice(arr, ndim=2):
        while arr.ndim > ndim:
            arr = arr[0]
        return arr

    for section in site_map.sections:
        p_sec = params[section.params_key]

        def emit(sites, src, prefix):
            for site in sites:
                if isinstance(site, WeightSite):
                    name = site.param or site.name
                    w = first_slice(src[name])
                    if site.fold_hadamard:
                        w = fold_hadamard_into_weight(w, axis=0)
                    yield f"{prefix}/{name}", w, site.trainable
                elif isinstance(site, FakeQuantSite):
                    yield (f"{prefix}/{site.param}",
                           first_slice(src[site.param]), site.trainable)

        yield from emit(section.block.weights + section.block.fakequant,
                        p_sec, section.params_key)
        for grp in section.block.groups:
            src = p_sec[grp.subtree] if grp.subtree else p_sec
            yield from emit(grp.weights + grp.fakequant, src,
                            f"{section.params_key}/{grp.name}")


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_fd_ste_gradient_every_registered_site(family):
    """For every registered trainable site of the family: FD of the
    fake-quant on the site's actual tensor, at step h = its own scale,
    equals the STE gradient on non-max coordinates.  (The linear
    Hadamard fold ahead of some sites is exact under autodiff; this
    pins the non-differentiable rounding step itself.)"""
    cfg, params, _, _ = _family_setup(family)
    spec = get_spec("quamba-w4a4")
    sites = list(_weight_site_tensors(get_site_map(cfg.family), params,
                                      spec))
    assert sites, f"{family}: no weight sites registered?"
    rng = np.random.default_rng(8)
    for label, w, trainable in sites:
        if not trainable:
            continue
        w = jnp.asarray(np.asarray(w), jnp.float32)
        s = float(Q.symmetric_scale(w, bits=spec.w_bits))
        f = lambda v: jnp.sum(Q.qdq(v, s, bits=spec.w_bits))
        g = np.asarray(jax.grad(f)(w))
        flat = np.asarray(w).reshape(-1)
        # probe coordinates whose |w| stays below half the abs-max (so
        # the +-1 LSB step can neither clip nor alter the scale) and
        # whose grid position is away from a rounding tie (where fp
        # error in (w +- s)/s could land on either side of the tie)
        z = flat / s
        ok = ((np.abs(flat) < 0.5 * np.abs(flat).max())
              & (np.abs(z - np.round(z) - 0.5) > 0.05)
              & (np.abs(z - np.round(z) + 0.5) > 0.05))
        cand = np.flatnonzero(ok)[:64]
        assert cand.size, f"{family} {label}: no probe coordinates"
        idx = rng.choice(cand, size=min(4, cand.size), replace=False)
        for i in idx:
            e = np.zeros(w.size, np.float32)
            e[i] = s
            e = jnp.asarray(e.reshape(w.shape))
            fd = (float(f(w + e)) - float(f(w - e))) / (2.0 * s)
            np.testing.assert_allclose(
                fd, g.reshape(-1)[i], atol=1e-3,
                err_msg=f"{family} {label} coord {i}")


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_qat_gradient_reaches_every_trainable_site(family):
    """Gradient flows through the STE quantize map to the fp parameter
    of every registered trainable weight/fake-quant site.

    Checked *at the quantize map*, not through the full model loss: a
    weight site's end-to-end gradient is x_hat^T delta, which vanishes
    legitimately whenever the site's quantized input activation
    collapses (a random-init tiny block can hit that at A4, and the
    mLSTM's saturating gates can starve a site even at A8) -- so
    per-site liveness of the *loss* gradient is a property of model
    conditioning, not of the QAT plumbing.  What the plumbing must
    guarantee is that ``jax.grad`` of each site's STE output reaches
    that site's fp parameter: the STE mask is 1 wherever the weight is
    inside the clip range, so this gradient is deterministically
    nonzero for calibrated scales.  A zero here is a real break -- a
    stray stop_gradient, or a registered site the walker never touches.

    The full QAT loss is then checked end to end at the looser, always
    valid level: finite everywhere, globally nonzero, and the learnable
    scale leaves live."""
    cfg, params, stats, batch = _family_setup(family)
    spec = get_spec("quamba-w4a4")
    site_map = get_site_map(cfg.family)

    def site_grad(out_path, param_path):
        def readout(p):
            new_params, qdata = quantize_with_site_map(
                p, stats, cfg, spec, ste=True)
            leaf = {"params": new_params, "qdata": qdata}
            for k in out_path:
                leaf = leaf[k]
            return jnp.sum(leaf.astype(jnp.float32))

        g = jax.grad(readout)(params)
        for k in param_path:
            g = g[k]
        return np.asarray(g)

    checked = 0
    for section in site_map.sections:
        sec = section.params_key

        def check(holder, qw_prefix, param_prefix):
            nonlocal checked
            for site in holder.weights:
                if not site.trainable:
                    continue
                pname = site.param or site.name
                label = f"{family} {sec}/{'/'.join(qw_prefix)}{site.name}"
                arr = site_grad(
                    ("qdata", "qw", sec) + qw_prefix + (site.name, "qw"),
                    (sec,) + param_prefix + (pname,))
                assert np.isfinite(arr).all(), label
                assert np.abs(arr).max() > 0, \
                    f"no gradient reaches {label}"
                checked += 1
            for site in holder.fakequant:
                if not site.trainable:
                    continue
                label = f"{family} {sec}/{'/'.join(param_prefix)}" \
                        f"{site.param} (fakequant)"
                arr = site_grad(
                    ("params", sec) + param_prefix + (site.param,),
                    (sec,) + param_prefix + (site.param,))
                assert np.isfinite(arr).all(), label
                assert np.abs(arr).max() > 0, \
                    f"no gradient reaches {label}"
                checked += 1

        check(section.block, (), ())
        for grp in section.block.groups:
            check(grp, (grp.name,),
                  (grp.subtree,) if grp.subtree else ())
    assert checked > 0, f"{family}: no trainable sites walked"

    # end-to-end smoke on the actual training objective
    qat = QATConfig(learn_scales=True)
    state = init_qat_state(params, cfg, spec, stats, qat)
    loss = make_qat_loss(cfg, spec, stats)
    grads = jax.grad(lambda t: loss(t, batch)[0])(state["trainable"])
    leaves = [np.asarray(l) for l in jax.tree.leaves(grads["params"])]
    assert all(np.isfinite(a).all() for a in leaves)
    assert max(np.abs(a).max() for a in leaves) > 0, \
        f"{family}: full QAT loss gradient is identically zero"

    scale_g = [np.asarray(l) for l in jax.tree.leaves(grads["scales"])]
    assert scale_g, f"{family}: learn_scales produced no scale leaves"
    assert all(np.isfinite(a).all() for a in scale_g)
    assert max(np.abs(a).max() for a in scale_g) > 0


def test_trainable_false_blocks_weight_and_fakequant_gradient():
    spec = get_spec("quamba-w4a8")
    w0 = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
    c0 = jax.random.normal(jax.random.PRNGKey(5), (4, 16))

    def total(w, c, trainable):
        block = BlockSites(
            weights=(WeightSite("w", trainable=trainable),),
            fakequant=(FakeQuantSite("c", trainable=trainable),))
        p, _, qw = quantize_block(block, {"w": w, "c": c}, {}, spec,
                                  stacked=False, ste=True)
        return (jnp.sum(qw["w"]["qw"] * qw["w"]["s_w"])
                + jnp.sum(p["c"]))

    gw, gc = jax.grad(lambda w, c: total(w, c, True), argnums=(0, 1))(
        w0, c0)
    assert float(jnp.abs(gw).max()) > 0 and float(jnp.abs(gc).max()) > 0
    gw, gc = jax.grad(lambda w, c: total(w, c, False), argnums=(0, 1))(
        w0, c0)
    assert float(jnp.abs(gw).max()) == 0 and float(jnp.abs(gc).max()) == 0


def test_trainable_false_blocks_scale_override_gradient():
    spec = get_spec("quamba")
    s0 = jnp.float32(0.2)
    for trainable, want in ((True, 1.0), (False, 0.0)):
        block = BlockSites(scales=(ScaleSite("x", trainable=trainable),))
        g = jax.grad(lambda s: jnp.sum(quantize_block(
            block, {}, {}, spec, stacked=False, ste=True,
            overrides={"x": s})[1]["x"]))(s0)
        assert float(g) == want


def test_scale_overrides_round_trip_is_identity():
    """Extracting the trainable scales from a PTQ pass and feeding them
    back unchanged must reproduce the PTQ qdata exactly (aliases keep
    resolving from the overridden values)."""
    cfg, params, stats, _ = _family_setup("mamba")
    spec = get_spec("quamba-w4a4")
    _, qdata = quantize_with_site_map(params, stats, cfg, spec)
    ov = trainable_scale_overrides(get_site_map(cfg.family),
                                   qdata["scales"])
    assert jax.tree.leaves(ov), "no trainable scales extracted"
    _, qdata2 = quantize_with_site_map(params, stats, cfg, spec,
                                       scale_overrides=ov)
    for a, b in zip(jax.tree.leaves(qdata["scales"]),
                    jax.tree.leaves(qdata2["scales"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# pipeline level: STE forward == PTQ forward, recovery, artifact
# ---------------------------------------------------------------------------

def test_ste_training_forward_equals_deployed_ptq_loss():
    """The loss QAT minimizes IS the deployed loss: at step 0 the STE
    forward must match the PTQ artifact's qdq forward on the same
    batch."""
    cfg, params, stats, batch = _family_setup("mamba")
    spec = get_spec("quamba-w4a4")
    ste_loss = float(make_qat_loss(cfg, spec, stats)(
        {"params": params}, batch)[0])
    qm = api.Quantizer(cfg, spec).with_stats(stats).quantize(params)
    ptq_loss = float(qm.loss(batch)[0])
    np.testing.assert_allclose(ste_loss, ptq_loss, rtol=0, atol=1e-6)


def test_qat_config_plumbing():
    qat = QATConfig(steps=40, lr=2e-3, warmup_frac=0.25, min_lr_ratio=0.2,
                    clip_norm=0.5)
    opt = qat_optim_config(qat)
    assert (opt.lr, opt.warmup_steps, opt.total_steps) == (2e-3, 10, 40)
    assert (opt.min_lr_ratio, opt.clip_norm) == (0.2, 0.5)
    cfg, params, stats, _ = _family_setup("mamba")
    with pytest.raises(ValueError, match="at least one batch"):
        qat_eval_loss(cfg, get_spec("quamba-w4a4"), stats,
                      {"params": params}, [])
    # fp specs have nothing to recover
    with pytest.raises(ValueError, match="nothing to recover"):
        api.Quantizer(cfg, "fp").finetune(params, [])


def test_qat_step_decreases_train_loss():
    cfg, params, stats, _ = _family_setup("mamba")
    spec = get_spec("quamba-w4a4")
    qat = QATConfig(steps=8, lr=1e-3, learn_scales=True)
    state = init_qat_state(params, cfg, spec, stats, qat)
    step = jax.jit(make_qat_step(cfg, spec, stats, qat))
    batch = next(iter(batches(cfg.vocab_size, 4, 32, seed=13,
                              num_steps=1)))
    first = None
    for _ in range(8):
        state, metrics = step(state, batch)
        first = first if first is not None else float(metrics["loss"])
    assert float(metrics["loss"]) < first


@pytest.fixture(scope="module")
def tiny_trained():
    """A small fp-trained mamba + calibration stats + eval split: the
    substrate for the recovery and artifact tests (and the source of
    the empirically-real w4a4 PTQ gap a random init would not show)."""
    cfg = scale_down(get_config("mamba-130m"), layers=2, width=64,
                     vocab=128)
    state = init_train_state(jax.random.PRNGKey(1), cfg)
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=3e-3, warmup_steps=20, total_steps=150, weight_decay=0.0)))
    for b in batches(cfg.vocab_size, 8, 64, seed=1, num_steps=150):
        state, _ = step(state, b)
    params = state["params"]
    calib = list(batches(cfg.vocab_size, 2, 32, seed=5, num_steps=4))
    stats = api.calibration_stats(cfg, params, calib)
    ev = list(eval_batches(cfg.vocab_size, 8, 64, 4))
    return cfg, params, stats, ev


def test_qat_recovers_half_the_w4a4_gap(tiny_trained):
    """The PR acceptance bar: on the synthetic-corpus smoke model, QAT
    closes >= 50% of the eval-loss gap between quamba-w4a4 PTQ and fp
    within a CI-budget step count."""
    cfg, params, stats, ev = tiny_trained
    fp = jax.jit(lambda p, b: loss_fn(p, cfg, b)[0])
    fp_loss = np.mean([float(fp(params, b)) for b in ev])

    quant = api.Quantizer(cfg, "quamba-w4a4").with_stats(stats)
    ptq = quant.quantize(params)
    pf = jax.jit(lambda p, b: loss_fn(p, cfg, b, qctx=ptq.qctx())[0])
    ptq_loss = np.mean([float(pf(ptq.params, b)) for b in ev])
    gap = ptq_loss - fp_loss
    assert gap > 0.1, f"w4a4 PTQ shows no real gap ({gap=})"

    qm = quant.finetune(
        params, batches(cfg.vocab_size, 8, 64, seed=3, num_steps=80),
        qat=QATConfig(steps=80, lr=1e-3, learn_scales=True),
        eval_batches=ev, log=lambda *_: None)
    qf = jax.jit(lambda p, b: loss_fn(p, cfg, b, qctx=qm.qctx())[0])
    qat_loss = np.mean([float(qf(qm.params, b)) for b in ev])
    recovery = (ptq_loss - qat_loss) / gap
    assert recovery >= 0.5, (
        f"QAT recovered only {recovery:.1%} of the w4a4 gap "
        f"(fp {fp_loss:.4f}, ptq {ptq_loss:.4f}, qat {qat_loss:.4f})")

    # history tracks the deployed loss: its start point is the PTQ loss
    # (same params, same scales), its end point is the artifact's loss
    h = qm.qat_history
    assert h["steps"] == 80 and h["learn_scales"]
    np.testing.assert_allclose(h["eval_loss_start"], ptq_loss, atol=1e-5)
    np.testing.assert_allclose(h["eval_loss_final"], qat_loss, atol=1e-5)


def test_finetuned_artifact_roundtrips_and_runs_on_kernels(
        tiny_trained, tmp_path):
    """finetune() output is an ordinary artifact: nibble-packed, saves,
    loads bit-identically, executes on the kernels backend with <= 1e-5
    parity against its own qdq forward.

    The parity comparison runs with ``forward(..., unroll=True)`` so the
    layer stack executes with op-by-op semantics, where the two backends
    are bit-identical.  Compiled as one lax.scan body, XLA:CPU's fusion
    emitter contracts cross-op mul+add pairs into fmas in the qdq path's
    float segments (conv taps, D*u) -- ``optimization_barrier`` does not
    stop it, and there is no flag -- shifting those floats by an ulp vs
    the interpret-mode kernels (opaque to fusion), which can flip a
    downstream requant that lands on a rounding tie.  Parity is a
    statement about the arithmetic the two backends perform, so it is
    asserted at op semantics, not at the mercy of fusion codegen."""
    from repro.models import forward
    cfg, params, stats, ev = tiny_trained
    spec = dataclasses.replace(get_spec("quamba-w4a8"), backend="kernels")
    qm = api.Quantizer(cfg, spec).with_stats(stats).finetune(
        params, batches(cfg.vocab_size, 8, 64, seed=4, num_steps=5),
        qat=QATConfig(steps=5, lr=1e-4, learn_scales=True),
        log=lambda *_: None)
    assert qm.describe()["effective_backend"] == "kernels"
    assert "qw4" in qm.qdata["qw"]["layers"]["in_proj"]

    path = os.path.join(str(tmp_path), "qat_w4a8")
    qm.save(path)
    qm2 = api.load(path)
    assert qm2.describe()["effective_backend"] == "kernels"

    batch = ev[0]
    lg_k, _ = forward(qm2.params, cfg, batch, qctx=qm2.qctx(),
                      unroll=True)
    lg_q, _ = forward(qm2.params, cfg, batch,
                      qctx=qm2.qctx(backend="qdq"), unroll=True)
    np.testing.assert_allclose(np.asarray(lg_k), np.asarray(lg_q),
                               rtol=1e-5, atol=1e-5)
    # and loading changed nothing about the numerics
    lg_orig, _ = forward(qm.params, cfg, batch, qctx=qm.qctx(),
                         unroll=True)
    np.testing.assert_array_equal(np.asarray(lg_k), np.asarray(lg_orig))


def test_w4a4_preset_registered_and_falls_back_to_qdq():
    spec = get_spec("quamba-w4a4")
    spec.validate()
    assert spec.w_bits == 4 and spec.a_bits == 4
    assert spec.soft_edge == 0.25
    reason = kernel_backend_fallback_reason(
        dataclasses.replace(spec, backend="kernels"))
    assert reason is not None and "a_bits=4" in reason
