"""Sharding rules + a real multi-device SPMD run (8 host devices in a
subprocess, since device count locks at first jax init)."""
import functools
import json
import os
import subprocess
import sys
import textwrap

import pytest

# without an explicit platform, jax probes for accelerator plugins in
# the subprocess and the tiny test models spend minutes not running
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SUBPROC_ENV = {"PYTHONPATH": "src",
                "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
                "HOME": os.environ.get("HOME", "/root"),
                "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}

# both tests exercise the repro.dist sharding rules, which are not
# present in every checkout yet; skip cleanly instead of failing
pytest.importorskip("repro.dist.sharding")

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, cell_supported


def test_specs_divide_for_all_archs():
    """Every param spec's sharded dims divide on the production mesh."""
    import jax
    from repro.dist.sharding import param_spec
    from jax.sharding import PartitionSpec

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    mesh = FakeMesh()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        from repro.models import init_params
        shapes = jax.eval_shape(
            functools.partial(init_params, cfg=cfg), jax.random.PRNGKey(0))
        flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
        for path, leaf in flat:
            spec = param_spec(path, leaf.shape, mesh, cfg, fsdp=True)
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                size = mesh.shape[ax] if isinstance(ax, str) else \
                    int(__import__("numpy").prod([mesh.shape[a]
                                                  for a in ax]))
                assert dim % size == 0, (arch, path, leaf.shape, spec)


_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, scale_down
    from repro.dist.sharding import (batch_shardings,
                                     train_state_shardings)
    from repro.optim.adamw import OptimConfig
    from repro.train.step import init_train_state, make_train_step
    from repro.data import batches

    cfg = scale_down(get_config("llama3-8b"), width=256)
    from repro.launch.mesh import _mk, use_mesh
    mesh = _mk((2, 4), ("data", "model"))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    shapes = jax.eval_shape(lambda: state)
    st_sh = train_state_shardings(shapes, mesh, cfg)
    (b,) = list(batches(cfg.vocab_size, 8, 32, seed=0, num_steps=1))
    b_sh = batch_shardings(jax.eval_shape(lambda: b), mesh)
    step = make_train_step(cfg, OptimConfig(total_steps=10))
    with use_mesh(mesh):
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh))
        state = jax.device_put(state, st_sh)
        b = jax.device_put(b, b_sh)
        new_state, metrics = jitted(state, b)
        loss = float(metrics["loss"])
    # unsharded single-device reference
    ref_state = init_train_state(jax.random.PRNGKey(0), cfg)
    ref_new, ref_m = jax.jit(step)(ref_state, b)
    print(json.dumps({
        "loss": loss, "ref_loss": float(ref_m["loss"]),
        "param_delta": max(jax.tree.leaves(jax.tree.map(
            lambda a, c: float(jnp.abs(a - c).max()),
            new_state["params"], ref_new["params"]))),
    }))
""")


def test_spmd_train_step_matches_single_device():
    """The sharded train step is numerically the single-device step."""
    r = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT],
                       capture_output=True, text=True,
                       env=dict(_SUBPROC_ENV),
                       cwd=_REPO_ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert abs(out["loss"] - out["ref_loss"]) < 1e-3
    assert out["param_delta"] < 1e-3


_ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import json
    import jax
    from repro.configs import get_config, scale_down
    from repro.models import init_params
    from repro.serve.engine import LLMEngine
    from repro.serve.params import SamplingParams

    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = LLMEngine(params, cfg, max_batch=4, max_len=64)
    states = [eng.add_request([2 + i, 5, 7],
                              SamplingParams(max_tokens=8))
              for i in range(6)]
    eng.run()
    print(json.dumps({"sharded": eng.mesh is not None,
                      "outputs": [list(s.token_ids) for s in states]}))
""")


def test_engine_dp_slot_sharding_matches_single_device():
    """With >1 device the LLMEngine spreads decode slots over the data
    axis (repro.dist.sharding rules) and greedy outputs are unchanged."""
    outs = []
    for ndev in (1, 2):
        r = subprocess.run([sys.executable, "-c", _ENGINE_SCRIPT % ndev],
                           capture_output=True, text=True,
                           env=dict(_SUBPROC_ENV),
                           cwd=_REPO_ROOT, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(json.loads(r.stdout.strip().splitlines()[-1]))
    assert outs[0]["sharded"] is False          # one device: inert
    assert outs[1]["sharded"] is True           # two devices: slots DP
    assert outs[0]["outputs"] == outs[1]["outputs"]
