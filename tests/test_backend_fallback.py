"""BackendFallbackWarning contract (PR 10): exactly one structured
warning per process per distinct reason (an engine calling ``qctx()``
per dispatch must not spam identical warnings, but a *new* reason from
a different artifact still surfaces), and ``describe()``'s effective
backend always matches what actually executes."""
import dataclasses
import warnings

import numpy as np
import jax
import pytest

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.kernels import ops as kops
from repro.models import forward
from repro.models import init_params
from repro.models.quantize import (make_qctx,
                                   reset_backend_fallback_warnings)
from repro.quant.recipe import BackendFallbackWarning, get_spec

jax.config.update("jax_platform_name", "cpu")

MATMUL_OPS = ("int8_matmul", "int4_matmul")


@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"), layers=2, width=64,
                     vocab=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    calib = list(eval_batches(cfg.vocab_size, 2, 32, 2, seed=7))
    stats = api.calibration_stats(cfg, params, calib)
    return cfg, params, stats


def _quantized(cfg, params, stats, preset, backend=None):
    spec = get_spec(preset)
    if backend is not None:
        spec = dataclasses.replace(spec, backend=backend)
    return api.Quantizer(cfg, spec).with_stats(stats).quantize(params)


def _count_matmuls(monkeypatch):
    counts = {name: 0 for name in MATMUL_OPS}
    for name in MATMUL_OPS:
        orig = getattr(kops, name)

        def wrap(*a, __o=orig, __n=name, **kw):
            counts[__n] += 1
            return __o(*a, **kw)

        monkeypatch.setattr(kops, name, wrap)
    return counts


# ---------------------------------------------------------------------------
# once-per-process-per-reason
# ---------------------------------------------------------------------------

def test_exactly_one_warning_per_reason(setup):
    cfg, params, stats = setup
    qm = _quantized(cfg, params, stats, "quamba-w4a4", backend="kernels")
    reset_backend_fallback_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(5):                   # per-dispatch qctx() calls
            make_qctx(qm.spec, qm.qdata)
    assert len(rec) == 1, [str(r.message) for r in rec]
    w = rec[0].message
    assert isinstance(w, BackendFallbackWarning)
    assert w.requested == "kernels" and w.effective == "qdq"
    assert "a_bits=4" in w.reason
    # the artifact's describe() names the same reason
    d = qm.describe()
    assert d["effective_backend"] == "qdq"
    assert d["backend_fallback_reason"] == w.reason


def test_new_reason_still_warns_after_earlier_one(setup):
    cfg, params, stats = setup
    qm_a4 = _quantized(cfg, params, stats, "quamba-w4a4",
                       backend="kernels")
    qm_rot = _quantized(cfg, params, stats, "quarot", backend="kernels")
    reset_backend_fallback_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        make_qctx(qm_a4.spec, qm_a4.qdata)   # reason 1: a_bits=4
        make_qctx(qm_a4.spec, qm_a4.qdata)   # repeat: silent
        make_qctx(qm_rot.spec, qm_rot.qdata)  # reason 2: quarot
        make_qctx(qm_rot.spec, qm_rot.qdata)  # repeat: silent
    reasons = [r.message.reason for r in rec]
    assert len(reasons) == 2, reasons
    assert "a_bits=4" in reasons[0] and "quarot" in reasons[1]


def test_reset_hook_rearms_the_warning(setup):
    cfg, params, stats = setup
    qm = _quantized(cfg, params, stats, "quamba-w4a4", backend="kernels")
    reset_backend_fallback_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        make_qctx(qm.spec, qm.qdata)
        reset_backend_fallback_warnings()
        make_qctx(qm.spec, qm.qdata)
    assert len(rec) == 2


def test_honored_kernels_request_never_warns(setup):
    cfg, params, stats = setup
    qm = _quantized(cfg, params, stats, "quamba-kernels")
    reset_backend_fallback_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("error", BackendFallbackWarning)
        qm.qctx()
        qm.qctx(backend="qdq")               # an explicit qdq request
        _quantized(cfg, params, stats, "quamba").qctx()


# ---------------------------------------------------------------------------
# describe()'s effective backend == what executed
# ---------------------------------------------------------------------------

def _run_forward(cfg, qm, qctx):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(9), (2, 16),
                                          0, cfg.vocab_size)}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BackendFallbackWarning)
        lg, _ = forward(qm.params, cfg, batch, qctx=qctx)
    return np.asarray(lg)


def test_effective_backend_kernels_actually_dispatches(setup,
                                                       monkeypatch):
    cfg, params, stats = setup
    qm = _quantized(cfg, params, stats, "quamba-kernels")
    assert qm.describe()["effective_backend"] == "kernels"
    counts = _count_matmuls(monkeypatch)
    _run_forward(cfg, qm, qm.qctx())
    assert counts["int8_matmul"] > 0, counts


def test_effective_backend_qdq_never_dispatches(setup, monkeypatch):
    cfg, params, stats = setup
    qm = _quantized(cfg, params, stats, "quamba")
    assert qm.describe()["effective_backend"] == "qdq"
    counts = _count_matmuls(monkeypatch)
    _run_forward(cfg, qm, qm.qctx())
    assert all(c == 0 for c in counts.values()), counts


def test_fallback_spec_executes_on_qdq_despite_kernels_request(
        setup, monkeypatch):
    """quamba-w4a4 with backend="kernels": describe() reports qdq, and
    the forward indeed dispatches zero kernel matmuls -- the report and
    the execution can never drift apart."""
    cfg, params, stats = setup
    qm = _quantized(cfg, params, stats, "quamba-w4a4", backend="kernels")
    d = qm.describe()
    assert d["requested_backend"] == "kernels"
    assert d["effective_backend"] == "qdq"
    counts = _count_matmuls(monkeypatch)
    reset_backend_fallback_warnings()
    lg = _run_forward(cfg, qm, qm.qctx())
    assert all(c == 0 for c in counts.values()), counts
    # and the fallback numerics equal an explicit qdq request
    np.testing.assert_array_equal(
        lg, _run_forward(cfg, qm, qm.qctx(backend="qdq")))
