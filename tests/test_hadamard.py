"""Hadamard transform tests: orthogonality, FWHT vs dense, compute
invariance of the W_out fold (paper §4.2)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant.hadamard import (decompose, fold_hadamard_into_weight,
                                  fwht, had_transform, had_transform_t,
                                  hadamard_matrix_np)

SIZES = [2, 8, 12, 20, 24, 40, 128, 160, 768, 1024, 2048, 2560, 5120]


@pytest.mark.parametrize("n", SIZES)
def test_orthogonality(n):
    h = hadamard_matrix_np(n, normalized=False)
    assert np.allclose(h @ h.T, n * np.eye(n), atol=1e-2)
    assert set(np.unique(h)) <= {-1.0, 1.0}


@pytest.mark.parametrize("n", SIZES)
def test_fwht_matches_dense(n):
    rng = np.random.default_rng(n)
    x = rng.normal(size=(4, n)).astype(np.float32)
    h = hadamard_matrix_np(n, normalized=False)
    got = np.asarray(fwht(jnp.asarray(x)))
    want = x @ h.T
    assert np.allclose(got, want, atol=1e-2 * np.abs(want).max())


@pytest.mark.parametrize("n", [128, 768, 2560])
def test_inverse_round_trip(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(3, n)).astype(np.float32))
    back = had_transform_t(had_transform(x))
    assert np.allclose(np.asarray(back), np.asarray(x), atol=1e-3)


@pytest.mark.parametrize("n", [64, 768, 2560])
def test_fold_compute_invariance(n):
    """(H y) @ (H W) == y @ W -- the zero-overhead fusion of §4.2."""
    rng = np.random.default_rng(n)
    y = jnp.asarray(rng.normal(size=(5, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(n, 32)).astype(np.float32))
    ref = y @ w
    got = had_transform(y) @ fold_hadamard_into_weight(w, axis=0)
    assert np.allclose(np.asarray(got), np.asarray(ref),
                       atol=1e-3 * float(jnp.abs(ref).max()))


def test_hadamard_flattens_outliers():
    """Rotation spreads single-channel outliers across the basis."""
    rng = np.random.default_rng(0)
    y = rng.normal(size=(256, 2048)).astype(np.float32)
    y[:, 7] *= 300.0                        # massive channel outlier
    yh = np.asarray(had_transform(jnp.asarray(y)))
    kurt = lambda a: float((((a - a.mean()) / a.std()) ** 4).mean())
    assert kurt(yh) < kurt(y) / 5
    assert np.abs(yh).max() < np.abs(y).max() / 3


@given(st.sampled_from([48, 96, 160, 384, 1280]))
@settings(max_examples=5, deadline=None)
def test_decompose_valid(n):
    p, m = decompose(n)
    assert (2 ** p) * m == n and m in (1, 12, 20)


def test_decompose_rejects_impossible():
    with pytest.raises(ValueError):
        decompose(18)
