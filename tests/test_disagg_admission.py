"""Roofline-informed admission (``repro.serve.disagg.admission``):
the decode-knee batch solve, dispatch-overhead chunk sizing, mesh
scaling, and the occupancy-feedback worker-ratio controller."""
import pytest

pytestmark = pytest.mark.serve

from repro.configs import get_config, scale_down
from repro.dist import roofline
from repro.serve.disagg.admission import (AdmissionController,
                                          DISPATCH_OVERHEAD_S,
                                          RooflinePlan, plan_decode)


def _plan(**kw):
    """A mid-size synthetic part where the knee lands strictly inside
    (1, cap): N=1e9 int8 params, 128 KiB state/seq, A100-ish ceilings."""
    kw.setdefault("n_params", 1_000_000_000)
    kw.setdefault("state_bytes_per_seq", 131_072)
    kw.setdefault("peak_flops", 312e12)
    kw.setdefault("hbm_bw", 2.0e12)
    return plan_decode(None, **kw)


# ---------------------------------------------------------------------------
# plan_decode
# ---------------------------------------------------------------------------

def test_knee_solves_compute_equals_memory():
    p = _plan()
    # analytically: knee = (W/bw) / (2N/peak - S/bw); check the derived
    # pow2 batch brackets it and the bottleneck flips across the knee
    denom = 2 * p.n_params / 312e12 - p.state_bytes_per_seq / 2.0e12
    knee = (p.weight_bytes / 2.0e12) / denom
    assert 1 < p.max_batch <= knee < 2 * p.max_batch
    below = plan_decode(None, n_params=p.n_params,
                        state_bytes_per_seq=p.state_bytes_per_seq,
                        peak_flops=312e12, hbm_bw=2.0e12,
                        max_batch_cap=p.max_batch)
    assert below.bottleneck == "memory"     # under the knee: bw-bound
    assert p.decode_tokens_per_s == pytest.approx(
        p.max_batch / p.decode_step_s)


def test_tiny_model_state_dominates_and_caps():
    """When per-seq state reads outweigh per-seq compute the memory
    ceiling never crosses -- batch to the cap (the scale_down configs
    land here)."""
    p = plan_decode(None, n_params=1000, state_bytes_per_seq=10**6,
                    max_batch_cap=16)
    assert p.max_batch == 16 and p.bottleneck == "memory"
    cfg = scale_down(get_config("mamba-130m"))
    q = plan_decode(cfg)
    assert q.max_batch == 64                # default cap
    assert q.n_params > 0 and q.state_bytes_per_seq > 0


def test_quantization_halves_nothing_but_weights():
    """int8 weights shrink the weight-read term 4x, moving the knee
    (and so max_batch) down -- state stays fp32 either way."""
    kw = dict(n_params=1_000_000_000, state_bytes_per_seq=131_072,
              peak_flops=312e12, hbm_bw=2.0e12, max_batch_cap=1024)
    q = plan_decode(None, quantized=True, **kw)
    f = plan_decode(None, quantized=False, **kw)
    assert f.weight_bytes == 4 * q.weight_bytes
    assert f.max_batch >= 2 * q.max_batch
    assert q.state_bytes_per_seq == f.state_bytes_per_seq


def test_mesh_slice_scales_batch_not_cap():
    one = _plan(n_devices=1)
    four = _plan(n_devices=4, max_batch_cap=1024)
    assert four.max_batch == 4 * one.max_batch
    capped = _plan(n_devices=4, max_batch_cap=one.max_batch)
    assert capped.max_batch == one.max_batch    # cap binds last


def test_prefill_chunk_covers_dispatch_overhead():
    p = _plan()
    chunk_s = 2.0 * p.n_params * p.prefill_chunk / 312e12
    assert chunk_s >= DISPATCH_OVERHEAD_S           # not launch-bound
    assert 2.0 * p.n_params * (p.prefill_chunk // 2) / 312e12 \
        < DISPATCH_OVERHEAD_S                       # and minimal pow2
    # heavier overhead -> bigger chunk; capped at max_chunk_cap
    big = _plan(dispatch_overhead_s=100 * DISPATCH_OVERHEAD_S)
    assert big.prefill_chunk > p.prefill_chunk
    assert _plan(dispatch_overhead_s=10.0).prefill_chunk == 1024


def test_plan_to_json_roundtrips_scalars():
    d = _plan().to_json()
    assert isinstance(d, dict)
    for k in ("max_batch", "prefill_chunk", "decode_step_s",
              "bottleneck", "terms"):
        assert k in d
    assert set(d["terms"]) >= {"compute_s", "memory_s", "step_s"}
    # repo-wide roofline constants are the defaults when not overridden
    default = plan_decode(None, n_params=10**9,
                          state_bytes_per_seq=131_072)
    assert default.terms["step_s"] > 0
    assert roofline.PEAK_FLOPS > 0 and roofline.HBM_BW > 0


# ---------------------------------------------------------------------------
# AdmissionController
# ---------------------------------------------------------------------------

def _controller(p=2, d=2):
    return AdmissionController(_plan(), prefill_workers=p,
                               decode_workers=d)


def test_controller_validation():
    with pytest.raises(ValueError, match="worker"):
        AdmissionController(_plan(), prefill_workers=0,
                            decode_workers=1)
    with pytest.raises(ValueError, match="ewma"):
        AdmissionController(_plan(), prefill_workers=1,
                            decode_workers=1, ewma=0.0)
    with pytest.raises(ValueError, match="low"):
        AdmissionController(_plan(), prefill_workers=1,
                            decode_workers=1, low=0.9, high=0.5)


def test_starved_shifts_decode_to_prefill():
    c = _controller()
    for _ in range(50):     # saturated prefill, deep queue, idle decode
        c.observe(queue_depth=10 ** 6, prefill_busy=1.0,
                  decode_occupancy=0.1)
    s = c.suggest_workers()
    assert s == {"prefill": 3, "decode": 1}
    assert s["prefill"] + s["decode"] == 4      # total preserved


def test_flooded_shifts_prefill_to_decode():
    c = _controller()
    for _ in range(50):     # decode slots full, prefill pool idle
        c.observe(queue_depth=0, prefill_busy=0.0,
                  decode_occupancy=1.0)
    assert c.suggest_workers() == {"prefill": 1, "decode": 3}


def test_pools_never_drop_below_one():
    c = _controller(p=1, d=1)
    for _ in range(50):
        c.observe(queue_depth=10 ** 6, prefill_busy=1.0,
                  decode_occupancy=0.0)
    assert c.suggest_workers() == {"prefill": 1, "decode": 1}
    for _ in range(100):
        c.observe(queue_depth=0, prefill_busy=0.0,
                  decode_occupancy=1.0)
    assert c.suggest_workers() == {"prefill": 1, "decode": 1}


def test_balanced_load_keeps_split_and_ewma_converges():
    c = _controller()
    for _ in range(200):
        c.observe(queue_depth=2, prefill_busy=0.5,
                  decode_occupancy=0.6)
    assert c.suggest_workers() == {"prefill": 2, "decode": 2}
    assert c.prefill_busy == pytest.approx(0.5, abs=1e-6)
    assert c.decode_occupancy == pytest.approx(0.6, abs=1e-6)
    j = c.to_json()
    assert j["observations"] == 200
    assert j["suggested"] == {"prefill": 2, "decode": 2}
    assert j["plan"]["max_batch"] == c.plan.max_batch
