"""Training substrate: convergence, microbatch equivalence, resume,
straggler accounting, preemption checkpoint."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.data import batches
from repro.optim import OptimConfig
from repro.train import (LoopConfig, Trainer, init_train_state,
                         make_train_step, train)


@pytest.fixture(scope="module")
def tiny():
    cfg = scale_down(get_config("mamba-130m"))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    return cfg, state


def test_loss_decreases(tiny):
    cfg, state = tiny
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=1e-3, warmup_steps=5, total_steps=40)))
    losses = []
    for b in batches(cfg.vocab_size, 8, 64, seed=1, num_steps=25):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatch_grads_equivalent(tiny):
    cfg, state = tiny
    opt = OptimConfig(lr=1e-3, warmup_steps=5, total_steps=40)
    s1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    s2 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    (b,) = list(batches(cfg.vocab_size, 8, 32, seed=2, num_steps=1))
    n1, m1 = s1(state, b)
    n2, m2 = s2(state, b)
    # same data -> nearly identical parameter updates
    deltas = jax.tree.map(lambda a, c: float(jnp.abs(a - c).max()),
                          n1["params"], n2["params"])
    assert max(jax.tree.leaves(deltas)) < 5e-3


def test_resume_from_checkpoint(tiny, tmp_path):
    cfg, state = tiny
    opt = OptimConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step = make_train_step(cfg, opt)
    data = lambda s0: batches(cfg.vocab_size, 4, 32, seed=3,
                              start_step=s0)
    lcfg = LoopConfig(total_steps=6, ckpt_dir=str(tmp_path),
                      ckpt_every=3, log_every=0)
    train(lcfg, step, state, data, log=lambda *_: None)
    # resume continues from step 6 (fresh state object; restores)
    lcfg2 = LoopConfig(total_steps=9, ckpt_dir=str(tmp_path),
                       ckpt_every=3, log_every=0)
    t = Trainer(lcfg2, step, state, log=lambda *_: None)
    assert t.start_step == 6
    t.run(data(t.start_step))
    from repro.train import checkpoint as ckpt
    assert ckpt.latest_step(str(tmp_path)) == 9


def test_compressed_training_converges(tiny):
    cfg, _ = tiny
    state = init_train_state(jax.random.PRNGKey(5), cfg,
                             compress_grads=True)
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=1e-3, warmup_steps=5, total_steps=40), compress_grads=True))
    losses = []
    for b in batches(cfg.vocab_size, 8, 64, seed=6, num_steps=20):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.15
