"""End-to-end quantization recipe tests: calibrate -> quantize -> run for
every family; Quamba's logit error must beat naive static on SSM archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import forward, init_params
from repro.models.quantize import make_qctx, quantize_model
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import PRESETS, get_spec

ARCHS = ["mamba-130m", "llama3-8b", "granite-moe-1b-a400m",
         "whisper-medium", "paligemma-3b", "zamba2-1.2b", "xlstm-1.3b"]


def _setup(arch, seed=0):
    cfg = scale_down(get_config(arch))
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    b, l = 2, 32

    def mk(k):
        if cfg.family == "audio":
            return {"frames": jax.random.normal(k, (b, 24, cfg.d_model)),
                    "tokens": jax.random.randint(k, (b, 8), 0,
                                                 cfg.vocab_size)}
        if cfg.family == "vlm":
            return {"patches": jax.random.normal(
                        k, (b, cfg.prefix_len, cfg.d_model)),
                    "tokens": jax.random.randint(
                        k, (b, l - cfg.prefix_len), 0, cfg.vocab_size)}
        return {"tokens": jax.random.randint(k, (b, l), 0,
                                             cfg.vocab_size)}

    batches = [mk(jax.random.PRNGKey(i)) for i in range(3)]
    stats = run_calibration(
        lambda p, bt: forward(p, cfg, bt, qctx={"mode": "calib"}),
        params, batches)
    return cfg, params, stats, batches


@pytest.mark.parametrize("arch", ARCHS)
def test_all_methods_run_and_finite(arch):
    cfg, params, stats, batches = _setup(arch)
    fp, _ = forward(params, cfg, batches[0])
    for method in ("quamba", "static", "dynamic", "smoothquant",
                   "quarot", "in_per", "out_had"):
        spec = get_spec(method)
        np_, qdata = quantize_model(params, stats, cfg, spec)
        lg, _ = jax.jit(lambda p, b: forward(
            p, cfg, b, qctx=make_qctx(spec, qdata)))(np_, batches[0])
        assert bool(jnp.isfinite(lg).all()), method
        rel = float(jnp.abs(lg - fp).max() / jnp.abs(fp).max())
        assert rel < 1.5, (method, rel)


@pytest.mark.parametrize("arch", ["mamba-130m", "zamba2-1.2b"])
def test_quamba_beats_naive_static_on_ssm(arch):
    cfg, params, stats, batches = _setup(arch)
    fp, _ = forward(params, cfg, batches[0])

    def err(method):
        spec = get_spec(method)
        np_, qdata = quantize_model(params, stats, cfg, spec)
        lg, _ = forward(np_, cfg, batches[0],
                        qctx=make_qctx(spec, qdata))
        return float(jnp.abs(lg - fp).mean())

    assert err("quamba") < err("static")


def test_w4a8_preset_runs():
    cfg, params, stats, batches = _setup("mamba-130m")
    spec = get_spec("quamba-w4a8")
    np_, qdata = quantize_model(params, stats, cfg, spec)
    lg, _ = forward(np_, cfg, batches[0], qctx=make_qctx(spec, qdata))
    assert bool(jnp.isfinite(lg).all())
    assert int(jax.tree.leaves(qdata["qw"])[0].max()) <= 7  # int4 range


def test_quantized_weights_are_int8():
    cfg, params, stats, _ = _setup("mamba-130m")
    spec = get_spec("quamba")
    _, qdata = quantize_model(params, stats, cfg, spec)
    for leaf in jax.tree.leaves(
            jax.tree.map(lambda q: q["qw"], qdata["qw"],
                         is_leaf=lambda x: isinstance(x, dict)
                         and "qw" in x)):
        assert leaf.dtype == jnp.int8


def test_hadamard_fold_compute_invariance_in_model():
    """quamba with/without rotation agree in fp (no quant): the fold is
    exact, so turning quantization 'off' via huge scales must match."""
    cfg, params, stats, batches = _setup("mamba-130m")
    fp, _ = forward(params, cfg, batches[0])
    spec = get_spec("quamba")
    np_, qdata = quantize_model(params, stats, cfg, spec)
    lg, _ = forward(np_, cfg, batches[0], qctx=make_qctx(spec, qdata))
    # the quantized model should track fp within W8A8 noise
    rel = float(jnp.abs(lg - fp).max() / jnp.abs(fp).max())
    assert rel < 0.25, rel
