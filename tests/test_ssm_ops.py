"""Chunked recurrences vs sequential oracles (SSD / mLSTM), plus the
theoretical error-bound experiments of paper §A."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.ssd import ssd_chunked, ssd_reference, ssd_step
from repro.models.xlstm import mlstm_chunked, mlstm_reference
from repro.quant.errors import (simulate_quantized_lti,
                                simulate_theorem_system)


def _ssd_inputs(b, l, h, hd, n, seed):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32),
            jnp.asarray(np.abs(rng.normal(size=(b, l, h))) * 0.2,
                        jnp.float32),
            jnp.asarray(-np.abs(rng.normal(size=h)) - 0.1, jnp.float32),
            jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, l, n)), jnp.float32),
            jnp.asarray(rng.normal(size=h), jnp.float32))


@given(st.integers(1, 2), st.sampled_from([16, 32, 64]),
       st.integers(1, 4), st.sampled_from([4, 8]), st.sampled_from([4, 8]),
       st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_matches_sequential(b, l, h, hd, n, chunk):
    x, dt, a, bm, cm, d = _ssd_inputs(b, l, h, hd, n, seed=l * h)
    if l % chunk:
        chunk = l
    y1, s1 = ssd_chunked(x, dt, a, bm, cm, d, chunk=chunk,
                         return_state=True)
    y2, s2 = ssd_reference(x, dt, a, bm, cm, d)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert np.allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


def test_ssd_state_carry():
    x, dt, a, bm, cm, d = _ssd_inputs(2, 32, 2, 8, 4, seed=5)
    y_full, s_full = ssd_chunked(x, dt, a, bm, cm, d, chunk=8,
                                 return_state=True)
    h0 = None
    ys = []
    for i in range(0, 32, 16):
        sl = lambda t: t[:, i:i + 16]
        y, h0 = ssd_chunked(sl(x), sl(dt), a, sl(bm), sl(cm), d, chunk=8,
                            h0=h0, return_state=True)
        ys.append(y)
    assert np.allclose(np.asarray(jnp.concatenate(ys, 1)),
                       np.asarray(y_full), atol=1e-4)
    assert np.allclose(np.asarray(h0), np.asarray(s_full), atol=1e-4)


@given(st.integers(1, 2), st.sampled_from([16, 48]), st.integers(1, 3),
       st.sampled_from([8, 16]), st.sampled_from([8, 16]))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunked_matches_sequential(b, l, h, hd, chunk):
    rng = np.random.default_rng(b * l + hd)
    q = jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(b, l, h)) * 2, jnp.float32)
    lf = jnp.asarray(np.log(1 / (1 + np.exp(
        -rng.normal(size=(b, l, h)) * 2))), jnp.float32)
    if l % chunk:
        chunk = l
    y1 = mlstm_chunked(q, k, v, li, lf, chunk=chunk)
    y2, _ = mlstm_reference(q, k, v, li, lf)
    assert np.allclose(np.asarray(y1), np.asarray(y2), atol=5e-4)


def test_mlstm_numerically_stable_extreme_gates():
    """Exponential input gates up to e^20 must not produce inf/nan."""
    rng = np.random.default_rng(0)
    b, l, h, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, l, h, hd)), jnp.float32)
    li = jnp.full((b, l, h), 20.0, jnp.float32)
    lf = jnp.full((b, l, h), -0.01, jnp.float32)
    y = mlstm_chunked(q, k, v, li, lf, chunk=8)
    assert bool(jnp.isfinite(y).all())


# ---------------------------------------------------------------------------
# error-bound experiments (paper Thm 4.1 / Fig. 5)
# ---------------------------------------------------------------------------

def test_theorem_corrected_bound_holds():
    from repro.quant.errors import CORRECTED_CONSTANT
    r = simulate_theorem_system(steps=200)
    beps = 0.7 * 0.01
    corrected = beps * CORRECTED_CONSTANT
    assert (r["err"] <= corrected + 1e-9).all()
    # the paper's stated bound is exceeded (the erratum we document)
    paper_at_T = beps * np.exp(0.0) / (np.e - 1.0)
    assert r["err"].max() > paper_at_T
    # and the corrected constant is reasonably tight
    assert r["err"].max() > 0.5 * corrected


@pytest.mark.parametrize("measure", ["legt", "legs"])
def test_hippo_errors_bounded(measure):
    """Fig. 5: quantization error does not diverge with t."""
    r = simulate_quantized_lti(measure, steps=400)
    early = r["state_err"][:200].max()
    late = r["state_err"][200:].max()
    assert late <= 2.0 * early
    assert np.isfinite(r["state_err"]).all()
