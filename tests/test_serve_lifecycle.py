"""Request-centric serving API: lifecycle (QUEUED -> PREFILLING ->
DECODING -> FINISHED), scheduler policies, cancellation, streaming, and
per-request metrics."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, scale_down
from repro.models import decode_step, init_decode_state, init_params
from repro.serve import (FinishReason, LLMEngine, Metrics,
                         Request, RequestStatus, SamplingParams)
from repro.serve.scheduler import (FCFSScheduler, PriorityScheduler,
                                   make_scheduler)

pytestmark = pytest.mark.serve


@pytest.fixture(scope="module")
def setup():
    cfg = scale_down(get_config("mamba-130m"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_ref(params, cfg, prompt, n):
    state = init_decode_state(cfg, 1, 64, cache_dtype=jnp.float32)
    lg = None
    for t in prompt:
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([t], jnp.int32))
    out = []
    for _ in range(n):
        nt = int(jnp.argmax(lg[0]))
        out.append(nt)
        lg, state = decode_step(params, cfg, state,
                                jnp.asarray([nt], jnp.int32))
    return out


# ---------------------------------------------------------------------------
# acceptance: >= 3 concurrent requests, different SamplingParams, one
# cancelled / one stop-token / one max_tokens, metrics JSON complete
# ---------------------------------------------------------------------------

def test_lifecycle_three_concurrent_requests_with_metrics(setup):
    cfg, params = setup
    ref = _greedy_ref(params, cfg, [3, 1, 4], 8)
    stop_tok = ref[2]                      # hits mid-decode at token 3

    eng = LLMEngine(params, cfg, max_batch=3, max_len=64)
    a = eng.add_request([3, 1, 4],
                        SamplingParams(max_tokens=8,
                                       stop_token_ids=(stop_tok,)),
                        request_id="stopper")
    b = eng.add_request([9], SamplingParams(temperature=0.9, top_k=6,
                                            top_p=0.9, seed=5,
                                            max_tokens=4),
                        request_id="lengther")
    c = eng.add_request([5, 5], SamplingParams(max_tokens=50),
                        request_id="victim")
    assert all(s.status is RequestStatus.QUEUED for s in (a, b, c))

    eng.step()                             # all three admitted + 1 token
    assert all(s.status is RequestStatus.DECODING for s in (a, b, c))
    eng.step()
    assert eng.cancel("victim")
    assert c.status is RequestStatus.FINISHED
    assert c.finish_reason is FinishReason.CANCELLED
    assert len(c.token_ids) == 2           # kept what it produced
    eng.run()

    assert a.finish_reason is FinishReason.STOP
    # stops at the FIRST occurrence of the stop token, inclusive
    assert a.token_ids == ref[:ref.index(stop_tok) + 1]
    assert b.finish_reason is FinishReason.LENGTH
    assert len(b.token_ids) == 4
    assert not eng.has_unfinished()

    mj = eng.metrics_json()
    for rid in ("stopper", "lengther", "victim"):
        m = mj["requests"][rid]
        assert m["ttft_ms"] is not None and m["ttft_ms"] >= 0
        assert m["tpot_ms"] is not None and m["tpot_ms"] >= 0
    assert mj["requests"]["stopper"]["finish_reason"] == "stop"
    assert mj["requests"]["lengther"]["finish_reason"] == "length"
    assert mj["requests"]["victim"]["finish_reason"] == "cancelled"
    assert mj["engine"]["requests_finished"] == 3
    assert mj["engine"]["requests_cancelled"] == 1
    assert mj["engine"]["tokens_generated"] == len(a.token_ids) + 4 + 2
    assert mj["engine"]["decode_steps"] == eng.counters["decode_steps"]
    json.dumps(mj)                         # JSON-serializable throughout


# ---------------------------------------------------------------------------
# engine edge cases
# ---------------------------------------------------------------------------

def test_stop_token_hit_mid_decode(setup):
    cfg, params = setup
    ref = _greedy_ref(params, cfg, [5], 8)
    eng = LLMEngine(params, cfg, max_batch=1, max_len=32)
    st = eng.add_request([5], SamplingParams(max_tokens=8,
                                             stop_token_ids=(ref[3],)))
    eng.run()
    assert st.finish_reason is FinishReason.STOP
    # first occurrence of the stop token, inclusive
    assert st.token_ids == ref[:ref.index(ref[3]) + 1]


def test_max_tokens_eviction_and_readmission(setup):
    """One slot, two requests: the first finishes by length, frees the
    slot, and the queued request is admitted and completes."""
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=1, max_len=32)
    first = eng.add_request([3, 1], SamplingParams(max_tokens=3))
    second = eng.add_request([7], SamplingParams(max_tokens=2))
    eng.step()
    assert second.status is RequestStatus.QUEUED     # no free slot yet
    assert eng.scheduler.queue_depth == 1
    eng.run()
    assert first.finish_reason is FinishReason.LENGTH
    assert len(first.token_ids) == 3
    assert second.finish_reason is FinishReason.LENGTH
    assert len(second.token_ids) == 2
    # queue time of the second request spans the first one's decode
    mj = eng.metrics_json()
    q2 = mj["requests"][second.request_id]["queue_time_ms"]
    assert q2 is not None and q2 > 0


def test_cancel_queued_vs_inflight_vs_unknown(setup):
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=1, max_len=32)
    flying = eng.add_request([2], SamplingParams(max_tokens=20),
                             request_id="flying")
    queued = eng.add_request([4], SamplingParams(max_tokens=20),
                             request_id="queued")
    eng.step()
    # queued: dequeued without ever touching a slot
    assert eng.cancel("queued")
    assert queued.status is RequestStatus.FINISHED
    assert queued.finish_reason is FinishReason.CANCELLED
    assert queued.token_ids == [] and queued.scheduled_time is None
    # in-flight: evicted at the step boundary, slot reusable
    assert eng.cancel("flying")
    assert flying.finish_reason is FinishReason.CANCELLED
    assert len(flying.token_ids) == 1
    assert eng.scheduler.live() == []
    # unknown / already finished -> False, engine is idle
    assert not eng.cancel("nope")
    assert not eng.cancel("flying")
    assert not eng.has_unfinished()
    # the freed slot admits new work
    fresh = eng.add_request([6], SamplingParams(max_tokens=2))
    eng.run()
    assert fresh.finish_reason is FinishReason.LENGTH


def test_empty_queue_step_is_noop(setup):
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=2, max_len=32)
    assert eng.step() == []
    assert eng.counters["decode_steps"] == 0
    assert eng.metrics.decode_steps == 0
    st = eng.add_request([3], SamplingParams(max_tokens=2))
    eng.run()
    steps_after = eng.counters["decode_steps"]
    assert st.finished and steps_after == 2
    assert eng.step() == []                # drained engine: still a no-op
    assert eng.counters["decode_steps"] == steps_after


def test_streaming_iterator_drives_engine(setup):
    cfg, params = setup
    ref = _greedy_ref(params, cfg, [3, 1, 4], 5)
    eng = LLMEngine(params, cfg, max_batch=2, max_len=32)
    got_cb = []
    st = eng.add_request([3, 1, 4], SamplingParams(max_tokens=5),
                         on_token=got_cb.append)
    pulled = list(st.stream)               # no explicit run(): pull pumps
    assert pulled == ref == list(st.token_ids) == got_cb
    assert st.finished and not eng.has_unfinished()
    # drain() on a finished stream is empty, iteration stays exhausted
    assert st.stream.drain() == []
    assert list(st.stream) == []


def test_reentrant_cancel_from_on_token_callback(setup):
    """An on_token callback that cancels its own request mid-step must
    not corrupt the slot table or double-release (the 'stop when you
    see token X' pattern)."""
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=2, max_len=32)
    seen = []

    def stop_after_two(tok):
        seen.append(tok)
        if len(seen) == 2:
            eng.cancel("self-stop")

    st = eng.add_request([3, 1], SamplingParams(max_tokens=20),
                         request_id="self-stop",
                         on_token=stop_after_two)
    other = eng.add_request([5], SamplingParams(max_tokens=4))
    eng.run()
    assert st.finish_reason is FinishReason.CANCELLED
    assert len(st.token_ids) == 2 == len(seen)
    assert other.finish_reason is FinishReason.LENGTH
    assert len(other.token_ids) == 4
    assert not eng.has_unfinished()


def test_greedy_request_with_topk_stays_on_fast_path(setup):
    """Greedy rows ignore top-k/top-p, so they must not flip the core
    onto the truncating sampler variant for the whole batch."""
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=1, max_len=32)
    st = eng.add_request([3], SamplingParams(temperature=0.0, top_k=50,
                                             top_p=0.5, max_tokens=2))
    eng.run()
    assert st.finished and not eng.core._truncate


def test_per_request_seed_reproducible_across_batch_mix(setup):
    """A seeded request draws the same tokens whatever else the batch
    is doing (per-slot keys, not a shared engine key)."""
    cfg, params = setup
    sp = SamplingParams(temperature=1.0, top_k=12, seed=99, max_tokens=5)

    def run_with(extra):
        eng = LLMEngine(params, cfg, max_batch=2, max_len=32)
        st = eng.add_request([2, 7], sp)
        if extra:
            eng.add_request([4], SamplingParams(temperature=0.5,
                                                max_tokens=7))
        eng.run()
        return list(st.token_ids)

    alone, mixed = run_with(False), run_with(True)
    assert alone == mixed and len(alone) == 5


# ---------------------------------------------------------------------------
# scheduler policies
# ---------------------------------------------------------------------------

def test_fcfs_vs_priority_admission_order(setup):
    cfg, params = setup

    def finish_order(policy):
        eng = LLMEngine(params, cfg, max_batch=1, max_len=32,
                        scheduler=policy)
        for name, prio in (("lo", 0), ("hi", 5), ("mid", 1)):
            eng.add_request([3], SamplingParams(max_tokens=2),
                            request_id=name, priority=prio)
        order = []
        while eng.has_unfinished():
            order += [o.request_id for o in eng.step() if o.finished]
        return order

    assert finish_order("fcfs") == ["lo", "hi", "mid"]
    # all three are queued before the first step, so the single slot
    # is handed out purely by policy: hi (5) > mid (1) > lo (0)
    assert finish_order("priority") == ["hi", "mid", "lo"]


def test_make_scheduler_resolution():
    assert isinstance(make_scheduler("fcfs", 2), FCFSScheduler)
    assert isinstance(make_scheduler("priority", 2), PriorityScheduler)
    assert isinstance(make_scheduler(None, 2), FCFSScheduler)
    assert isinstance(make_scheduler(PriorityScheduler, 3),
                      PriorityScheduler)
    ready = FCFSScheduler(4)
    assert make_scheduler(ready, 4) is ready
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("sjf", 2)
    with pytest.raises(ValueError, match="max_batch"):
        make_scheduler(FCFSScheduler(2), 4)


def test_priority_ties_break_fcfs():
    sched = PriorityScheduler(1)
    from repro.serve.request import RequestState
    a = RequestState(Request([1], SamplingParams(), request_id="a",
                             priority=2))
    b = RequestState(Request([1], SamplingParams(), request_id="b",
                             priority=2))
    c = RequestState(Request([1], SamplingParams(), request_id="c",
                             priority=7))
    for s in (a, b, c):
        sched.add(s)
    assert sched._pick() is c
    assert sched._pick() is a              # FCFS among equal priorities
    assert sched._pick() is b


# ---------------------------------------------------------------------------
# metrics math (fake clock) + validation + request objects
# ---------------------------------------------------------------------------

def test_metrics_math_with_fake_clock():
    t = [0.0]
    m = Metrics(clock=lambda: t[0])
    m.on_submit("r", prompt_len=4)         # t=0: arrival
    t[0] = 1.0
    m.on_schedule("r")                     # queue_time = 1s
    t[0] = 2.0
    m.on_token("r")                        # ttft = 2s
    for dt in (2.5, 3.0, 3.5):
        t[0] = dt
        m.on_token("r")                    # tpot = 0.5s over 3 gaps
    m.on_finish("r", "length")
    r = m.request("r")
    assert r["queue_time_ms"] == pytest.approx(1000.0)
    assert r["ttft_ms"] == pytest.approx(2000.0)
    assert r["tpot_ms"] == pytest.approx(500.0)
    assert r["generated"] == 4 and r["finish_reason"] == "length"
    mj = m.to_json(extra_counters={"prefill_dispatches": 7})
    assert mj["summary"]["ttft_ms"]["mean"] == pytest.approx(2000.0)
    assert mj["engine"]["prefill_dispatches"] == 7
    # tokens_per_s counts from first SUBMISSION (t=0) to the last
    # token (t=3.5) -- queue + prefill wall time included by design
    assert mj["engine"]["tokens_per_s"] == pytest.approx(4 / 3.5)


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError, match="greedy"):
        SamplingParams(greedy=False)
    sp = SamplingParams(temperature=2.0, greedy=True)
    assert sp.is_greedy and sp.effective_temperature == 0.0
    import dataclasses
    with pytest.raises(dataclasses.FrozenInstanceError):
        sp.temperature = 1.0               # frozen


def test_request_defaults_and_validation():
    r = Request([1, 2])
    assert r.params == SamplingParams()          # greedy defaults
    assert r.request_id.startswith("req-")
    with pytest.raises(ValueError, match="empty prompt"):
        Request([])


def test_ready_request_objects_and_duplicate_ids(setup):
    cfg, params = setup
    eng = LLMEngine(params, cfg, max_batch=1, max_len=32)
    r0 = Request([3], SamplingParams(max_tokens=2))
    r1 = Request([5], SamplingParams(max_tokens=2))
    s0 = eng.add_request(r0)                 # ready Request objects are
    s1 = eng.add_request(r1)                 # accepted as-is
    eng.run()
    assert r0.done and r1.done
    assert len(s0.token_ids) == 2 and len(s1.token_ids) == 2
    # explicit duplicate request_ids are rejected
    eng2 = LLMEngine(params, cfg, max_batch=1, max_len=32)
    eng2.add_request([1], SamplingParams(max_tokens=1), request_id="x")
    with pytest.raises(ValueError, match="duplicate"):
        eng2.add_request([2], SamplingParams(max_tokens=1),
                         request_id="x")
    # a ready Request plus separate params/priority is ambiguous
    with pytest.raises(ValueError, match="Request itself"):
        eng2.add_request(Request([1, 2]), SamplingParams(max_tokens=1))
    with pytest.raises(ValueError, match="Request itself"):
        eng2.add_request(Request([1, 2]), priority=3)
