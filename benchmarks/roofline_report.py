"""§Roofline: render the dry-run roofline table from results/*.jsonl.

Reads the artifacts produced by ``python -m repro.launch.dryrun --all``
(single-pod; the multi-pod file proves the 'pod' axis shards).
"""
from __future__ import annotations

import json
import os

from benchmarks import common

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def run() -> list:
    path = os.path.join(RESULTS, "dryrun_single.jsonl")
    if not os.path.exists(path):
        common.emit("roofline/missing", 0.0,
                    "run: python -m repro.launch.dryrun --all "
                    "--out results/dryrun_single.jsonl")
        return []
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if r["status"] == "ok"]
    for r in ok:
        common.emit(
            f"roofline/{r['arch']}/{r['shape']}",
            r["step_lower_bound_s"] * 1e6,
            f"bottleneck={r['bottleneck']};compute_s={r['compute_s']:.3g};"
            f"memory_s={r['memory_s']:.3g};"
            f"collective_s={r['collective_s']:.3g};"
            f"mfu_bound={r.get('mfu_bound', 0):.4f};"
            f"useful={r.get('useful_flops_ratio', 0):.3f}")
    common.emit("roofline/cells_ok", 0.0,
                f"{len(ok)}/{len(rows)} (skips are long_500k on pure "
                "full-attention archs, per DESIGN.md)")
    return ok


if __name__ == "__main__":
    run()
