"""Shared benchmark infrastructure.

One small Mamba LM is trained once per invocation (checkpoint-cached under
results/bench_model) and reused by every accuracy table, so ``python -m
benchmarks.run`` stays fast and the numbers across tables are comparable.
"""
from __future__ import annotations

import math
import os
import time
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax

from repro import api
from repro.configs import ModelConfig, get_config, scale_down
from repro.data import batches, eval_batches
from repro.models import forward, loss_fn
from repro.optim import OptimConfig
from repro.train import checkpoint as ckpt
from repro.train import init_train_state, make_train_step

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "results")
TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "250"))
SEQ = 128
VOCAB = 1024


def bench_config(arch: str = "mamba-130m", **kw) -> ModelConfig:
    return scale_down(get_config(arch), layers=3, width=192, vocab=VOCAB,
                      **kw)


def trained_model(arch: str = "mamba-130m") -> Tuple[ModelConfig, Dict]:
    """Train (or restore) the shared benchmark model."""
    cfg = bench_config(arch)
    ckpt_dir = os.path.join(BENCH_DIR, f"bench_model_{arch}")
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    if ckpt.latest_step(ckpt_dir) == TRAIN_STEPS:
        state, _ = ckpt.restore(ckpt_dir, state)
        return cfg, state["params"]
    step = jax.jit(make_train_step(cfg, OptimConfig(
        lr=2e-3, warmup_steps=20, total_steps=TRAIN_STEPS)))
    for b in batches(cfg.vocab_size, 16, SEQ, seed=11,
                     num_steps=TRAIN_STEPS):
        state, _ = step(state, b)
    ckpt.save(ckpt_dir, TRAIN_STEPS, state, keep=1)
    return cfg, state["params"]


def calibration_stats(cfg: ModelConfig, params, n: int = 6):
    calib = eval_batches(cfg.vocab_size, 8, SEQ, n, seed=777)
    return api.calibration_stats(cfg, params, calib)


def perplexity_of(cfg: ModelConfig, params, qctx=None, n: int = 4
                  ) -> float:
    evalb = eval_batches(cfg.vocab_size, 16, SEQ, n, seed=999)
    f = jax.jit(lambda p, b: loss_fn(p, cfg, b, qctx=qctx)[0])
    return math.exp(float(np.mean([float(f(params, b)) for b in evalb])))


def perplexity_of_model(model: api.QuantizedModel, n: int = 4) -> float:
    """Perplexity of a QuantizedModel artifact (fp or quantized)."""
    # pass params as a jit argument (closing over them would bake the
    # whole weight tree into the executable as XLA constants)
    return perplexity_of(model.cfg, model.params, model.qctx(), n)


def quantized_model(cfg, params, stats, method_or_spec) -> api.QuantizedModel:
    """Quantize through the public facade -> QuantizedModel artifact."""
    return api.Quantizer(cfg, method_or_spec).with_stats(stats) \
        .quantize(params)


def quantized(cfg, params, stats, method_or_spec):
    """Back-compat helper: (qparams, qctx) pair from the artifact."""
    qm = quantized_model(cfg, params, stats, method_or_spec)
    return qm.params, qm.qctx()


def cloze_accuracy(cfg: ModelConfig, params, qctx=None, n: int = 4
                   ) -> float:
    """Proxy zero-shot task: next-token top-1 accuracy on the held-out
    split (the Markov corpus has a well-defined most-likely successor)."""
    import jax.numpy as jnp
    evalb = eval_batches(cfg.vocab_size, 16, SEQ, n, seed=31337)
    f = jax.jit(lambda p, b: jnp.mean(
        (jnp.argmax(forward(p, cfg, b, qctx=qctx)[0], -1)
         == b["targets"]).astype(jnp.float32)))
    return float(np.mean([float(f(params, b)) for b in evalb]))


def timer(fn, *args, warmup: int = 3, iters: int = 20) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
