"""Paper Table 5: component ablation -- naive W8A8, +input percentile,
+output Hadamard, full Quamba."""
from __future__ import annotations

from benchmarks import common

METHODS = ("static", "in_per", "out_had", "quamba")
LABELS = {"static": "W8A8", "in_per": "+InPer", "out_had": "+OutHad",
          "quamba": "Quamba"}


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    out = {"fp16": common.perplexity_of(cfg, params)}
    for m in METHODS:
        model = common.quantized_model(cfg, params, stats, m)
        out[LABELS[m]] = common.perplexity_of_model(model)
    for k, v in out.items():
        common.emit(f"table5/ppl_{k}", 0.0, f"ppl={v:.4f}")
    common.emit("table5/quamba_best", 0.0, str(
        out["Quamba"] <= min(out["W8A8"], out["+InPer"], out["+OutHad"])
        + 1e-6))
    return out


if __name__ == "__main__":
    run()
