"""Paper §E / Tables 7-8: low-bit-width quantization of SSMs.

The paper shows W4A4 QuaRot fails on Mamba and W2A16 Quip# degrades it
more than Transformers.  We evaluate the beyond-paper presets that share
Quamba's recipe at lower weight precision (W4A8) and with per-channel
weight scales, reproducing the qualitative claim: below W8, SSM accuracy
falls off faster than the W8A8 recipe.
"""
from __future__ import annotations

from benchmarks import common
from repro.quant.recipe import QuantSpec

VARIANTS = {
    "quamba_w8a8": QuantSpec(method="quamba"),
    "quamba_w4a8": QuantSpec(method="quamba", w_bits=4),
    "quamba_w4a8_pc": QuantSpec(method="quamba", w_bits=4,
                                per_channel_w=True),
    "quamba_w8a8_pc": QuantSpec(method="quamba", per_channel_w=True),
}


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    out = {"fp16": common.perplexity_of(cfg, params)}
    for name, spec in VARIANTS.items():
        qparams, qctx = common.quantized(cfg, params, stats, spec)
        out[name] = common.perplexity_of(cfg, qparams, qctx)
        common.emit(f"table8/ppl_{name}", 0.0, f"ppl={out[name]:.4f}")
    common.emit("table8/w4_degrades_more", 0.0, str(
        out["quamba_w4a8"] >= out["quamba_w8a8"]))
    common.emit("table8/pc_helps_w4", 0.0, str(
        out["quamba_w4a8_pc"] <= out["quamba_w4a8"] + 1e-6))
    return out


if __name__ == "__main__":
    run()
