"""Paper Table 6: sensitivity to the percentile p for the SSM input."""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.quant.recipe import QuantSpec


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    out = {}
    for p in (99.0, 99.9, 99.99, 99.999):
        spec = QuantSpec(method="quamba", percentile=p)
        qparams, qctx = common.quantized(cfg, params, stats, spec)
        out[p] = common.perplexity_of(cfg, qparams, qctx)
        common.emit(f"table6/ppl_p{p}", 0.0, f"ppl={out[p]:.4f}")
    return out


if __name__ == "__main__":
    run()
