"""Paper Table 9 (§F): alternative 8-bit schemes for the SSM input x."""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.quant.recipe import QuantSpec

VARIANTS = {
    "sym_minmax_static": QuantSpec(method="quamba",
                                   input_quant="sym_minmax",
                                   percentile=100.0),
    "sym_percentile": QuantSpec(method="quamba",
                                input_quant="sym_percentile"),
    "asym_percentile": QuantSpec(method="quamba",
                                 input_quant="asym_percentile"),
    "log2": QuantSpec(method="quamba", input_quant="log2"),
    "dynamic": QuantSpec(method="quamba", input_quant="dynamic"),
}


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    out = {}
    for name, spec in VARIANTS.items():
        qparams, qctx = common.quantized(cfg, params, stats, spec)
        out[name] = common.cloze_accuracy(cfg, qparams, qctx)
        common.emit(f"table9/acc_{name}", 0.0, f"acc={out[name]:.4f}")
    return out


if __name__ == "__main__":
    run()
