"""Paper Table 1: latency profiling (CPU proxy).

The paper measures wall-clock W8A8 vs FP16 on A5000/Orin.  Without a GPU
we report the measurable CPU-side proxies plus the structural byte ratio
that drives the TPU speedup:

  * decode-step (TPOT) latency, fp vs quamba-quantized, via the engine
  * int8 vs fp32 matmul microbenchmark (XLA integer path)
  * weight + state bytes fp16 vs int8 (the model-size column of Table 1)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.models import decode_step, init_decode_state


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    qparams, qctx = common.quantized(cfg, params, stats, "quamba")
    out = {}

    b = 8
    state = init_decode_state(cfg, b, 256, cache_dtype=jnp.float32)
    tok = jnp.zeros((b,), jnp.int32)
    fp_step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t)[0])
    q_step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t,
                                                 qctx=qctx)[0])
    out["tpot_fp_us"] = common.timer(fp_step, params, state, tok)
    out["tpot_quamba_us"] = common.timer(q_step, qparams, state, tok)
    common.emit("table1/tpot_fp16", out["tpot_fp_us"], "decode_step")
    common.emit("table1/tpot_quamba", out["tpot_quamba_us"],
                "decode_step(simulated int8; real speedup needs TPU)")

    # int8 vs fp32 GEMM (the acceleration Table 1 banks on)
    m = k = n = 1024
    rng = np.random.default_rng(0)
    qx = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int8))
    qw = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int8))
    fx = qx.astype(jnp.float32)
    fw = qw.astype(jnp.float32)
    int8_mm = jax.jit(lambda a, bb: jax.lax.dot_general(
        a, bb, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32))
    f32_mm = jax.jit(lambda a, bb: a @ bb)
    out["gemm_int8_us"] = common.timer(int8_mm, qx, qw)
    out["gemm_f32_us"] = common.timer(f32_mm, fx, fw)
    common.emit("table1/gemm_int8", out["gemm_int8_us"], f"{m}x{k}x{n}")
    common.emit("table1/gemm_f32", out["gemm_f32_us"], f"{m}x{k}x{n}")

    # model-size column: fp16 vs W8A8 weight bytes
    n_params = sum(int(np.prod(l.shape)) for l in
                   jax.tree.leaves(params))
    fp16_gb = n_params * 2 / 1e9
    int8_gb = n_params * 1 / 1e9
    out["size_ratio"] = fp16_gb / int8_gb
    common.emit("table1/model_size", 0.0,
                f"fp16={fp16_gb:.4f}GB;int8={int8_gb:.4f}GB;"
                f"ratio={out['size_ratio']:.2f}x")
    return out


if __name__ == "__main__":
    run()
