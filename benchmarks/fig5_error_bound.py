"""Paper Fig. 5 + Thm 4.1: LTI quantization-error boundedness."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.quant.errors import (CORRECTED_CONSTANT, simulate_quantized_lti,
                                simulate_theorem_system)


def run() -> dict:
    out = {}
    r = simulate_theorem_system(steps=200)
    beps = 0.7 * 0.01
    out["thm_max_ratio"] = float(r["err"].max() / beps)
    common.emit("fig5/theorem_err_over_beps", 0.0,
                f"max={out['thm_max_ratio']:.3f};"
                f"corrected_bound={CORRECTED_CONSTANT:.3f}")
    for measure in ("legt", "legs"):
        rr = simulate_quantized_lti(measure, steps=400)
        bounded = rr["state_err"][200:].max() <= 2 * rr["state_err"][:200].max()
        out[measure] = float(rr["state_err"].max())
        common.emit(f"fig5/{measure}", 0.0,
                    f"max_state_err={out[measure]:.2e};bounded={bounded}")
    return out


if __name__ == "__main__":
    run()
