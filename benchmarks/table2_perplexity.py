"""Paper Table 2: perplexity of quantization methods on the Mamba family.

CPU-scale reproduction: the shared trained Mamba LM evaluated under every
method.  The paper's qualitative ordering to reproduce:
  static << dynamic < SmQ-SSM < Quamba ~ QuaRot-SSM ~ FP16
(static collapses; Quamba closes the gap to FP16.)
"""
from __future__ import annotations

from benchmarks import common


METHODS = ("static", "dynamic", "smoothquant", "quarot", "quamba")


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    out = {"fp16": common.perplexity_of(cfg, params)}
    for m in METHODS:
        model = common.quantized_model(cfg, params, stats, m)
        out[m] = common.perplexity_of_model(model)
    for k, v in out.items():
        common.emit(f"table2/ppl_{k}", 0.0, f"ppl={v:.4f}")
    # the paper's headline orderings
    ok1 = out["quamba"] < out["static"]
    ok2 = out["quamba"] <= out["smoothquant"] * 1.05
    common.emit("table2/ordering", 0.0,
                f"quamba<static={ok1};quamba<=smq={ok2}")
    return out


if __name__ == "__main__":
    run()
