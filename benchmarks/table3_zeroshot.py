"""Paper Table 3: zero-shot accuracy across quantization methods.

Proxy task on CPU: next-token top-1 accuracy on the held-out synthetic
split (a well-posed 'cloze' task for the Markov corpus).  The claim to
reproduce: Quamba stays within ~1% of FP16 while naive static collapses.
"""
from __future__ import annotations

from benchmarks import common

METHODS = ("static", "dynamic", "smoothquant", "quarot", "quamba")


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    out = {"fp16": common.cloze_accuracy(cfg, params)}
    for m in METHODS:
        qparams, qctx = common.quantized(cfg, params, stats, m)
        out[m] = common.cloze_accuracy(cfg, qparams, qctx)
    for k, v in out.items():
        common.emit(f"table3/acc_{k}", 0.0, f"acc={v:.4f}")
    drop = out["fp16"] - out["quamba"]
    common.emit("table3/quamba_drop", 0.0, f"drop={drop:.4f}")
    return out


if __name__ == "__main__":
    run()
