"""Paper Table 4: quantizing a hybrid attention+SSM(+MoE) model.

Zamba2 (hybrid family) stands in for Jamba: the same combination matrix --
which sub-module gets quantized -- reproduced with a trained reduced
hybrid.  Claims: quantizing the SSM naively degrades the model; Quamba's
SSM treatment + W8A8 attention recovers accuracy.
"""
from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.quant.recipe import QuantSpec


def run() -> dict:
    cfg, params = common.trained_model("zamba2-1.2b")
    stats = common.calibration_stats(cfg, params)
    out = {"fp16": common.perplexity_of(cfg, params)}
    combos = {
        "mamba_static": QuantSpec(method="static"),
        "mamba_quamba": QuantSpec(method="quamba"),
    }
    for name, spec in combos.items():
        qparams, qctx = common.quantized(cfg, params, stats, spec)
        out[name] = common.perplexity_of(cfg, qparams, qctx)
    for k, v in out.items():
        common.emit(f"table4/ppl_{k}", 0.0, f"ppl={v:.4f}")
    common.emit("table4/quamba_recovers", 0.0,
                f"{out['mamba_quamba'] < out['mamba_static']}")
    return out


if __name__ == "__main__":
    run()
