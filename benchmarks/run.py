"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [table ...]``
prints ``name,us_per_call,derived`` CSV lines.

``pr_speed`` additionally writes ``BENCH_PR.json`` at the repo root
(decode TPOT fp vs quamba vs quamba+kernels, prefill tokens/s and
dispatch counts, bytes moved) -- the perf trajectory future PRs are
measured against.  ``BENCH_SMOKE=1`` shrinks iteration counts for CI.
"""
from __future__ import annotations

import sys
import time


TABLES = (
    "table1_latency",
    "table2_perplexity",
    "table3_zeroshot",
    "table4_hybrid",
    "table5_ablation",
    "table6_percentile",
    "table8_lowbit",
    "table9_input_quant",
    "fig5_error_bound",
    "roofline_report",
    "pr_speed",
)


def main() -> None:
    want = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived")
    for name in want:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        mod.run()
        print(f"# {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
