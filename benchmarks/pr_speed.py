"""PR perf trajectory: decode TPOT (fp vs quamba-qdq vs quamba+kernels
vs quamba-w4a8 on the int4-matmul kernels backend, with the nibble-packed
matmul weight bytes next to the int8 figure),
chunked-prefill throughput/dispatch counts, bytes moved, the
request-lifecycle serving metrics (per-request TTFT/TPOT/queue-time,
queue-depth and occupancy series through the scheduler), and the
shared-prefix prefix-cache workload (``serve.prefix_cache``: hit-path
vs miss-path TTFT, hit rate, bytes), the speculative-decoding workload
(``serve.spec_decode``: tokens/s uplift over vanilla decode on the
kernel backend, acceptance rate, greedy bit-identity), and the
trace-driven open-loop load test (``serve.loadgen``: p99 TTFT,
goodput, async-pump vs sync time-weighted occupancy, prefix-cache
spill-tier counters), and the disaggregated prefill/decode workload
(``serve.disagg``: p95 TTFT through split worker pools, snapshot
transfer bytes/latency, stream-identity control), and the QAT
recovery table (``qat``: fp vs PTQ vs QAT-finetuned eval loss per
sub-8-bit preset with the recovered fraction of the PTQ gap).  The
file carries a top-level ``run_meta`` provenance stamp (git commit,
timestamp, jax backend/device, seed) which the perf gate ignores.

``python -m benchmarks.run pr_speed`` writes the results to
``BENCH_PR.json`` at the repo root so future PRs have a baseline to
beat.  On CPU the Pallas kernels execute in interpret mode, so the
kernel-backend wall clock is NOT the deployment number -- the json
records ``interpret_mode`` so the trajectory is comparable only within
a fixed backend; the dispatch counts and byte ratios are
hardware-independent.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import platform
import subprocess
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels._backend import default_interpret
from repro.quant.recipe import get_spec
from repro.models import (decode_step, init_decode_state, param_count,
                          prefill_step)
from repro.serve import LLMEngine, SamplingParams, SpecConfig
from repro.serve.disagg import DisaggEngine
from repro.serve.loadgen import (SLO, ClusteredArrivals, RAGLongPrompt,
                                 SharedPrefixChat, WorkloadMix)
from repro.serve.loadgen import run as loadgen_run

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_PR.json")
DECODE_BATCH = 8
PREFILL_LEN = 256
PREFILL_CHUNK = 128
# One seed governs every stochastic stream in this file (the QAT data
# order, its eval split); it is stamped into run_meta so an archived
# BENCH_PR.json records exactly which streams produced its numbers.
BENCH_SEED = int(os.environ.get("BENCH_SEED", "0"))


def _run_meta() -> dict:
    """Provenance stamp for BENCH_PR.json: which code, when, on what.

    Top-level so bisecting a perf regression from archived artifacts
    does not require the CI run that produced them; the gate
    (``scripts/compare_bench.py``) reads only its dotted metric keys
    and ignores this block entirely.
    """
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True,
            text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None
    dev = jax.devices()[0]
    return {
        "git_commit": commit,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "jax_version": jax.__version__,
        "backend": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "seed": BENCH_SEED,
    }


def _tpot(cfg, params, qctx, iters: int = 20) -> float:
    state = init_decode_state(cfg, DECODE_BATCH, 256,
                              cache_dtype=jnp.float32)
    tok = jnp.zeros((DECODE_BATCH,), jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t,
                                               qctx=qctx)[0])
    return common.timer(step, params, state, tok, iters=iters)


def _prefill_rate(cfg, params, qctx, iters: int = 5):
    """(tokens/s through chunked prefill, tokens/s per-token fallback)."""
    toks = jnp.zeros((1, PREFILL_CHUNK), jnp.int32)
    state = init_decode_state(cfg, 1, PREFILL_LEN + 8,
                              cache_dtype=jnp.float32)
    pf = jax.jit(lambda p, s, t: prefill_step(p, cfg, s, t,
                                              qctx=qctx)[1])
    us_chunk = common.timer(pf, params, state, toks, iters=iters)
    chunked_tps = PREFILL_CHUNK / (us_chunk / 1e6)

    tok1 = jnp.zeros((1,), jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t,
                                               qctx=qctx)[1])
    us_tok = common.timer(step, params, state, tok1, iters=iters)
    per_token_tps = 1.0 / (us_tok / 1e6)
    return chunked_tps, per_token_tps


def _engine_dispatches(cfg, params, qctx) -> dict:
    eng = LLMEngine(params, cfg, max_batch=2, max_len=PREFILL_LEN + 8,
                    qctx=qctx, prefill_chunk=PREFILL_CHUNK)
    prompt = [int(t) for t in np.arange(PREFILL_LEN) % cfg.vocab_size]
    eng.add_request(prompt, SamplingParams(max_tokens=2))
    eng.run()
    return {
        "prompt_len": PREFILL_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "prefill_dispatches": eng.counters["prefill_dispatches"],
        "per_token_dispatches_would_be": PREFILL_LEN - 1,
    }


def _prefix_cache_workload(cfg, params, qctx, smoke: bool) -> dict:
    """Shared-prefix serving: one cold request pays the prefill and
    fills the ``StateCache``; the following requests reuse the same
    prompt and restore the cached SSM state instead of prefilling.
    The hit/miss TTFT split is the cache's measurable win (miss-side
    TTFT includes the prefill compiles a cold engine pays either way).
    """
    shared_len = 96 if smoke else 192
    chunk = 32
    eng = LLMEngine(params, cfg, max_batch=2, max_len=shared_len + 24,
                    qctx=qctx, prefill_chunk=chunk, prefix_cache_mb=64)
    shared = [(5 * j + 3) % cfg.vocab_size for j in range(shared_len)]
    prompt = shared + [7, 11]
    n_hot = 3 if smoke else 6
    eng.add_request(list(prompt), SamplingParams(max_tokens=4))
    eng.run()                       # cold: full prefill, cache filled
    for _ in range(n_hot):          # hot: full hits, zero prefill
        eng.add_request(list(prompt), SamplingParams(max_tokens=4))
    eng.run()
    pc = eng.metrics_json()["prefix_cache"]
    return {
        "shared_prefix_len": shared_len,
        "prefill_chunk": chunk,
        "requests": 1 + n_hot,
        "hit_rate": pc["hit_rate"],
        "full_hit_rate": pc["full_hit_rate"],
        "tokens_reused": pc["tokens_reused"],
        "bytes_in_use": pc["bytes_in_use"],
        "entries": pc["entries"],
        "prefix_restores": eng.counters["prefix_restores"],
        "ttft_ms_hit": pc["ttft_ms_hit"],
        "ttft_ms_miss": pc["ttft_ms_miss"],
    }


def _spec_decode_workload(cfg, qm, smoke: bool) -> dict:
    """Speculative decoding on the int8 kernel path: the target runs
    the Pallas ``kernels`` backend (per-dispatch cost dominates on the
    CPU smoke path -- interpret mode makes every launch expensive, the
    same shape as a launch-bound accelerator serving a small model) and
    a self-draft rides the cheap XLA ``qdq`` backend over the SAME
    weights, so acceptance sits near 1.0 and each round replaces
    ``k + 1`` target dispatches with one fused draft scan + one
    multi-token verify.  A shared prefix plus the prefix cache keeps
    prefill out of the timed window; a warmup request pays every
    compile before the clock starts.  Greedy spec streams must be
    bit-identical to the vanilla control by construction.
    """
    k = 4
    shared_len = 32 if smoke else 64
    n_req = 4
    max_tokens = 12 if smoke else 24
    chunk = 32
    kq = qm.qctx(backend="kernels")
    shared = [(5 * j + 3) % cfg.vocab_size for j in range(shared_len)]

    def serve(spec):
        eng = LLMEngine(qm.params, cfg, max_batch=n_req,
                        max_len=shared_len + max_tokens + 8, qctx=kq,
                        prefill_chunk=chunk, prefix_cache_mb=32,
                        speculative=spec)
        # warmup: same prompt length -> compiles prefill chunks, the
        # decode step / fused spec round, and fills the prefix cache
        eng.add_request(shared + [cfg.vocab_size - 1],
                        SamplingParams(max_tokens=k + 2))
        eng.run()
        sts = [eng.add_request(shared + [i + 1],
                               SamplingParams(max_tokens=max_tokens),
                               request_id=f"spec{i}")
               for i in range(n_req)]
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        return [list(s.token_ids) for s in sts], \
            n_req * max_tokens / dt, eng

    s_van, tps_van, _ = serve(None)
    s_spec, tps_spec, eng = serve(
        SpecConfig(draft="self", k=k, draft_qctx=qm.qctx(backend="qdq")))
    sd = eng.metrics_json()["spec_decode"]
    return {
        "k": k,
        "draft": "self (qdq backend)",
        "target_backend": "kernels",
        "shared_prefix_len": shared_len,
        "requests": n_req,
        "max_tokens": max_tokens,
        "tokens_per_s": tps_spec,
        "vanilla_tokens_per_s": tps_van,
        "uplift": tps_spec / tps_van,
        "streams_match_greedy": s_spec == s_van,
        "acceptance_rate": sd["acceptance_rate"],
        "rounds": sd["rounds"],
        "drafted_tokens": sd["drafted_tokens"],
        "accepted_tokens": sd["accepted_tokens"],
        "rolled_back_tokens": sd["rolled_back_tokens"],
        "per_request_speedup": sd["per_request_speedup"],
    }


def _matmul_weight_bytes(q4_tree, q8_tree):
    """(int4 bytes, int8 bytes) over the matmul weight sites.

    Walks the nibble-packed W4A8 qdata next to the W8A8 qdata of the
    SAME model: every ``{"qw4"}`` leaf stores two weights per byte while
    its int8 counterpart stores one, so the ratio is a measured storage
    fact, not an assumed 0.5 (odd contraction dims pad a nibble row).
    Non-matmul sites (conv taps, the A matrix) are excluded on both
    sides -- they stay int8 under W4A8 by design.
    """
    b4 = b8 = 0
    if isinstance(q4_tree, dict):
        if "qw4" in q4_tree:
            b4 += int(q4_tree["qw4"].size)          # int8 leaf: 1 B/elem
            b8 += int(q8_tree["qw"].size)
        elif "s_w" not in q4_tree:                  # group node: recurse
            for k, v in q4_tree.items():
                s4, s8 = _matmul_weight_bytes(v, q8_tree[k])
                b4, b8 = b4 + s4, b8 + s8
    return b4, b8


def _w4a8_section(cfg, params, stats, qm_int8, iters: int) -> dict:
    """W4A8 on the real kernels backend (PR 8): ``quamba-w4a8`` routes
    every matmul site through the nibble-packed ``int4_matmul`` Pallas
    kernel -- no qdq fallback -- so the TPOT here is an executed-kernel
    number and the weight-bytes figure reflects the packed storage."""
    spec = dataclasses.replace(get_spec("quamba-w4a8"), backend="kernels")
    qm4 = common.quantized_model(cfg, params, stats, spec)
    desc = qm4.describe()
    b4, b8 = _matmul_weight_bytes(qm4.qdata["qw"], qm_int8.qdata["qw"])
    return {
        "preset": "quamba-w4a8",
        "effective_backend": desc["effective_backend"],
        "backend_fallback_reason": desc["backend_fallback_reason"],
        "tpot_kernels_ms": _tpot(cfg, qm4.params, qm4.qctx(), iters) / 1e3,
        "matmul_weight_bytes_int4": b4,
        "matmul_weight_bytes_int8": b8,
        "matmul_weight_bytes_ratio": b4 / b8,
    }


def _qat_section(cfg, params, stats, smoke: bool) -> dict:
    """QAT recovery table (PR 10): eval loss of the fp model vs plain
    PTQ vs a short QAT fine-tune, per sub-8-bit preset, with the
    recovered fraction of the PTQ gap.  Every stochastic stream (train
    order, eval split) derives from ``BENCH_SEED`` so the table is
    reproducible bit-for-bit.  Under BENCH_SMOKE only the headline
    ``quamba-w4a4`` row runs -- the skipped presets are recorded, not
    silently dropped."""
    from repro import api
    from repro.data import batches, eval_batches
    from repro.models import loss_fn
    from repro.train.qat import QATConfig

    all_presets = ("quamba-w4a8", "quamba-w4a8-se", "quamba-w4a4")
    presets = ("quamba-w4a4",) if smoke else all_presets
    steps = 10 if smoke else 40
    ev = eval_batches(cfg.vocab_size, 8, common.SEQ, 2 if smoke else 4,
                      seed=999 + BENCH_SEED)

    def mean_loss(p, qctx=None):
        f = jax.jit(lambda pp, b: loss_fn(pp, cfg, b, qctx=qctx)[0])
        return float(np.mean([float(f(p, b)) for b in ev]))

    section: dict = {
        "fp_eval_loss": mean_loss(params),
        "steps": steps,
        "lr": 1e-3,
        "seed": BENCH_SEED,
        "skipped_presets": sorted(set(all_presets) - set(presets)),
    }
    for preset in presets:
        quant = api.Quantizer(cfg, preset).with_stats(stats)
        ptq = quant.quantize(params)
        ptq_loss = mean_loss(ptq.params, ptq.qctx())
        qm = quant.finetune(
            params,
            batches(cfg.vocab_size, 8, common.SEQ, seed=29 + BENCH_SEED,
                    num_steps=steps),
            qat=QATConfig(steps=steps, lr=1e-3, learn_scales=True))
        qat_loss = mean_loss(qm.params, qm.qctx())
        gap = ptq_loss - section["fp_eval_loss"]
        key = preset.replace("quamba-", "").replace("-", "_")
        section[key] = {
            "preset": preset,
            "ptq_eval_loss": ptq_loss,
            "qat_eval_loss": qat_loss,
            # w8-ish presets can have a near-zero PTQ gap; report a full
            # recovery there instead of a 0/0 blow-up
            "recovery": ((ptq_loss - qat_loss) / gap
                         if gap > 1e-4 else 1.0),
        }
    return section


def _serve_lifecycle(cfg, params, qctx, n_requests: int) -> dict:
    """Request-lifecycle metrics through the scheduler: a burst of
    heterogeneous requests (greedy + sampled) deeper than the slot
    count, so the queue-depth/occupancy series actually move.  The
    TTFT/queue numbers feed the CI perf gate's scheduling coverage."""
    eng = LLMEngine(params, cfg, max_batch=4, max_len=96, qctx=qctx,
                    prefill_chunk=32)
    for i in range(n_requests):
        sp = (SamplingParams(max_tokens=8) if i % 2 == 0 else
              SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                             seed=i, max_tokens=8))
        eng.add_request([(3 * i + j) % cfg.vocab_size
                         for j in range(2 + i % 6)], sp)
    eng.run()
    mj = eng.metrics_json()
    e = mj["engine"]
    return {
        "requests": n_requests,
        "max_batch": 4,
        "ttft_ms": mj["summary"]["ttft_ms"],
        "tpot_ms": mj["summary"]["tpot_ms"],
        "queue_time_ms": mj["summary"]["queue_time_ms"],
        "queue_depth_series": e["queue_depth_series"],
        "queue_depth_max": max(e["queue_depth_series"], default=0),
        "occupancy_mean": e["occupancy_mean"],
        "tokens_per_s": e["tokens_per_s"],
        "decode_steps": e["decode_steps"],
        "prefill_dispatches": e["prefill_dispatches"],
    }


def _loadgen_workload(cfg, params, qctx, smoke: bool) -> dict:
    """Trace-driven open-loop load test (``repro.serve.loadgen``):
    a seeded chat+RAG mix with bursty arrivals and mid-flight cancels,
    replayed twice on the SAME trace -- once through the async
    ``EnginePump`` and once through the sync consumer-pumped control.

    Arrivals are CLUSTERED (bursts of >= max_batch requests, one gap
    apart) and the pacing self-calibrates: a ``time_scale=0`` probe
    measures the pure drain time, then the inter-cluster gap is set to
    ~1.3x one cluster's share of it.  The async pump drains each burst
    at full batch during the following gap; the sync control cannot
    decode until the last burst has landed -- that idle window is what
    the time-weighted occupancy comparison charges it for.  The trace
    is saved next to the checkpoint so the run is replayable
    bit-for-bit.
    """
    n_clusters = 3 if smoke else 5
    n = n_clusters * 4                  # one full batch per burst
    mix = WorkloadMix(
        [(3, SharedPrefixChat(n_prefixes=4, prefix_len=24,
                              suffix_len=(1, 4), max_tokens=(4, 8))),
         (1, RAGLongPrompt(prompt_len=(32, 56), max_tokens=(2, 4)))],
        cancel_fraction=0.1)
    trace = mix.build(
        n_requests=n, vocab_size=cfg.vocab_size, seed=1234,
        arrivals=ClusteredArrivals(n_clusters=n_clusters, gap_s=1.0,
                                   spread_s=0.002))
    os.makedirs(common.BENCH_DIR, exist_ok=True)
    trace_path = trace.save(os.path.join(common.BENCH_DIR,
                                         "loadgen_trace.json"))

    def engine():
        return LLMEngine(params, cfg, max_batch=4, max_len=96,
                         qctx=qctx, prefill_chunk=32, prefix_cache_mb=8)

    probe = loadgen_run(engine(), trace, pump="sync", time_scale=0.0)
    # inter-cluster gap = 1.3x one cluster's drain share (the nominal
    # gap is 1.0 s, so time_scale IS the gap in seconds)
    ts = 1.3 * probe["wall_s"] / n_clusters
    slo = SLO(ttft_p99_ms=120_000.0)     # finiteness gate, not a perf bar
    rep_a = loadgen_run(engine(), trace, slo, pump="async",
                        time_scale=ts)
    rep_s = loadgen_run(engine(), trace, pump="sync", time_scale=ts)

    sync_occ = rep_s["occupancy_mean"]
    return {
        "trace": rep_a["trace"],
        "trace_path": os.path.abspath(trace_path),
        "time_scale": ts,
        "wall_s": rep_a["wall_s"],
        "ttft_ms": rep_a["ttft_ms"],
        "tpot_ms": rep_a["tpot_ms"],
        "queue_time_ms": rep_a["queue_time_ms"],
        "submit_lag_ms": rep_a["submit_lag_ms"],
        "goodput_requests": rep_a["goodput_requests"],
        "goodput_tokens": rep_a["goodput_tokens"],
        "goodput_rps": rep_a["goodput_rps"],
        "completed": rep_a["completed"],
        "cancelled": rep_a["cancelled"],
        "steps": rep_a["steps"],
        "steps_before_last_arrival": rep_a["steps_before_last_arrival"],
        "occupancy_mean": rep_a["occupancy_mean"],
        "slo": rep_a["slo"],
        "streams_match_sync": (rep_a["token_streams"]
                               == rep_s["token_streams"]),
        "sync_control": {
            "occupancy_mean": sync_occ,
            "steps_before_last_arrival":
                rep_s["steps_before_last_arrival"],
            "wall_s": rep_s["wall_s"],
            "goodput_requests": rep_s["goodput_requests"],
        },
        "occupancy_gain": (rep_a["occupancy_mean"] / sync_occ
                           if sync_occ else None),
    }


def _disagg_workload(cfg, params, qctx, smoke: bool) -> dict:
    """Disaggregated prefill/decode serving (``repro.serve.disagg``):
    a clustered-burst chat+RAG trace through a DisaggEngine (1 prefill
    + 2 decode workers, thread mode) and through the single-process
    control on the same knobs.  Streams must match bit for bit; the
    disagg-only costs -- snapshot transfer bytes/latency and per-role
    occupancy -- ride next to the TTFT tail the CI gate watches
    (``serve.disagg.ttft_ms.p95``)."""
    n_clusters = 2 if smoke else 4
    n = n_clusters * 4
    mix = WorkloadMix(
        [(3, SharedPrefixChat(n_prefixes=4, prefix_len=24,
                              suffix_len=(1, 4), max_tokens=(4, 8))),
         (1, RAGLongPrompt(prompt_len=(32, 56), max_tokens=(2, 4)))])
    trace = mix.build(
        n_requests=n, vocab_size=cfg.vocab_size, seed=4321,
        arrivals=ClusteredArrivals(n_clusters=n_clusters, gap_s=1.0,
                                   spread_s=0.002))
    mono = LLMEngine(params, cfg, max_batch=4, max_len=96, qctx=qctx,
                     prefill_chunk=32)
    rep_m = loadgen_run(mono, trace, pump="sync", time_scale=0.0)
    with DisaggEngine(params, cfg, prefill_workers=1, decode_workers=2,
                      max_batch=2, max_len=96, qctx=qctx,
                      prefill_chunk=32) as eng:
        rep_d = loadgen_run(eng, trace, pump="sync", time_scale=0.0)
        mj = eng.metrics_json()
    d = mj["disagg"]
    return {
        "prefill_workers": 1,
        "decode_workers": 2,
        "requests": n,
        "ttft_ms": rep_d["ttft_ms"],
        "tpot_ms": rep_d["tpot_ms"],
        "goodput_requests": rep_d["goodput_requests"],
        "streams_match_single_process": (rep_d["token_streams"]
                                         == rep_m["token_streams"]),
        "transfers": d["transport"]["transfers"],
        "transfer_bytes": d["transport"]["bytes"],
        "transfer_latency_ms": d["transport"]["latency_ms"],
        "direct_admits": d["transport"]["direct_admits"],
        "prefill_occupancy": d["prefill"]["occupancy"],
        "decode_occupancy_mean": d["decode"]["occupancy_mean"],
        "snapshot_restores": d["decode"]["snapshot_restores"],
        "admission_suggested": d["admission"]["suggested"],
    }


def _spill_workload(cfg, params, qctx, smoke: bool) -> dict:
    """Host-RAM spill tier under real eviction pressure: the device
    budget holds ~1.6 state snapshots while the workload cycles more
    prefixes than that, so earlier prefixes are LRU-evicted to host;
    the second pass over the same prefixes must still HIT (promoted
    back from host).  Three stream controls prove correctness: spill
    == big-device-cache == cache-off, bit for bit.
    """
    di, ds, w = cfg.d_inner, cfg.d_state, cfg.conv_width
    entry_bytes = cfg.n_layers * (di * ds + (w - 1) * di) * 4
    device_mb = 1.6 * entry_bytes / (1 << 20)
    n_prefixes = 3 if smoke else 4
    plen = 40

    def prompts():
        for i in range(n_prefixes):
            head = [(11 * i + 2 * j + 1) % cfg.vocab_size
                    for j in range(plen)]
            yield head + [i + 1, 5]

    def serve(**cache_kw):
        eng = LLMEngine(params, cfg, max_batch=2, max_len=plen + 12,
                        qctx=qctx, prefill_chunk=16, **cache_kw)
        streams = []
        for _ in range(2):               # pass 2 re-visits evictees
            for p in prompts():
                st = eng.add_request(list(p),
                                     SamplingParams(max_tokens=4))
                eng.run()
                streams.append(list(st.token_ids))
        return eng, streams

    eng_spill, s_spill = serve(prefix_cache_mb=device_mb,
                               prefix_cache_spill_mb=64)
    _, s_device = serve(prefix_cache_mb=64)
    _, s_off = serve()
    pc = eng_spill.metrics_json()["prefix_cache"]
    return {
        "requests": 2 * n_prefixes,
        "device_budget_mb": device_mb,
        "entry_bytes": entry_bytes,
        "hit_rate": pc["hit_rate"],
        "spills": pc["spills"],
        "spilled_bytes": pc["spilled_bytes"],
        "promotions": pc["promotions"],
        "promoted_bytes": pc["promoted_bytes"],
        "host_entries": pc["host_entries"],
        "host_bytes_in_use": pc["host_bytes_in_use"],
        "streams_match_device_tier": s_spill == s_device,
        "streams_match_cache_off": s_spill == s_off,
    }


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    qm = common.quantized_model(cfg, params, stats, "quamba")
    smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    iters = 3 if smoke else 20
    p_iters = 2 if smoke else 5

    out: dict = {
        "run_meta": _run_meta(),
        "model": cfg.name,
        "interpret_mode": default_interpret(),
        "decode_batch": DECODE_BATCH,
    }
    out["tpot_fp_us"] = _tpot(cfg, params, None, iters)
    out["tpot_quamba_qdq_us"] = _tpot(cfg, qm.params,
                                      qm.qctx(backend="qdq"), iters)
    out["tpot_quamba_kernels_ms"] = _tpot(cfg, qm.params,
                                          qm.qctx(backend="kernels"),
                                          iters) / 1e3
    common.emit("pr_speed/tpot_fp", out["tpot_fp_us"], "decode_step")
    common.emit("pr_speed/tpot_quamba_qdq", out["tpot_quamba_qdq_us"],
                "decode_step(fake-quant oracle)")
    common.emit("pr_speed/tpot_quamba_kernels",
                out["tpot_quamba_kernels_ms"] * 1e3,
                "decode_step(int8 Pallas kernels; interpret mode off-TPU)")

    out["w4a8"] = _w4a8_section(cfg, params, stats, qm, iters)
    w4 = out["w4a8"]
    common.emit(
        "pr_speed/tpot_w4a8_kernels", w4["tpot_kernels_ms"] * 1e3,
        f"decode_step(int4 matmul kernels, backend="
        f"{w4['effective_backend']}); matmul weights "
        f"{w4['matmul_weight_bytes_int4']} B vs int8 "
        f"{w4['matmul_weight_bytes_int8']} B "
        f"({w4['matmul_weight_bytes_ratio']:.3f}x)")

    out["qat"] = _qat_section(cfg, params, stats, smoke)
    q4 = out["qat"]["w4a4"]
    common.emit(
        "pr_speed/qat_w4a4_recovery", q4["recovery"],
        f"eval loss fp {out['qat']['fp_eval_loss']:.3f} | ptq "
        f"{q4['ptq_eval_loss']:.3f} | qat {q4['qat_eval_loss']:.3f} "
        f"({q4['recovery']:.0%} of the PTQ gap recovered in "
        f"{out['qat']['steps']} steps, seed {BENCH_SEED})")

    ch_tps, tok_tps = _prefill_rate(cfg, qm.params, qm.qctx(), p_iters)
    out["prefill_chunked_tokens_per_s"] = ch_tps
    out["prefill_per_token_tokens_per_s"] = tok_tps
    common.emit("pr_speed/prefill_chunked", 1e6 / max(ch_tps, 1e-9),
                f"{ch_tps:.0f} tok/s (chunk={PREFILL_CHUNK})")
    common.emit("pr_speed/prefill_per_token", 1e6 / max(tok_tps, 1e-9),
                f"{tok_tps:.0f} tok/s (1 dispatch/token)")
    out["engine_prefill"] = _engine_dispatches(cfg, qm.params, qm.qctx())

    out["serve"] = _serve_lifecycle(cfg, qm.params, qm.qctx(),
                                    n_requests=6 if smoke else 12)
    common.emit("pr_speed/serve_ttft", out["serve"]["ttft_ms"]["mean"]
                * 1e3,  # stats are ms; emit expects us
                f"mean TTFT over {out['serve']['requests']} requests "
                f"(queue depth max {out['serve']['queue_depth_max']})")

    out["serve"]["prefix_cache"] = _prefix_cache_workload(
        cfg, qm.params, qm.qctx(), smoke)
    pc = out["serve"]["prefix_cache"]
    common.emit(
        "pr_speed/serve_prefix_cache_ttft_hit",
        pc["ttft_ms_hit"]["mean"] * 1e3,
        f"hit {pc['ttft_ms_hit']['mean']:.1f} ms vs miss "
        f"{pc['ttft_ms_miss']['mean']:.1f} ms over a "
        f"{pc['shared_prefix_len']}-token shared prefix "
        f"(hit rate {pc['hit_rate']:.2f})")

    sd = _spec_decode_workload(cfg, qm, smoke)
    out["serve"]["spec_decode"] = sd
    common.emit(
        "pr_speed/serve_spec_decode", 1e6 / max(sd["tokens_per_s"], 1e-9),
        f"{sd['tokens_per_s']:.0f} tok/s spec vs "
        f"{sd['vanilla_tokens_per_s']:.0f} vanilla "
        f"({sd['uplift']:.2f}x, acceptance "
        f"{sd['acceptance_rate']:.2f}, k={sd['k']}, greedy streams "
        f"match: {sd['streams_match_greedy']})")

    lg = _loadgen_workload(cfg, qm.params, qm.qctx(), smoke)
    lg["spill"] = _spill_workload(cfg, qm.params, qm.qctx(), smoke)
    out["serve"]["loadgen"] = lg
    common.emit(
        "pr_speed/serve_loadgen_ttft_p99", lg["ttft_ms"]["p99"] * 1e3,
        f"p99 TTFT over {lg['trace']['n_requests']} open-loop requests "
        f"(goodput {lg['goodput_requests']}, async occupancy "
        f"{lg['occupancy_mean']:.2f} vs sync "
        f"{lg['sync_control']['occupancy_mean']:.2f})")
    common.emit(
        "pr_speed/serve_spill_promotions",
        float(lg["spill"]["promotions"]),
        f"{lg['spill']['spills']} spills / "
        f"{lg['spill']['promotions']} promotions, streams match "
        f"cache-off: {lg['spill']['streams_match_cache_off']}")

    dg = _disagg_workload(cfg, qm.params, qm.qctx(), smoke)
    out["serve"]["disagg"] = dg
    common.emit(
        "pr_speed/serve_disagg_ttft_p95", dg["ttft_ms"]["p95"] * 1e3,
        f"p95 TTFT through {dg['prefill_workers']} prefill + "
        f"{dg['decode_workers']} decode workers "
        f"({dg['transfers']} snapshot transfers, "
        f"{dg['transfer_bytes']} B, streams match: "
        f"{dg['streams_match_single_process']})")

    # bytes moved per decode step: weights read once per token (the
    # memory-bound regime the paper's 1.7x rides on) + recurrent state
    n_params = param_count(cfg)
    di, n, w = cfg.d_inner, cfg.d_state, cfg.conv_width
    state_elems = DECODE_BATCH * cfg.n_layers * (di * n + (w - 1) * di)
    out["bytes"] = {
        "weights_fp16_mb": n_params * 2 / 1e6,
        "weights_int8_mb": n_params * 1 / 1e6,
        "state_fp32_mb": state_elems * 4 / 1e6,
        "state_int8_mb": state_elems * 1 / 1e6,
        "weight_ratio": 2.0,
    }

    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    common.emit("pr_speed/bench_pr_json", 0.0,
                os.path.abspath(OUT_PATH))
    return out


if __name__ == "__main__":
    run()
