"""PR perf trajectory: decode TPOT (fp vs quamba-qdq vs quamba+kernels),
chunked-prefill throughput/dispatch counts, bytes moved, the
request-lifecycle serving metrics (per-request TTFT/TPOT/queue-time,
queue-depth and occupancy series through the scheduler), and the
shared-prefix prefix-cache workload (``serve.prefix_cache``: hit-path
vs miss-path TTFT, hit rate, bytes).

``python -m benchmarks.run pr_speed`` writes the results to
``BENCH_PR.json`` at the repo root so future PRs have a baseline to
beat.  On CPU the Pallas kernels execute in interpret mode, so the
kernel-backend wall clock is NOT the deployment number -- the json
records ``interpret_mode`` so the trajectory is comparable only within
a fixed backend; the dispatch counts and byte ratios are
hardware-independent.
"""
from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels._backend import default_interpret
from repro.models import (decode_step, init_decode_state, param_count,
                          prefill_step)
from repro.serve import LLMEngine, SamplingParams

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_PR.json")
DECODE_BATCH = 8
PREFILL_LEN = 256
PREFILL_CHUNK = 128


def _tpot(cfg, params, qctx, iters: int = 20) -> float:
    state = init_decode_state(cfg, DECODE_BATCH, 256,
                              cache_dtype=jnp.float32)
    tok = jnp.zeros((DECODE_BATCH,), jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t,
                                               qctx=qctx)[0])
    return common.timer(step, params, state, tok, iters=iters)


def _prefill_rate(cfg, params, qctx, iters: int = 5):
    """(tokens/s through chunked prefill, tokens/s per-token fallback)."""
    toks = jnp.zeros((1, PREFILL_CHUNK), jnp.int32)
    state = init_decode_state(cfg, 1, PREFILL_LEN + 8,
                              cache_dtype=jnp.float32)
    pf = jax.jit(lambda p, s, t: prefill_step(p, cfg, s, t,
                                              qctx=qctx)[1])
    us_chunk = common.timer(pf, params, state, toks, iters=iters)
    chunked_tps = PREFILL_CHUNK / (us_chunk / 1e6)

    tok1 = jnp.zeros((1,), jnp.int32)
    step = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t,
                                               qctx=qctx)[1])
    us_tok = common.timer(step, params, state, tok1, iters=iters)
    per_token_tps = 1.0 / (us_tok / 1e6)
    return chunked_tps, per_token_tps


def _engine_dispatches(cfg, params, qctx) -> dict:
    eng = LLMEngine(params, cfg, max_batch=2, max_len=PREFILL_LEN + 8,
                    qctx=qctx, prefill_chunk=PREFILL_CHUNK)
    prompt = [int(t) for t in np.arange(PREFILL_LEN) % cfg.vocab_size]
    eng.add_request(prompt, SamplingParams(max_tokens=2))
    eng.run()
    return {
        "prompt_len": PREFILL_LEN,
        "prefill_chunk": PREFILL_CHUNK,
        "prefill_dispatches": eng.counters["prefill_dispatches"],
        "per_token_dispatches_would_be": PREFILL_LEN - 1,
    }


def _prefix_cache_workload(cfg, params, qctx, smoke: bool) -> dict:
    """Shared-prefix serving: one cold request pays the prefill and
    fills the ``StateCache``; the following requests reuse the same
    prompt and restore the cached SSM state instead of prefilling.
    The hit/miss TTFT split is the cache's measurable win (miss-side
    TTFT includes the prefill compiles a cold engine pays either way).
    """
    shared_len = 96 if smoke else 192
    chunk = 32
    eng = LLMEngine(params, cfg, max_batch=2, max_len=shared_len + 24,
                    qctx=qctx, prefill_chunk=chunk, prefix_cache_mb=64)
    shared = [(5 * j + 3) % cfg.vocab_size for j in range(shared_len)]
    prompt = shared + [7, 11]
    n_hot = 3 if smoke else 6
    eng.add_request(list(prompt), SamplingParams(max_tokens=4))
    eng.run()                       # cold: full prefill, cache filled
    for _ in range(n_hot):          # hot: full hits, zero prefill
        eng.add_request(list(prompt), SamplingParams(max_tokens=4))
    eng.run()
    pc = eng.metrics_json()["prefix_cache"]
    return {
        "shared_prefix_len": shared_len,
        "prefill_chunk": chunk,
        "requests": 1 + n_hot,
        "hit_rate": pc["hit_rate"],
        "full_hit_rate": pc["full_hit_rate"],
        "tokens_reused": pc["tokens_reused"],
        "bytes_in_use": pc["bytes_in_use"],
        "entries": pc["entries"],
        "prefix_restores": eng.counters["prefix_restores"],
        "ttft_ms_hit": pc["ttft_ms_hit"],
        "ttft_ms_miss": pc["ttft_ms_miss"],
    }


def _serve_lifecycle(cfg, params, qctx, n_requests: int) -> dict:
    """Request-lifecycle metrics through the scheduler: a burst of
    heterogeneous requests (greedy + sampled) deeper than the slot
    count, so the queue-depth/occupancy series actually move.  The
    TTFT/queue numbers feed the CI perf gate's scheduling coverage."""
    eng = LLMEngine(params, cfg, max_batch=4, max_len=96, qctx=qctx,
                    prefill_chunk=32)
    for i in range(n_requests):
        sp = (SamplingParams(max_tokens=8) if i % 2 == 0 else
              SamplingParams(temperature=0.8, top_k=20, top_p=0.95,
                             seed=i, max_tokens=8))
        eng.add_request([(3 * i + j) % cfg.vocab_size
                         for j in range(2 + i % 6)], sp)
    eng.run()
    mj = eng.metrics_json()
    e = mj["engine"]
    return {
        "requests": n_requests,
        "max_batch": 4,
        "ttft_ms": mj["summary"]["ttft_ms"],
        "tpot_ms": mj["summary"]["tpot_ms"],
        "queue_time_ms": mj["summary"]["queue_time_ms"],
        "queue_depth_series": e["queue_depth_series"],
        "queue_depth_max": max(e["queue_depth_series"], default=0),
        "occupancy_mean": e["occupancy_mean"],
        "tokens_per_s": e["tokens_per_s"],
        "decode_steps": e["decode_steps"],
        "prefill_dispatches": e["prefill_dispatches"],
    }


def run() -> dict:
    cfg, params = common.trained_model()
    stats = common.calibration_stats(cfg, params)
    qm = common.quantized_model(cfg, params, stats, "quamba")
    smoke = os.environ.get("BENCH_SMOKE", "") == "1"
    iters = 3 if smoke else 20
    p_iters = 2 if smoke else 5

    out: dict = {
        "model": cfg.name,
        "interpret_mode": default_interpret(),
        "decode_batch": DECODE_BATCH,
    }
    out["tpot_fp_us"] = _tpot(cfg, params, None, iters)
    out["tpot_quamba_qdq_us"] = _tpot(cfg, qm.params,
                                      qm.qctx(backend="qdq"), iters)
    out["tpot_quamba_kernels_us"] = _tpot(cfg, qm.params,
                                          qm.qctx(backend="kernels"),
                                          iters)
    common.emit("pr_speed/tpot_fp", out["tpot_fp_us"], "decode_step")
    common.emit("pr_speed/tpot_quamba_qdq", out["tpot_quamba_qdq_us"],
                "decode_step(fake-quant oracle)")
    common.emit("pr_speed/tpot_quamba_kernels",
                out["tpot_quamba_kernels_us"],
                "decode_step(int8 Pallas kernels; interpret mode off-TPU)")

    ch_tps, tok_tps = _prefill_rate(cfg, qm.params, qm.qctx(), p_iters)
    out["prefill_chunked_tokens_per_s"] = ch_tps
    out["prefill_per_token_tokens_per_s"] = tok_tps
    common.emit("pr_speed/prefill_chunked", 1e6 / max(ch_tps, 1e-9),
                f"{ch_tps:.0f} tok/s (chunk={PREFILL_CHUNK})")
    common.emit("pr_speed/prefill_per_token", 1e6 / max(tok_tps, 1e-9),
                f"{tok_tps:.0f} tok/s (1 dispatch/token)")
    out["engine_prefill"] = _engine_dispatches(cfg, qm.params, qm.qctx())

    out["serve"] = _serve_lifecycle(cfg, qm.params, qm.qctx(),
                                    n_requests=6 if smoke else 12)
    common.emit("pr_speed/serve_ttft", out["serve"]["ttft_ms"]["mean"]
                * 1e3,  # stats are ms; emit expects us
                f"mean TTFT over {out['serve']['requests']} requests "
                f"(queue depth max {out['serve']['queue_depth_max']})")

    out["serve"]["prefix_cache"] = _prefix_cache_workload(
        cfg, qm.params, qm.qctx(), smoke)
    pc = out["serve"]["prefix_cache"]
    common.emit(
        "pr_speed/serve_prefix_cache_ttft_hit",
        pc["ttft_ms_hit"]["mean"] * 1e3,
        f"hit {pc['ttft_ms_hit']['mean']:.1f} ms vs miss "
        f"{pc['ttft_ms_miss']['mean']:.1f} ms over a "
        f"{pc['shared_prefix_len']}-token shared prefix "
        f"(hit rate {pc['hit_rate']:.2f})")

    # bytes moved per decode step: weights read once per token (the
    # memory-bound regime the paper's 1.7x rides on) + recurrent state
    n_params = param_count(cfg)
    di, n, w = cfg.d_inner, cfg.d_state, cfg.conv_width
    state_elems = DECODE_BATCH * cfg.n_layers * (di * n + (w - 1) * di)
    out["bytes"] = {
        "weights_fp16_mb": n_params * 2 / 1e6,
        "weights_int8_mb": n_params * 1 / 1e6,
        "state_fp32_mb": state_elems * 4 / 1e6,
        "state_int8_mb": state_elems * 1 / 1e6,
        "weight_ratio": 2.0,
    }

    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    common.emit("pr_speed/bench_pr_json", 0.0,
                os.path.abspath(OUT_PATH))
    return out


if __name__ == "__main__":
    run()
