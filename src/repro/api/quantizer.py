"""Quantizer: the chainable builder over calibrate -> quantize.

    qm = (Quantizer(cfg, spec="quamba")
          .calibrate(batches)
          .quantize(params))          # -> QuantizedModel

absorbs the legacy free-function chain (``run_calibration`` ->
``quantize_model`` -> ``make_qctx``): the calibration forward is derived
from the config automatically, stats merge across batches with the
conservative elementwise max (paper §5.1), and the result is a saveable
:class:`repro.api.QuantizedModel` artifact.

``calibrate(batches)`` records the stream; the statistics run lazily
inside ``quantize(params)`` (calibration needs the fp params).  To share
one calibration pass across several specs, compute the stats once with
:func:`calibration_stats` and hand them to each builder via
``with_stats``.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional, Union

from repro.api.artifact import QuantizedModel
from repro.configs.base import ModelConfig
from repro.models import forward
from repro.quant.calibrate import run_calibration
from repro.quant.recipe import QuantSpec, get_spec


def _calib_forward(cfg: ModelConfig) -> Callable:
    return lambda p, b: forward(p, cfg, b, qctx={"mode": "calib"})


def calibration_stats(cfg: ModelConfig, params: Dict, batches: Iterable,
                      max_batches: Optional[int] = None):
    """Run the calibration pass once; reusable across many specs."""
    return run_calibration(_calib_forward(cfg), params, batches,
                           max_batches=max_batches)


class Quantizer:
    """Builds a :class:`QuantizedModel` from a config and a quant spec.

    ``spec`` is a preset name from ``repro.quant.recipe.PRESETS`` (e.g.
    ``"quamba"``, ``"static"``, ``"quamba-w4a8"``), a ``QuantSpec``, or
    ``None`` / ``"fp"`` for a pass-through fp artifact (useful so callers
    can treat fp and quantized models uniformly).
    """

    def __init__(self, cfg: ModelConfig,
                 spec: Union[str, QuantSpec, None] = "quamba"):
        self.cfg = cfg
        if isinstance(spec, str):
            spec = get_spec(spec)            # "fp" -> None
        if spec is not None:
            spec.validate()
        self.spec: Optional[QuantSpec] = spec
        self._stats = None
        self._batches: Optional[Iterable] = None
        self._max_batches: Optional[int] = None

    # -- calibration ------------------------------------------------------
    def calib_forward(self) -> Callable:
        """The auto-derived calibration forward: emits per-site activation
        stats (stacked per layer by the scan) instead of quantizing."""
        return _calib_forward(self.cfg)

    def calibrate(self, batches: Iterable,
                  max_batches: Optional[int] = None) -> "Quantizer":
        """Record the calibration stream (consumed inside ``quantize``)."""
        self._batches = batches
        self._max_batches = max_batches
        return self

    def with_stats(self, stats) -> "Quantizer":
        """Supply pre-computed calibration stats (skips ``calibrate``)."""
        self._stats = stats
        return self

    @property
    def stats(self):
        return self._stats

    # -- quantization -----------------------------------------------------
    def quantize(self, params: Dict) -> QuantizedModel:
        """Apply the spec's recipe site-by-site via the family's
        registered site map -> a saveable artifact."""
        if self.spec is None:
            return QuantizedModel(params=params, qdata=None, spec=None,
                                  cfg=self.cfg)
        if self._stats is None:
            if self._batches is None:
                raise ValueError(
                    "no calibration data: call .calibrate(batches) or "
                    ".with_stats(stats) before .quantize(params)")
            self._stats = calibration_stats(
                self.cfg, params, self._batches,
                max_batches=self._max_batches)
            self._batches = None             # generator: consumed once
        from repro.models.quantize import quantize_model
        new_params, qdata = quantize_model(params, self._stats, self.cfg,
                                           self.spec)
        return QuantizedModel(params=new_params, qdata=qdata,
                              spec=self.spec, cfg=self.cfg)

    # -- QAT recovery -----------------------------------------------------
    def finetune(self, params: Dict, train_batches: Iterable,
                 qat=None, eval_batches: Optional[Iterable] = None,
                 log: Callable = print) -> QuantizedModel:
        """Quantization-aware fine-tune, then quantize: recover the
        accuracy a sub-8-bit spec loses under plain PTQ.

        Runs ``repro.train.qat.finetune`` (straight-through estimators
        over the qdq forward, calibration stats frozen) for
        ``qat.steps`` steps on ``train_batches``, then applies the
        standard PTQ quantization to the finetuned params -- with the
        QAT-learned activation scales when ``qat.learn_scales`` -- so
        the result is an ordinary :class:`QuantizedModel`: it saves,
        loads, and runs on the kernels backend exactly like a
        ``quantize()`` artifact.  The recovery history is attached as
        ``qm.qat_history``.
        """
        if self.spec is None:
            raise ValueError("finetune requires a quantized spec; "
                             "fp models have nothing to recover")
        if self._stats is None:
            if self._batches is None:
                raise ValueError(
                    "no calibration data: call .calibrate(batches) or "
                    ".with_stats(stats) before .finetune(params, ...)")
            self._stats = calibration_stats(
                self.cfg, params, self._batches,
                max_batches=self._max_batches)
            self._batches = None
        from repro.quant.sitemap import quantize_with_site_map
        from repro.train.qat import QATConfig, finetune as qat_finetune
        qat = qat or QATConfig()
        tuned, scales, history = qat_finetune(
            params, self.cfg, self.spec, self._stats, train_batches,
            qat=qat, eval_batches=eval_batches, log=log)
        new_params, qdata = quantize_with_site_map(
            tuned, self._stats, self.cfg, self.spec,
            scale_overrides=scales)
        qm = QuantizedModel(params=new_params, qdata=qdata,
                            spec=self.spec, cfg=self.cfg)
        qm.qat_history = history
        return qm


def quantize(params: Dict, cfg: ModelConfig, calib_batches: Iterable,
             spec: Union[str, QuantSpec, None] = "quamba",
             max_batches: Optional[int] = None) -> QuantizedModel:
    """One-shot convenience: calibrate on ``calib_batches`` and quantize."""
    return (Quantizer(cfg, spec)
            .calibrate(calib_batches, max_batches=max_batches)
            .quantize(params))
