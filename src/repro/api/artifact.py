"""QuantizedModel: the saveable product of the quantization pipeline.

Bundles ``(params, qdata, spec, cfg)`` so callers never hand-thread a raw
``qctx`` dict into forward/loss/engine again.  Serialization reuses the
fault-tolerant key-path tree format of ``repro.train.checkpoint``
(atomic tmp-dir rename, per-leaf crc32), so a saved artifact survives
crashed writers and detects corruption on load.

Layout of ``save(path)``:
  <path>/quantized_model.json    spec + cfg (dataclass fields) + version
  <path>/arrays/                 params (+ qdata) leaves, self-describing
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward, loss_fn
from repro.quant.recipe import QuantSpec

# 1 -- original layout (all weights one value per byte)
# 2 -- 4-bit matmul weights stored nibble-packed ({"qw4", "s_w"} leaves)
#      + effective-backend metadata; v1 artifacts still load (their
#      unpacked w4 sites simply keep the qdq oracle, with a warning)
_FORMAT_VERSION = 2


@dataclasses.dataclass
class QuantizedModel:
    """A quantized (or fp, when ``spec is None``) model artifact."""

    params: Dict
    qdata: Optional[Dict]
    spec: Optional[QuantSpec]
    cfg: ModelConfig

    # -- execution --------------------------------------------------------
    def qctx(self, int8_compute: bool = False,
             backend: Optional[str] = None) -> Optional[Dict]:
        """The forward-pass quant context (None in fp mode).

        ``backend`` overrides ``spec.backend`` without re-quantizing
        ("qdq" fake-quant oracle vs "kernels" int8 Pallas execution) --
        the qdata is identical between the two, only execution differs.
        """
        if self.spec is None or self.qdata is None:
            return None
        from repro.models.quantize import make_qctx  # local: avoid cycle
        return make_qctx(self.spec, self.qdata, int8_compute=int8_compute,
                         backend=backend)

    def forward(self, batch: Dict, **kw):
        """Quantized forward pass -> (logits, aux)."""
        return forward(self.params, self.cfg, batch,
                       qctx=self.qctx(), **kw)

    def loss(self, batch: Dict, **kw):
        """Quantized loss -> (loss, metrics)."""
        return loss_fn(self.params, self.cfg, batch,
                       qctx=self.qctx(), **kw)

    def engine(self, **kw):
        """A request-centric ``repro.serve.LLMEngine`` over this model
        (continuous batching; ``add_request`` + SamplingParams + streams
        + per-request TTFT/TPOT metrics).

        The spec's ``quantize_kv_cache`` flag flows through: attention KV
        caches are stored int8 with per-entry scales when it is set.
        ``engine(prefix_cache_mb=64)`` turns on prefix state caching:
        prefilled prompt prefixes are snapshotted (in the artifact's
        own state layout -- e.g. int8 KV entries under
        ``quantize_kv_cache``) and later
        requests sharing a prefix restore instead of re-prefilling; see
        ``repro.serve.cache`` and docs/serving.md.

        ``engine(speculative=SpecConfig(draft="self", k=4))`` turns on
        speculative multi-token decoding: a draft proposes ``k`` tokens
        per round and the target verifies all of them in one fused
        dispatch, with O(1) state-snapshot rollback (greedy streams
        stay bit-identical to vanilla decode); see ``repro.serve.spec``
        and the speculative-decoding section of docs/serving.md.
        """
        from repro.serve.engine import LLMEngine  # local: avoid cycle
        return LLMEngine(self.params, self.cfg, qctx=self.qctx(), **kw)

    def generate(self, prompts: List[List[int]], *,
                 max_new_tokens: int = 32, temperature: float = 0.0,
                 max_len: int = 2048) -> List[List[int]]:
        """Convenience batch generation through the serving engine."""
        from repro.serve.engine import generate
        return generate(self.params, self.cfg, prompts,
                        max_new_tokens=max_new_tokens,
                        temperature=temperature, qctx=self.qctx(),
                        max_len=max_len)

    def describe(self) -> Dict[str, Any]:
        """Structured summary: method/bits/family plus the *effective*
        execution backend -- "kernels" only when the spec AND the qdata
        can actually feed the Pallas kernels; otherwise "qdq" with the
        fallback reason spelled out (the same reason the one-shot
        ``BackendFallbackWarning`` carries)."""
        if self.spec is None:
            return {"method": "fp", "w_bits": None, "a_bits": None,
                    "family": self.cfg.family, "model": self.cfg.name,
                    "requested_backend": None, "effective_backend": "fp",
                    "backend_fallback_reason": None,
                    "format_version": _FORMAT_VERSION}
        from repro.models.quantize import backend_fallback_reason
        requested = self.spec.backend
        reason = (backend_fallback_reason(self.spec, self.qdata)
                  if requested == "kernels" else None)
        effective = ("kernels" if requested == "kernels" and reason is None
                     else "qdq")
        return {"method": self.spec.method, "w_bits": self.spec.w_bits,
                "a_bits": self.spec.a_bits, "family": self.cfg.family,
                "model": self.cfg.name, "requested_backend": requested,
                "effective_backend": effective,
                "backend_fallback_reason": reason,
                "format_version": _FORMAT_VERSION}

    # -- persistence ------------------------------------------------------
    def save(self, path: str) -> str:
        """Atomic: arrays + metadata are staged together and committed
        with a single directory swap, so a crash mid-save never leaves a
        torn artifact (and never destroys the previous one)."""
        from repro.train import checkpoint as ckpt
        path = os.path.abspath(path)
        parent = os.path.dirname(path)
        os.makedirs(parent, exist_ok=True)
        ckpt.gc_stale_dirs(parent, os.path.basename(path))
        stage = f"{path}.tmp-{os.getpid()}"
        os.makedirs(stage)
        trees: Dict[str, Any] = {"params": self.params}
        if self.qdata is not None:
            trees["qdata"] = self.qdata
        ckpt.save_tree(os.path.join(stage, "arrays"), trees)
        desc = self.describe()
        meta = {
            "format_version": _FORMAT_VERSION,
            "spec": (dataclasses.asdict(self.spec)
                     if self.spec is not None else None),
            "cfg": dataclasses.asdict(self.cfg),
            # effective backend at save time, so a served artifact's
            # execution path is auditable without loading the arrays
            "effective_backend": desc["effective_backend"],
            "backend_fallback_reason": desc["backend_fallback_reason"],
        }
        with open(os.path.join(stage, "quantized_model.json"), "w") as f:
            json.dump(meta, f, indent=1)
        ckpt.commit_dir(stage, path)
        return path

    @classmethod
    def load(cls, path: str) -> "QuantizedModel":
        from repro.train import checkpoint as ckpt
        with open(os.path.join(path, "quantized_model.json")) as f:
            meta = json.load(f)
        if meta["format_version"] > _FORMAT_VERSION:
            raise ValueError(
                f"artifact at {path} has format_version "
                f"{meta['format_version']} > supported {_FORMAT_VERSION}")
        trees = ckpt.load_tree(os.path.join(path, "arrays"))
        spec = (QuantSpec(**meta["spec"])
                if meta["spec"] is not None else None)
        cfg = ModelConfig(**meta["cfg"])
        qdata = trees.get("qdata")
        # int8 weights round-trip through .npy bit-exactly; re-wrap as jnp
        # lazily (forward casts as needed), keeping load cheap.
        return cls(params=trees["params"], qdata=qdata, spec=spec, cfg=cfg)

    # -- misc -------------------------------------------------------------
    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        m = self.spec.method if self.spec is not None else "fp"
        bits = (f"W{self.spec.w_bits}A{self.spec.a_bits}"
                if self.spec is not None else "fp32")
        return (f"QuantizedModel({self.cfg.name}, method={m}, {bits}, "
                f"family={self.cfg.family})")
