"""Public quantization API (the supported entry point).

    from repro import api

    qm = (api.Quantizer(cfg, spec="quamba")
          .calibrate(calib_batches)
          .quantize(params))            # -> QuantizedModel artifact
    logits, _ = qm.forward(batch)
    loss, metrics = qm.loss(batch)
    eng = qm.engine(max_batch=8)        # continuous-batching server
    qm.save("artifacts/mamba-quamba")   # atomic, crc-checked
    qm2 = api.load("artifacts/mamba-quamba")

Architecture families resolve their quant sites through the declarative
site-map registry (``repro.quant.sitemap``); supporting a new family is a
``register_site_map`` call, not an edit to this package.
"""
from repro.api.artifact import QuantizedModel
from repro.api.quantizer import Quantizer, calibration_stats, quantize
from repro.train.qat import QATConfig

load = QuantizedModel.load

__all__ = ["QuantizedModel", "Quantizer", "QATConfig",
           "calibration_stats", "quantize", "load"]
