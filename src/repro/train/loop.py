"""Fault-tolerant training loop.

Production behaviors implemented (and unit-tested on CPU):
  * resume-from-latest on start (atomic checkpoints, crc-verified;
    corrupted/torn checkpoints fall back to the previous step)
  * periodic + final checkpointing with retention
  * restart-safe data order (the stream is indexed by step, so a resumed
    run consumes exactly the batches it would have)
  * straggler watchdog: per-step wall-clock EWMA; steps slower than
    ``straggler_factor`` x EWMA are logged and counted (on a real cluster
    this signal feeds the preemption/re-shard controller; see DESIGN.md
    §Fault-tolerance)
  * preemption hook: SIGTERM triggers a final checkpoint before exit
  * elastic scaling: on restart the loop accepts a different device count
    -- state is resharded by the in_shardings of the re-jitted step (the
    checkpoint stores unsharded logical arrays).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, Iterable, Optional

import jax

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    keep: int = 3
    log_every: int = 50
    straggler_factor: float = 3.0


class Trainer:
    def __init__(self, loop_cfg: LoopConfig, train_step: Callable,
                 state: Dict, log: Callable = print):
        self.cfg = loop_cfg
        self.train_step = jax.jit(train_step)
        self.state = state
        self.log = log
        self.start_step = 0
        self.straggler_steps = 0
        self._ewma = None
        self._preempted = False
        if loop_cfg.ckpt_dir:
            try:
                self.state, restored = ckpt.restore_any(
                    loop_cfg.ckpt_dir, self.state)
                self.start_step = restored
                self.log(f"[loop] resumed from step {restored}")
            except FileNotFoundError:
                pass

    def _handle_sigterm(self, *_):
        self._preempted = True

    def run(self, data: Iterable[Dict]) -> Dict:
        cfg = self.cfg
        old = signal.signal(signal.SIGTERM, self._handle_sigterm)
        metrics = {}
        try:
            it = iter(data)
            for step in range(self.start_step, cfg.total_steps):
                batch = next(it)
                t0 = time.monotonic()
                self.state, metrics = self.train_step(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0

                if self._ewma is None:
                    self._ewma = dt
                elif dt > cfg.straggler_factor * self._ewma:
                    self.straggler_steps += 1
                    self.log(f"[loop] straggler step {step}: "
                             f"{dt:.2f}s vs ewma {self._ewma:.2f}s")
                self._ewma = 0.9 * self._ewma + 0.1 * dt

                done = step + 1
                if cfg.log_every and done % cfg.log_every == 0:
                    self.log(f"[loop] step {done} "
                             f"loss {float(metrics['loss']):.4f} "
                             f"({dt*1e3:.0f} ms)")
                if cfg.ckpt_dir and (done % cfg.ckpt_every == 0
                                     or self._preempted
                                     or done == cfg.total_steps):
                    ckpt.save(cfg.ckpt_dir, done, self.state,
                              keep=cfg.keep)
                if self._preempted:
                    self.log(f"[loop] preempted at step {done}; "
                             "checkpointed and exiting")
                    break
        finally:
            signal.signal(signal.SIGTERM, old)
        return metrics


def train(cfg_loop: LoopConfig, train_step: Callable, state: Dict,
          data_factory: Callable[[int], Iterable[Dict]],
          log: Callable = print) -> Dict:
    """data_factory(start_step) must yield the stream from that step --
    keeps the data order exact across restarts."""
    trainer = Trainer(cfg_loop, train_step, state, log=log)
    return trainer.run(data_factory(trainer.start_step))
