"""QAT recovery pass for sub-8-bit presets (Q-S5 / QS4D style).

PTQ holds accuracy at W8A8, but the W4A8/W4A4 presets leave an eval-loss
gap.  This module closes it with a short quantization-aware fine-tune:
every step re-quantizes the current fp params *differentiably* through
the site map (``quantize_with_site_map(..., ste=True)``), runs the
ordinary qdq forward on the result, and backpropagates through the
straight-through estimators:

  * weight sites      -- per-site STE: the fake-quant grid values are
    float ``round_ste`` outputs, so the gradient reaches the fp weight
    (1 inside the representable range, 0 where the value saturates)
  * activation sites  -- clipped STE via the STE-composed ``Q.qdq``;
    the calibrated scales stay frozen, or become learnable leaves when
    ``QATConfig.learn_scales`` is set (LSQ-style scale gradients)

The STE forward is numerically identical to quantizing the same params
with the same scales and running the qdq oracle, so the loss being
minimized *is* the deployed PTQ loss.  The pass drives the existing
``Trainer`` loop (checkpointing, straggler watchdog, SIGTERM hooks all
apply); the finetuned params then go through the standard PTQ quantize
to produce a normal artifact -- int8/nibble-packed storage, kernels
backend eligibility, save/load -- nothing downstream knows QAT happened.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.optim.adamw import OptimConfig, adamw_update, init_opt_state
from repro.quant.recipe import QuantSpec
from repro.quant.sitemap import (get_site_map, quantize_with_site_map,
                                 trainable_scale_overrides)
from repro.train.loop import LoopConfig, Trainer


@dataclasses.dataclass(frozen=True)
class QATConfig:
    """Schedule + knobs of one QAT recovery pass.

    The defaults are a short recovery run: low LR (the model is already
    trained; QAT nudges weights onto the quantization grid), brief
    warmup, cosine decay to a fraction of the peak, no weight decay
    (decay fights the calibrated grid alignment).
    """

    steps: int = 100
    lr: float = 1e-4
    warmup_frac: float = 0.1            # fraction of steps spent warming up
    min_lr_ratio: float = 0.1
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    learn_scales: bool = False          # activation scales become leaves
    log_every: int = 0                  # 0 = silent loop


def qat_optim_config(qat: QATConfig) -> OptimConfig:
    return OptimConfig(
        lr=qat.lr,
        warmup_steps=max(1, int(qat.warmup_frac * qat.steps)),
        total_steps=qat.steps,
        min_lr_ratio=qat.min_lr_ratio,
        weight_decay=qat.weight_decay,
        clip_norm=qat.clip_norm,
    )


def _qdq_spec(spec: QuantSpec) -> QuantSpec:
    """QAT differentiates the qdq oracle; a kernels request is honored
    only by the final artifact, never by the training forward."""
    if spec.backend != "qdq":
        return dataclasses.replace(spec, backend="qdq")
    return spec


def make_qat_loss(cfg: ModelConfig, spec: QuantSpec, stats) -> Callable:
    """loss(trainable, batch) -> (loss, metrics), differentiable in
    ``trainable = {"params": fp params[, "scales": learnable scales]}``."""
    spec_qdq = _qdq_spec(spec)

    def qat_loss(trainable: Dict, batch: Dict):
        qparams, qdata = quantize_with_site_map(
            trainable["params"], stats, cfg, spec_qdq,
            ste=True, scale_overrides=trainable.get("scales"))
        qctx = {"mode": "quant", "spec": spec_qdq, **qdata}
        return loss_fn(qparams, cfg, batch, qctx=qctx)

    return qat_loss


def init_qat_state(params: Dict, cfg: ModelConfig, spec: QuantSpec,
                   stats, qat: QATConfig) -> Dict:
    """{"trainable": {"params"[, "scales"]}, "opt": AdamW moments}.

    With ``learn_scales`` the calibrated PTQ scales of every trainable
    base ``ScaleSite`` seed the learnable leaves; alias sites keep
    resolving from them, so shared scales can never drift apart.
    """
    trainable: Dict = {"params": params}
    if qat.learn_scales:
        _, qdata = quantize_with_site_map(params, stats, cfg,
                                          _qdq_spec(spec))
        trainable["scales"] = trainable_scale_overrides(
            get_site_map(cfg.family), qdata["scales"])
    return {"trainable": trainable, "opt": init_opt_state(trainable)}


def make_qat_step(cfg: ModelConfig, spec: QuantSpec, stats,
                  qat: QATConfig) -> Callable:
    """qat_step(state, batch) -> (state, metrics) for the Trainer loop."""
    opt_cfg = qat_optim_config(qat)
    grad_fn = jax.value_and_grad(make_qat_loss(cfg, spec, stats),
                                 has_aux=True)

    def qat_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        (_, metrics), grads = grad_fn(state["trainable"], batch)
        trainable, opt, opt_metrics = adamw_update(
            opt_cfg, state["trainable"], grads, state["opt"])
        return ({"trainable": trainable, "opt": opt},
                {**metrics, **opt_metrics})

    return qat_step


def qat_eval_loss(cfg: ModelConfig, spec: QuantSpec, stats,
                  trainable: Dict, batches: Iterable[Dict]) -> float:
    """Mean eval loss of the quantized forward at the current QAT state.

    Uses the STE forward, which is numerically identical to PTQ-quantizing
    ``trainable`` with the same stats/scales and running the qdq oracle --
    so this is the deployed-loss tracker, not a proxy.
    """
    loss = jax.jit(lambda t, b: make_qat_loss(cfg, spec, stats)(t, b)[0])
    vals = [float(loss(trainable, b)) for b in batches]
    if not vals:
        raise ValueError("qat_eval_loss needs at least one batch")
    return sum(vals) / len(vals)


def finetune(params: Dict, cfg: ModelConfig, spec: QuantSpec, stats,
             train_batches: Iterable[Dict], qat: Optional[QATConfig] = None,
             eval_batches: Optional[Iterable[Dict]] = None,
             ckpt_dir: Optional[str] = None,
             log: Callable = print) -> Tuple[Dict, Optional[Dict], Dict]:
    """Run the QAT pass; returns (finetuned fp params, learned scales or
    None, history dict).

    The caller re-quantizes the returned params (passing the learned
    scales as ``scale_overrides``) to obtain the recovered artifact --
    ``repro.api.Quantizer.finetune`` does exactly that.
    """
    qat = qat or QATConfig()
    state = init_qat_state(params, cfg, spec, stats, qat)
    history: Dict = {"steps": qat.steps, "learn_scales": qat.learn_scales}
    if eval_batches is not None:
        eval_batches = list(eval_batches)
        history["eval_loss_start"] = qat_eval_loss(
            cfg, spec, stats, state["trainable"], eval_batches)
    loop = LoopConfig(total_steps=qat.steps, ckpt_dir=ckpt_dir,
                      log_every=qat.log_every)
    trainer = Trainer(loop, make_qat_step(cfg, spec, stats, qat), state,
                      log=log)
    metrics = trainer.run(train_batches)
    trainable = trainer.state["trainable"]
    if metrics:
        history["final_train_loss"] = float(metrics["loss"])
    if eval_batches is not None:
        history["eval_loss_final"] = qat_eval_loss(
            cfg, spec, stats, trainable, eval_batches)
    return trainable["params"], trainable.get("scales"), history
