"""Train-step builders: loss -> grads -> (optional EF-int8 compression)
-> AdamW, with microbatch gradient accumulation and remat."""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn
from repro.optim.adamw import OptimConfig, adamw_update, init_opt_state
from repro.optim.compression import (compress_tree_with_feedback,
                                     init_error_state)


def init_train_state(key: jax.Array, cfg: ModelConfig,
                     compress_grads: bool = False,
                     param_dtype: str = None) -> Dict:
    """param_dtype='bfloat16' stores weights in the compute dtype and an
    fp32 master copy with the (ZeRO-sharded) optimizer moments -- removes
    per-use fp32->bf16 weight casts (EXPERIMENTS.md §Perf)."""
    from repro.models import init_params
    params = init_params(key, cfg)
    if param_dtype is not None:
        keep_master = True
        lowp = jax.tree.map(
            lambda p: p.astype(param_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        state = {"params": lowp,
                 "opt": init_opt_state(params, keep_master=True)}
        state["opt"]["master"] = params
    else:
        state = {"params": params, "opt": init_opt_state(params)}
    if compress_grads:
        state["err"] = init_error_state(params)
    return state


def make_train_step(cfg: ModelConfig, opt_cfg: OptimConfig, *,
                    remat: bool = True, microbatches: int = 1,
                    compress_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 accumulates gradients over equal splits of the batch
    (sequential lax.scan: peak activation memory / microbatches).
    """

    def loss_wrap(params, batch):
        return loss_fn(params, cfg, batch, remat=remat)

    grad_fn = jax.value_and_grad(loss_wrap, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        split = lambda x: x.reshape(
            (microbatches, x.shape[0] // microbatches) + x.shape[1:])
        mb = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, b):
            (loss, metrics), grads = grad_fn(params, b)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, (loss, metrics)

        acc, (losses, metricses) = jax.lax.scan(body, zeros, mb)
        grads = jax.tree.map(lambda a: a / microbatches, acc)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metricses)
        return jnp.mean(losses), metrics, grads

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_state = dict(state)
        if compress_grads:
            grads, new_state["err"] = compress_tree_with_feedback(
                grads, state["err"])
        params, opt, opt_metrics = adamw_update(
            opt_cfg, state["params"], grads, state["opt"])
        new_state["params"] = params
        new_state["opt"] = opt
        return new_state, {**metrics, **opt_metrics}

    return train_step


def make_eval_step(cfg: ModelConfig, qctx=None):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, cfg, batch, qctx=qctx)
        return metrics

    return eval_step
