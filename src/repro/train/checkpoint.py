"""Fault-tolerant checkpointing: atomic step directories, manifest with
integrity hashes, retention, resume-from-latest.

Layout:
  <dir>/step_00001000.tmp-<nonce>/   (written first)
  <dir>/step_00001000/               (atomic rename when complete)
      manifest.json                  (leaf paths, shapes, dtypes, crc32)
      arr_00000.npy ...
A crashed writer leaves only .tmp-* litter, which ``latest_step`` ignores
and ``save`` garbage-collects -- restart is always consistent.  On a real
multi-host cluster each host writes its own param shards under
``host_<k>/`` (see DESIGN.md §Fault-tolerance); in this container there
is one host.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    # GC stale tmp dirs from crashed writers
    for name in os.listdir(ckpt_dir):
        if ".tmp-" in name:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(jax.device_get(tree))
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp-" not in name:
            if os.path.exists(os.path.join(ckpt_dir, name,
                                           "manifest.json")):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``.  Verifies crc32 of
    every leaf; raises on corruption (caller falls back to older step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    _, treedef = _flatten(tree_like)
    loaded = []
    for meta in leaves_meta:
        arr = np.load(os.path.join(d, meta["file"]))
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {d}/{meta['file']}")
        loaded.append(arr)
    return jax.tree.unflatten(treedef, loaded), step


def restore_any(ckpt_dir: str, tree_like):
    """Try newest -> oldest until one restores cleanly (node-failure /
    torn-write recovery path)."""
    for step in sorted(all_steps(ckpt_dir), reverse=True):
        try:
            return restore(ckpt_dir, tree_like, step)
        except Exception:
            continue
    raise FileNotFoundError(f"no restorable checkpoint in {ckpt_dir}")
