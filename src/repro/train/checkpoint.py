"""Fault-tolerant checkpointing: atomic step directories, manifest with
integrity hashes, retention, resume-from-latest.

Layout:
  <dir>/step_00001000.tmp-<nonce>/   (written first)
  <dir>/step_00001000/               (atomic rename when complete)
      manifest.json                  (leaf paths, shapes, dtypes, crc32)
      arr_00000.npy ...
A crashed writer leaves only .tmp-* litter, which ``latest_step`` ignores
and ``save`` garbage-collects -- restart is always consistent.  On a real
multi-host cluster each host writes its own param shards under
``host_<k>/`` (see DESIGN.md §Fault-tolerance); in this container there
is one host.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    # GC stale tmp dirs from crashed writers
    for name in os.listdir(ckpt_dir):
        if ".tmp-" in name:
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(jax.device_get(tree))
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic commit

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and ".tmp-" not in name:
            if os.path.exists(os.path.join(ckpt_dir, name,
                                           "manifest.json")):
                out.append(int(name[5:]))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, step: Optional[int] = None):
    """Restore into the structure of ``tree_like``.  Verifies crc32 of
    every leaf; raises on corruption (caller falls back to older step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    _, treedef = _flatten(tree_like)
    loaded = []
    for meta in leaves_meta:
        arr = np.load(os.path.join(d, meta["file"]))
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {d}/{meta['file']}")
        loaded.append(arr)
    return jax.tree.unflatten(treedef, loaded), step


# ---------------------------------------------------------------------------
# self-describing trees (no tree_like needed on load)
#
# ``save``/``restore`` above serialize leaves positionally and need a
# template tree to rebuild the structure.  Quantized-model artifacts
# (repro.api) must load standalone, so these variants additionally record
# each leaf's key path in the manifest and rebuild nested dicts/lists on
# load.  Same atomic tmp-dir + crc32 discipline as ``save``.
# ---------------------------------------------------------------------------

def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
        return True
    except OSError:
        return False


def gc_stale_dirs(parent: str, base: str) -> None:
    """Remove tmp/aside litter of ``base`` left by crashed writers.

    Two safety rules: a dir whose owner pid is still alive belongs to a
    concurrent writer and is left alone; an ``.old-`` backup is kept
    whenever ``base`` itself is missing -- after a crash mid-swap it may
    be the only surviving copy."""
    for name in os.listdir(parent):
        tag = next((t for t in (".tmp-", ".old-")
                    if name.startswith(base + t)), None)
        if tag is None:
            continue
        suffix = name[len(base + tag):]
        pid = int(suffix) if suffix.isdigit() else None
        if pid is not None and pid != os.getpid() and _pid_alive(pid):
            continue
        if tag == ".old-" and not os.path.exists(
                os.path.join(parent, base)):
            continue
        shutil.rmtree(os.path.join(parent, name), ignore_errors=True)


def _encode_keypath(path) -> list:
    out = []
    for k in path:
        if hasattr(k, "key"):          # DictKey
            if not isinstance(k.key, str):
                raise TypeError(
                    f"save_tree supports string dict keys only, got "
                    f"{k.key!r} ({type(k.key).__name__}): non-string "
                    f"keys would be ambiguous with sequence indices on "
                    f"load")
            out.append({"k": k.key})
        elif hasattr(k, "idx"):        # SequenceKey
            out.append({"i": k.idx})
        elif hasattr(k, "name"):       # GetAttrKey
            out.append({"k": k.name})
        else:
            raise TypeError(f"unsupported tree key {k!r}")
    return out


def save_tree(path: str, tree) -> str:
    """Write ``tree`` (nested dicts/lists of arrays) self-describingly.

    Dict keys must be strings; tuple nodes load back as lists; leafless
    subtrees (empty dicts) leave no keypath, so they are dropped from
    dict nodes on load and ``load_tree`` raises when one sat inside a
    list (the surrounding indices cannot be reconstructed)."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    gc_stale_dirs(parent, os.path.basename(path))
    tmp = f"{path}.tmp-{os.getpid()}"
    os.makedirs(tmp)
    flat = jax.tree_util.tree_flatten_with_path(jax.device_get(tree))[0]
    manifest = {"format": "tree-v1", "leaves": []}
    for i, (keypath, leaf) in enumerate(flat):
        arr = np.asarray(leaf)
        fname = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "file": fname, "path": _encode_keypath(keypath),
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(arr.tobytes()),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    commit_dir(tmp, path)
    return path


def commit_dir(tmp: str, path: str) -> None:
    """Swap ``tmp`` into ``path``: the old version is renamed aside (not
    deleted) before the new one lands, so a crash never destroys the only
    copy; the aside dir is removed once the swap succeeds."""
    if os.path.exists(path):
        old = f"{path}.old-{os.getpid()}"
        shutil.rmtree(old, ignore_errors=True)
        os.rename(path, old)
        os.rename(tmp, path)
        shutil.rmtree(old, ignore_errors=True)
    else:
        os.rename(tmp, path)


def _insert_at(root: dict, path: list, value):
    node = root
    for step, nxt in zip(path, path[1:] + [None]):
        key = step["k"] if "k" in step else step["i"]
        if nxt is None:
            node[key] = value
        else:
            node = node.setdefault(key, {})
    return root


def _listify(node):
    """Convert int-keyed dicts (from SequenceKeys) back into lists."""
    if not isinstance(node, dict):
        return node
    if node and all(isinstance(k, int) for k in node):
        idxs = sorted(node)
        if idxs != list(range(len(idxs))):
            # a leafless element (e.g. an empty dict) inside a list
            # leaves no keypath, so the saved indices have a gap and the
            # original structure is unrecoverable
            raise IOError(
                "saved tree has leafless elements inside a list; "
                "save_tree cannot round-trip those")
        return [_listify(node[i]) for i in idxs]
    return {k: _listify(v) for k, v in node.items()}


def load_tree(path: str):
    """Rebuild a tree written by ``save_tree``; verifies crc32 per leaf."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    root: Dict = {}
    empty = True
    for meta in manifest["leaves"]:
        arr = np.load(os.path.join(path, meta["file"]))
        if zlib.crc32(arr.tobytes()) != meta["crc32"]:
            raise IOError(f"checkpoint corruption in {path}/{meta['file']}")
        if not meta["path"]:
            return arr                        # bare-leaf tree
        _insert_at(root, meta["path"], arr)
        empty = False
    return _listify(root) if not empty else {}


def restore_any(ckpt_dir: str, tree_like):
    """Try newest -> oldest until one restores cleanly (node-failure /
    torn-write recovery path)."""
    for step in sorted(all_steps(ckpt_dir), reverse=True):
        try:
            return restore(ckpt_dir, tree_like, step)
        except Exception:
            continue
    raise FileNotFoundError(f"no restorable checkpoint in {ckpt_dir}")
