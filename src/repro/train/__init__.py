from repro.train.step import init_train_state, make_train_step, make_eval_step
from repro.train.loop import LoopConfig, Trainer, train
from repro.train.qat import QATConfig, make_qat_loss, make_qat_step
from repro.train import checkpoint
