"""Serving launcher: calibrate + quantize + serve batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-130m --small \
      --quant quamba --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.models import init_params
from repro.serve import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--quant", default="quamba")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.small:
        cfg = scale_down(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)

    calib = eval_batches(cfg.vocab_size, 4, 64, 4, seed=777)
    model = api.Quantizer(cfg, args.quant).calibrate(calib) \
        .quantize(params)
    eng = model.engine(max_batch=4, max_len=128)
    for i in range(args.requests):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3],
                           max_new_tokens=args.max_new))
    t0 = time.time()
    eng.run()
    print(f"{args.requests} requests served in {time.time()-t0:.2f}s "
          f"({args.quant})")


if __name__ == "__main__":
    main()
