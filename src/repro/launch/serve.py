"""Serving launcher: calibrate + quantize + serve a request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-130m --small \
      --quant quamba --requests 8 --policy fcfs --metrics-out metrics.json

Requests go through the request-centric API (``LLMEngine.add_request``
with per-request ``SamplingParams``); per-request TTFT/TPOT/queue-time
and engine occupancy land in the metrics JSON.

``--prefix-cache-mb N`` enables prefix state caching: every request
here shares the same few-shot-style prompt head, so after the first
prefill the remaining requests restore the cached SSM state instead of
re-prefilling (watch ``prefix_cache.hit_rate`` and the hit/miss TTFT
split in the printed summary).  ``--prefix-cache-spill-mb M`` adds the
host-RAM spill tier behind it.

``--speculative-draft self --speculative-k 4`` turns on speculative
multi-token decoding: the draft proposes k tokens per round, the
target verifies all of them in ONE fused dispatch, rejection is an
O(1) state-snapshot rollback.  ``self`` drafts with the target's own
weights (acceptance 1.0 -- pure dispatch amortization); an arch name
(e.g. ``mamba-130m`` while serving mamba-370m) drafts with a smaller
model (demo-initialised weights here, matching the launcher's random
target).  Greedy output is bit-identical to vanilla decode either
way; the summary prints acceptance rate and tokens-per-round.

``--disagg --prefill-workers N --decode-workers M`` serves through the
disaggregated split (``repro.serve.disagg``): prefill workers turn
prompts into packed SSM-state snapshots, decode workers restore them
into zero-prefill seats, and the frontend keeps the exact LLMEngine
surface -- token streams stay bit-identical to single-process serving
and the summary gains a ``disagg`` section (snapshot transfer
bytes/latency, per-role occupancy, the admission controller's
suggested worker split).  ``--disagg-mode process`` runs each worker
in its own spawned process instead of in-process threads.

Load generation (``repro.serve.loadgen``):

  # write a replayable seeded trace
  ... --emit-trace trace.json --trace-requests 32 --trace-seed 7
  # replay it (sync pump: two runs are bit-identical, including the
  # schedule -- the printed digest proves it)
  ... --loadgen trace.json
  # realtime open-loop run through the async EnginePump + SLO gate
  ... --loadgen trace.json --pump async --slo-ttft-p99-ms 500
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import jax

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.models import init_params
from repro.serve import SamplingParams, SpecConfig
from repro.serve.loadgen import (SLO, BurstyArrivals, RAGLongPrompt,
                                 SharedPrefixChat, Trace, WorkloadMix)
from repro.serve.loadgen import run as loadgen_run


def _default_mix(cancel_fraction: float) -> WorkloadMix:
    return WorkloadMix(
        [(3, SharedPrefixChat(n_prefixes=4, prefix_len=24,
                              suffix_len=(1, 4), max_tokens=(4, 8))),
         (1, RAGLongPrompt(prompt_len=(32, 56), max_tokens=(2, 4)))],
        cancel_fraction=cancel_fraction)


def _spec_config(args, cfg) -> "SpecConfig | None":
    """``--speculative-draft`` -> a ``SpecConfig`` (None when unset).

    ``self`` (or the target's own arch name) shares the target's
    weights; any other arch gets demo-initialised weights, consistent
    with the launcher's randomly initialised target."""
    d = args.speculative_draft
    if not d:
        return None
    if d == "self" or d == cfg.name:
        return SpecConfig(draft="self", k=args.speculative_k)
    dc = get_config(d)
    if args.small:
        dc = scale_down(dc)
    dparams = init_params(jax.random.PRNGKey(1), dc)
    return SpecConfig(draft=dc, draft_params=dparams,
                      k=args.speculative_k)


def _print_spec(mj: dict) -> None:
    sd = mj.get("spec_decode")
    if not sd:
        return
    spd = sd.get("per_request_speedup") or {}
    acc = sd.get("acceptance_rate")
    print(f"spec decode: k={sd['k']} draft={sd['draft']}; "
          f"acceptance {acc if acc is None else round(acc, 3)} "
          f"({sd['accepted_tokens']}/{sd['drafted_tokens']} drafted "
          f"accepted, {sd['rolled_back_tokens']} rolled back, "
          f"{sd['rounds']} rounds); "
          f"{spd.get('mean', float('nan')):.2f} tokens/round "
          f"per request")


def _disagg_engine(args, model, max_len: int):
    """A ``DisaggEngine`` over the quantized artifact (``--disagg``)."""
    from repro.serve.disagg import DisaggEngine
    if args.speculative_draft:
        raise SystemExit("--disagg does not compose with "
                         "--speculative-draft: the decode workers run "
                         "vanilla decode")
    if args.policy:
        raise SystemExit("--disagg admits via the roofline controller; "
                         "drop --policy")
    # the decode workers' prefix cache IS the admission mechanism, so
    # it cannot be disabled -- --prefix-cache-mb only grows it
    return DisaggEngine(
        model.params, model.cfg, qctx=model.qctx(),
        prefill_workers=args.prefill_workers,
        decode_workers=args.decode_workers,
        max_batch=4, max_len=max_len, mode=args.disagg_mode,
        prefix_cache_mb=max(args.prefix_cache_mb, 64.0))


def _print_disagg(mj: dict) -> None:
    d = mj.get("disagg")
    if not d:
        return
    tr = d["transport"]
    print(f"disagg: {d['prefill']['workers']} prefill + "
          f"{d['decode']['workers']} decode workers ({d['mode']} "
          f"mode); {tr['transfers']} snapshot transfers, "
          f"{tr['bytes'] / 1e6:.2f} MB shipped, "
          f"{tr['direct_admits']} direct admits")
    lat = tr["latency_ms"]
    if lat:
        print(f"  transfer latency p50 {lat['p50']:.2f} / "
              f"p95 {lat['p95']:.2f} ms; "
              f"{d['decode']['snapshot_restores']} snapshot restores, "
              f"{d['decode']['fallback_prefill_dispatches']} fallback "
              f"prefills on decode workers")
    occ = d["decode"]["occupancy_mean"]
    sug = d["admission"]["suggested"]
    print(f"  occupancy: prefill {d['prefill']['occupancy']:.2f}, "
          f"decode {'n/a' if occ is None else format(occ, '.2f')}; "
          f"admission suggests {sug['prefill']}p:{sug['decode']}d")


def _loadgen(args, model) -> None:
    trace = Trace.load(args.loadgen)
    need = max(len(e.prompt) + e.max_tokens for e in trace.events)
    if args.disagg:
        eng = _disagg_engine(args, model, need + 8)
    else:
        eng = model.engine(
            max_batch=4, max_len=need + 8, scheduler=args.policy,
            prefix_cache_mb=(args.prefix_cache_mb or None),
            prefix_cache_spill_mb=(args.prefix_cache_spill_mb or None),
            speculative=_spec_config(args, model.cfg))
    slo = SLO(ttft_p95_ms=args.slo_ttft_p95_ms,
              ttft_p99_ms=args.slo_ttft_p99_ms,
              tpot_p95_ms=args.slo_tpot_p95_ms)
    report = loadgen_run(eng, trace, slo if slo.to_json() else None,
                         pump=args.pump, time_scale=args.time_scale)
    # the digest covers streams AND schedule: two sync replays of one
    # trace print the same hash, which is the determinism contract
    digest = hashlib.sha256(json.dumps(
        {"streams": report["token_streams"],
         "schedule": report["schedule"]},
        sort_keys=True).encode()).hexdigest()
    ttft, occ = report["ttft_ms"], report["occupancy_mean"]
    print(f"loadgen: {trace.name} x{len(trace)} ({args.pump} pump, "
          f"time_scale {args.time_scale:g}) in {report['wall_s']:.2f}s")
    if ttft:
        print(f"  TTFT p50 {ttft['p50']:.1f} / p95 {ttft['p95']:.1f} / "
              f"p99 {ttft['p99']:.1f} ms; goodput "
              f"{report['goodput_requests']} req "
              f"({report['goodput_rps']:.2f} rps), "
              f"{report['cancelled']} cancelled, occupancy "
              f"{occ:.2f}" if occ is not None else "")
    print(f"  replay digest {digest[:16]} "
          f"(streams+schedule, sha256)")
    mj = eng.metrics_json()
    _print_spec(mj)
    _print_disagg(mj)
    if "slo" in report:
        verdict = "PASS" if report["slo"]["ok"] else "FAIL"
        print(f"  SLO {verdict}: {report['slo']['objectives']}")
        for v in report["slo"]["violations"]:
            print(f"    violation: {v}")
    if args.metrics_out:
        report.pop("token_streams")
        with open(args.metrics_out, "w") as f:
            json.dump({"loadgen": report, "engine": mj}, f,
                      indent=1, sort_keys=True)
        print(f"metrics -> {args.metrics_out}")
    if args.disagg:
        eng.close()
    if "slo" in report and not report["slo"]["ok"]:
        raise SystemExit(1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--quant", default="quamba")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default=None,
                    choices=["fcfs", "priority", "cache-aware"],
                    help="scheduler policy (default: fcfs, or "
                         "cache-aware when --prefix-cache-mb is set)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="prefix state cache byte budget in MiB "
                         "(0 disables)")
    ap.add_argument("--prefix-cache-spill-mb", type=float, default=0.0,
                    help="host-RAM spill tier budget in MiB behind the "
                         "device prefix cache (0 disables)")
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="length of the shared prompt head the demo "
                         "requests reuse (exercises the prefix cache)")
    ap.add_argument("--speculative-draft", default=None,
                    help="enable speculative decoding: 'self' drafts "
                         "with the target's own weights; an arch name "
                         "(e.g. mamba-130m) drafts with that model "
                         "(demo-initialised weights)")
    ap.add_argument("--speculative-k", type=int, default=4,
                    help="draft tokens verified per fused round "
                         "(>= 1; each round commits 1..k+1 tokens)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the per-request metrics JSON here")
    dg = ap.add_argument_group("disaggregated serving")
    dg.add_argument("--disagg", action="store_true",
                    help="serve through split prefill/decode worker "
                         "pools (repro.serve.disagg); streams stay "
                         "bit-identical to single-process serving")
    dg.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill worker pool size under --disagg")
    dg.add_argument("--decode-workers", type=int, default=1,
                    help="decode worker pool size under --disagg")
    dg.add_argument("--disagg-mode", default="thread",
                    choices=["thread", "process"],
                    help="thread = in-process workers (default); "
                         "process = one spawned process per worker")
    lg = ap.add_argument_group("load generation")
    lg.add_argument("--loadgen", default=None, metavar="TRACE.json",
                    help="replay a saved loadgen trace instead of the "
                         "demo request burst")
    lg.add_argument("--emit-trace", default=None, metavar="TRACE.json",
                    help="build a seeded chat+RAG trace, save it, exit")
    lg.add_argument("--trace-requests", type=int, default=32)
    lg.add_argument("--trace-seed", type=int, default=0)
    lg.add_argument("--trace-cancel-fraction", type=float, default=0.1)
    lg.add_argument("--pump", default="sync",
                    choices=["sync", "async"],
                    help="sync = deterministic replay (default); "
                         "async = realtime open-loop EnginePump")
    lg.add_argument("--time-scale", type=float, default=1.0,
                    help="stretch/compress the arrival schedule "
                         "(0 = submit as fast as possible)")
    lg.add_argument("--slo-ttft-p95-ms", type=float, default=None)
    lg.add_argument("--slo-ttft-p99-ms", type=float, default=None)
    lg.add_argument("--slo-tpot-p95-ms", type=float, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.small:
        cfg = scale_down(cfg)

    if args.emit_trace:
        mix = _default_mix(args.trace_cancel_fraction)
        trace = mix.build(n_requests=args.trace_requests,
                          vocab_size=cfg.vocab_size,
                          seed=args.trace_seed,
                          arrivals=BurstyArrivals())
        trace.save(args.emit_trace)
        print(f"trace -> {args.emit_trace} ({len(trace)} requests, "
              f"{trace.n_cancelled} cancelled, span {trace.span_s:.2f}s, "
              f"seed {args.trace_seed})")
        return

    params = init_params(jax.random.PRNGKey(0), cfg)

    calib = eval_batches(cfg.vocab_size, 4, 64, 4, seed=777)
    model = api.Quantizer(cfg, args.quant).calibrate(calib) \
        .quantize(params)

    if args.loadgen:
        _loadgen(args, model)
        return

    if args.disagg:
        eng = _disagg_engine(args, model,
                             args.shared_prefix + args.max_new + 16)
    else:
        eng = model.engine(
            max_batch=4, max_len=args.shared_prefix + args.max_new + 16,
            scheduler=args.policy,
            prefix_cache_mb=(args.prefix_cache_mb or None),
            prefix_cache_spill_mb=(args.prefix_cache_spill_mb or None),
            speculative=_spec_config(args, cfg))
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, max_tokens=args.max_new)
    shared = [(7 * j + 1) % cfg.vocab_size
              for j in range(args.shared_prefix)]
    for i in range(args.requests):
        # every request reuses the shared head (a system prompt /
        # few-shot template); odd requests get a priority bump so
        # --policy priority is visible
        eng.add_request(shared + [1 + i, 2, 3], sp, priority=i % 2)
    t0 = time.time()
    eng.run()
    mj = eng.metrics_json()
    ttft = mj["summary"]["ttft_ms"]
    how = (f"disagg {args.prefill_workers}p:{args.decode_workers}d"
           if args.disagg else type(eng.scheduler).__name__)
    print(f"{args.requests} requests served in {time.time()-t0:.2f}s "
          f"({args.quant}, {how})")
    if ttft:
        print(f"TTFT mean {ttft['mean']:.1f} ms, p95 {ttft['p95']:.1f} ms;"
              f" {mj['engine']['tokens_per_s']:.1f} tok/s, occupancy "
              f"{mj['engine']['occupancy_mean']:.2f}")
    pc = mj.get("prefix_cache")
    if pc:
        hit = pc["ttft_ms_hit"] or {}
        miss = pc["ttft_ms_miss"] or {}
        print(f"prefix cache: hit rate {pc['hit_rate']}, "
              f"{pc['tokens_reused']} tokens reused, "
              f"{pc['bytes_in_use'] / 1e6:.2f} MB in "
              f"{pc['entries']} entries; TTFT hit "
              f"{hit.get('mean', float('nan')):.1f} ms vs miss "
              f"{miss.get('mean', float('nan')):.1f} ms")
    _print_spec(mj)
    _print_disagg(mj)
    if args.metrics_out:
        # mj already carries the engine/prefix_cache/spec_decode/
        # disagg sections metrics.dump would rebuild
        with open(args.metrics_out, "w") as f:
            json.dump(mj, f, indent=1, sort_keys=True)
        print(f"metrics -> {args.metrics_out}")
    if args.disagg:
        eng.close()


if __name__ == "__main__":
    main()
