"""Serving launcher: calibrate + quantize + serve a request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba-130m --small \
      --quant quamba --requests 8 --policy fcfs --metrics-out metrics.json

Requests go through the request-centric API (``LLMEngine.add_request``
with per-request ``SamplingParams``); per-request TTFT/TPOT/queue-time
and engine occupancy land in the metrics JSON.

``--prefix-cache-mb N`` enables prefix state caching: every request
here shares the same few-shot-style prompt head, so after the first
prefill the remaining requests restore the cached SSM state instead of
re-prefilling (watch ``prefix_cache.hit_rate`` and the hit/miss TTFT
split in the printed summary).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import api
from repro.configs import get_config, scale_down
from repro.data import eval_batches
from repro.models import init_params
from repro.serve import SamplingParams


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--quant", default="quamba")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--policy", default=None,
                    choices=["fcfs", "priority", "cache-aware"],
                    help="scheduler policy (default: fcfs, or "
                         "cache-aware when --prefix-cache-mb is set)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="prefix state cache byte budget in MiB "
                         "(0 disables)")
    ap.add_argument("--shared-prefix", type=int, default=48,
                    help="length of the shared prompt head the demo "
                         "requests reuse (exercises the prefix cache)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the per-request metrics JSON here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.small:
        cfg = scale_down(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)

    calib = eval_batches(cfg.vocab_size, 4, 64, 4, seed=777)
    model = api.Quantizer(cfg, args.quant).calibrate(calib) \
        .quantize(params)
    eng = model.engine(
        max_batch=4, max_len=args.shared_prefix + args.max_new + 16,
        scheduler=args.policy,
        prefix_cache_mb=(args.prefix_cache_mb or None))
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, max_tokens=args.max_new)
    shared = [(7 * j + 1) % cfg.vocab_size
              for j in range(args.shared_prefix)]
    for i in range(args.requests):
        # every request reuses the shared head (a system prompt /
        # few-shot template); odd requests get a priority bump so
        # --policy priority is visible
        eng.add_request(shared + [1 + i, 2, 3], sp, priority=i % 2)
    t0 = time.time()
    eng.run()
    mj = eng.metrics_json()
    ttft = mj["summary"]["ttft_ms"]
    print(f"{args.requests} requests served in {time.time()-t0:.2f}s "
          f"({args.quant}, {type(eng.scheduler).__name__})")
    if ttft:
        print(f"TTFT mean {ttft['mean']:.1f} ms, p95 {ttft['p95']:.1f} ms;"
              f" {mj['engine']['tokens_per_s']:.1f} tok/s, occupancy "
              f"{mj['engine']['occupancy_mean']:.2f}")
    pc = mj.get("prefix_cache")
    if pc:
        hit = pc["ttft_ms_hit"] or {}
        miss = pc["ttft_ms_miss"] or {}
        print(f"prefix cache: hit rate {pc['hit_rate']}, "
              f"{pc['tokens_reused']} tokens reused, "
              f"{pc['bytes_in_use'] / 1e6:.2f} MB in "
              f"{pc['entries']} entries; TTFT hit "
              f"{hit.get('mean', float('nan')):.1f} ms vs miss "
              f"{miss.get('mean', float('nan')):.1f} ms")
    if args.metrics_out:
        eng.metrics.dump(args.metrics_out, eng.counters,
                         pc if pc else None)
        print(f"metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
