"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is a
second data-parallel axis crossing the inter-pod links (DCN/optical), so
gradient reduction over 'pod' is the only traffic that leaves a pod.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).

All constructors go through ``_mk`` / ``use_mesh`` so the same code runs
on jax versions with and without ``jax.sharding.AxisType`` /
``jax.set_mesh`` (0.4.x lacks both; ``Mesh`` itself is the context
manager there).
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    at = getattr(jax.sharding, "AxisType", None)
    if at is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(at.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating ``mesh`` (``jax.set_mesh`` when the
    installed jax has it, the ``Mesh`` context manager otherwise)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return _mk((n // model, model), ("data", "model"))


def parse_mesh_arg(spec: str):
    """Mesh from a CLI string: '16x16' -> (data, model);
    '2x16x16' -> (pod, data, model); 'auto' -> host mesh over all
    devices (data only)."""
    if spec == "auto":
        return make_host_mesh()
    dims = tuple(int(d) for d in spec.lower().split("x"))
    if len(dims) == 2:
        return _mk(dims, ("data", "model"))
    if len(dims) == 3:
        return _mk(dims, ("pod", "data", "model"))
    raise ValueError(f"mesh spec {spec!r}: want DxM or PxDxM or 'auto'")


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a mesh (includes 'pod')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
