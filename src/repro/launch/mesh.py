"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is a
second data-parallel axis crossing the inter-pod links (DCN/optical), so
gradient reduction over 'pod' is the only traffic that leaves a pod.

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=_auto(2))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names of a mesh (includes 'pod')."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
