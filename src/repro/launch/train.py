"""Distributed training launcher.

On a real TPU cluster every host runs this same script (jax.distributed
initializes from the TPU environment); in this container it trains on the
available CPU devices.  The mesh, shardings, fault-tolerant loop,
checkpointing and (optional) int8 gradient compression are all exercised.

  PYTHONPATH=src python -m repro.launch.train --arch mamba-130m \
      --small --steps 100 [--compress-grads] [--fsdp]

``--qat-steps N`` appends a QAT recovery pass after the fp run: the
trained params are calibrated, PTQ-quantized with ``--qat-preset``
(default ``quamba-w4a4``), fine-tuned for N steps through the
straight-through estimators, and the fp / PTQ / QAT eval losses plus
the recovered fraction of the gap are printed.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax

from repro.configs import get_config, scale_down
from repro.data import batches
from repro.dist.sharding import batch_shardings, train_state_shardings
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.optim import OptimConfig
from repro.train import LoopConfig, Trainer, init_train_state, \
    make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba-130m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--qat-steps", type=int, default=0,
                    help="run a QAT recovery pass for this many steps "
                         "after fp training (0 = off)")
    ap.add_argument("--qat-preset", default="quamba-w4a4")
    ap.add_argument("--qat-lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.small:
        cfg = scale_down(cfg)
    cfg = dataclasses.replace(cfg, dtype="float32")

    mesh = make_host_mesh(model=args.model_parallel)
    state = init_train_state(jax.random.PRNGKey(0), cfg,
                             compress_grads=args.compress_grads)
    shapes = jax.eval_shape(lambda: state)
    st_sh = train_state_shardings(shapes, mesh, cfg, fsdp=args.fsdp)
    step = make_train_step(
        cfg, OptimConfig(warmup_steps=max(1, args.steps // 10),
                         total_steps=args.steps),
        remat=True, microbatches=args.microbatches,
        compress_grads=args.compress_grads)

    with use_mesh(mesh):
        state = jax.device_put(state, st_sh)
        data = lambda s0: (
            jax.device_put(b, batch_shardings(jax.eval_shape(lambda: b),
                                              mesh))
            for b in batches(cfg.vocab_size, args.batch, args.seq,
                             seed=17, start_step=s0))
        loop = LoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                          ckpt_every=50, log_every=10)
        trainer = Trainer(loop, functools.partial(step), state)
        trainer.run(data(trainer.start_step))
    print(f"done; stragglers observed: {trainer.straggler_steps}")

    if args.qat_steps > 0:
        _qat_recovery(trainer.state["params"], cfg, args)


def _qat_recovery(params, cfg, args) -> None:
    """Calibrate -> PTQ -> QAT finetune the freshly-trained params and
    report the recovered fraction of the PTQ eval-loss gap."""
    from repro import api
    from repro.data import eval_batches
    from repro.models import loss_fn
    from repro.train.qat import QATConfig

    calib = list(batches(cfg.vocab_size, args.batch, args.seq, seed=23,
                         num_steps=4))
    ev = eval_batches(cfg.vocab_size, args.batch, args.seq, 4)
    stats = api.calibration_stats(cfg, params, calib)
    mean = lambda qm_or_none: sum(
        float((loss_fn(params, cfg, b)[0] if qm_or_none is None
               else qm_or_none.loss(b)[0])) for b in ev) / len(ev)

    fp_loss = mean(None)
    quant = api.Quantizer(cfg, args.qat_preset).with_stats(stats)
    ptq_loss = mean(quant.quantize(params))
    qat = QATConfig(steps=args.qat_steps, lr=args.qat_lr,
                    learn_scales=True, log_every=10)
    qm = quant.finetune(
        params, batches(cfg.vocab_size, args.batch, args.seq, seed=29,
                        num_steps=args.qat_steps), qat=qat)
    qat_loss = mean(qm)
    gap = ptq_loss - fp_loss
    rec = (ptq_loss - qat_loss) / gap if gap > 1e-9 else float("nan")
    print(f"[qat] preset={args.qat_preset} eval loss: fp {fp_loss:.4f} | "
          f"ptq {ptq_loss:.4f} | qat {qat_loss:.4f} "
          f"(recovered {rec:.1%} of the gap)")


if __name__ == "__main__":
    main()
