import os
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry run: lower + compile every (arch x shape) cell on the
production mesh and emit memory/cost/roofline evidence.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k [--multi-pod] [--fsdp] [--out results.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all
  # CI smoke (8 host devices, scaled-down config, all serve variants):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.dryrun --arch mamba-130m \
      --shape decode_small --scale-down --mesh 2x4 \
      --variants fp,bf16,quamba,kv8

The FIRST lines above set XLA_FLAGS before any jax import -- jax locks
the device count at first init.  An existing
``xla_force_host_platform_device_count`` in the environment wins (the
CI smoke job asks for 8 devices, not 512); the 512-device default only
applies when nothing is set.
"""
import argparse
import functools
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, SHAPE_BY_NAME, cell_supported,
                           get_config, scale_down)
from repro.dist import hlo_cost
from repro.dist import roofline as RL
from repro.dist.sharding import (batch_shardings, decode_state_shardings,
                                 qdata_shardings, train_state_shardings)
from repro.launch.mesh import (make_production_mesh, parse_mesh_arg,
                               use_mesh)
from repro.models import (decode_input_specs, decode_step, forward,
                          input_specs, loss_fn)
from repro.optim.adamw import OptimConfig
from repro.train.step import init_train_state, make_train_step


def _train_step_fn(cfg, microbatches: int):
    opt_cfg = OptimConfig()
    return make_train_step(cfg, opt_cfg, remat=True,
                           microbatches=microbatches)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             fsdp: bool = False, microbatches: int = 1,
             serve_dtype: str = None, quant: str = None,
             kv_dtype: str = None, cfg_overrides: dict = None,
             bf16_params: bool = False, verbose: bool = True,
             mesh=None, scale: bool = False) -> dict:
    """Variants (the §Perf hillclimb levers):
      serve_dtype='bfloat16'  -- decode/prefill params stored bf16
      quant='quamba'          -- decode with int8 weights + static scales
      kv_dtype='int8'         -- int8 KV cache (beyond-paper)
      cfg_overrides           -- dataclasses.replace fields (e.g. chunking)
      mesh                    -- explicit mesh (default: production mesh)
      scale                   -- scale_down(cfg) for smoke runs
    """
    import dataclasses as _dc
    cfg = get_config(arch)
    if scale:
        cfg = scale_down(cfg)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPE_BY_NAME[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason}

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            state_shapes = jax.eval_shape(
                functools.partial(
                    init_train_state, cfg=cfg,
                    param_dtype="bfloat16" if bf16_params else None),
                jax.random.PRNGKey(0))
            state_sh = train_state_shardings(state_shapes, mesh, cfg,
                                             fsdp=fsdp)
            batch = input_specs(cfg, shape)
            batch_sh = batch_shardings(batch, mesh)
            step = _train_step_fn(cfg, microbatches)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch)
            n_params = RL.count_params(state_shapes["params"])
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                functools.partial(_init_params, cfg=cfg),
                jax.random.PRNGKey(0))
            from repro.dist.sharding import param_shardings
            p_sh = param_shardings(params_shapes, mesh, cfg, fsdp=fsdp)
            batch = input_specs(cfg, shape)
            batch_sh = batch_shardings(batch, mesh)
            fwd = lambda p, b: forward(p, cfg, b, remat=True)[0]
            jitted = jax.jit(fwd, in_shardings=(p_sh, batch_sh))
            lowered = jitted.lower(params_shapes, batch)
            n_params = RL.count_params(params_shapes)
        else:  # decode
            params_shapes = jax.eval_shape(
                functools.partial(_init_params, cfg=cfg),
                jax.random.PRNGKey(0))
            if serve_dtype:
                params_shapes = _cast_float_leaves(params_shapes,
                                                   serve_dtype)
            from repro.dist.sharding import param_shardings
            # kv_dtype=int8 builds the real quantized cache layout (int8
            # entries + per-entry scales) so attention families compile
            # the path they would actually serve
            state, token = decode_input_specs(
                cfg, shape,
                cache_dtype=jnp.dtype(kv_dtype) if kv_dtype else None)
            state_sh = decode_state_shardings(state, mesh, cfg)
            token_sh = batch_shardings(token, mesh)
            n_params = RL.count_params(params_shapes)
            if quant:
                from repro.models.quantize import (make_qctx,
                                                   quantize_model)
                from repro.quant.recipe import get_spec
                spec = get_spec(quant)
                calib_b = input_specs(
                    cfg, dataclasses_replace_shape(shape))
                stats_shapes = jax.eval_shape(
                    lambda p, b: forward(p, cfg, b,
                                         qctx={"mode": "calib"})[1],
                    params_shapes, calib_b)
                qparams_shapes, qdata_shapes = jax.eval_shape(
                    lambda p, st: quantize_model(p, st, cfg, spec),
                    params_shapes, stats_shapes)
                p_sh = param_shardings(qparams_shapes, mesh, cfg,
                                       fsdp=fsdp)
                qd_sh = qdata_shardings(qdata_shapes, mesh, cfg)
                serve_step = lambda p, qd, s, t: decode_step(
                    p, cfg, s, t,
                    qctx=make_qctx(spec, qd, int8_compute=True))
                jitted = jax.jit(
                    serve_step,
                    in_shardings=(p_sh, qd_sh, state_sh, token_sh),
                    donate_argnums=(2,))
                lowered = jitted.lower(qparams_shapes, qdata_shapes,
                                       state, token)
            else:
                p_sh = param_shardings(params_shapes, mesh, cfg,
                                       fsdp=fsdp)
                serve_step = lambda p, s, t: decode_step(p, cfg, s, t)
                jitted = jax.jit(serve_step,
                                 in_shardings=(p_sh, state_sh, token_sh),
                                 donate_argnums=(1,))
                lowered = jitted.lower(params_shapes, state, token)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
    except Exception:       # not every backend implements it (CPU)
        mem = None
    xla_cost = hlo_cost.xla_cost_dict(compiled)
    hlo = compiled.as_text()
    # trip-count-aware totals (XLA's cost_analysis counts while bodies
    # once; see repro.dist.hlo_cost): flops/bytes/collectives per chip.
    parsed = hlo_cost.analyze(hlo)
    cost = {"flops": parsed["flops"],
            "bytes accessed": parsed["bytes accessed"]}
    coll = {"total": parsed["collective_bytes"],
            "count": parsed["collective_count"]}
    coll_by = parsed.get("collective_by_type", {})

    # MODEL_FLOPS = 6*N*D (train: fwd+bwd; decode/prefill: 2*N*D fwd only)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    n_active = _active_params(cfg, n_params)
    factor = 6.0 if shape.kind == "train" else 2.0
    chips = mesh.size
    model_flops = factor * n_active * tokens / chips  # per-chip share
    mesh_desc = "x".join(str(d) for d in tuple(dict(mesh.shape).values()))

    terms = RL.roofline_terms(cost, coll, model_flops=model_flops)
    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": mesh_desc,
        "fsdp": fsdp,
        "microbatches": microbatches,
        "kind": shape.kind,
        "n_params": int(n_params),
        "n_active_params": int(n_active),
        "bytes_per_device": int(mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes)
        if hasattr(mem, "temp_size_in_bytes") else None,
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "arg_bytes": getattr(mem, "argument_size_in_bytes", None),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "xla_entry_flops": float(xla_cost.get("flops", 0.0)),
        "xla_entry_bytes": float(xla_cost.get("bytes accessed", 0.0)),
        "collective_by_type": {k: v for k, v in coll_by.items() if v},
        "bytes_by_op": parsed.get("bytes_by_op", {}),
        "variant": {k: v for k, v in (("serve_dtype", serve_dtype),
                                      ("quant", quant),
                                      ("kv_dtype", kv_dtype),
                                      ("bf16_params", bf16_params),
                                      ("overrides", cfg_overrides))
                    if v},
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in terms.items()},
    }
    if verbose:
        print(json.dumps(result))
        sys.stdout.flush()
    return result


def _init_params(key, cfg):
    from repro.models import init_params
    return init_params(key, cfg)


def _cast_float_leaves(tree, dtype: str, only_names=None):
    """Re-dtype ShapeDtypeStructs (serve-precision variants)."""
    dt = jnp.dtype(dtype)

    def one(path, leaf):
        name = str(getattr(path[-1], "key", "")) if path else ""
        is_float = jnp.issubdtype(leaf.dtype, jnp.floating)
        if not is_float:
            return leaf
        if only_names is not None and name not in only_names:
            return leaf
        return jax.ShapeDtypeStruct(leaf.shape, dt)

    return jax.tree_util.tree_map_with_path(one, tree)


def dataclasses_replace_shape(shape):
    """A short calibration-shaped batch for eval_shape'ing the quantize
    pipeline (structure is what matters, not size)."""
    import dataclasses as _dc
    return _dc.replace(shape, seq_len=256, global_batch=2, kind="prefill")


def _active_params(cfg, n_params: int) -> int:
    """active params for MoE (top_k of n_experts in every MoE FFN)."""
    if cfg.family != "moe":
        return n_params
    expert_p = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
    active_expert_p = expert_p * cfg.top_k / cfg.n_experts
    return int(n_params - expert_p + active_expert_p)


# serve-precision variants for decode cells (--variants): the §Perf
# hillclimb ladder fp -> bf16 weights -> Quamba int8 -> +int8 KV
VARIANTS = {
    "fp": {},
    "bf16": {"serve_dtype": "bfloat16"},
    "quamba": {"quant": "quamba"},
    "kv8": {"quant": "quamba", "kv_dtype": "int8"},
}


# Baseline production settings per arch for train_4k: gradient-accumulation
# microbatches sized so activations fit 16GB HBM, FSDP where fp32 params +
# optimizer alone overflow a chip.  (A production launcher always picks
# these; the §Perf hillclimb starts from here.)
TRAIN_MICROBATCHES = {
    "whisper-medium": 2,
    "qwen3-moe-30b-a3b": 8,
    "granite-moe-1b-a400m": 4,
    "paligemma-3b": 4,
    "llama3-8b": 8,
    "qwen3-32b": 8,
    "granite-3-8b": 8,
    "granite-3-2b": 8,
    "zamba2-1.2b": 8,
    "xlstm-1.3b": 4,
}
FSDP_ARCHS = {"qwen3-32b", "qwen3-moe-30b-a3b"}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--serve-dtype", default=None)
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--quant", default=None)
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--mesh", default=None,
                    help="mesh shape 'DxM' / 'PxDxM' / 'auto' "
                         "(default: the 16x16 production mesh)")
    ap.add_argument("--scale-down", action="store_true",
                    help="scale_down(cfg) -- CI smoke on host devices")
    ap.add_argument("--variants", default=None,
                    help="comma list of " + ",".join(VARIANTS)
                         + " -- run each as its own cell")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    mesh = parse_mesh_arg(args.mesh) if args.mesh else None
    variants = [None]
    if args.variants:
        unknown = [v for v in args.variants.split(",")
                   if v not in VARIANTS]
        assert not unknown, f"unknown variants {unknown}; " \
                            f"choose from {sorted(VARIANTS)}"
        variants = args.variants.split(",")

    results = []
    for arch, shape in cells:
        mb = args.microbatches
        fsdp = args.fsdp
        if args.all and shape == "train_4k":
            mb = TRAIN_MICROBATCHES.get(arch, mb)
            fsdp = fsdp or arch in FSDP_ARCHS
        shape_kind = SHAPE_BY_NAME[shape].kind
        for variant in variants:
            # serve variants only alter decode cells; run other kinds once
            if (variant not in (None, "fp")
                    and shape_kind != "decode"):
                continue
            if variant is not None:
                # --variants supersedes the individual serve flags: each
                # row must compile exactly what its name says (an
                # inherited --quant would silently turn the "fp" row
                # into a quantized compile)
                kw = dict(VARIANTS[variant])
            else:
                kw = dict(serve_dtype=args.serve_dtype, quant=args.quant,
                          kv_dtype=args.kv_dtype)
            try:
                r = run_cell(arch, shape, multi_pod=args.multi_pod,
                             fsdp=fsdp, microbatches=mb,
                             bf16_params=args.bf16_params,
                             mesh=mesh, scale=args.scale_down,
                             verbose=False, **kw)
            except Exception as e:  # a failing cell is a bug: be loud
                r = {"arch": arch, "shape": shape, "status": "error",
                     "error": f"{type(e).__name__}: {e}"}
            # uniform row schema: every row names its variant
            r["variant_name"] = variant or "fp"
            print(json.dumps(r))
            sys.stdout.flush()
            results.append(r)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(r) + "\n")
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"# dryrun finished: {len(results)} cells, {n_err} errors",
          file=sys.stderr)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
