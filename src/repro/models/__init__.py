from repro.models.common import param_count, cross_entropy
from repro.models.model import (
    init_params, forward, loss_fn, init_decode_state, decode_step,
    prefill_step, supports_seq_prefill, input_specs, decode_input_specs,
    decode_state_batch_axes, verify_step, select_verify_state,
    select_scan_state, supports_verify,
)
