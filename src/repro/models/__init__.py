from repro.models.common import param_count, cross_entropy
from repro.models.model import (
    init_params, forward, loss_fn, init_decode_state, decode_step,
    input_specs, decode_input_specs,
)
