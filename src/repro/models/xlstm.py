"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM is a matrix-memory linear recurrence (exponential input gate,
sigmoid forget gate, max-stabilizer m):
    m_t = max(m_{t-1} + log f_t, log i_t)
    C_t = e^{m_{t-1}+lf_t-m_t} C_{t-1} + e^{li_t-m_t} v_t k_t^T
    n_t = e^{m_{t-1}+lf_t-m_t} n_{t-1} + e^{li_t-m_t} k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, e^{-m_t})

The chunkwise form below (the TPU-friendly one: (T x T) score matmuls per
chunk + a short scan over chunk summaries) is exactly equivalent and is
validated against the sequential reference in tests.

Quamba transfer (DESIGN.md §Arch-applicability): the recurrence input v is
the sensitive tensor (same causal-error argument as the paper's Thm 4.1),
so it gets the percentile clip; the cell output is rotated with a Hadamard
matrix before the down projection, with H folded into the weight.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import is_calib, is_quant, linear
from repro.quant.hadamard import had_transform
from repro.quant.observers import observe
from repro.quant import quantizers as Q
from repro.quant import recipe as qrecipe


# ---------------------------------------------------------------------------
# mLSTM cell (chunkwise parallel + sequential step)
# ---------------------------------------------------------------------------

def mlstm_chunked(q, k, v, li, lf, chunk: int = 128, state=None,
                  return_state: bool = False):
    """q/k/v (b,L,h,hd); li/lf (b,L,h) log input/forget gates.
    state: (C (b,h,hd,hd), n (b,h,hd), m (b,h)).  Returns h (b,L,h,hd)."""
    b, L, h, hd = q.shape
    t = min(chunk, L)
    assert L % t == 0
    nc = L // t
    f32 = jnp.float32
    q = q.astype(f32) * (hd ** -0.5)
    k = k.astype(f32)
    v = v.astype(f32)

    qr = q.reshape(b, nc, t, h, hd)
    kr = k.reshape(b, nc, t, h, hd)
    vr = v.reshape(b, nc, t, h, hd)
    lir = li.astype(f32).reshape(b, nc, t, h)
    lfr = lf.astype(f32).reshape(b, nc, t, h)
    lf_cum = jnp.cumsum(lfr, axis=2)                       # (b,nc,t,h)

    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), f32)
        n0 = jnp.zeros((b, h, hd), f32)
        m0 = jnp.full((b, h), -1e30, f32)
    else:
        c0, n0, m0 = (s.astype(f32) for s in state)

    # intra-chunk log weights w[t,s] = lf_cum[t] - lf_cum[s] + li[s], s<=t
    wlog = (lf_cum[:, :, :, None, :] - lf_cum[:, :, None, :, :]
            + lir[:, :, None, :, :])                       # (b,nc,t,s,h)
    mask = jnp.tril(jnp.ones((t, t), bool))[None, None, :, :, None]
    wlog = jnp.where(mask, wlog, -1e30)

    # chunk summaries for the inter-chunk scan
    lf_tot = lf_cum[:, :, -1, :]                           # (b,nc,h)
    tail = lf_tot[:, :, None, :] - lf_cum + lir            # (b,nc,t,h)
    m_chunk = jnp.max(tail, axis=2)                        # (b,nc,h)

    # local intra stabilizer per position (carry-in part added in-scan)
    m_intra = jnp.max(wlog, axis=3)                        # (b,nc,t,h)

    def scan_body(carry, inp):
        # The q @ C_in carry contraction happens HERE so the (b,h,hd,hd)
        # chunk states are never stacked into a (b,nc,h,hd,hd) tensor --
        # at hd=1024 that stack dominated the memory roofline
        # (EXPERIMENTS.md §Perf C3 iteration 2).
        c_in, n_in, m_in = carry
        qc, kc, vc, tail_c, lft_c, mch_c, lfcum_c, mintra_c = inp
        m_hist = m_in[:, None, :] + lfcum_c                # (b,t,h)
        m_loc = jnp.maximum(m_hist, mintra_c)
        carry_w = jnp.exp(m_hist - m_loc)                  # (b,t,h)
        y_carry = jnp.einsum("bthd,bhdv->bthv", qc, c_in) * \
            carry_w[..., None]
        den_carry = jnp.einsum("bthd,bhd->bth", qc, n_in) * carry_w

        m_out = jnp.maximum(m_in + lft_c, mch_c)           # (b,h)
        w = jnp.exp(tail_c - m_out[:, None, :])            # (b,t,h)
        decay = jnp.exp(m_in + lft_c - m_out)
        c_new = decay[..., None, None] * c_in + \
            jnp.einsum("bth,bthk,bthv->bhkv", w, kc, vc)
        n_new = decay[..., None] * n_in + \
            jnp.einsum("bth,bthk->bhk", w, kc)
        return (c_new, n_new, m_out), (y_carry, den_carry, m_loc)

    xs = (jnp.moveaxis(qr, 1, 0), jnp.moveaxis(kr, 1, 0),
          jnp.moveaxis(vr, 1, 0), jnp.moveaxis(tail, 1, 0),
          jnp.moveaxis(lf_tot, 1, 0), jnp.moveaxis(m_chunk, 1, 0),
          jnp.moveaxis(lf_cum, 1, 0), jnp.moveaxis(m_intra, 1, 0))
    (c_f, n_f, m_f), (y_carry, den_carry, m_loc) = jax.lax.scan(
        scan_body, (c0, n0, m0), xs)
    y_carry = jnp.moveaxis(y_carry, 0, 1)                  # (b,nc,t,h,hd)
    den_carry = jnp.moveaxis(den_carry, 0, 1)
    m_loc = jnp.moveaxis(m_loc, 0, 1)                      # (b,nc,t,h)

    scores = jnp.einsum("bcthd,bcshd->bctsh", qr, kr)      # (b,nc,t,s,h)
    sw = scores * jnp.exp(wlog - m_loc[:, :, :, None, :])
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", sw, vr)
    den_intra = jnp.sum(sw, axis=3)

    y_raw = y_intra + y_carry
    den = den_intra + den_carry
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_loc))
    out = (y_raw / denom[..., None]).reshape(b, L, h, hd)
    if return_state:
        return out, (c_f, n_f, m_f)
    return out


def mlstm_step(state, q, k, v, li, lf):
    """Single step.  state (C (b,h,hd,hd), n, m); q/k/v (b,h,hd);
    li/lf (b,h)."""
    f32 = jnp.float32
    c, n, m = (s.astype(f32) for s in state)
    hd = q.shape[-1]
    q = q.astype(f32) * (hd ** -0.5)
    k, v = k.astype(f32), v.astype(f32)
    li, lf = li.astype(f32), lf.astype(f32)
    m_new = jnp.maximum(m + lf, li)
    fw = jnp.exp(m + lf - m_new)
    iw = jnp.exp(li - m_new)
    c_new = fw[..., None, None] * c + iw[..., None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k, v)
    n_new = fw[..., None] * n + iw[..., None] * k
    y = jnp.einsum("bhk,bhkv->bhv", q, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n_new)),
                      jnp.exp(-m_new))
    return y / den[..., None], (c_new, n_new, m_new)


def mlstm_reference(q, k, v, li, lf, state=None):
    b, L, h, hd = q.shape
    if state is None:
        state = (jnp.zeros((b, h, hd, hd)), jnp.zeros((b, h, hd)),
                 jnp.full((b, h), -1e30))
    ys = []
    for i in range(L):
        y, state = mlstm_step(state, q[:, i], k[:, i], v[:, i],
                              li[:, i], lf[:, i])
        ys.append(y)
    return jnp.stack(ys, 1), state


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def init_mlstm_block(key: jax.Array, cfg: ModelConfig) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    ks = jax.random.split(key, 7)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "up_proj": common.dense_init(ks[0], d, 2 * di),    # (x, z-gate)
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.conv_width, di),
                                          jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": common.dense_init(ks[2], di, di),
        "wk": common.dense_init(ks[3], di, di),
        "wv": common.dense_init(ks[4], di, di),
        "w_gates": common.dense_init(ks[5], di, 2 * cfg.ssm_heads),
        "b_gates": jnp.concatenate([
            jnp.zeros((cfg.ssm_heads,)),                    # input gate
            3.0 * jnp.ones((cfg.ssm_heads,)),               # forget gate
        ]).astype(jnp.float32),
        "gnorm": jnp.ones((di,), jnp.float32),
        "down_proj": common.dense_init(ks[6], di, d),
    }


def _conv_silu(x, w, b, state=None):
    from repro.models.mamba import _depthwise_conv_silu
    return _depthwise_conv_silu(x, w, b, state)


def _mlstm_inner(p, cfg, xu, qctx, aux, conv_state=None, cell_state=None,
                 seq: bool = True):
    """Shared q/k/v/gate computation.  xu: (b, L, di) up-projected input."""
    b = xu.shape[0]
    heads = cfg.ssm_heads
    di = cfg.d_inner
    hd = di // heads
    xc, conv_state = _conv_silu(xu, p["conv_w"], p["conv_b"], conv_state)
    q = linear(p, "wq", xc, qctx)
    k = linear(p, "wk", xc, qctx)
    v = linear(p, "wv", xu, qctx, site="wv")
    if is_calib(qctx):
        aux["v"] = observe(v)
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            v = Q.dynamic_qdq(v)
        else:
            v = qrecipe.ssm_input_qdq(v, qctx["scales"]["v"], spec)
    gates = linear(p, "w_gates", xu, qctx) + p["b_gates"].astype(xu.dtype)
    li_pre, lf_pre = jnp.split(gates, 2, axis=-1)
    li = li_pre.astype(jnp.float32)                    # exponential in-gate
    lf = jax.nn.log_sigmoid(lf_pre.astype(jnp.float32))
    shp = (b, -1, heads, hd) if seq else (b, heads, hd)
    gshp = (b, -1, heads) if seq else (b, heads)
    # (constraining q/k/v to head_dim sharding here was measured 3x WORSE
    # -- GSPMD's chosen all-gather schedule beats forcing local hd
    # contractions at these shapes; §Perf C3 iteration 3, refuted)
    return (q.reshape(shp), k.reshape(shp), v.reshape(shp),
            li.reshape(gshp), lf.reshape(gshp), conv_state)


def mlstm_block(p: Dict, cfg: ModelConfig, x: jax.Array, qctx=None
                ) -> Tuple[jax.Array, Dict]:
    aux: Dict = {}
    b, L, d = x.shape
    di = cfg.d_inner
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_calib(qctx):
        aux["in"] = observe(h)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    xz = linear(p, "up_proj", h, qctx)
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, li, lf, _ = _mlstm_inner(p, cfg, xu, qctx, aux)
    y = mlstm_chunked(q, k, v, li, lf).reshape(b, L, di).astype(x.dtype)
    y = common.rmsnorm(y, p["gnorm"], cfg.norm_eps) * common.silu(z)
    if is_calib(qctx):
        aux["y"] = observe(y)
        aux["y_had"] = observe(had_transform(y))
    if is_quant(qctx) and qctx["spec"].use_hadamard:
        out = linear(p, "down_proj", had_transform(y), qctx,
                     site="down_proj_had")
    elif is_quant(qctx):
        spec = qctx["spec"]
        y = (Q.dynamic_qdq(y) if spec.method == "dynamic"
             else qrecipe.act_qdq(y, qctx["scales"]["y"], spec))
        out = linear(p, "down_proj", y, qctx)
    else:
        out = linear(p, "down_proj", y, qctx)
    return x + out, aux


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict:
    heads = cfg.ssm_heads
    hd = cfg.d_inner // heads
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner),
                          jnp.float32),
        "C": jnp.zeros((batch, heads, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, heads, hd), jnp.float32),
        "m": jnp.full((batch, heads), -1e30, jnp.float32),
    }


def mlstm_block_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
                     qctx=None) -> Tuple[jax.Array, Dict]:
    aux: Dict = {}
    b, d = x.shape
    di = cfg.d_inner
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    xz = linear(p, "up_proj", h, qctx)
    xu, z = jnp.split(xz, 2, axis=-1)
    q, k, v, li, lf, conv_state = _mlstm_inner(
        p, cfg, xu[:, None, :], qctx, aux, conv_state=state["conv"])
    y, (c_n, n_n, m_n) = mlstm_step(
        (state["C"], state["n"], state["m"]),
        q[:, 0], k[:, 0], v[:, 0], li[:, 0], lf[:, 0])
    y = y.reshape(b, di).astype(x.dtype)
    y = common.rmsnorm(y, p["gnorm"], cfg.norm_eps) * common.silu(z)
    if is_quant(qctx) and qctx["spec"].use_hadamard:
        out = linear(p, "down_proj", had_transform(y), qctx,
                     site="down_proj_had")
    else:
        out = linear(p, "down_proj", y, qctx)
    new_state = {"conv": conv_state, "C": c_n, "n": n_n, "m": m_n}
    return x + out, new_state


# ---------------------------------------------------------------------------
# sLSTM block (sequential; true recurrence)
# ---------------------------------------------------------------------------

def init_slstm_block(key: jax.Array, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    heads = cfg.ssm_heads
    hd = d // heads
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_in": common.dense_init(ks[0], d, 4 * d),      # z, i, f, o
        "r": 0.1 * jax.random.normal(ks[1], (4, heads, hd, hd),
                                     jnp.float32),
        "b": jnp.concatenate([jnp.zeros((2 * d,)),
                              3.0 * jnp.ones((d,)),      # forget bias
                              jnp.zeros((d,))]).astype(jnp.float32),
        "gnorm": jnp.ones((d,), jnp.float32),
        "up": common.dense_init(ks[2], d, 2 * 2 * d),    # gated ffn
        "down": common.dense_init(ks[3], 2 * d, d),
    }


def _slstm_cell_step(p, cfg, u4, hprev, c, n):
    """u4: (b, 4d) pre-activations from the input; recurrent term added
    here.  Returns (h, c, n)."""
    b = hprev.shape[0]
    d = cfg.d_model
    heads = cfg.ssm_heads
    hd = d // heads
    hr = hprev.reshape(b, heads, hd)
    rec = jnp.einsum("bhk,ghkv->bghv", hr.astype(jnp.float32),
                     p["r"].astype(jnp.float32)).reshape(b, 4 * d)
    za, ia, fa, oa = jnp.split(u4.astype(jnp.float32) + rec +
                               p["b"].astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(za)
    i = jnp.exp(jnp.minimum(ia, 10.0))      # capped exponential gate
    f = jax.nn.sigmoid(fa)
    o = jax.nn.sigmoid(oa)
    c_new = f * c + i * z
    n_new = f * n + i
    h = o * c_new / jnp.maximum(n_new, 1e-6)
    return h, c_new, n_new


def slstm_block(p: Dict, cfg: ModelConfig, x: jax.Array, qctx=None
                ) -> Tuple[jax.Array, Dict]:
    aux: Dict = {}
    b, L, d = x.shape
    hn = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_calib(qctx):
        aux["in"] = observe(hn)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        hn = qrecipe.act_qdq(hn, qctx["scales"]["in"], qctx["spec"])
    u4 = linear(p, "w_in", hn, qctx)                    # (b, L, 4d)

    def body(carry, u):
        hprev, c, n = carry
        h, c, n = _slstm_cell_step(p, cfg, u, hprev, c, n)
        return (h, c, n), h

    zero = jnp.zeros((b, d), jnp.float32)
    (_, _, _), hs = jax.lax.scan(body, (zero, zero, zero),
                                 jnp.moveaxis(u4, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = common.rmsnorm(y, p["gnorm"], cfg.norm_eps)
    x = x + y
    # gated FFN
    if is_calib(qctx):
        aux["ffn_in"] = observe(x)
    gu = linear(p, "up", x, qctx)
    g, u = jnp.split(gu, 2, axis=-1)
    ff = common.silu(g) * u
    if is_calib(qctx):
        aux["ffn_down_in"] = observe(ff)
    out = linear(p, "down", ff, qctx)
    return x + out, aux


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_block_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
                     qctx=None) -> Tuple[jax.Array, Dict]:
    hn = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        hn = qrecipe.act_qdq(hn, qctx["scales"]["in"], qctx["spec"])
    u4 = linear(p, "w_in", hn, qctx)
    h, c, n = _slstm_cell_step(p, cfg, u4, state["h"], state["c"],
                               state["n"])
    y = common.rmsnorm(h.astype(x.dtype), p["gnorm"], cfg.norm_eps)
    x = x + y
    gu = linear(p, "up", x, qctx)
    g, u = jnp.split(gu, 2, axis=-1)
    out = linear(p, "down", common.silu(g) * u, qctx)
    return x + out, {"h": h, "c": c, "n": n}
