"""Top-level model assembly: init / forward / loss / decode for all
families (dense, moe, hybrid, ssm, mamba, audio, vlm).

Layers are stacked and executed with ``lax.scan`` so the HLO stays compact
at any depth (essential for 40-cell dry-run compiles).  Heterogeneous
families (zamba2's shared attention, xlstm's sLSTM cadence) scan over
repeating *groups*.

Every forward accepts ``qctx``:
  None                      -- fp
  {"mode": "calib"}         -- emit per-site activation stats (stacked per
                               layer by the scan)
  {"mode": "quant", "spec", "scales", "qw"}  -- quantized execution; the
                               scales/qw trees carry a leading layer axis
                               and ride the scan alongside the weights.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import common
from repro.models.attention import init_kv_cache
from repro.models.mamba import (init_mamba_block, init_mamba_state,
                                mamba_block, mamba_block_prefill,
                                mamba_block_step, mamba_block_verify)
from repro.models.transformer import (decoder_layer, encoder_layer,
                                      init_decoder_layer,
                                      init_encoder_layer,
                                      sinusoidal_positions)
from repro.models.xlstm import (init_mlstm_block, init_mlstm_state,
                                init_slstm_block, init_slstm_state,
                                mlstm_block, mlstm_block_step, slstm_block,
                                slstm_block_step)
from repro.models.zamba import (init_mamba2_block, init_mamba2_state,
                                mamba2_block, mamba2_block_prefill,
                                mamba2_block_step)

MOE_AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key: jax.Array, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _layer_qctx(qctx, sc, qw):
    if qctx is None or qctx.get("mode") != "quant":
        return qctx
    out = {"mode": "quant", "spec": qctx["spec"], "scales": sc, "qw": qw}
    if qctx.get("int8_compute"):
        out["int8_compute"] = True
    return out


def _scan_blocks(block_fn, x, layers_p, qctx, qname: str,
                 remat: bool = False, unroll: bool = False):
    """Scan a stacked block over ``x``.  block_fn(lp, x, qctx)->(x, aux).

    unroll=True runs the stack as a Python loop instead of ``lax.scan``,
    so each layer executes with plain op-by-op semantics.  The backend
    parity harness relies on this: compiled as one scan-body computation,
    XLA:CPU's fusion emitter contracts cross-op mul+add pairs into fmas
    inside the qdq path's float segments, shifting them by an ulp
    relative to the interpret-mode kernels (which are opaque to fusion)
    -- enough to flip a downstream requant that lands on a rounding tie.
    Op-by-op, the two backends are bit-identical.
    """
    quant = qctx is not None and qctx.get("mode") == "quant"
    if unroll:
        n = jax.tree.leaves(layers_p)[0].shape[0]
        auxs = []
        for i in range(n):
            lp = jax.tree.map(lambda a: a[i], layers_p)
            if quant:
                sc = jax.tree.map(lambda a: a[i], qctx["scales"][qname])
                qw = jax.tree.map(lambda a: a[i], qctx["qw"][qname])
                x, aux = block_fn(lp, x, _layer_qctx(qctx, sc, qw))
            else:
                x, aux = block_fn(lp, x, qctx)
            auxs.append(aux)
        return x, jax.tree.map(lambda *ys: jnp.stack(ys, 0), *auxs)
    if quant:
        xs = (layers_p, qctx["scales"][qname], qctx["qw"][qname])

        def body(h, t):
            lp, sc, qw = t
            return block_fn(lp, h, _layer_qctx(qctx, sc, qw))
    else:
        xs = layers_p

        def body(h, lp):
            return block_fn(lp, h, qctx)

    if remat:
        body = jax.checkpoint(body)
    return jax.lax.scan(body, x, xs)


def _scan_blocks_cache(step_fn, x, layers_p, caches, qctx, qname: str):
    """Scan a stacked decode step with per-layer cache/state."""
    quant = qctx is not None and qctx.get("mode") == "quant"
    if quant:
        xs = (layers_p, caches, qctx["scales"][qname], qctx["qw"][qname])

        def body(h, t):
            lp, c, sc, qw = t
            out, new_c = step_fn(lp, h, c, _layer_qctx(qctx, sc, qw))
            return out, new_c
    else:
        xs = (layers_p, caches)

        def body(h, t):
            lp, c = t
            out, new_c = step_fn(lp, h, c, qctx)
            return out, new_c
    return jax.lax.scan(body, x, xs)


def _group_tree(tree, groups: int, per: int):
    """Reshape stacked (G*P, ...) leaves to (G, P, ...)."""
    return jax.tree.map(
        lambda a: a[: groups * per].reshape((groups, per) + a.shape[1:]),
        tree)


def _tail_tree(tree, start: int):
    return jax.tree.map(lambda a: a[start:], tree)


def _embed(params, cfg: ModelConfig, tokens: jax.Array,
           dtype) -> jax.Array:
    return params["embed"].astype(dtype)[tokens]


def _logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = common.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"])
    return (x.astype(jnp.float32) @ w.astype(jnp.float32))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    cfg.validate()
    keys = jax.random.split(key, 8)
    p: Dict[str, Any] = {
        "embed": common.embed_init(keys[0], cfg.vocab_size, cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(keys[1], cfg.d_model,
                                         cfg.vocab_size)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(
            lambda k: init_decoder_layer(k, cfg), keys[2], cfg.n_layers)
    elif fam == "moe":
        p["layers"] = _stack_init(
            lambda k: init_decoder_layer(k, cfg, use_moe=True), keys[2],
            cfg.n_layers)
    elif fam == "audio":
        p["enc_layers"] = _stack_init(
            lambda k: init_encoder_layer(k, cfg), keys[2],
            cfg.n_enc_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["layers"] = _stack_init(
            lambda k: init_decoder_layer(k, cfg, cross=True), keys[3],
            cfg.n_layers)
    elif fam == "mamba":
        p["layers"] = _stack_init(
            lambda k: init_mamba_block(k, cfg), keys[2], cfg.n_layers)
    elif fam == "hybrid":
        p["layers"] = _stack_init(
            lambda k: init_mamba2_block(k, cfg), keys[2], cfg.n_layers)
        p["shared"] = init_decoder_layer(keys[3], cfg)
    elif fam == "ssm":
        groups, per = _xlstm_layout(cfg)
        p["m_blocks"] = jax.vmap(
            lambda k: _stack_init(lambda kk: init_mlstm_block(kk, cfg),
                                  k, per))(jax.random.split(keys[2],
                                                            groups))
        p["s_blocks"] = _stack_init(
            lambda k: init_slstm_block(k, cfg), keys[3], groups)
    else:
        raise ValueError(fam)
    return p


def _xlstm_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(groups, mlstm_per_group): pattern = per mLSTM then 1 sLSTM."""
    k = cfg.slstm_every
    assert k > 1 and cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k, k - 1


def _hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(groups, per, tail): shared attn after each group of ``per``."""
    per = cfg.attn_period
    groups = cfg.n_layers // per
    tail = cfg.n_layers - groups * per
    return groups, per, tail


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params: Dict, cfg: ModelConfig, batch: Dict, qctx=None,
            remat: bool = False, unroll: bool = False
            ) -> Tuple[jax.Array, Dict]:
    """Returns (logits, aux).  batch keys by family:
      lm families: tokens (B, L)
      audio:       frames (B, Le, d) + tokens (B, Ld)
      vlm:         patches (B, P, d) + tokens (B, Lt)

    unroll=True executes the homogeneous layer stack as a Python loop
    (op-by-op semantics) instead of ``lax.scan`` -- see
    :func:`_scan_blocks`; the backend-parity harness uses it to compare
    kernels vs qdq without fusion-codegen noise.  Group-structured
    families (hybrid, ssm) unroll their inner stacks only.
    """
    dt = _dtype(cfg)
    fam = cfg.family
    aux_out: Dict = {}

    if fam == "audio":
        frames = batch["frames"].astype(dt)
        le = frames.shape[1]
        frames = frames + sinusoidal_positions(le, cfg.d_model
                                               ).astype(dt)[None]
        enc, enc_aux = _scan_blocks(
            lambda lp, h, q: encoder_layer(lp, cfg, h, qctx=q),
            frames, params["enc_layers"], qctx, "enc_layers", remat,
            unroll)
        enc = common.rmsnorm(enc, params["enc_norm"], cfg.norm_eps)
        aux_out["enc_layers"] = enc_aux
        x = _embed(params, cfg, batch["tokens"], dt)
        x, dec_aux = _scan_blocks(
            lambda lp, h, q: decoder_layer(
                lp, cfg, h, mask_kind="causal", enc_out=enc, qctx=q)[:2],
            x, params["layers"], qctx, "layers", remat, unroll)
        aux_out["layers"] = dec_aux
        return _logits(params, cfg, x), aux_out

    if fam == "vlm":
        text = _embed(params, cfg, batch["tokens"], dt)
        x = jnp.concatenate([batch["patches"].astype(dt), text], axis=1)
        x, aux = _scan_blocks(
            lambda lp, h, q: decoder_layer(
                lp, cfg, h, mask_kind="prefix", qctx=q)[:2],
            x, params["layers"], qctx, "layers", remat, unroll)
        aux_out["layers"] = aux
        logits = _logits(params, cfg, x[:, cfg.prefix_len:])
        return logits, aux_out

    x = _embed(params, cfg, batch["tokens"], dt)

    if fam in ("dense", "moe"):
        x, aux = _scan_blocks(
            lambda lp, h, q: decoder_layer(
                lp, cfg, h, mask_kind="causal", qctx=q)[:2],
            x, params["layers"], qctx, "layers", remat, unroll)
        aux_out["layers"] = aux
    elif fam == "mamba":
        x, aux = _scan_blocks(
            lambda lp, h, q: mamba_block(lp, cfg, h, qctx=q),
            x, params["layers"], qctx, "layers", remat, unroll)
        aux_out["layers"] = aux
    elif fam == "hybrid":
        groups, per, tail = _hybrid_layout(cfg)
        gp = _group_tree(params["layers"], groups, per)
        quant = qctx is not None and qctx.get("mode") == "quant"
        g_sc = (_group_tree(qctx["scales"]["layers"], groups, per)
                if quant else None)
        g_qw = (_group_tree(qctx["qw"]["layers"], groups, per)
                if quant else None)

        def group_body(h, t):
            if quant:
                lp, sc, qw = t
                gq = {"mode": "quant", "spec": qctx["spec"],
                      "scales": {"g": sc}, "qw": {"g": qw},
                      "int8_compute": qctx.get("int8_compute", False)}
                h, aux = _scan_blocks(
                    lambda q_lp, hh, q: mamba2_block(q_lp, cfg, hh, q),
                    h, lp, gq, "g", remat, unroll)
                shq = _layer_qctx(qctx, qctx["scales"]["shared"],
                                  qctx["qw"]["shared"])
            else:
                lp = t
                h, aux = _scan_blocks(
                    lambda q_lp, hh, q: mamba2_block(q_lp, cfg, hh, q),
                    h, lp, qctx, "g", remat, unroll)
                shq = qctx
            h, aux_s, _ = decoder_layer(params["shared"], cfg, h,
                                        mask_kind="causal", qctx=shq)
            return h, (aux, aux_s)

        xs = (gp, g_sc, g_qw) if quant else gp
        x, (aux_m, aux_s) = jax.lax.scan(group_body, x, xs)
        aux_out["layers"] = aux_m
        aux_out["shared"] = aux_s
        if tail:
            tp = _tail_tree(params["layers"], groups * per)
            tq = qctx
            if quant:
                tq = {"mode": "quant", "spec": qctx["spec"],
                      "scales": {"t": _tail_tree(qctx["scales"]["layers"],
                                                 groups * per)},
                      "qw": {"t": _tail_tree(qctx["qw"]["layers"],
                                             groups * per)}}
            x, aux_t = _scan_blocks(
                lambda lp, hh, q: mamba2_block(lp, cfg, hh, q),
                x, tp, tq, "t", remat, unroll)
            aux_out["tail"] = aux_t
    elif fam == "ssm":
        groups, per = _xlstm_layout(cfg)
        quant = qctx is not None and qctx.get("mode") == "quant"

        def group_body(h, t):
            if quant:
                (mp, sp), (msc, mqw), (ssc, sqw) = t
                gq = {"mode": "quant", "spec": qctx["spec"],
                      "scales": {"g": msc}, "qw": {"g": mqw},
                      "int8_compute": qctx.get("int8_compute", False)}
                h, aux_m = _scan_blocks(
                    lambda lp, hh, q: mlstm_block(lp, cfg, hh, q),
                    h, mp, gq, "g", remat, unroll)
                h, aux_s = slstm_block(sp, cfg, h,
                                       _layer_qctx(qctx, ssc, sqw))
            else:
                mp, sp = t
                h, aux_m = _scan_blocks(
                    lambda lp, hh, q: mlstm_block(lp, cfg, hh, q),
                    h, mp, qctx, "g", remat, unroll)
                h, aux_s = slstm_block(sp, cfg, h, qctx)
            return h, (aux_m, aux_s)

        if quant:
            xs = ((params["m_blocks"], params["s_blocks"]),
                  (qctx["scales"]["m_blocks"], qctx["qw"]["m_blocks"]),
                  (qctx["scales"]["s_blocks"], qctx["qw"]["s_blocks"]))
        else:
            xs = (params["m_blocks"], params["s_blocks"])
        x, (aux_m, aux_s) = jax.lax.scan(group_body, x, xs)
        aux_out["m_blocks"] = aux_m
        aux_out["s_blocks"] = aux_s
    else:
        raise ValueError(fam)

    return _logits(params, cfg, x), aux_out


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict, qctx=None,
            remat: bool = False) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch, qctx=qctx, remat=remat)
    mask = batch.get("mask")
    loss = common.cross_entropy(logits, batch["targets"], mask)
    metrics = {"ce_loss": loss}
    moe_aux = _collect_moe_aux(aux)
    if moe_aux is not None:
        loss = loss + MOE_AUX_COEF * moe_aux
        metrics["moe_aux"] = moe_aux
    metrics["loss"] = loss
    return loss, metrics


def _collect_moe_aux(aux) -> Optional[jax.Array]:
    vals = []

    def visit(t):
        if isinstance(t, dict):
            for k, v in t.items():
                if k == "moe_aux_loss":
                    vals.append(jnp.mean(v))
                else:
                    visit(v)

    visit(aux)
    if not vals:
        return None
    return sum(vals)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _stack_state(make_one, n: int):
    """n independent copies of a zero-initialized state tree."""
    one = make_one()
    return jax.tree.map(
        lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim), one)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      cache_dtype=jnp.bfloat16) -> Dict:
    fam = cfg.family
    # per-row positions: continuous batching keeps independent sequences
    # at different depths within one decode batch
    state: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        state["caches"] = _stack_state(
            lambda: init_kv_cache(cfg, batch, max_len, cache_dtype),
            cfg.n_layers)
    elif fam == "audio":
        state["caches"] = _stack_state(
            lambda: init_kv_cache(cfg, batch, max_len, cache_dtype),
            cfg.n_layers)
        state["enc_out"] = jnp.zeros((batch, 0, cfg.d_model), _dtype(cfg))
    elif fam == "mamba":
        state["layers"] = _stack_state(
            lambda: init_mamba_state(cfg, batch), cfg.n_layers)
    elif fam == "hybrid":
        state["layers"] = _stack_state(
            lambda: init_mamba2_state(cfg, batch), cfg.n_layers)
        groups, _, _ = _hybrid_layout(cfg)
        # one KV cache per shared-attention invocation site
        state["shared_cache"] = _stack_state(
            lambda: init_kv_cache(cfg, batch, max_len, cache_dtype),
            groups)
    elif fam == "ssm":
        groups, per = _xlstm_layout(cfg)
        state["m_blocks"] = _stack_state(
            lambda: _stack_state(lambda: init_mlstm_state(cfg, batch),
                                 per), groups)
        state["s_blocks"] = _stack_state(
            lambda: init_slstm_state(cfg, batch), groups)
    return state


def _hybrid_stack(params: Dict, cfg: ModelConfig, state: Dict,
                  x: jax.Array, pos: jax.Array, qctx, *, seq: bool):
    """Walk the hybrid (Mamba-2 groups + shared attention) stack once.

    seq=False: x (B, d), per-token decode via ``mamba2_block_step``.
    seq=True:  x (B, L, d), chunked prefill via ``mamba2_block_prefill``
    (the shared attention appends all L entries to its KV cache in one
    dispatch).  Returns (h, new_layers, new_shared_cache).
    """
    groups, per, tail = _hybrid_layout(cfg)
    gp = _group_tree(params["layers"], groups, per)
    gs = _group_tree(state["layers"], groups, per)
    quant = qctx is not None and qctx.get("mode") == "quant"
    block = mamba2_block_prefill if seq else mamba2_block_step

    def run_group(h, lp, ls, gq, sh_cache_g):
        h, new_ls = _scan_blocks_cache(
            lambda q_lp, hh, c, q: block(q_lp, cfg, hh, c, q),
            h, lp, ls, gq, "g")
        shq = (_layer_qctx(qctx, qctx["scales"]["shared"],
                           qctx["qw"]["shared"]) if quant else qctx)
        h2, _, new_cache = decoder_layer(
            params["shared"], cfg, h if seq else h[:, None, :],
            mask_kind="causal", cache=sh_cache_g, cache_pos=pos,
            qctx=shq)
        return (h2 if seq else h2[:, 0]), new_ls, new_cache

    new_groups = []
    new_sh = []
    h = x
    for g in range(groups):
        lp = jax.tree.map(lambda a: a[g], gp)
        ls = jax.tree.map(lambda a: a[g], gs)
        sh_cache_g = jax.tree.map(lambda a: a[g],
                                  state["shared_cache"])
        gq = qctx
        if quant:
            gq = {"mode": "quant", "spec": qctx["spec"],
                  "scales": {"g": jax.tree.map(
                      lambda a: a[g], _group_tree(
                          qctx["scales"]["layers"], groups, per))},
                  "qw": {"g": jax.tree.map(
                      lambda a: a[g], _group_tree(
                          qctx["qw"]["layers"], groups, per))}}
        h, new_ls, sh_cache_g = run_group(h, lp, ls, gq, sh_cache_g)
        new_groups.append(new_ls)
        new_sh.append(sh_cache_g)
    if tail:
        tp = _tail_tree(params["layers"], groups * per)
        ts = _tail_tree(state["layers"], groups * per)
        tq = qctx
        if quant:
            tq = {"mode": "quant", "spec": qctx["spec"],
                  "scales": {"t": _tail_tree(
                      qctx["scales"]["layers"], groups * per)},
                  "qw": {"t": _tail_tree(qctx["qw"]["layers"],
                                         groups * per)}}
        h, new_ts = _scan_blocks_cache(
            lambda q_lp, hh, c, q: block(q_lp, cfg, hh, c, q),
            h, tp, ts, tq, "t")
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs, 0), *new_groups)
    flat = jax.tree.map(
        lambda a: a.reshape((groups * per,) + a.shape[2:]), stacked)
    if tail:
        flat = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], 0), flat, new_ts)
    new_sh_cache = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_sh)
    return h, flat, new_sh_cache


def decode_step(params: Dict, cfg: ModelConfig, state: Dict,
                tokens: jax.Array, qctx=None
                ) -> Tuple[jax.Array, Dict]:
    """One generation step.  tokens: (B,) int32.  Returns (logits, state)."""
    dt = _dtype(cfg)
    fam = cfg.family
    pos = state["pos"]
    new_state = dict(state)

    if fam in ("dense", "moe", "vlm", "audio"):
        x = _embed(params, cfg, tokens[:, None], dt)        # (B, 1, d)
        enc_out = state.get("enc_out") if fam == "audio" else None

        def step(lp, h, cache, q):
            h2, _, new_cache = decoder_layer(
                lp, cfg, h, mask_kind="causal", enc_out=enc_out,
                cache=cache, cache_pos=pos, qctx=q)
            return h2, new_cache

        x, new_caches = _scan_blocks_cache(
            step, x, params["layers"], state["caches"], qctx, "layers")
        new_state["caches"] = new_caches
        x = x[:, 0]
    elif fam == "mamba":
        x = _embed(params, cfg, tokens, dt)                 # (B, d)
        x, new_layers = _scan_blocks_cache(
            lambda lp, h, c, q: mamba_block_step(lp, cfg, h, c, q),
            x, params["layers"], state["layers"], qctx, "layers")
        new_state["layers"] = new_layers
    elif fam == "hybrid":
        x = _embed(params, cfg, tokens, dt)
        x, flat, new_sh = _hybrid_stack(params, cfg, state, x, pos,
                                        qctx, seq=False)
        new_state["layers"] = flat
        new_state["shared_cache"] = new_sh
    elif fam == "ssm":
        x = _embed(params, cfg, tokens, dt)
        groups, per = _xlstm_layout(cfg)
        quant = qctx is not None and qctx.get("mode") == "quant"
        new_m, new_s = [], []
        h = x
        for g in range(groups):
            mp = jax.tree.map(lambda a: a[g], params["m_blocks"])
            ms = jax.tree.map(lambda a: a[g], state["m_blocks"])
            gq = qctx
            sq = qctx
            if quant:
                gq = {"mode": "quant", "spec": qctx["spec"],
                      "scales": {"g": jax.tree.map(
                          lambda a: a[g], qctx["scales"]["m_blocks"])},
                      "qw": {"g": jax.tree.map(
                          lambda a: a[g], qctx["qw"]["m_blocks"])}}
                sq = _layer_qctx(
                    qctx,
                    jax.tree.map(lambda a: a[g],
                                 qctx["scales"]["s_blocks"]),
                    jax.tree.map(lambda a: a[g], qctx["qw"]["s_blocks"]))
            h, ms_new = _scan_blocks_cache(
                lambda lp, hh, c, q: mlstm_block_step(lp, cfg, hh, c, q),
                h, mp, ms, gq, "g")
            sp = jax.tree.map(lambda a: a[g], params["s_blocks"])
            ss = jax.tree.map(lambda a: a[g], state["s_blocks"])
            h, ss_new = slstm_block_step(sp, cfg, h, ss, sq)
            new_m.append(ms_new)
            new_s.append(ss_new)
        new_state["m_blocks"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, 0), *new_m)
        new_state["s_blocks"] = jax.tree.map(
            lambda *xs: jnp.stack(xs, 0), *new_s)
        x = h
    else:
        raise ValueError(fam)

    new_state["pos"] = pos + 1
    logits = _logits(params, cfg, x[None] if x.ndim == 1 else x)
    if logits.ndim == 3:
        logits = logits[:, 0] if logits.shape[1] == 1 else logits[:, -1]
    return logits, new_state


# families whose decode state can be advanced a whole sequence chunk at
# a time: recurrent families carry state via h0/h_last, attention
# families scatter a whole chunk of KV entries per dispatch, hybrid does
# both.  audio stays per-token (cross-attention bookkeeping).
SEQ_PREFILL_FAMILIES = ("mamba", "dense", "moe", "vlm", "hybrid")


def supports_seq_prefill(cfg: ModelConfig) -> bool:
    return cfg.family in SEQ_PREFILL_FAMILIES


def prefill_step(params: Dict, cfg: ModelConfig, state: Dict,
                 tokens: jax.Array, qctx=None) -> Tuple[jax.Array, Dict]:
    """Advance the decode state by a whole chunk of prompt tokens.

    tokens: (B, L) int32.  One dispatch replaces L ``decode_step``
    dispatches: recurrent layers run their sequence forward with the
    state carried in and out, attention layers append L KV entries at
    the per-row positions and mask each query row to its own absolute
    position (chunked prefill).  The per-token math is identical to
    ``decode_step``, so streams after a chunked prefill are
    bit-identical to per-token prefill.  Returns (last-position logits
    (B, V), new state); chain calls for longer prompts.
    """
    if not supports_seq_prefill(cfg):
        raise NotImplementedError(
            f"sequence prefill not implemented for family {cfg.family!r}")
    dt = _dtype(cfg)
    fam = cfg.family
    L = tokens.shape[1]
    pos = state["pos"]
    x = _embed(params, cfg, tokens, dt)                 # (B, L, d)
    new_state = dict(state)
    if fam == "mamba":
        x, new_layers = _scan_blocks_cache(
            lambda lp, h, c, q: mamba_block_prefill(lp, cfg, h, c, q),
            x, params["layers"], state["layers"], qctx, "layers")
        new_state["layers"] = new_layers
    elif fam in ("dense", "moe", "vlm"):
        def step(lp, h, cache, q):
            h2, _, new_cache = decoder_layer(
                lp, cfg, h, mask_kind="causal", cache=cache,
                cache_pos=pos, qctx=q)
            return h2, new_cache

        x, new_caches = _scan_blocks_cache(
            step, x, params["layers"], state["caches"], qctx, "layers")
        new_state["caches"] = new_caches
    else:                                               # hybrid
        x, flat, new_sh = _hybrid_stack(params, cfg, state, x, pos,
                                        qctx, seq=True)
        new_state["layers"] = flat
        new_state["shared_cache"] = new_sh
    new_state["pos"] = state["pos"] + L
    logits = _logits(params, cfg, x[:, -1:])
    return logits[:, 0], new_state


# ---------------------------------------------------------------------------
# speculative verify (multi-token decode with per-step state snapshots)
# ---------------------------------------------------------------------------

def supports_verify(cfg: ModelConfig) -> bool:
    """True when the family has a fused multi-token verify path."""
    return cfg.family == "mamba"


def verify_step(params: Dict, cfg: ModelConfig, state: Dict,
                tokens: jax.Array, qctx=None) -> Tuple[jax.Array, Dict]:
    """Advance M tokens in ONE dispatch, keeping EVERY boundary state.

    tokens: (B, M) int32 -- the next committed token followed by the
    draft tokens.  Returns (logits (B, M, V), steps): ``logits[:, i]``
    is the distribution after consuming ``tokens[:, i]``, and ``steps``
    is a decode-state tree whose recurrent leaves gain a per-step axis
    directly after their batch axis (``steps['pos']`` becomes (B, M)).
    ``select_verify_state`` collapses it to the snapshot of any accepted
    prefix -- the O(1) speculative-decode rollback.  Each step runs
    ``decode_step``'s exact per-token ops, so accepting i tokens and
    restoring snapshot i is bit-identical to having decoded them one by
    one.
    """
    if not supports_verify(cfg):
        raise NotImplementedError(
            f"verify_step not implemented for family {cfg.family!r}")
    dt = _dtype(cfg)
    m = tokens.shape[1]
    x = _embed(params, cfg, tokens, dt)                 # (B, M, d)
    x, step_layers = _scan_blocks_cache(
        lambda lp, h, c, q: mamba_block_verify(lp, cfg, h, c, q),
        x, params["layers"], state["layers"], qctx, "layers")
    steps = dict(state)
    steps["layers"] = step_layers
    steps["pos"] = state["pos"][:, None] + 1 + jnp.arange(m)[None, :]
    return _logits(params, cfg, x), steps


def select_verify_state(cfg: ModelConfig, steps: Dict,
                        idx: jax.Array) -> Dict:
    """Collapse ``verify_step``'s per-step axis to one snapshot per row.

    idx: (B,) int32 -- for row b keep the state after fed token
    ``idx[b]`` (0-based).  Returns a regular decode state; this gather
    IS the speculative rollback: O(1) in tokens, no recompute.
    """
    axes = _batch_axis_map(cfg)
    out = dict(steps)
    out["pos"] = jnp.take_along_axis(
        steps["pos"], idx.astype(jnp.int32)[:, None], axis=1)[:, 0]
    for key, axis in axes.items():
        if key == "pos" or key not in steps:
            continue

        def one(a, axis=axis):
            # step axis sits directly after the leaf's batch axis
            shape = [1] * a.ndim
            shape[axis] = idx.shape[0]
            ix = idx.astype(jnp.int32).reshape(shape)
            return jnp.squeeze(
                jnp.take_along_axis(a, ix, axis=axis + 1), axis=axis + 1)

        out[key] = jax.tree.map(one, steps[key])
    return out


def select_scan_state(cfg: ModelConfig, stacked: Dict,
                      idx: jax.Array) -> Dict:
    """Collapse a ``lax.scan``-stacked decode-state tree (per-step axis
    LEADING, ahead of every batch axis) to one snapshot per row.

    The speculative drafter emits one such tree per round (its scan ys
    are the full decode state after each draft step); idx: (B,) -- row
    ``b`` keeps scan step ``idx[b]``.  Counterpart of
    :func:`select_verify_state`, whose step axis sits after each leaf's
    batch axis instead.
    """
    axes = _batch_axis_map(cfg)
    out = {}
    for key, axis in axes.items():
        if key not in stacked:
            continue

        def one(a, axis=axis):
            shape = [1] * a.ndim
            shape[axis + 1] = idx.shape[0]
            ix = idx.astype(jnp.int32).reshape(shape)
            return jnp.squeeze(jnp.take_along_axis(a, ix, axis=0), axis=0)

        out[key] = jax.tree.map(one, stacked[key])
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    """Model inputs for train/prefill shapes (paper-style stand-ins)."""
    b, L = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = _dtype(cfg)
    sds = jax.ShapeDtypeStruct
    if cfg.family == "audio":
        le = (3 * L // 4 // 128) * 128
        ld = L - le
        batch = {"frames": sds((b, le, cfg.d_model), dt),
                 "tokens": sds((b, ld), i32)}
        if shape.kind == "train":
            batch["targets"] = sds((b, ld), i32)
        return batch
    if cfg.family == "vlm":
        lt = L - cfg.prefix_len
        batch = {"patches": sds((b, cfg.prefix_len, cfg.d_model), dt),
                 "tokens": sds((b, lt), i32)}
        if shape.kind == "train":
            batch["targets"] = sds((b, lt), i32)
        return batch
    batch = {"tokens": sds((b, L), i32)}
    if shape.kind == "train":
        batch["targets"] = sds((b, L), i32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec,
                       cache_dtype=None) -> Tuple:
    """(state_specs, token_spec) for decode shapes: one new token against
    a cache of shape.seq_len.  cache_dtype=int8 yields the quantized KV
    cache layout (int8 entries + per-entry fp32 scales)."""
    b = shape.global_batch
    kw = {} if cache_dtype is None else {"cache_dtype": cache_dtype}
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, b, shape.seq_len, **kw))
    if cfg.family == "audio":
        le = (3 * shape.seq_len // 4 // 128) * 128
        state = dict(state)
        state["enc_out"] = jax.ShapeDtypeStruct(
            (b, le, cfg.d_model), _dtype(cfg))
    token = jax.ShapeDtypeStruct((b,), jnp.int32)
    return state, token


def reset_slot(cfg: ModelConfig, state: Dict, i: int) -> Dict:
    """Zero one decode slot (serving engine slot reuse).

    Attention KV caches need no clearing -- stale entries sit beyond the
    per-row position mask.  Recurrent states (conv tails, SSM/mLSTM/sLSTM
    states) must be zeroed; the mLSTM stabilizer resets to -inf.
    """
    new = dict(state)
    new["pos"] = state["pos"].at[i].set(0)
    fam = cfg.family

    def zero_axis(tree, axis: int):
        def one(a):
            idx = (slice(None),) * axis + (i,)
            return a.at[idx].set(jnp.zeros_like(a[idx]))
        return jax.tree.map(one, tree)

    if fam == "mamba" or fam == "hybrid":
        new["layers"] = zero_axis(state["layers"], 1)
    if fam == "ssm":
        mb = zero_axis(state["m_blocks"], 2)
        mb = dict(mb)
        mb["m"] = state["m_blocks"]["m"].at[:, :, i].set(-1e30)
        new["m_blocks"] = mb
        new["s_blocks"] = zero_axis(state["s_blocks"], 1)
    return new


def decode_state_batch_axes(cfg: ModelConfig) -> Dict[str, int]:
    """Batch-dim axis of each top-level decode-state entry (public: the
    serving engine and ``repro.dist.sharding`` shard slots along it)."""
    return _batch_axis_map(cfg)


def _batch_axis_map(cfg: ModelConfig):
    """Batch-dim axis of each top-level decode-state entry."""
    fam = cfg.family
    axes = {"pos": 0}
    if fam in ("dense", "moe", "vlm", "audio"):
        axes["caches"] = 1
        if fam == "audio":
            axes["enc_out"] = 0
    elif fam in ("mamba", "hybrid"):
        axes["layers"] = 1
        if fam == "hybrid":
            axes["shared_cache"] = 1
    elif fam == "ssm":
        axes["m_blocks"] = 2
        axes["s_blocks"] = 1
    return axes


def slice_slot(cfg: ModelConfig, state: Dict, i: int) -> Dict:
    """Extract slot ``i`` of the decode state as a batch-1 state tree
    (the serving engine prefills one slot without paying full-batch
    compute)."""
    axes = _batch_axis_map(cfg)
    out = {}
    for key, axis in axes.items():
        if key not in state:
            continue

        def one(a, axis=axis):
            idx = (slice(None),) * axis + (slice(i, i + 1),)
            return a[idx]

        out[key] = jax.tree.map(one, state[key])
    return out


def write_slot(cfg: ModelConfig, state: Dict, slot_state: Dict,
               i: int) -> Dict:
    """Write a batch-1 state tree (from ``slice_slot``) back into slot
    ``i`` of the full decode state."""
    axes = _batch_axis_map(cfg)
    out = dict(state)
    for key, axis in axes.items():
        if key not in state:
            continue

        def one(o, n, axis=axis):
            idx = (slice(None),) * axis + (slice(i, i + 1),)
            return o.at[idx].set(n.astype(o.dtype))

        out[key] = jax.tree.map(one, state[key], slot_state[key])
    return out


def merge_slot(cfg: ModelConfig, old: Dict, new: Dict, i: int) -> Dict:
    """Take slot ``i`` of ``new`` and keep every other slot from ``old``
    (serving engine: prefill one slot without disturbing live ones)."""
    axes = _batch_axis_map(cfg)
    out = {}
    for key, axis in axes.items():
        if key not in old:
            continue

        def one(o, n, axis=axis):
            idx = (slice(None),) * axis + (i,)
            return o.at[idx].set(n[idx])

        out[key] = jax.tree.map(one, old[key], new[key])
    return out
