"""Chunked SSD (Mamba-2) linear recurrence, pure jnp.

State-space dual form: per head h with scalar decay a_t = exp(dt_t * A_h),
    S_t = a_t S_{t-1} + dt_t * B_t (x) x_t          (S: (n, hd))
    y_t = C_t S_t + D_h x_t

Computed chunkwise so nothing of size O(L * n * hd) is materialized:
intra-chunk contributions use (T x T) decay-masked score matmuls (MXU
friendly), inter-chunk state is carried by a short lax.scan over chunks.
Used by the Zamba2 backbone; the quantized path feeds it percentile-
clipped x (Quamba's recipe transferred to Mamba-2, see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_chunked(x: jax.Array, dt: jax.Array, a_head: jax.Array,
                bmat: jax.Array, cmat: jax.Array, d_head: jax.Array,
                chunk: int = 128, h0: Optional[jax.Array] = None,
                return_state: bool = False):
    """x (b,L,h,hd); dt (b,L,h); a_head (h,) negative; bmat/cmat (b,L,n);
    d_head (h,).  Returns y (b,L,h,hd) [and final state (b,h,n,hd)]."""
    b, L, h, hd = x.shape
    n = bmat.shape[-1]
    t = min(chunk, L)
    assert L % t == 0, (L, t)
    nc = L // t
    f32 = jnp.float32

    xr = x.astype(f32).reshape(b, nc, t, h, hd)
    dtr = dt.astype(f32).reshape(b, nc, t, h)
    br = bmat.astype(f32).reshape(b, nc, t, n)
    cr = cmat.astype(f32).reshape(b, nc, t, n)

    # log decay per step and cumulative within chunk
    la = dtr * a_head.astype(f32)                     # (b,nc,t,h) (<0)
    lcum = jnp.cumsum(la, axis=2)                     # cumulative log decay

    # ---- intra-chunk: y[t'] += sum_{s<=t'} C_t'.B_s e^{lcum_t'-lcum_s} dt_s x_s
    cb = jnp.einsum("bctn,bcsn->bcts", cr, br)        # (b,nc,t,t)
    decay = lcum[:, :, :, None, :] - lcum[:, :, None, :, :]  # (b,nc,t,s,h)
    mask = jnp.tril(jnp.ones((t, t), bool))
    # mask BEFORE exp: the upper triangle holds large positive values
    # whose exp overflows and poisons gradients via inf * 0
    decay = jnp.where(mask[None, None, :, :, None], decay, -1e30)
    scores = jnp.exp(decay) * cb[..., None]           # (b,nc,t,s,h)
    dx = dtr[..., None] * xr                          # (b,nc,t,h,hd)
    y_intra = jnp.einsum("bctsh,bcshd->bcthd", scores, dx)

    # ---- chunk summary state: S_c = sum_s e^{lcum_T - lcum_s} dt_s B_s (x) x_s
    tail = lcum[:, :, -1:, :] - lcum                  # (b,nc,t,h)
    sb = jnp.einsum("bcsn,bcsh,bcshd->bchnd",
                    br, jnp.exp(tail) * dtr, xr)      # (b,nc,h,n,hd)

    # ---- inter-chunk scan carrying S (b,h,n,hd)
    chunk_decay = jnp.exp(lcum[:, :, -1, :])          # (b,nc,h)

    def body(s_prev, inp):
        dec, s_c = inp                                # (b,h), (b,h,n,hd)
        s_new = dec[..., None, None] * s_prev + s_c
        return s_new, s_prev

    s_init = (h0.astype(f32) if h0 is not None
              else jnp.zeros((b, h, n, hd), f32))
    s_last, s_prevs = jax.lax.scan(
        body, s_init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(sb, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)             # (b,nc,h,n,hd)

    # ---- inter-chunk contribution: y[t'] += C_t' e^{lcum_t'} S_prev
    y_inter = jnp.einsum("bctn,bcth,bchnd->bcthd",
                         cr, jnp.exp(lcum), s_prevs)

    y = (y_intra + y_inter).reshape(b, L, h, hd)
    y = y + d_head.astype(f32)[None, None, :, None] * x.astype(f32)
    if return_state:
        return y, s_last
    return y


def ssd_step(s: jax.Array, x: jax.Array, dt: jax.Array, a_head: jax.Array,
             bmat: jax.Array, cmat: jax.Array, d_head: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
    """Single decode step.  s (b,h,n,hd); x (b,h,hd); dt (b,h);
    bmat/cmat (b,n).  Returns (y (b,h,hd), s_new)."""
    f32 = jnp.float32
    dec = jnp.exp(dt.astype(f32) * a_head.astype(f32))        # (b,h)
    contrib = jnp.einsum("bn,bhd->bhnd", bmat.astype(f32),
                         dt.astype(f32)[..., None] * x.astype(f32))
    s_new = dec[..., None, None] * s.astype(f32) + contrib
    y = jnp.einsum("bn,bhnd->bhd", cmat.astype(f32), s_new)
    y = y + d_head.astype(f32)[None, :, None] * x.astype(f32)
    return y, s_new


def ssd_seq(x: jax.Array, dt: jax.Array, a_head: jax.Array,
            bmat: jax.Array, cmat: jax.Array, d_head: jax.Array,
            h0: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (lax.scan over time) SSD; always returns (y, s_last).

    Same semantics as ``ssd_chunked(..., return_state=True)`` but the
    recurrence runs strictly in time order with the exact fp operations
    of :func:`ssd_step` -- so a chunked prefill through this path is
    bitwise-identical to stepping token by token (the chunkwise
    einsum form of ``ssd_chunked`` is NOT: it reassociates the decay
    products).  The serving engine's prefill->decode handoff for the
    hybrid family relies on this.
    """
    b, L, h, hd = x.shape
    n = bmat.shape[-1]
    s0 = (h0.astype(jnp.float32) if h0 is not None
          else jnp.zeros((b, h, n, hd), jnp.float32))

    def body(s, t):
        x_t, dt_t, b_t, c_t = t
        y_t, s_new = ssd_step(s, x_t, dt_t, a_head, b_t, c_t, d_head)
        return s_new, y_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, bmat, cmat))
    s_last, ys = jax.lax.scan(body, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_last


def ssd_reference(x, dt, a_head, bmat, cmat, d_head, h0=None):
    """Slow sequential oracle for tests."""
    b, L, h, hd = x.shape
    n = bmat.shape[-1]
    s = (h0.astype(jnp.float32) if h0 is not None
         else jnp.zeros((b, h, n, hd), jnp.float32))
    ys = []
    for i in range(L):
        y, s = ssd_step(s, x[:, i], dt[:, i], a_head, bmat[:, i],
                        cmat[:, i], d_head)
        ys.append(y)
    return jnp.stack(ys, axis=1), s
