"""SwiGLU MLP and capacity-based Mixture-of-Experts layers."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import is_calib, linear
from repro.quant.observers import observe


def init_mlp(key: jax.Array, d: int, ff: int) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "wi": common.dense_init(k1, d, 2 * ff),   # fused gate & up
        "wo": common.dense_init(k2, ff, d),
    }


def mlp(p: Dict, x: jax.Array, qctx=None) -> Tuple[jax.Array, Dict]:
    aux: Dict = {}
    if is_calib(qctx):
        aux["mlp_in"] = observe(x)
    gu = linear(p, "wi", x, qctx, site="mlp_wi")
    gate, up = jnp.split(gu, 2, axis=-1)
    h = common.silu(gate) * up
    if is_calib(qctx):
        aux["down_in"] = observe(h)
    out = linear(p, "wo", h, qctx, site="mlp_wo")
    return out, aux


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-based dispatch, EP-shardable)
# ---------------------------------------------------------------------------

def init_moe(key: jax.Array, cfg: ModelConfig) -> Dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": common.dense_init(k1, d, e),
        "wi": jax.random.truncated_normal(
            k2, -2, 2, (e, d, 2 * ff), jnp.float32) / jnp.sqrt(d),
        "wo": jax.random.truncated_normal(
            k3, -2, 2, (e, ff, d), jnp.float32) / jnp.sqrt(ff),
    }


def moe(p: Dict, cfg: ModelConfig, x: jax.Array, qctx=None,
        no_drop: bool = False) -> Tuple[jax.Array, Dict]:
    """Switch-style capacity dispatch.

    Tokens route to top-k experts; each expert processes at most
    C = ceil(T * k / E * capacity_factor) tokens (overflow dropped).
    The (E, C, d) buffers and (E, ...) expert weights shard over the
    'model' axis => expert parallelism; GSPMD inserts the all-to-alls.
    """
    aux: Dict = {}
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)
    if is_calib(qctx):
        aux["moe_in"] = observe(x)

    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                  # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    if no_drop:
        cap = t  # decode: capacity == tokens, nothing can overflow
    else:
        cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_i, e, dtype=jnp.int32)       # (T, K, E)
    flat = onehot.reshape(t * k, e)
    pos_in_e = (jnp.cumsum(flat, axis=0) - flat).reshape(t, k, e)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                 # (T, K)
    keep = pos < cap
    eidx = gate_i
    pos_c = jnp.where(keep, pos, 0)

    # Dispatch = skinny int32 scatter of token ids + one row gather.
    # (A direct scatter-add of the (T, K, d) float payload makes GSPMD
    # replicate the expert buffer and all-reduce it -- measured 26x more
    # collective bytes on the production mesh; EXPERIMENTS.md §Perf C2.)
    tok_ids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[:, None],
                               (t, k))
    # dropped slots write to a spill column (cap) that is sliced away
    pos_s = jnp.where(keep, pos, cap)
    slot_tok = jnp.full((e, cap + 1), t, jnp.int32)
    slot_tok = slot_tok.at[eidx.reshape(-1), pos_s.reshape(-1)].set(
        tok_ids.reshape(-1))[:, :cap]                          # (E, C)
    # (forcing replication of the token table here was measured WORSE:
    # the constraint's transpose turns into an extra psum in backward;
    # EXPERIMENTS.md §Perf C2 iteration 2, refuted)
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = jnp.take(xt_pad, slot_tok.reshape(-1), axis=0).reshape(
        e, cap, d)
    buf = common.maybe_constrain(buf, "model", None, None)     # EP

    # expert compute (batched over E; EP shards this axis)
    gu = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    gate, up = jnp.split(gu, 2, axis=-1)
    h = common.silu(gate) * up
    yb = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
    yb = common.maybe_constrain(yb, "model", None, None)

    # gather back with routing weights
    gathered = yb[eidx.reshape(-1), pos_c.reshape(-1)].reshape(t, k, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    out = jnp.sum(gathered * gate_w[..., None].astype(x.dtype), axis=1)

    if is_calib(qctx):
        aux["moe_frac_dropped"] = {
            "amax": 1.0 - keep.mean(dtype=jnp.float32),
            "p": jnp.zeros((5,), jnp.float32),
            "cmax": jnp.zeros((d,), jnp.float32),
        }
    # auxiliary load-balancing loss (Switch): E * sum(frac_tokens * router_prob)
    me = jnp.mean(jax.nn.one_hot(gate_i[:, 0], e, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), {**aux, "moe_aux_loss": aux_loss}
