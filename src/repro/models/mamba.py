"""Mamba-1 block (the paper's architecture) with the full Quamba dataflow.

The block implements all three execution modes through ``qctx``:
  * fp      -- plain bf16/fp32 forward
  * calib   -- forward + per-site activation summaries (paper §5.1)
  * quant   -- the paper Fig. 4 precision mapping:
      - fused RMSNorm emits a statically-quantized int8 block input
      - in_proj / x_proj / dt_proj / out_proj are W8A8 per-tensor
      - the SSM input x uses the percentile-max scale (§4.2, p=99.999)
      - (B_t, C_t, dt_t) are quantized per-tensor int8
      - the gated SSM output is rotated with a Hadamard matrix and
        quantized in the outlier-free space; H is folded into W_out
        (compute-invariance), so the rotation costs one fused transform.

Baselines (static / dynamic / SmQ-SSM / QuaRot-SSM, Tables 2/3/5/9) ride
the same code path -- ``QuantSpec`` toggles decide which sites clip,
rotate, or recompute scales dynamically.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import is_calib, is_quant, linear
from repro.quant.hadamard import had_transform, had_transform_t
from repro.quant.observers import observe
from repro.quant import quantizers as Q
from repro.quant import recipe as qrecipe
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def use_kernel_backend(qctx) -> bool:
    """True when this qctx routes the block through the Pallas kernels
    (``QuantSpec.backend == "kernels"``) instead of the qdq fake-quant
    oracle.  Requires int8 conv taps in the qdata (absent in artifacts
    quantized before the kernel backend existed -> fall back); 4-bit
    specs additionally require nibble-packed matmul sites (absent in
    pre-v2 artifacts, which stored w4 unpacked and ran qdq-only)."""
    if not is_quant(qctx):
        return False
    if not qrecipe.uses_kernel_backend(qctx["spec"]):
        return False
    # the fused conv kernel needs the int8 taps ("conv_w" in the block's
    # qw dict) -- absent in pre-backend artifacts, which keep the oracle
    qw = qctx.get("qw", {})
    if "conv_w" not in qw:
        return False
    if qctx["spec"].w_bits == 4 and "qw4" not in qw.get("in_proj", {}):
        return False
    return True


def _matmul(qx: jax.Array, lin: Dict, s_x) -> jax.Array:
    """One quantized projection on the kernel backend: ``int4_matmul``
    when the site is nibble-packed ({"qw4", "s_w"}, W4A8), ``int8_matmul``
    otherwise.  Dispatch goes through the ``kops`` module attributes so
    routing tests can monkeypatch and count per-kernel calls."""
    if "qw4" in lin:
        return kops.int4_matmul(qx, lin["qw4"], s_x, lin["s_w"])
    return kops.int8_matmul(qx, lin["qw"], s_x, lin["s_w"])


def init_mamba_block(key: jax.Array, cfg: ModelConfig) -> Dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    dtr, w = cfg.resolved_dt_rank, cfg.conv_width
    ks = jax.random.split(key, 6)
    # dt bias: softplus^{-1}(dt) for dt ~ U[1e-3, 1e-1] (Mamba init)
    u = jax.random.uniform(ks[0], (di,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))   # inverse softplus
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "in_proj": common.dense_init(ks[1], d, 2 * di),
        "conv_w": 0.1 * jax.random.normal(ks[2], (w, di), jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": common.dense_init(ks[3], di, dtr + 2 * n),
        "dt_proj": common.dense_init(ks[4], dtr, di,
                                     scale=dtr ** -0.5),
        "dt_bias": dt_bias,
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": common.dense_init(ks[5], di, d),
    }


def _depthwise_conv_silu(x: jax.Array, w: jax.Array, b: jax.Array,
                         state: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv + SiLU.  x (B, L, D); w (W, D).
    state: (B, W-1, D) previous tail (decode/chunked prefill)."""
    bsz, L, d = x.shape
    width = w.shape[0]
    pad = state if state is not None else jnp.zeros(
        (bsz, width - 1, d), x.dtype)
    xp = jnp.concatenate([pad.astype(x.dtype), x], axis=1)
    y = sum(xp[:, k:k + L] * w[k].astype(x.dtype) for k in range(width))
    y = y + b.astype(x.dtype)
    return common.silu(y), xp[:, -(width - 1):]


def _ssm_params(p: Dict, cfg: ModelConfig, xc: jax.Array, qctx,
                aux: Dict):
    """Compute the selection parameters (dt, B, C) from the SSM input."""
    dtr, n = cfg.resolved_dt_rank, cfg.d_state
    bcdt = linear(p, "x_proj", xc, qctx)
    dt_low, bmat, cmat = jnp.split(bcdt, [dtr, dtr + n], axis=-1)
    if is_calib(qctx):
        aux["dt_low"] = observe(dt_low)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        dt_low = qrecipe.act_qdq(dt_low, qctx["scales"]["dt_low"],
                                 qctx["spec"])
    dt = common.softplus(linear(p, "dt_proj", dt_low, qctx)
                         + p["dt_bias"].astype(xc.dtype))
    if is_calib(qctx):
        aux["dt"] = observe(dt)
        aux["B"] = observe(bmat)
        aux["C"] = observe(cmat)
    if is_quant(qctx):
        spec: qrecipe.QuantSpec = qctx["spec"]
        sc = qctx["scales"]
        if spec.method == "dynamic":
            dt = Q.dynamic_qdq(dt)
            bmat = Q.dynamic_qdq(bmat)
            cmat = Q.dynamic_qdq(cmat)
        else:
            dt = qrecipe.act_qdq(dt, sc["dt"], spec)
            bmat = qrecipe.act_qdq(bmat, sc["B"], spec)
            cmat = qrecipe.act_qdq(cmat, sc["C"], spec)
    return dt, bmat, cmat


def _quant_ssm_input(xc: jax.Array, qctx, aux: Dict) -> jax.Array:
    """The paper's central treatment of the sensitive SSM input x."""
    if is_calib(qctx):
        aux["x"] = observe(xc)
        aux["x_had"] = observe(had_transform(xc))   # for QuaRot-SSM
        return xc
    if not is_quant(qctx):
        return xc
    spec: qrecipe.QuantSpec = qctx["spec"]
    sc = qctx["scales"]
    if spec.method == "dynamic":
        return Q.dynamic_qdq(xc)
    if spec.method == "quarot":
        # QuaRot-SSM (§C): rotate, quantize, rotate back -- costs two extra
        # transforms (+ transposes on GPU) at inference; Quamba avoids this.
        xr = had_transform(xc)
        xr = qrecipe.act_qdq(xr, sc["x_had"], spec)
        return had_transform_t(xr)
    return qrecipe.ssm_input_qdq(xc, sc["x"], spec)


def _quant_A(p: Dict, qctx) -> jax.Array:
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method != "dynamic":
            a = Q.qdq(a, qctx["scales"]["A"])
    return a


# ---------------------------------------------------------------------------
# kernel-backed int8 execution (QuantSpec.backend == "kernels")
# ---------------------------------------------------------------------------
#
# The paper's deployed dataflow (Fig. 4): activations are quantized ONCE
# to int8 at each site and the int8 tensors feed the fused Pallas kernels
# directly -- no qdq round-trips, no fp reference scan.  All calls go
# through the ``kops`` module attributes so routing tests can monkeypatch
# them and count dispatches.

def _kernel_out_proj(y2d: jax.Array, sc: Dict, qw: Dict,
                     spec) -> jax.Array:
    """SSM output -> out_proj: Hadamard-rotate+quantize (H folded into
    W_out) or plain static quantize, then one int8 matmul."""
    if spec.use_hadamard:
        q_y = kops.hadamard_quant(y2d, sc["y_had"])
        return _matmul(q_y, qw["out_proj_had"], sc["y_had"])
    q_y = Q.quantize(y2d, sc["y"])
    return _matmul(q_y, qw["out_proj"], sc["y"])


def _kernel_selection(bcdt: jax.Array, p: Dict, cfg: ModelConfig,
                      sc: Dict, qw: Dict):
    """(dt_low | B | C) fp32 rows -> (qdt, qB, qC) int8 rows."""
    dtr, n = cfg.resolved_dt_rank, cfg.d_state
    dt_low, bmat, cmat = jnp.split(bcdt, [dtr, dtr + n], axis=-1)
    q_dt_low = Q.quantize(dt_low, sc["dt_low"])
    dt = _matmul(q_dt_low, qw["dt_proj"], sc["dt_low"])
    dt = common.softplus(dt + p["dt_bias"].astype(jnp.float32))
    return (Q.quantize(dt, sc["dt"]), Q.quantize(bmat, sc["B"]),
            Q.quantize(cmat, sc["C"]))


def _kernel_scan_operands(p: Dict, sc: Dict, qw: Dict):
    """(qA int8, scale vector (s_u, s_dt, s_A, s_B, s_C), D fp32).

    qA is precomputed at quantize time (sitemap ``QuantizedTensor``);
    the on-the-fly derivation only remains for qdata generated before
    that site existed."""
    if "A" in qw:
        qa = qw["A"]["qw"]
    else:
        qa = Q.quantize(-jnp.exp(p["A_log"].astype(jnp.float32)),
                        sc["A"])
    svec = jnp.stack([jnp.asarray(s, jnp.float32) for s in
                      (sc["x"], sc["dt"], sc["A"], sc["B"], sc["C"])])
    return qa, svec, p["D"].astype(jnp.float32)


def _mamba_kernels_seq(p: Dict, cfg: ModelConfig, x: jax.Array, qctx,
                       state: Optional[Dict] = None
                       ) -> Tuple[jax.Array, Optional[Dict]]:
    """Kernel-backed sequence forward.  x (B, L, d); optional recurrent
    ``state`` {"conv", "h"} turns it into a prefill chunk (state carried
    across chunks via the conv tail and the scan's h0/h_last)."""
    spec, sc, qw = qctx["spec"], qctx["scales"], qctx["qw"]
    bsz, L, d = x.shape
    di = cfg.d_inner
    x2d = x.astype(jnp.float32).reshape(-1, d)

    # fused residual-add + RMSNorm + static int8 quantization (§4.3);
    # the residual operand is zero here because the block adds its own
    # residual on return (the layer scan owns the stream).
    q_in, _ = kops.rmsnorm_quant(x2d, jnp.zeros_like(x2d), p["norm"],
                                 sc["in"], eps=cfg.norm_eps)
    xz = _matmul(q_in, qw["in_proj"], sc["in"])
    xc, z = jnp.split(xz, 2, axis=-1)
    z = z.reshape(bsz, L, di)

    # fused int8 conv + SiLU + requant straight to the SSM-input scale
    # (the percentile-max scale of §4.2) -- one kernel, int8 in/out.
    qxc = Q.quantize(xc, sc["conv_in"]).reshape(bsz, L, di)
    conv_state = (Q.quantize(state["conv"].astype(jnp.float32),
                             sc["conv_in"])
                  if state is not None else None)
    cw = qw["conv_w"]
    qu, new_conv_q = kops.causal_conv1d(
        qxc, cw["qw"], p["conv_b"], sc["conv_in"], cw["s_w"],
        s_out=sc["x"], state=conv_state, apply_silu=True)

    # selection parameters from the already-int8 SSM input
    bcdt = _matmul(qu.reshape(-1, di), qw["x_proj"], sc["x"])
    qdt, qb, qc = _kernel_selection(bcdt, p, cfg, sc, qw)
    n = cfg.d_state
    qdt = qdt.reshape(bsz, L, di)
    qb, qc = qb.reshape(bsz, L, n), qc.reshape(bsz, L, n)
    qa, svec, dres = _kernel_scan_operands(p, sc, qw)

    h0 = state["h"] if state is not None else None
    y, h_last = kops.selective_scan(qu, qdt, qa, qb, qc, svec, dres,
                                    z=z, h0=h0)
    out = _kernel_out_proj(y.reshape(-1, di), sc, qw, spec)
    out = x + out.reshape(bsz, L, d).astype(x.dtype)
    if state is None:
        return out, None
    new_conv = (new_conv_q.astype(jnp.float32)
                * jnp.asarray(sc["conv_in"], jnp.float32)
                ).astype(state["conv"].dtype)
    return out, {"conv": new_conv, "h": h_last}


def _mamba_kernels_step(p: Dict, cfg: ModelConfig, x: jax.Array,
                        state: Dict, qctx) -> Tuple[jax.Array, Dict]:
    """Kernel-backed single-token decode.  x (B, d)."""
    spec, sc, qw = qctx["spec"], qctx["scales"], qctx["qw"]
    di = cfg.d_inner
    x2d = x.astype(jnp.float32)

    q_in, _ = kops.rmsnorm_quant(x2d, jnp.zeros_like(x2d), p["norm"],
                                 sc["in"], eps=cfg.norm_eps)
    xz = _matmul(q_in, qw["in_proj"], sc["in"])
    xc, z = jnp.split(xz, 2, axis=-1)

    qxc = Q.quantize(xc, sc["conv_in"])[:, None, :]       # (B, 1, di)
    conv_q = Q.quantize(state["conv"].astype(jnp.float32), sc["conv_in"])
    cw = qw["conv_w"]
    qu3, new_conv_q = kops.causal_conv1d(
        qxc, cw["qw"], p["conv_b"], sc["conv_in"], cw["s_w"],
        s_out=sc["x"], state=conv_q, apply_silu=True)
    qu = qu3[:, 0]                                        # (B, di)

    bcdt = _matmul(qu, qw["x_proj"], sc["x"])
    qdt, qb, qc = _kernel_selection(bcdt, p, cfg, sc, qw)
    qa, svec, dres = _kernel_scan_operands(p, sc, qw)

    # fused single-token scan step: reads/writes the state in one pass
    y, h_new = kops.selective_scan_step(qu, qdt, qa, qb, qc, svec, dres,
                                        state["h"], z=z)
    out = _kernel_out_proj(y, sc, qw, spec)
    new_conv = (new_conv_q.astype(jnp.float32)
                * jnp.asarray(sc["conv_in"], jnp.float32)
                ).astype(state["conv"].dtype)
    return x + out.astype(x.dtype), {"conv": new_conv, "h": h_new}


def mamba_block(p: Dict, cfg: ModelConfig, x: jax.Array, qctx=None
                ) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward.  x: residual stream (B, L, d)."""
    if use_kernel_backend(qctx):
        out, _ = _mamba_kernels_seq(p, cfg, x, qctx)
        return out, {}
    aux: Dict = {}
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_calib(qctx):
        aux["in"] = observe(h)
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            h = Q.dynamic_qdq(h)
        else:
            # fused RMSNorm -> int8 (paper §4.3)
            h = qrecipe.act_qdq(h, qctx["scales"]["in"], spec)

    xz = linear(p, "in_proj", h, qctx)
    xc, z = jnp.split(xz, 2, axis=-1)
    if is_calib(qctx):
        aux["conv_in"] = observe(xc)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        xc = qrecipe.act_qdq(xc, qctx["scales"]["conv_in"], qctx["spec"])

    xc, _ = _depthwise_conv_silu(xc, p["conv_w"], p["conv_b"])
    xc = _quant_ssm_input(xc, qctx, aux)
    dt, bmat, cmat = _ssm_params(p, cfg, xc, qctx, aux)

    a = _quant_A(p, qctx)
    if is_quant(qctx):
        # quant mode is the deployment oracle: evaluate the recurrence
        # strictly in time order like the fused kernel (and per-token
        # decode) so backend parity is not at the mercy of the parallel
        # scan's float re-association flipping a requant tie downstream
        y, _ = kref.selective_scan_seq_ref(xc, dt, a, bmat, cmat,
                                           p["D"].astype(jnp.float32),
                                           z=z)
    else:
        y = kref.selective_scan_ref(xc, dt, a, bmat, cmat,
                                    p["D"].astype(jnp.float32), z=z)
    y = y.astype(x.dtype)

    # ---- output: Hadamard-rotated quantization (paper §4.2) ----
    if is_calib(qctx):
        aux["y"] = observe(y)
        aux["y_had"] = observe(had_transform(y))
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            y = Q.dynamic_qdq(y)
            out = linear(p, "out_proj", y, qctx)
        elif spec.use_hadamard:
            # y^H = H y; W_out already H-folded at quantize time, so the
            # matmul is compute-invariant: (1/n)(H W)^T (H y) == W^T y.
            yh = had_transform(y)
            out = linear(p, "out_proj", yh, qctx, site="out_proj_had")
        else:
            y = qrecipe.act_qdq(y, qctx["scales"]["y"], spec)
            out = linear(p, "out_proj", y, qctx)
    else:
        out = linear(p, "out_proj", y, qctx)
    return x + out, aux


def init_mamba_state(cfg: ModelConfig, batch: int) -> Dict:
    di, n, w = cfg.d_inner, cfg.d_state, cfg.conv_width
    return {
        "conv": jnp.zeros((batch, w - 1, di), jnp.float32),
        "h": jnp.zeros((batch, di, n), jnp.float32),
    }


def mamba_block_prefill(p: Dict, cfg: ModelConfig, x: jax.Array,
                        state: Dict, qctx=None) -> Tuple[jax.Array, Dict]:
    """Sequence forward with recurrent-state carry (chunked prefill).

    x: (B, L, d); state: {"conv", "h"} as produced by
    ``init_mamba_state``.  One dispatch processes the whole chunk; the
    conv tail and the scan's h0/h_last carry across chunks, and the
    recurrence is evaluated strictly in time order, so chunked prefill
    followed by ``mamba_block_step`` decode matches per-token stepping.
    """
    if use_kernel_backend(qctx):
        return _mamba_kernels_seq(p, cfg, x, qctx, state=state)

    aux: Dict = {}
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    # mirror mamba_block_step's site handling exactly (parity contract);
    # note dynamic-method scales are recomputed per *call*, so a chunked
    # prefill is only an approximation of per-token stepping there --
    # the engine keeps the per-token path for dynamic specs
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    xz = linear(p, "in_proj", h, qctx)
    xc, z = jnp.split(xz, 2, axis=-1)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        xc = qrecipe.act_qdq(xc, qctx["scales"]["conv_in"], qctx["spec"])

    xc, new_conv = _depthwise_conv_silu(xc, p["conv_w"], p["conv_b"],
                                        state=state["conv"])
    xc = _quant_ssm_input(xc, qctx, aux)
    dt, bmat, cmat = _ssm_params(p, cfg, xc, qctx, aux)
    a = _quant_A(p, qctx)
    y, h_last = kref.selective_scan_seq_ref(
        xc, dt, a, bmat, cmat, p["D"].astype(jnp.float32), z=z,
        h0=state["h"])
    y = y.astype(x.dtype)
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            y = Q.dynamic_qdq(y)
            out = linear(p, "out_proj", y, qctx)
        elif spec.use_hadamard:
            yh = had_transform(y)
            out = linear(p, "out_proj", yh, qctx, site="out_proj_had")
        else:
            y = qrecipe.act_qdq(y, qctx["scales"]["y"], spec)
            out = linear(p, "out_proj", y, qctx)
    else:
        out = linear(p, "out_proj", y, qctx)
    return x + out, {"conv": new_conv, "h": h_last}


def _conv_tails(xp: jax.Array, width: int) -> jax.Array:
    """Per-step conv-state snapshots from the padded conv input.

    xp: (B, W-1+M, D) -- previous tail followed by the M fed tokens.
    Returns (B, M, W-1, D) where entry i is the conv state after
    consuming fed token i (the window a subsequent decode step would
    read), i.e. exactly what M sequential ``mamba_block_step`` calls
    would have stored.
    """
    m = xp.shape[1] - (width - 1)
    return jnp.stack([xp[:, i + 1:i + width] for i in range(m)], axis=1)


def _mamba_kernels_verify(p: Dict, cfg: ModelConfig, x: jax.Array,
                          state: Dict, qctx) -> Tuple[jax.Array, Dict]:
    """Kernel-backed multi-token verify.  x (B, M, d).  One fused
    ``selective_scan_verify`` dispatch covers all M recurrence steps and
    emits the state at every step boundary."""
    spec, sc, qw = qctx["spec"], qctx["scales"], qctx["qw"]
    bsz, m, d = x.shape
    di = cfg.d_inner
    x2d = x.astype(jnp.float32).reshape(-1, d)

    q_in, _ = kops.rmsnorm_quant(x2d, jnp.zeros_like(x2d), p["norm"],
                                 sc["in"], eps=cfg.norm_eps)
    xz = _matmul(q_in, qw["in_proj"], sc["in"])
    xc, z = jnp.split(xz, 2, axis=-1)
    z = z.reshape(bsz, m, di)

    qxc = Q.quantize(xc, sc["conv_in"]).reshape(bsz, m, di)
    conv_q = Q.quantize(state["conv"].astype(jnp.float32),
                        sc["conv_in"])
    cw = qw["conv_w"]
    qu, _ = kops.causal_conv1d(
        qxc, cw["qw"], p["conv_b"], sc["conv_in"], cw["s_w"],
        s_out=sc["x"], state=conv_q, apply_silu=True)

    bcdt = _matmul(qu.reshape(-1, di), qw["x_proj"], sc["x"])
    qdt, qb, qc = _kernel_selection(bcdt, p, cfg, sc, qw)
    n = cfg.d_state
    qdt = qdt.reshape(bsz, m, di)
    qb, qc = qb.reshape(bsz, m, n), qc.reshape(bsz, m, n)
    qa, svec, dres = _kernel_scan_operands(p, sc, qw)

    y, h_steps = kops.selective_scan_verify(qu, qdt, qa, qb, qc, svec,
                                            dres, state["h"], z=z)
    out = _kernel_out_proj(y.reshape(-1, di), sc, qw, spec)
    out = x + out.reshape(bsz, m, d).astype(x.dtype)
    # int8 conv windows dequantize to exactly what per-token stepping
    # would have stored (quantize is idempotent on grid values)
    xp_q = jnp.concatenate([conv_q, qxc], axis=1)
    conv_steps = (_conv_tails(xp_q, cfg.conv_width).astype(jnp.float32)
                  * jnp.asarray(sc["conv_in"], jnp.float32)
                  ).astype(state["conv"].dtype)
    return out, {"conv": conv_steps, "h": h_steps}


def mamba_block_verify(p: Dict, cfg: ModelConfig, x: jax.Array,
                       state: Dict, qctx=None) -> Tuple[jax.Array, Dict]:
    """Speculative-verify forward: M tokens, state at EVERY boundary.

    x: (B, M, d); state: {"conv", "h"} as in ``mamba_block_step``.
    Returns (out (B, M, d), steps {"conv": (B, M, W-1, di),
    "h": (B, M, di, n)}) where steps[...][:, i] is the recurrent state
    after consuming fed token i.  Each step runs the exact op sequence
    of ``mamba_block_step``, so accepting a prefix of the fed tokens and
    restoring its snapshot is bit-identical to having decoded them one
    by one -- the property speculative decoding's rollback relies on.
    """
    if use_kernel_backend(qctx):
        return _mamba_kernels_verify(p, cfg, x, state, qctx)
    aux: Dict = {}
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    xz = linear(p, "in_proj", h, qctx)
    xc, z = jnp.split(xz, 2, axis=-1)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        xc = qrecipe.act_qdq(xc, qctx["scales"]["conv_in"], qctx["spec"])

    bsz, m, _ = xc.shape
    width = p["conv_w"].shape[0]
    xp = jnp.concatenate([state["conv"].astype(xc.dtype), xc], axis=1)
    y_conv = sum(xp[:, k:k + m] * p["conv_w"][k].astype(xc.dtype)
                 for k in range(width)) + p["conv_b"].astype(xc.dtype)
    conv_steps = _conv_tails(xp, width)
    xc = common.silu(y_conv)
    xc = _quant_ssm_input(xc, qctx, aux)
    dt, bmat, cmat = _ssm_params(p, cfg, xc, qctx, aux)
    a = _quant_A(p, qctx)
    y, h_steps = kref.selective_scan_states_ref(
        xc, dt, a, bmat, cmat, p["D"].astype(jnp.float32), z=z,
        h0=state["h"])
    y = y.astype(x.dtype)
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            y = Q.dynamic_qdq(y)
            out = linear(p, "out_proj", y, qctx)
        elif spec.use_hadamard:
            yh = had_transform(y)
            out = linear(p, "out_proj", yh, qctx, site="out_proj_had")
        else:
            y = qrecipe.act_qdq(y, qctx["scales"]["y"], spec)
            out = linear(p, "out_proj", y, qctx)
    else:
        out = linear(p, "out_proj", y, qctx)
    return x + out, {"conv": conv_steps, "h": h_steps}


def mamba_block_step(p: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
                     qctx=None) -> Tuple[jax.Array, Dict]:
    """Single-token decode.  x: (B, d); state: {"conv", "h"}."""
    if use_kernel_backend(qctx):
        return _mamba_kernels_step(p, cfg, x, state, qctx)
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    xz = linear(p, "in_proj", h, qctx)
    xc, z = jnp.split(xz, 2, axis=-1)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        xc = qrecipe.act_qdq(xc, qctx["scales"]["conv_in"], qctx["spec"])

    xc3, new_conv = _depthwise_conv_silu(
        xc[:, None, :], p["conv_w"], p["conv_b"], state=state["conv"])
    xc = xc3[:, 0]
    aux: Dict = {}
    xc = _quant_ssm_input(xc, qctx, aux)
    dt, bmat, cmat = _ssm_params(p, cfg, xc, qctx, aux)
    a = _quant_A(p, qctx)
    y, h_new = kref.selective_scan_step_ref(
        state["h"], xc, dt, a, bmat, cmat, p["D"].astype(jnp.float32),
        z=z)
    y = y.astype(x.dtype)
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            y = Q.dynamic_qdq(y)
            out = linear(p, "out_proj", y, qctx)
        elif spec.use_hadamard:
            yh = had_transform(y)
            out = linear(p, "out_proj", yh, qctx, site="out_proj_had")
        else:
            y = qrecipe.act_qdq(y, qctx["scales"]["y"], spec)
            out = linear(p, "out_proj", y, qctx)
    else:
        out = linear(p, "out_proj", y, qctx)
    return x + out, {"conv": new_conv, "h": h_new}
