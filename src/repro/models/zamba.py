"""Zamba2-style hybrid: Mamba-2 backbone + periodic shared attention block.

The Mamba-2 block rides the chunked SSD scan (``repro.models.ssd``); the
shared transformer block (single weight set, applied every
``cfg.attn_period`` backbone layers) reuses the zoo's attention + MLP.
Quamba's recipe transfers directly: percentile clip on the SSD input x,
Hadamard-rotated gated output folded into out_proj (DESIGN.md
§Arch-applicability), plus W8A8 on the shared attention (the paper's
Jamba treatment, Table 4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import is_calib, is_quant, linear
from repro.models.mamba import _depthwise_conv_silu
from repro.models.ssd import ssd_chunked, ssd_seq, ssd_step
from repro.quant.hadamard import had_transform
from repro.quant.observers import observe
from repro.quant import quantizers as Q
from repro.quant import recipe as qrecipe


def init_mamba2_block(key: jax.Array, cfg: ModelConfig) -> Dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    heads = cfg.ssm_heads
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[0], (heads,)) *
                 (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "in_proj": common.dense_init(ks[1], d, 2 * di + 2 * n + heads),
        "conv_w": 0.1 * jax.random.normal(
            ks[2], (cfg.conv_width, di + 2 * n), jnp.float32),
        "conv_b": jnp.zeros((di + 2 * n,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "gnorm": jnp.ones((di,), jnp.float32),
        "out_proj": common.dense_init(ks[3], di, d),
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, heads = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    return jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n],
                     axis=-1)  # z, x, B, C, dt


def _gated_out(p, cfg, y, z, x_res, qctx, aux):
    """RMSNorm-gated output + Hadamard quant + out_proj (shared by
    forward/step)."""
    y = common.rmsnorm(y * common.silu(z), p["gnorm"], cfg.norm_eps)
    if is_calib(qctx):
        aux["y"] = observe(y)
        aux["y_had"] = observe(had_transform(y))
    if is_quant(qctx):
        spec = qctx["spec"]
        if spec.method == "dynamic":
            y = Q.dynamic_qdq(y)
            out = linear(p, "out_proj", y, qctx)
        elif spec.use_hadamard:
            out = linear(p, "out_proj", had_transform(y), qctx,
                         site="out_proj_had")
        else:
            y = qrecipe.act_qdq(y, qctx["scales"]["y"], spec)
            out = linear(p, "out_proj", y, qctx)
    else:
        out = linear(p, "out_proj", y, qctx)
    return x_res + out


def mamba2_block(p: Dict, cfg: ModelConfig, x: jax.Array, qctx=None
                 ) -> Tuple[jax.Array, Dict]:
    aux: Dict = {}
    b, L, d = x.shape
    di, n, heads = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    hd = di // heads
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_calib(qctx):
        aux["in"] = observe(h)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    z, xi, bmat, cmat, dt = _split_in_proj(
        cfg, linear(p, "in_proj", h, qctx))
    xbc, _ = _depthwise_conv_silu(
        jnp.concatenate([xi, bmat, cmat], -1), p["conv_w"], p["conv_b"])
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    if is_calib(qctx):
        aux["x"] = observe(xi)
    if is_quant(qctx):
        spec = qctx["spec"]
        xi = (Q.dynamic_qdq(xi) if spec.method == "dynamic"
              else qrecipe.ssm_input_qdq(xi, qctx["scales"]["x"], spec))
    dt = common.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = ssd_chunked(xi.reshape(b, L, heads, hd), dt, a_head,
                    bmat, cmat, p["D"])
    y = y.reshape(b, L, di).astype(x.dtype)
    return _gated_out(p, cfg, y, z, x, qctx, aux), aux


def init_mamba2_state(cfg: ModelConfig, batch: int) -> Dict:
    di, n, heads = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    hd = di // heads
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n),
                          jnp.float32),
        "h": jnp.zeros((batch, heads, n, hd), jnp.float32),
    }


def mamba2_block_prefill(p: Dict, cfg: ModelConfig, x: jax.Array,
                         state: Dict, qctx=None
                         ) -> Tuple[jax.Array, Dict]:
    """Sequence forward with recurrent-state carry (chunked prefill).

    x: (B, L, d); state: {"conv", "h"} from ``init_mamba2_state``.  One
    dispatch advances the whole chunk; the conv tail and SSD state carry
    across chunks.  The recurrence runs through :func:`ssd_seq` (strict
    time order, ``ssd_step``'s exact ops), so chunked prefill followed
    by ``mamba2_block_step`` decode matches per-token stepping bitwise
    -- ``ssd_chunked`` would not (it reassociates decay products).
    """
    aux: Dict = {}
    b, L, d = x.shape
    di, n, heads = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    hd = di // heads
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    # mirror mamba2_block_step's site handling exactly (parity contract);
    # dynamic-method scales recompute per call, so chunked prefill only
    # approximates per-token stepping there -- the engine keeps the
    # per-token path for dynamic specs
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    z, xi, bmat, cmat, dt = _split_in_proj(
        cfg, linear(p, "in_proj", h, qctx))
    xbc, conv_new = _depthwise_conv_silu(
        jnp.concatenate([xi, bmat, cmat], -1), p["conv_w"], p["conv_b"],
        state=state["conv"])
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    if is_quant(qctx):
        spec = qctx["spec"]
        xi = (Q.dynamic_qdq(xi) if spec.method == "dynamic"
              else qrecipe.ssm_input_qdq(xi, qctx["scales"]["x"], spec))
    dt = common.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = ssd_seq(xi.reshape(b, L, heads, hd), dt, a_head,
                       bmat, cmat, p["D"], h0=state["h"])
    y = y.reshape(b, L, di).astype(x.dtype)
    out = _gated_out(p, cfg, y, z, x, qctx, aux)
    return out, {"conv": conv_new, "h": h_new}


def mamba2_block_step(p: Dict, cfg: ModelConfig, x: jax.Array,
                      state: Dict, qctx=None) -> Tuple[jax.Array, Dict]:
    aux: Dict = {}
    b, d = x.shape
    di, n, heads = cfg.d_inner, cfg.d_state, cfg.ssm_heads
    hd = di // heads
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    if is_quant(qctx) and qctx["spec"].method != "dynamic":
        h = qrecipe.act_qdq(h, qctx["scales"]["in"], qctx["spec"])
    z, xi, bmat, cmat, dt = _split_in_proj(
        cfg, linear(p, "in_proj", h, qctx))
    xbc3, conv_new = _depthwise_conv_silu(
        jnp.concatenate([xi, bmat, cmat], -1)[:, None, :],
        p["conv_w"], p["conv_b"], state=state["conv"])
    xi, bmat, cmat = jnp.split(xbc3[:, 0], [di, di + n], axis=-1)
    if is_quant(qctx):
        spec = qctx["spec"]
        xi = (Q.dynamic_qdq(xi) if spec.method == "dynamic"
              else qrecipe.ssm_input_qdq(xi, qctx["scales"]["x"], spec))
    dt = common.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = ssd_step(state["h"], xi.reshape(b, heads, hd), dt,
                        a_head, bmat, cmat, p["D"])
    y = y.reshape(b, di).astype(x.dtype)
    out = _gated_out(p, cfg, y, z, x, qctx, aux)
    return out, {"conv": conv_new, "h": h_new}
