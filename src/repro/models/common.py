"""Shared model-zoo primitives: init, norms, rope, masks, losses."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, in_dim: int, out_dim: int,
               scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init (stored fp32; cast at compute time)."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return std * jax.random.truncated_normal(
        key, -2.0, 2.0, (in_dim, out_dim), jnp.float32)


def embed_init(key: jax.Array, vocab: int, dim: int) -> jax.Array:
    return 0.02 * jax.random.truncated_normal(
        key, -2.0, 2.0, (vocab, dim), jnp.float32)


# ---------------------------------------------------------------------------
# fp / calib / quant linear dispatch
# ---------------------------------------------------------------------------

def linear(p: dict, name: str, x: jax.Array, qctx=None,
           site: Optional[str] = None) -> jax.Array:
    """Apply the linear ``p[name]`` (in_dim, out_dim).

    In quant mode (qctx = {"mode": "quant", "scales": {...}, "qw": {...}})
    the site's int8 weight + static activation scale are used instead --
    this is the single integration point of the W8A8 path into every model.
    """
    site = site or name
    if qctx is not None and qctx.get("mode") == "quant" \
            and site in qctx.get("qw", {}):
        from repro.quant import qlinear  # local import to avoid cycle
        s_x = qctx["scales"].get(site)
        qlin = qctx["qw"][site]
        int_stored = ("qw4" in qlin            # nibble-packed int4 (PR 8)
                      or qlin["qw"].dtype == jnp.int8)
        if qctx.get("int8_compute") and s_x is not None \
                and int_stored and qlin["s_w"].ndim == 0:
            # true integer path: int8 x int8 -> int32 on the MXU; weights
            # are read at 1 byte/elem with no dequantized copy (§Perf C3)
            return qlinear.apply_int8(x, s_x, qctx["qw"][site],
                                      out_dtype=x.dtype)
        return qlinear.apply_qdq(x, s_x, qctx["qw"][site],
                                 out_dtype=x.dtype)
    return x @ p[name].astype(x.dtype)


def maybe_constrain(x: jax.Array, *spec):
    """with_sharding_constraint when a mesh with the named axes is active;
    no-op otherwise (single-device tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        names = set(mesh.axis_names)
        fitted = tuple(a if (a in names and d % mesh.shape[a] == 0)
                       else None
                       for a, d in zip(spec, x.shape))
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*fitted))
    except Exception:
        return x


def is_calib(qctx) -> bool:
    return qctx is not None and qctx.get("mode") == "calib"


def is_quant(qctx) -> bool:
    return qctx is not None and qctx.get("mode") == "quant"


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def rmsnorm_heads(x: jax.Array, w: jax.Array, eps: float = 1e-5
                  ) -> jax.Array:
    """Per-head qk-norm: x (..., H, hd), w (hd,)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def softplus(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., L, H, hd) or (..., H, hd) with matching pos (..., L)/(...,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = pos.astype(jnp.float32)[..., None] * freqs  # (..., L, hd/2)
    angles = angles[..., None, :]                        # broadcast heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., ::2], x32[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------

def causal_mask(q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
    """bool (..., Lq, Lk): True = attend."""
    return q_pos[..., :, None] >= k_pos[..., None, :]


def prefix_causal_mask(q_pos: jax.Array, k_pos: jax.Array,
                       prefix_len: int) -> jax.Array:
    """Prefix-LM mask: full attention within the first ``prefix_len``
    positions, causal afterwards (PaliGemma)."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    in_prefix = k_pos[..., None, :] < prefix_len
    return jnp.logical_or(causal, in_prefix)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross entropy.  logits (B, L, V), targets (B, L)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# parameter counting (analytic; used by roofline's 6*N*D MODEL_FLOPS)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, v = cfg.d_model, cfg.vocab_size
    hd = cfg.resolved_head_dim
    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += v * d

    def attn_params():
        return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
            + cfg.n_heads * hd * d

    def mlp_params(ff):
        return 3 * d * ff

    if cfg.family in ("dense", "vlm"):
        total += cfg.n_layers * (attn_params() + mlp_params(cfg.d_ff) + 2 * d)
    elif cfg.family == "moe":
        e = cfg.n_experts if not active_only else cfg.top_k
        per_layer = attn_params() + d * cfg.n_experts \
            + e * 3 * d * cfg.moe_d_ff + 2 * d
        total += cfg.n_layers * per_layer
    elif cfg.family == "audio":
        total += (cfg.n_enc_layers * (attn_params() + mlp_params(cfg.d_ff)
                                      + 2 * d))
        # decoder: self-attn + cross-attn + mlp
        total += cfg.n_layers * (2 * attn_params() + mlp_params(cfg.d_ff)
                                 + 3 * d)
    elif cfg.family == "mamba":
        di, n, dtr = cfg.d_inner, cfg.d_state, cfg.resolved_dt_rank
        per_layer = (d * 2 * di               # in_proj
                     + cfg.conv_width * di + di   # conv
                     + di * (dtr + 2 * n)     # x_proj
                     + dtr * di + di          # dt_proj
                     + di * n + di            # A_log, D
                     + di * d + d)            # out_proj, norm
        total += cfg.n_layers * per_layer
    elif cfg.family == "hybrid":
        di, n = cfg.d_inner, cfg.d_state
        heads = cfg.ssm_heads
        per_mamba = (d * (2 * di + 2 * n * 1 + heads)  # in_proj(z,x,B,C,dt)
                     + cfg.conv_width * (di + 2 * n)
                     + heads + heads              # A_log, D per head
                     + di                          # gate norm
                     + di * d + d)                 # out_proj, norm
        total += cfg.n_layers * per_mamba
        total += attn_params() + mlp_params(cfg.d_ff) + 2 * d  # shared blk
    elif cfg.family == "ssm":
        di = cfg.d_inner
        # mLSTM block: up-proj to 2*di, qkv projections on di, gates, down
        per_m = d * 2 * di + 3 * di * di // max(1, 1) // 1 \
            if False else 0
        per_m = (d * 2 * di          # up proj (x, gate)
                 + 3 * di * di       # q, k, v
                 + 2 * di            # i, f gate vectors (per-channel)
                 + di                 # skip/norm
                 + di * d + d)        # down proj + norm
        n_s = cfg.n_layers // max(1, cfg.slstm_every) if cfg.slstm_every else 0
        n_m = cfg.n_layers - n_s
        per_s = (4 * d * d + 4 * d   # gates i,f,z,o
                 + d * 2 * d + 2 * d * d // 2 * 0  # ffn approx below
                 + d * d * 2         # ffn (expand 2 simple)
                 + d * d * 2
                 + 2 * d)
        total += n_m * per_m + n_s * per_s
    return int(total)
