"""Model quantization transform: (fp params, calibration stats, QuantSpec)
-> (adjusted params, quant-context data) for every architecture family.

This is where the paper's recipe is wired site-by-site:
  * static per-tensor scales from calibrated abs-max (Eq. 2)
  * the SSM input ``x`` scale from the percentile max (§4.2)
  * ``out_proj`` is quantized with the Hadamard rotation folded in
    (W_out^H = H W_out), paired with the rotated activation scale ``y_had``
  * SmoothQuant-SSM folds per-channel factors into (norm, in_proj) and
    (conv, x_proj) pairs; QuaRot-SSM adds the rotated-input path
  * conv weights are fake-quantized in place (the fused int8 conv of §4.3)
  * MoE expert weights get weight-only int8 (the LLM.int8 analogue the
    paper pairs with Quamba on Jamba, Table 4)

Returned qdata = {"scales": ..., "qw": ...} mirrors the layer-stacked
structure that ``repro.models.model`` scans over.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.quant import quantizers as Q
from repro.quant import recipe as qrecipe
from repro.quant.baselines import fold_smoothing, smoothquant_factors
from repro.quant.observers import stats_scale


def make_qctx(spec: qrecipe.QuantSpec, qdata: Dict,
              int8_compute: bool = False) -> Dict:
    out = {"mode": "quant", "spec": spec, **qdata}
    if int8_compute:
        out["int8_compute"] = True
    return out


def _scale(stats, site: str, percentile: float = 100.0):
    return stats_scale(stats[site], percentile=percentile)


def _qw(w, spec, fold_had: bool = False, stacked: bool = True):
    fn = lambda wi: qrecipe.quantize_weight(
        wi, spec, fold_hadamard_axis=0 if fold_had else None)
    return jax.vmap(fn)(w) if stacked else fn(w)


def _wqdq(w, spec):
    """In-place weight fake-quant (conv weights)."""
    s = Q.symmetric_scale(w, bits=spec.w_bits)
    return Q.qdq(w, s, bits=spec.w_bits)


def _wqdq_experts(w, spec):
    """Per-expert weight fake-quant: w (..., E, in, out) with leading
    layer/expert batch dims -> one scale per (layer, expert)."""
    flat = w.reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda wi: _wqdq(wi, spec))(flat)
    return out.reshape(w.shape)


# ---------------------------------------------------------------------------
# per-block-type site maps
# ---------------------------------------------------------------------------

def _mamba_layer(params_l, stats_l, spec, cfg):
    """Stacked mamba-1 layers -> (new params, scales, qw)."""
    p = dict(params_l)
    if spec.method == "smoothquant":
        # Fold per-channel smoothing into (norm, in_proj) only.  The SSM
        # input x feeds BOTH x_proj and the scan itself, so smoothing the
        # x_proj pair would corrupt the recurrence (this is exactly why
        # SmQ-SSM "fails to address the sensitive x tensor", paper §5.3).
        def fold_one(norm, w_in, cmax_in):
            s1 = smoothquant_factors(cmax_in, w_in, spec.smooth_alpha)
            norm, w_in = fold_smoothing(norm, w_in, s1)
            new_amax = jnp.max(cmax_in / s1)
            return norm, w_in, jnp.maximum(new_amax, 1e-8) / 127.0

        (p["norm"], p["in_proj"], s_in) = jax.vmap(fold_one)(
            p["norm"], p["in_proj"], stats_l["in"]["cmax"])
        s_x = _scale(stats_l, "x")           # minmax: x left unsmoothed
    else:
        s_in = _scale(stats_l, "in")
        s_x = _scale(stats_l, "x", spec.x_percentile)

    scales = {
        "in": s_in,
        "conv_in": _scale(stats_l, "conv_in"),
        "x": s_x,
        "x_had": _scale(stats_l, "x_had"),
        "dt_low": _scale(stats_l, "dt_low"),
        "dt": _scale(stats_l, "dt"),
        "B": _scale(stats_l, "B"),
        "C": _scale(stats_l, "C"),
        "y": _scale(stats_l, "y"),
        "y_had": _scale(stats_l, "y_had"),
        "A": jax.vmap(lambda a: Q.symmetric_scale(-jnp.exp(a)))(
            p["A_log"]),
        # linear input scales (site name = weight name)
        "in_proj": s_in,
        "x_proj": s_x if spec.method != "quarot" else _scale(stats_l, "x"),
        "dt_proj": _scale(stats_l, "dt_low"),
        "out_proj": _scale(stats_l, "y"),
        "out_proj_had": _scale(stats_l, "y_had"),
    }
    qw = {
        "in_proj": _qw(p["in_proj"], spec),
        "x_proj": _qw(p["x_proj"], spec),
        "dt_proj": _qw(p["dt_proj"], spec),
        "out_proj": _qw(p["out_proj"], spec),
        "out_proj_had": _qw(p["out_proj"], spec, fold_had=True),
    }
    p["conv_w"] = jax.vmap(lambda w: _wqdq(w, spec))(p["conv_w"])
    return p, scales, qw


def _attn_scales_qw(p_attn, stats_l, spec, prefix: str = "",
                    stacked: bool = True):
    s_in = _scale(stats_l, prefix + "attn_in")
    s_o = _scale(stats_l, prefix + "o_in")
    scales = {"wq": s_in, "wk": s_in, "wv": s_in, "wo": s_o}
    qw = {k: _qw(p_attn[k], spec, stacked=stacked)
          for k in ("wq", "wk", "wv", "wo")}
    return scales, qw


def _mlp_scales_qw(p_mlp, stats_l, spec, stacked: bool = True):
    scales = {"mlp_wi": _scale(stats_l, "mlp_in"),
              "mlp_wo": _scale(stats_l, "down_in")}
    qw = {"mlp_wi": _qw(p_mlp["wi"], spec, stacked=stacked),
          "mlp_wo": _qw(p_mlp["wo"], spec, stacked=stacked)}
    return scales, qw


def _decoder_layer(params_l, stats_l, spec, cfg, cross=False,
                   use_moe=False, stacked=True):
    p = dict(params_l)
    if spec.method == "smoothquant":
        def fold_one(ln1, wq, wk, wv, cmax):
            s = smoothquant_factors(cmax, wq, spec.smooth_alpha)
            ln1 = ln1 / s
            shape = (-1, 1)
            return (ln1, wq * s.reshape(shape), wk * s.reshape(shape),
                    wv * s.reshape(shape))
        fold = jax.vmap(fold_one) if stacked else fold_one
        attn = dict(p["attn"])
        (p["ln1"], attn["wq"], attn["wk"], attn["wv"]) = fold(
            p["ln1"], p["attn"]["wq"], p["attn"]["wk"], p["attn"]["wv"],
            stats_l["attn_in"]["cmax"])
        p["attn"] = attn

    scales: Dict = {}
    qw: Dict = {}
    scales["attn"], qw["attn"] = _attn_scales_qw(
        p["attn"], stats_l, spec, stacked=stacked)
    if cross:
        scales["xattn"], qw["xattn"] = _attn_scales_qw(
            p["xattn"], stats_l, spec, prefix="x_", stacked=stacked)
    if use_moe:
        moe_p = dict(p["moe"])
        # weight-only int8 per expert (the LLM.int8 analogue, Table 4)
        moe_p["wi"] = _wqdq_experts(moe_p["wi"], spec)
        moe_p["wo"] = _wqdq_experts(moe_p["wo"], spec)
        p["moe"] = moe_p
        scales["moe"], qw["moe"] = {}, {}
    else:
        scales["mlp"], qw["mlp"] = _mlp_scales_qw(
            p["mlp"], stats_l, spec, stacked=stacked)
    return p, scales, qw


def _mamba2_layer(params_l, stats_l, spec, cfg):
    p = dict(params_l)
    s_in = _scale(stats_l, "in")
    s_x = _scale(stats_l, "x", spec.x_percentile)
    scales = {
        "in": s_in, "x": s_x,
        "y": _scale(stats_l, "y"), "y_had": _scale(stats_l, "y_had"),
        "in_proj": s_in,
        "out_proj": _scale(stats_l, "y"),
        "out_proj_had": _scale(stats_l, "y_had"),
    }
    qw = {
        "in_proj": _qw(p["in_proj"], spec),
        "out_proj": _qw(p["out_proj"], spec),
        "out_proj_had": _qw(p["out_proj"], spec, fold_had=True),
    }
    p["conv_w"] = jax.vmap(lambda w: _wqdq(w, spec))(p["conv_w"])
    return p, scales, qw


def _mlstm_layer(params_l, stats_l, spec, cfg, stacked=True):
    p = dict(params_l)
    s_in = _scale(stats_l, "in")
    s_v = _scale(stats_l, "v", spec.x_percentile)
    scales = {
        "in": s_in, "v": s_v,
        "y": _scale(stats_l, "y"), "y_had": _scale(stats_l, "y_had"),
        "up_proj": s_in,
        "wq": _scale(stats_l, "v"), "wk": _scale(stats_l, "v"),
        "wv": _scale(stats_l, "v"), "w_gates": _scale(stats_l, "v"),
        "down_proj": _scale(stats_l, "y"),
        "down_proj_had": _scale(stats_l, "y_had"),
    }
    qw = {k: _qw(p[k], spec, stacked=stacked)
          for k in ("up_proj", "wq", "wk", "wv", "w_gates", "down_proj")}
    qw["down_proj_had"] = _qw(p["down_proj"], spec, fold_had=True,
                              stacked=stacked)
    p["conv_w"] = (jax.vmap(lambda w: _wqdq(w, spec))(p["conv_w"])
                   if stacked else _wqdq(p["conv_w"], spec))
    return p, scales, qw


def _slstm_layer(params_l, stats_l, spec, cfg):
    p = dict(params_l)
    scales = {
        "in": _scale(stats_l, "in"),
        "w_in": _scale(stats_l, "in"),
        "up": _scale(stats_l, "ffn_in"),
        "down": _scale(stats_l, "ffn_down_in"),
    }
    qw = {k: _qw(p[k], spec) for k in ("w_in", "up", "down")}
    return p, scales, qw


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

def quantize_model(params: Dict, stats: Dict, cfg: ModelConfig,
                   spec: qrecipe.QuantSpec) -> Tuple[Dict, Dict]:
    """Returns (new_params, qdata).  Use ``make_qctx(spec, qdata)`` as the
    forward's qctx."""
    spec.validate()
    new_params = dict(params)
    scales: Dict = {}
    qw: Dict = {}
    fam = cfg.family
    if fam == "mamba":
        new_params["layers"], scales["layers"], qw["layers"] = \
            _mamba_layer(params["layers"], stats["layers"], spec, cfg)
    elif fam in ("dense", "vlm", "moe"):
        new_params["layers"], scales["layers"], qw["layers"] = \
            _decoder_layer(params["layers"], stats["layers"], spec, cfg,
                           use_moe=(fam == "moe"))
    elif fam == "audio":
        enc_p = dict(params["enc_layers"])
        sc_e: Dict = {}
        qw_e: Dict = {}
        sc_e["attn"], qw_e["attn"] = _attn_scales_qw(
            enc_p["attn"], stats["enc_layers"], spec)
        sc_e["mlp"], qw_e["mlp"] = _mlp_scales_qw(
            enc_p["mlp"], stats["enc_layers"], spec)
        scales["enc_layers"], qw["enc_layers"] = sc_e, qw_e
        new_params["layers"], scales["layers"], qw["layers"] = \
            _decoder_layer(params["layers"], stats["layers"], spec, cfg,
                           cross=True)
    elif fam == "hybrid":
        # stats come back grouped (groups, per, ...) by the group scan,
        # plus an optional flat "tail"; flatten to match stacked params.
        flat_stats = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), stats["layers"])
        if "tail" in stats:
            flat_stats = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                flat_stats, stats["tail"])
        new_params["layers"], scales["layers"], qw["layers"] = \
            _mamba2_layer(params["layers"], flat_stats, spec, cfg)
        # shared block stats come back stacked over group invocations;
        # reduce with max for one shared scale set.
        sh_stats = jax.tree.map(lambda a: jnp.max(a, axis=0),
                                stats["shared"])
        new_params["shared"], scales["shared"], qw["shared"] = \
            _decoder_layer(params["shared"], sh_stats, spec, cfg,
                           stacked=False)
    elif fam == "ssm":
        # m_blocks stacked (groups, per, ...): flatten, quantize, reshape
        g, per = params["m_blocks"]["norm"].shape[0], \
            params["m_blocks"]["norm"].shape[1]
        flat_p = jax.tree.map(
            lambda a: a.reshape((g * per,) + a.shape[2:]),
            params["m_blocks"])
        flat_s = jax.tree.map(
            lambda a: a.reshape((g * per,) + a.shape[2:]),
            stats["m_blocks"])
        np_, sc_m, qw_m = _mlstm_layer(flat_p, flat_s, spec, cfg)
        reshape_back = lambda t: jax.tree.map(
            lambda a: a.reshape((g, per) + a.shape[1:]), t)
        new_params["m_blocks"] = reshape_back(np_)
        scales["m_blocks"] = reshape_back(sc_m)
        qw["m_blocks"] = reshape_back(qw_m)
        new_params["s_blocks"], scales["s_blocks"], qw["s_blocks"] = \
            _slstm_layer(params["s_blocks"], stats["s_blocks"], spec, cfg)
    else:
        raise ValueError(fam)
    return new_params, {"scales": scales, "qw": qw}
