"""Per-family quantization site maps + the (now generic) model transform.

This is where the paper's recipe is wired site-by-site:
  * static per-tensor scales from calibrated abs-max (Eq. 2)
  * the SSM input ``x`` scale from the percentile max (§4.2)
  * ``out_proj`` is quantized with the Hadamard rotation folded in
    (W_out^H = H W_out), paired with the rotated activation scale ``y_had``
  * SmoothQuant-SSM folds per-channel factors into (norm, in_proj) and
    attention (ln1, qkv) pairs; QuaRot-SSM adds the rotated-input path
  * conv weights are fake-quantized in place (the fused int8 conv of §4.3)
  * MoE expert weights get weight-only int8 (the LLM.int8 analogue the
    paper pairs with Quamba on Jamba, Table 4)

The wiring itself is *declarative*: each architecture family registers a
``SiteMap`` (see ``repro.quant.sitemap``) and one generic walker turns
(params, stats, spec) into (new params, qdata).  Adding an architecture
means adding a registration, not a new ``if/elif`` branch.

Returned qdata = {"scales": ..., "qw": ...} mirrors the layer-stacked
structure that ``repro.models.model`` scans over.

NOTE: ``quantize_model`` / ``make_qctx`` remain importable here for
backward compatibility, but the supported entry point is ``repro.api``
(``Quantizer`` -> ``QuantizedModel``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.quant import recipe as qrecipe
from repro.quant.recipe import BackendFallbackWarning
from repro.quant.sitemap import (
    PCT_NEVER, PCT_X, PCT_X_UNLESS_QUAROT, AliasScale, BlockSites,
    ComputedScale, FakeQuantSite, Group, QuantizedTensor, ScaleSite,
    Section, SiteMap, SmoothFold, WeightSite, quantize_with_site_map,
    register_site_map,
)


def _section_fallback_reason(sec: Dict, spec: qrecipe.QuantSpec
                             ) -> Optional[str]:
    """Artifact-level kernel prerequisites of one qw section (recursive
    over Group sub-dicts).  Mirrors ``repro.models.mamba.use_kernel_backend``
    so the warning names the reason the block-level check will trip on."""
    if "in_proj" in sec and "x_proj" in sec and "conv_w" not in sec:
        return ("artifact predates int8 conv taps -- re-quantize to "
                "refresh the qdata")
    for name, lin in sec.items():
        if not isinstance(lin, dict):
            continue
        if "s_w" not in lin:          # Group sub-dict (attn/mlp/...)
            reason = _section_fallback_reason(lin, spec)
            if reason:
                return reason
        elif (spec.w_bits == 4 and name != "conv_w"
                and "qw4" not in lin):
            return (f"site {name!r} stores unpacked 4-bit weights "
                    "(pre-v2 artifact) -- re-quantize to nibble-pack")
    return None


def backend_fallback_reason(spec: Optional[qrecipe.QuantSpec],
                            qdata: Optional[Dict]) -> Optional[str]:
    """Why a kernels-backend request would execute on the qdq oracle,
    or None when the kernels path is fully honored.  Checks the spec
    (static scales, supported bit-widths, ...) and the artifact's qdata
    (conv taps present, w4 sites nibble-packed)."""
    reason = qrecipe.kernel_backend_fallback_reason(spec)
    if reason is not None:
        return reason
    for sec in ((qdata or {}).get("qw") or {}).values():
        if isinstance(sec, dict):
            reason = _section_fallback_reason(sec, spec)
            if reason:
                return reason
    return None


# fallback reasons that already warned in this process: the warning is
# one-shot per distinct reason (an engine calling qctx() per dispatch
# must not spam thousands of identical warnings), but a *new* reason --
# a different artifact with a different problem -- still surfaces.
_WARNED_FALLBACK_REASONS: set = set()


def reset_backend_fallback_warnings() -> None:
    """Forget which fallback reasons have warned (test isolation hook)."""
    _WARNED_FALLBACK_REASONS.clear()


def make_qctx(spec: qrecipe.QuantSpec, qdata: Dict,
              int8_compute: bool = False,
              backend: Optional[str] = None) -> Dict:
    """Assemble a forward-pass quant context.  ``backend`` overrides
    ``spec.backend`` ("qdq" oracle vs "kernels" int8/int4 execution)
    without re-quantizing -- the qdata is shared between the two.

    A kernels request the spec/qdata cannot honor emits one structured
    ``BackendFallbackWarning`` naming the reason -- never silent, and
    never repeated: exactly one warning per process per distinct reason
    (see ``reset_backend_fallback_warnings`` for test isolation)."""
    if backend is not None and backend != spec.backend:
        spec = dataclasses.replace(spec, backend=backend)
        spec.validate()
    if spec.backend == "kernels":
        reason = backend_fallback_reason(spec, qdata)
        if reason is not None and reason not in _WARNED_FALLBACK_REASONS:
            _WARNED_FALLBACK_REASONS.add(reason)
            warnings.warn(BackendFallbackWarning("kernels", "qdq", reason),
                          stacklevel=2)
    out = {"mode": "quant", "spec": spec, **qdata}
    if int8_compute:
        out["int8_compute"] = True
    return out


# ---------------------------------------------------------------------------
# per-block-type site declarations
# ---------------------------------------------------------------------------

# Mamba-1 (the paper's family).  The SSM input x feeds BOTH x_proj and the
# scan itself, so SmoothQuant folds only the (norm, in_proj) pair -- the
# x_proj fold would corrupt the recurrence (exactly why SmQ-SSM "fails to
# address the sensitive x tensor", paper §5.3).  Under QuaRot the x_proj
# input is the rotated x, so its scale stays minmax.
MAMBA_BLOCK = BlockSites(
    smooth=SmoothFold(kind="norm_linear", norm="norm",
                      weights=("in_proj",), stat="in", produces="in"),
    scales=(
        ScaleSite("in"),
        ScaleSite("conv_in"),
        # PCT_X_UNLESS_QUAROT: quamba's percentile scale normally; under
        # QuaRot (where the SSM input is quantized in the rotated domain
        # via "x_had" and this site only feeds the x_proj alias below)
        # the unrotated input keeps its minmax scale.
        ScaleSite("x", percentile=PCT_X_UNLESS_QUAROT),
        ScaleSite("x_had"),
        ScaleSite("dt_low"),
        ScaleSite("dt"),
        ScaleSite("B"),
        ScaleSite("C"),
        ScaleSite("y"),
        ScaleSite("y_had"),
        ComputedScale("A", fn="neg_exp_symmetric", param="A_log"),
        # linear input scales (site name = weight name).  x_proj MUST
        # alias "x", not own a site: the kernel dataflow feeds the SSM
        # input's int8 tensor straight into the x_proj matmul, so a
        # separately learned x_proj scale (QAT) would requantize the qdq
        # reference onto a different grid and break backend parity.
        AliasScale("in_proj", of="in"),
        AliasScale("x_proj", of="x"),
        AliasScale("dt_proj", of="dt_low"),
        AliasScale("out_proj", of="y"),
        AliasScale("out_proj_had", of="y_had"),
    ),
    weights=(
        WeightSite("in_proj"),
        WeightSite("x_proj"),
        WeightSite("dt_proj"),
        WeightSite("out_proj"),
        WeightSite("out_proj_had", param="out_proj", fold_hadamard=True),
        # int8 taps + scale for the fused conv kernel (backend="kernels");
        # the in-place fake-quant below keeps the qdq oracle identical
        # (same symmetric scale, so qw * s_w == the fake-quantized taps).
        # dtype="int8" pins one-value-per-byte storage even under w4 --
        # the conv kernel reads int8 taps; values still sit on the 4-bit
        # grid, so conv numerics match the oracle bit-for-bit either way.
        WeightSite("conv_w", dtype="int8"),
    ),
    # A = -exp(A_log) quantized once with the ComputedScale "A" above, so
    # the kernel backend's decode step never re-derives it per token
    computed=(QuantizedTensor("A", fn="neg_exp", param="A_log",
                              scale="A"),),
    fakequant=(FakeQuantSite("conv_w"),),
)

# Mamba-2 (Zamba2 hybrid backbone)
MAMBA2_BLOCK = BlockSites(
    scales=(
        ScaleSite("in"),
        ScaleSite("x", percentile=PCT_X),
        ScaleSite("y"),
        ScaleSite("y_had"),
        AliasScale("in_proj", of="in"),
        AliasScale("out_proj", of="y"),
        AliasScale("out_proj_had", of="y_had"),
    ),
    weights=(
        WeightSite("in_proj"),
        WeightSite("out_proj"),
        WeightSite("out_proj_had", param="out_proj", fold_hadamard=True),
    ),
    fakequant=(FakeQuantSite("conv_w"),),
)


def _attn_group(name: str = "attn", subtree: str = "attn",
                prefix: str = "") -> Group:
    """Per-tensor static W8A8 on the four projections (paper §I: attention
    activations are smooth; Quamba+LLM.int8 treatment of Table 4)."""
    return Group(
        name=name, subtree=subtree,
        scales=(
            ScaleSite("wq", stat=prefix + "attn_in"),
            AliasScale("wk", of="wq"),
            AliasScale("wv", of="wq"),
            ScaleSite("wo", stat=prefix + "o_in"),
        ),
        weights=(WeightSite("wq"), WeightSite("wk"), WeightSite("wv"),
                 WeightSite("wo")),
    )


_MLP_GROUP = Group(
    name="mlp", subtree="mlp",
    scales=(ScaleSite("mlp_wi", stat="mlp_in"),
            ScaleSite("mlp_wo", stat="down_in")),
    weights=(WeightSite("mlp_wi", param="wi"),
             WeightSite("mlp_wo", param="wo")),
)

# weight-only int8 per expert (the LLM.int8 analogue, Table 4)
_MOE_GROUP = Group(
    name="moe", subtree="moe",
    fakequant=(FakeQuantSite("wi", per_expert=True),
               FakeQuantSite("wo", per_expert=True)),
)

_QKV_SMOOTH = SmoothFold(kind="norm_qkv", norm="ln1",
                         weights=("wq", "wk", "wv"), stat="attn_in",
                         subtree="attn")


def _decoder_block(cross: bool = False, use_moe: bool = False) -> BlockSites:
    groups = [_attn_group()]
    if cross:
        groups.append(_attn_group(name="xattn", subtree="xattn",
                                  prefix="x_"))
    groups.append(_MOE_GROUP if use_moe else _MLP_GROUP)
    return BlockSites(smooth=_QKV_SMOOTH, groups=tuple(groups))


ENCODER_BLOCK = BlockSites(groups=(_attn_group(), _MLP_GROUP))

# xLSTM mLSTM block: the value path v is the outlier-carrying analogue of
# the SSM input, so it gets the percentile clip; q/k/v/gate projections
# read the un-clipped minmax scale.
MLSTM_BLOCK = BlockSites(
    scales=(
        ScaleSite("in"),
        ScaleSite("v", percentile=PCT_X),
        ScaleSite("y"),
        ScaleSite("y_had"),
        AliasScale("up_proj", of="in"),
        ScaleSite("wq", stat="v"),
        AliasScale("wk", of="wq"),
        AliasScale("wv", of="wq"),
        AliasScale("w_gates", of="wq"),
        AliasScale("down_proj", of="y"),
        AliasScale("down_proj_had", of="y_had"),
    ),
    weights=(
        WeightSite("up_proj"),
        WeightSite("wq"),
        WeightSite("wk"),
        WeightSite("wv"),
        WeightSite("w_gates"),
        WeightSite("down_proj"),
        WeightSite("down_proj_had", param="down_proj",
                   fold_hadamard=True),
    ),
    fakequant=(FakeQuantSite("conv_w"),),
)

SLSTM_BLOCK = BlockSites(
    scales=(
        ScaleSite("in"),
        AliasScale("w_in", of="in"),
        ScaleSite("up", stat="ffn_in"),
        ScaleSite("down", stat="ffn_down_in"),
    ),
    weights=(WeightSite("w_in"), WeightSite("up"), WeightSite("down")),
)


# ---------------------------------------------------------------------------
# family registrations
# ---------------------------------------------------------------------------

register_site_map(SiteMap("mamba", (
    Section("layers", MAMBA_BLOCK),
)))

register_site_map(SiteMap("dense", (
    Section("layers", _decoder_block()),
)), "dense", "vlm")

register_site_map(SiteMap("moe", (
    Section("layers", _decoder_block(use_moe=True)),
)))

register_site_map(SiteMap("audio", (
    Section("enc_layers", ENCODER_BLOCK),
    Section("layers", _decoder_block(cross=True)),
)))

register_site_map(SiteMap("hybrid", (
    Section("layers", MAMBA2_BLOCK, stats_transform="hybrid_flatten"),
    Section("shared", _decoder_block(), layout="single",
            stats_transform="max0"),
)))

register_site_map(SiteMap("ssm", (
    Section("m_blocks", MLSTM_BLOCK, layout="grouped"),
    Section("s_blocks", SLSTM_BLOCK),
)))


# ---------------------------------------------------------------------------
# top level (compatibility shim -- prefer repro.api)
# ---------------------------------------------------------------------------

def quantize_model(params: Dict, stats: Dict, cfg: ModelConfig,
                   spec: qrecipe.QuantSpec) -> Tuple[Dict, Dict]:
    """Returns (new_params, qdata) by walking the family's site map.

    Deprecated free-function surface: prefer
    ``repro.api.Quantizer(cfg, spec).calibrate(...).quantize(params)``,
    which returns a saveable ``QuantizedModel`` artifact.
    """
    return quantize_with_site_map(params, stats, cfg, spec)
