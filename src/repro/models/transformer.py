"""Transformer layers: decoder (self-attn [+ cross-attn] + MLP/MoE) and
encoder, shared by the dense / moe / audio / vlm families and by Zamba2's
shared block."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.attention import attention, init_attention, init_kv_cache
from repro.models.mlp import init_mlp, init_moe, mlp, moe


def init_decoder_layer(key: jax.Array, cfg: ModelConfig, *,
                       cross: bool = False, use_moe: bool = False) -> Dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": init_attention(ks[0], cfg),
        "ln2": jnp.ones((d,), jnp.float32),
    }
    if cross:
        p["lnx"] = jnp.ones((d,), jnp.float32)
        p["xattn"] = init_attention(ks[2], cfg)
    if use_moe:
        p["moe"] = init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff)
    return p


def decoder_layer(p: Dict, cfg: ModelConfig, x: jax.Array, *,
                  mask_kind: str = "causal",
                  enc_out: Optional[jax.Array] = None,
                  cache: Optional[Dict] = None,
                  cache_pos: Optional[jax.Array] = None,
                  use_rope: bool = True,
                  qctx=None) -> Tuple[jax.Array, Dict, Optional[Dict]]:
    """Returns (x, aux, new_cache)."""
    use_moe = "moe" in p
    h = common.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, aux1, new_cache = attention(
        p["attn"], cfg, h, mask_kind=mask_kind, cache=cache,
        cache_pos=cache_pos, use_rope=use_rope,
        qctx=_sub(qctx, "attn"))
    x = x + a
    if enc_out is not None:
        h = common.rmsnorm(x, p["lnx"], cfg.norm_eps)
        a, aux_x, _ = attention(p["xattn"], cfg, h, enc_out=enc_out,
                                use_rope=False, qctx=_sub(qctx, "xattn"))
        x = x + a
        aux1 = {**aux1, **{f"x_{k}": v for k, v in aux_x.items()}}
    h = common.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if use_moe:
        m, aux2 = moe(p["moe"], cfg, h, qctx=_sub(qctx, "moe"),
                      no_drop=cache is not None)
    else:
        m, aux2 = mlp(p["mlp"], h, qctx=_sub(qctx, "mlp"))
    return x + m, {**aux1, **aux2}, new_cache


def init_encoder_layer(key: jax.Array, cfg: ModelConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "attn": init_attention(k1, cfg),
        "ln2": jnp.ones((d,), jnp.float32),
        "mlp": init_mlp(k2, d, cfg.d_ff),
    }


def encoder_layer(p: Dict, cfg: ModelConfig, x: jax.Array, qctx=None
                  ) -> Tuple[jax.Array, Dict]:
    h = common.rmsnorm(x, p["ln1"], cfg.norm_eps)
    a, aux1, _ = attention(p["attn"], cfg, h, mask_kind="none",
                           use_rope=False, qctx=_sub(qctx, "attn"))
    x = x + a
    h = common.rmsnorm(x, p["ln2"], cfg.norm_eps)
    m, aux2 = mlp(p["mlp"], h, qctx=_sub(qctx, "mlp"))
    return x + m, {**aux1, **aux2}


def _sub(qctx, name: str):
    """Narrow a layer qctx to one sub-module's scales/qw namespace."""
    if qctx is None:
        return None
    if qctx.get("mode") != "quant":
        return qctx
    return {
        "mode": "quant",
        "spec": qctx["spec"],
        "scales": qctx["scales"].get(name, {}),
        "qw": qctx["qw"].get(name, {}),
        "int8_compute": qctx.get("int8_compute", False),
    }


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) *
                  (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe
