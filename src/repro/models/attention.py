"""GQA attention: full/prefix/cross masks, chunked online-softmax path for
long sequences, KV-cache decode, optional qk-norm, W8A8 quantized linears.

The paper (§I) shows self-attention activations are smooth -- per-tensor
static W8A8 on the four projections is sufficient -- which is exactly what
the quant path here does (the Quamba+LLM.int8-style treatment used for
Jamba in paper Table 4).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import is_calib, linear
from repro.quant.observers import observe

# switch to the chunked online-softmax path when Lq * Lk exceeds this
_CHUNK_THRESHOLD = 4096 * 4096
_Q_CHUNK = 1024
_KV_CHUNK = 1024


def init_attention(key: jax.Array, cfg: ModelConfig) -> Dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d, cfg.n_heads * hd),
        "wk": common.dense_init(ks[1], d, cfg.n_kv_heads * hd),
        "wv": common.dense_init(ks[2], d, cfg.n_kv_heads * hd),
        "wo": common.dense_init(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((hd,), jnp.float32)
        p["kn"] = jnp.ones((hd,), jnp.float32)
    return p


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict:
    hd = cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }
    if jnp.dtype(dtype) == jnp.int8:
        # int8 storage (QuantSpec.quantize_kv_cache): one fp32 scale per
        # (row, position, kv-head), written alongside each entry
        cache["k_s"] = jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                 jnp.float32)
        cache["v_s"] = jnp.zeros((batch, max_len, cfg.n_kv_heads),
                                 jnp.float32)
    return cache


def _attend(q, k, v, mask, softcap: float) -> jax.Array:
    """Direct attention. q (B,Lq,G,Hg,hd), k/v (B,Lk,G,hd), mask (B,Lq,Lk).

    Dots run on the operands' native dtypes with fp32 accumulation
    (preferred_element_type) instead of casting k/v up front: materializing
    an fp32 copy of a bf16 KV cache costs 3x the cache's bytes per decode
    step and dominated the decode roofline (EXPERIMENTS.md §Perf C1).
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqghd,bkgd->bghqk", q, k.astype(q.dtype),
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if mask is not None:
        s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bghqk,bkgd->bqghd", p.astype(v.dtype),
                      v, preferred_element_type=jnp.float32)


def _attend_int8(q, qk, k_s, qv, v_s, mask, softcap: float) -> jax.Array:
    """Attend over an int8 KV cache.  The per-entry scales fold into the
    score and probability tensors (B,G,Hg,1,S) -- a factor head_dim/Hg
    smaller than dequantizing the full (B,S,G,hd) cache would be."""
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    s = jnp.einsum("bqghd,bkgd->bghqk", q, qk.astype(q.dtype),
                   preferred_element_type=jnp.float32) * scale
    s = s * jnp.transpose(k_s, (0, 2, 1))[:, :, None, None, :]
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if mask is not None:
        s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = p * jnp.transpose(v_s, (0, 2, 1))[:, :, None, None, :]
    return jnp.einsum("bghqk,bkgd->bqghd", p, qv.astype(p.dtype),
                      preferred_element_type=jnp.float32)


def _chunked_attention(q, k, v, q_pos, k_pos, mask_kind: str,
                       prefix_len: int, softcap: float) -> jax.Array:
    """Online-softmax attention over kv chunks (flash-style, pure jnp).

    Peak memory is one (B, G, Hg, q_chunk, kv_chunk) score tile, so 32k
    prefill fits on-device; see DESIGN.md §Long-context.
    """
    b, lq, g, hg, hd = q.shape
    lk = k.shape[1]
    qc, kc = min(_Q_CHUNK, lq), min(_KV_CHUNK, lk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    qr = jnp.moveaxis(q.reshape(b, lq // qc, qc, g, hg, hd), 1, 0)
    qpr = q_pos.reshape(lq // qc, qc)
    kr = jnp.moveaxis(k.reshape(b, lk // kc, kc, g, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, lk // kc, kc, g, hd), 1, 0)
    kpr = k_pos.reshape(lk // kc, kc)

    def one_q_chunk(args):
        qi, qp = args                      # (b, qc, g, hg, hd), (qc,)

        def body(carry, kv):
            m, l, acc = carry
            ki, vi, kp = kv
            s = jnp.einsum("bqghd,bkgd->bghqk", qi, ki.astype(qi.dtype),
                           preferred_element_type=jnp.float32) * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            if mask_kind == "causal":
                msk = qp[:, None] >= kp[None, :]
            elif mask_kind == "prefix":
                msk = jnp.logical_or(qp[:, None] >= kp[None, :],
                                     kp[None, :] < prefix_len)
            else:
                msk = jnp.ones((qc, kc), bool)
            s = jnp.where(msk, s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bghqk,bkgd->bghqd", p.astype(vi.dtype), vi,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, hg, qc), -1e30, jnp.float32)
        l0 = jnp.zeros((b, g, hg, qc), jnp.float32)
        a0 = jnp.zeros((b, g, hg, qc, hd), jnp.float32)
        (_, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kr, vr, kpr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4))   # (b, qc, g, hg, hd)

    out = jax.lax.map(one_q_chunk, (qr, qpr))
    return jnp.moveaxis(out, 0, 1).reshape(b, lq, g, hg, hd)


def attention(p: Dict, cfg: ModelConfig, x: jax.Array, *,
              pos: Optional[jax.Array] = None,
              mask_kind: str = "causal",
              enc_out: Optional[jax.Array] = None,
              cache: Optional[Dict] = None,
              cache_pos: Optional[jax.Array] = None,
              use_rope: bool = True,
              qctx=None) -> Tuple[jax.Array, Dict, Optional[Dict]]:
    """Returns (out, calib_stats, new_cache).

    x (B, L, d).  mask_kind: causal | prefix | none.
    enc_out: cross-attention source (B, Lk, d) -- k/v from the encoder.
    cache + cache_pos: decode mode; k/v appended at cache_pos.
    """
    b, l, _ = x.shape
    hd = cfg.resolved_head_dim
    g, h = cfg.n_kv_heads, cfg.n_heads
    hg = h // g
    aux: Dict = {}
    if is_calib(qctx):
        aux["attn_in"] = observe(x)

    kv_src = enc_out if enc_out is not None else x
    q = linear(p, "wq", x, qctx)
    k = linear(p, "wk", kv_src, qctx)
    v = linear(p, "wv", kv_src, qctx)

    q = q.reshape(b, l, g, hg, hd)
    k = k.reshape(b, kv_src.shape[1], g, hd)
    v = v.reshape(b, kv_src.shape[1], g, hd)

    if cfg.qk_norm:
        q = common.rmsnorm_heads(q, p["qn"], cfg.norm_eps)
        k = common.rmsnorm_heads(k, p["kn"], cfg.norm_eps)

    is_cross = enc_out is not None
    new_cache = None

    if cache is not None and not is_cross:
        # ---- decode / chunked prefill: append k/v, attend over cache ----
        # cache_pos: per-row positions (B,) -- continuous batching keeps
        # independent sequences at different depths in one batch.  l may
        # exceed 1: a chunk of l tokens lands at cache_pos..cache_pos+l-1
        # in one dispatch (sequence prefill / speculative verify); each
        # query row masks to its own absolute position, so the math per
        # token matches the single-token path exactly.
        cur = (cache_pos if cache_pos.ndim == 1
               else jnp.full((b,), cache_pos, jnp.int32))
        step_pos = cur[:, None] + jnp.arange(l)[None, :]  # (B, L)
        if use_rope:
            q = common.apply_rope(q.reshape(b, l, h, hd), step_pos,
                                  cfg.rope_theta).reshape(b, l, g, hg, hd)
            k = common.apply_rope(k, step_pos, cfg.rope_theta)
        rows = jnp.arange(b)[:, None]                     # (B, 1)
        k_pos = jnp.arange(cache["k"].shape[1])
        mask = (k_pos[None, None, :] <= step_pos[:, :, None])  # (B,L,S)
        if cache["k"].dtype == jnp.int8:
            # int8 KV cache: quantize each new entry with its own
            # per-(row, position, head) scale; scales fold into the
            # attention scores on read (no dequantized cache copy)
            def q_entry(store, scales, val):        # val (B, L, g, hd)
                s = jnp.maximum(jnp.max(jnp.abs(val), axis=-1),
                                1e-8) / 127.0       # (B, L, g)
                qv = jnp.clip(jnp.round(val / s[..., None]),
                              -127, 127).astype(jnp.int8)
                return (store.at[rows, step_pos].set(qv),
                        scales.at[rows, step_pos].set(
                            s.astype(jnp.float32)))

            ck, ks = q_entry(cache["k"], cache["k_s"],
                             k.astype(jnp.float32))
            cv, vs = q_entry(cache["v"], cache["v_s"],
                             v.astype(jnp.float32))
            new_cache = {"k": ck, "v": cv, "k_s": ks, "v_s": vs}
            ctx = _attend_int8(q, ck, ks, cv, vs, mask,
                               cfg.attn_logit_softcap)
        else:
            ck = cache["k"].at[rows, step_pos].set(
                k.astype(cache["k"].dtype))
            cv = cache["v"].at[rows, step_pos].set(
                v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            # pass the cache in its storage dtype: _attend accumulates in
            # fp32 without materializing converted copies of the cache
            ctx = _attend(q, ck, cv, mask, cfg.attn_logit_softcap)
    else:
        # ---- full-sequence (train / prefill / encoder / cross) ----
        if pos is None:
            pos = jnp.arange(l)
        if use_rope and not is_cross:
            q = common.apply_rope(q.reshape(b, l, h, hd), pos,
                                  cfg.rope_theta).reshape(b, l, g, hg, hd)
            k = common.apply_rope(k, pos, cfg.rope_theta)
        lk = k.shape[1]
        eff_mask = "none" if is_cross else mask_kind
        if l * lk > _CHUNK_THRESHOLD and l % _Q_CHUNK == 0 \
                and lk % _KV_CHUNK == 0:
            ctx = _chunked_attention(
                q, k, v, pos, jnp.arange(lk) if is_cross else pos,
                eff_mask, cfg.prefix_len, cfg.attn_logit_softcap)
        else:
            if eff_mask == "none":
                mask = None
            elif eff_mask == "prefix":
                mask = common.prefix_causal_mask(pos, pos, cfg.prefix_len
                                                 )[None].repeat(b, 0)
            else:
                mask = common.causal_mask(pos, pos)[None].repeat(b, 0)
            ctx = _attend(q, k, v, mask, cfg.attn_logit_softcap)

    ctx = ctx.reshape(b, l, h * hd).astype(x.dtype)
    if is_calib(qctx):
        aux["o_in"] = observe(ctx)
    out = linear(p, "wo", ctx, qctx)
    return out, aux, new_cache
