"""Gradient compression for data-parallel reduction (beyond-paper
distributed-optimization trick; EXPERIMENTS.md §Perf collective term).

Int8 symmetric per-tensor compression with error feedback: before the DP
all-reduce each worker quantizes its local gradient to int8 + one fp32
scale (4x fewer bytes over ICI/DCN), the residual is remembered and added
to the next step's gradient, so the compression bias vanishes in
expectation (Karimireddy et al., EF-SGD).  Used by the shard_map train
step in ``repro.train.step`` when ``compress_grads=True``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q


def init_error_state(params) -> Dict:
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 tensor, fp32 scale)."""
    s = Q.symmetric_scale(g.astype(jnp.float32))
    return Q.quantize(g.astype(jnp.float32), s), s


def decompress(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def compress_tree_with_feedback(grads, err_state):
    """Apply EF-int8 compression leafwise.

    Returns (compressed_grads_fp32, new_err_state).  The returned gradient
    is the dequantized value (what every peer will see after the
    all-reduce of int8 payloads); err = (g + e) - dequant holds the
    information lost this step.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def psum_compressed(grads, axis_name: str, err_state):
    """shard_map helper: quantize -> int32 psum -> dequantize.

    The int8 payloads are summed in int32 (exact) and rescaled by the
    max participating scale; inside shard_map this lowers to an integer
    all-reduce, 4x smaller on the wire than fp32.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        s = Q.symmetric_scale(corrected)
        s_max = jax.lax.pmax(s, axis_name)
        q = Q.quantize(corrected, s_max)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = total.astype(jnp.float32) * s_max / n
        return mean.astype(g.dtype), corrected - decompress(q, s_max)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
