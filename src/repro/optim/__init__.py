from repro.optim.adamw import OptimConfig, adamw_update, init_opt_state, cosine_lr, clip_by_global_norm
from repro.optim.compression import compress_tree_with_feedback, init_error_state, psum_compressed
