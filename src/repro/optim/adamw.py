"""AdamW + schedules + clipping, pure JAX (no optax in this container)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def init_opt_state(params, keep_master: bool = False) -> Dict:
    """keep_master: store an fp32 master copy (use when params are bf16;
    the master lives with the ZeRO-sharded moments, params stay in the
    compute dtype so no per-use fp32->bf16 casts are materialized)."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    out = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if keep_master:
        out["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return out


def adamw_update(cfg: OptimConfig, params, grads, opt_state
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    has_master = "master" in opt_state

    def upd(p, g, m, v, w32):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w32
        w_new = w32 - lr * delta
        return w_new.astype(p.dtype), m_new, v_new, w_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_w = (tdef.flatten_up_to(opt_state["master"]) if has_master
              else [p.astype(jnp.float32) for p in flat_p])
    out = [upd(p, g, m, v, w) for p, g, m, v, w
           in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_opt = {"m": tdef.unflatten([o[1] for o in out]),
               "v": tdef.unflatten([o[2] for o in out]),
               "step": step}
    if has_master:
        new_opt["master"] = tdef.unflatten([o[3] for o in out])
    return new_params, new_opt, {"lr": lr, "grad_norm": gnorm}
