"""xlstm-1.3b [ssm]: mLSTM backbone with periodic sLSTM blocks.

48L d_model=2048 4H (kv=4) d_ff=0 (the mLSTM block carries its own
up/down projection, expand=2) vocab=50304.  [arXiv:2405.04517; unverified]
sLSTM at every 8th layer (xLSTM[7:1]).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    head_dim=512,
    expand=2,
    conv_width=4,
    ssm_heads=4,
    slstm_every=8,
    tie_embeddings=True,
)
