"""Configuration dataclasses for architectures and workload shapes.

Every assigned architecture (plus the paper's own Mamba family) is described
by a single ``ModelConfig``.  Workload shapes (train / prefill / decode /
long-context decode) are ``ShapeSpec`` instances.  A (ModelConfig, ShapeSpec)
pair is one *cell* of the dry-run / roofline matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description.

    The model zoo (``repro.models``) interprets this config; families:
      dense   -- decoder-only transformer (GQA + SwiGLU)
      moe     -- decoder-only transformer with MoE FFN
      hybrid  -- Mamba2 backbone with periodic shared attention (Zamba2)
      ssm     -- xLSTM (mLSTM backbone + periodic sLSTM)
      mamba   -- Mamba-1 (the paper's own architecture family)
      audio   -- encoder-decoder transformer, conv frontend stubbed (Whisper)
      vlm     -- prefix-LM transformer, patch frontend stubbed (PaliGemma)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    head_dim: int = 0                # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    attn_logit_softcap: float = 0.0

    # --- mixture of experts ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden size
    capacity_factor: float = 1.25

    # --- SSM / recurrent ---
    d_state: int = 16                # mamba: N; zamba2: mamba2 state
    conv_width: int = 4
    expand: int = 2                  # d_inner = expand * d_model
    ssm_heads: int = 0               # mamba2 / xlstm heads
    dt_rank: int = 0                 # mamba1 dt_rank; 0 -> ceil(d_model/16)
    attn_period: int = 0             # zamba2: shared attn every k mamba layers
    slstm_every: int = 0             # xlstm: sLSTM at layer i when i%k==k-1

    # --- encoder-decoder / prefix ---
    n_enc_layers: int = 0
    prefix_len: int = 0              # vlm: number of patch-embedding tokens

    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    max_seq_len: int = 1 << 19

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(1, -(-self.d_model // 16))

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "audio", "vlm") or (
            self.family == "hybrid" and self.attn_period > 0
        )

    @property
    def has_ssm(self) -> bool:
        return self.family in ("hybrid", "ssm", "mamba")

    @property
    def subquadratic(self) -> bool:
        """True if generation-time state is O(1) in context length.

        Pure full-attention models keep a KV cache that grows with the
        context, so ``long_500k`` is skipped for them (see DESIGN.md
        §Arch-applicability).  Hybrid models carry a KV cache for the
        shared-attention layers only; the backbone is constant-state, so we
        run them on long_500k (the cache is small: few layers).
        """
        return self.family in ("hybrid", "ssm", "mamba")

    def param_count(self) -> int:
        """Analytic parameter count (matches models.init within ~0.1%)."""
        from repro.models import param_count  # local import to avoid cycle

        return param_count(self)

    def active_param_count(self) -> int:
        from repro.models import param_count

        return param_count(self, active_only=True)

    def validate(self) -> None:
        assert self.family in (
            "dense", "moe", "hybrid", "ssm", "mamba", "audio", "vlm",
        ), self.family
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0 and self.moe_d_ff > 0
        if self.has_attention:
            assert self.n_heads % max(1, self.n_kv_heads) == 0
        if self.family == "audio":
            assert self.n_enc_layers > 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One workload shape (one column of the dry-run matrix)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

# Smoke shapes: the same cells at CI scale (host devices, scaled-down
# configs).  Deliberately NOT in SHAPES -- the production dry-run matrix
# stays 4 columns; these are addressable by name only.
TRAIN_SMALL = ShapeSpec("train_small", 256, 8, "train")
PREFILL_SMALL = ShapeSpec("prefill_small", 512, 8, "prefill")
DECODE_SMALL = ShapeSpec("decode_small", 512, 8, "decode")
SMOKE_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_SMALL, PREFILL_SMALL,
                                       DECODE_SMALL)

SHAPE_BY_NAME = {s.name: s for s in SHAPES + SMOKE_SHAPES}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Is (arch, shape) a valid dry-run cell?  Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k requires sub-quadratic generation state; "
            f"{cfg.name} is a pure full-attention model (KV cache at 512k "
            "context exceeds any per-device budget). Skipped per DESIGN.md."
        )
    return True, ""


def scale_down(cfg: ModelConfig, *, layers: int = 2, width: int = 128,
               vocab: int = 512, experts: int = 4) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    n_heads = min(cfg.n_heads, 4)
    ratio = max(1, cfg.n_heads // max(1, cfg.n_kv_heads))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    updates = dict(
        n_layers=layers,
        d_model=width,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_ff=0 if cfg.d_ff == 0 else max(4 * width // 2, 64),
        vocab_size=vocab,
        head_dim=width // n_heads,
        d_state=min(cfg.d_state, 16),
        max_seq_len=4096,
        dtype="float32",
    )
    if cfg.family == "moe":
        updates.update(
            n_experts=experts,
            top_k=min(cfg.top_k, 2),
            moe_d_ff=max(64, width // 2),
        )
    if cfg.family == "hybrid":
        updates.update(attn_period=2, ssm_heads=max(2, width // 64))
    if cfg.family == "ssm":
        updates.update(slstm_every=2, ssm_heads=2)
    if cfg.family == "mamba":
        updates.update(dt_rank=8)
    if cfg.family == "audio":
        updates.update(n_enc_layers=layers)
    if cfg.family == "vlm":
        updates.update(prefix_len=16)
    return dataclasses.replace(cfg, **updates)
