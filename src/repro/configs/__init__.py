from repro.configs.base import (
    ModelConfig, ShapeSpec, SHAPES, SHAPE_BY_NAME,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    cell_supported, scale_down,
)
from repro.configs.registry import (
    get_config, list_archs, ASSIGNED_ARCHS, MAMBA_ARCHS, dryrun_cells,
)
