"""paligemma-3b [vlm]: SigLIP + gemma; backbone only, patch frontend stubbed.

18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384 vocab=257216.
[arXiv:2407.07726; hf]  ``input_specs`` supplies precomputed patch
embeddings (batch, prefix_len=256, d_model) plus text tokens; the model is a
prefix-LM over the concatenation (full attention within the prefix,
causal over text).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16_384,
    vocab_size=257_216,
    head_dim=256,
    rope_theta=10_000.0,
    prefix_len=256,
    tie_embeddings=True,
)
