"""zamba2-1.2b [hybrid]: Mamba2 backbone + periodic shared attention block.

38L d_model=2048, ssm_state=64; shared attn 32H (kv=32, MHA) d_ff=8192,
vocab=32000.  [arXiv:2411.15242; hf]  The shared transformer block (one set
of weights, applied every ``attn_period`` mamba layers) follows the Zamba2
design; per-invocation LoRA deltas are omitted (noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_000,
    head_dim=64,
    rope_theta=10_000.0,
    d_state=64,
    expand=2,
    conv_width=4,
    ssm_heads=64,            # mamba2: d_inner / head_dim(64)
    attn_period=6,           # shared attn after every 6 mamba layers
    tie_embeddings=True,
)
