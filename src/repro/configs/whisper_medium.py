"""whisper-medium [audio]: enc-dec transformer backbone, conv frontend stubbed.

24L d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]  The mel/conv frontend is a STUB:
``input_specs`` supplies precomputed frame embeddings of shape
(batch, enc_len, d_model).  Whisper-medium has 24 encoder + 24 decoder
layers; ``n_layers`` counts decoder layers, ``n_enc_layers`` encoder layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    rope_theta=10_000.0,
    tie_embeddings=True,
    max_seq_len=65_536,
)
