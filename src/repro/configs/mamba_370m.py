"""mamba-370m: the paper's own architecture (Mamba-1, Gu & Dao 2023).

48L d_model=1024, d_state=16, expand=2, conv_width=4, vocab=50280.
Quamba's quantization recipe (percentile-clipped SSM input, Hadamard-
transformed SSM output) applies to every block of this family.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba-370m",
    family="mamba",
    n_layers=48,
    d_model=1024,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50_280,
    d_state=16,
    expand=2,
    conv_width=4,
    tie_embeddings=True,
)
