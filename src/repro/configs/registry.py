"""Architecture registry: ``get_config(arch_id)`` and enumeration helpers."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec, cell_supported

# arch-id -> module under repro.configs (module defines CONFIG)
_ARCH_MODULES = {
    "whisper-medium": "whisper_medium",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "paligemma-3b": "paligemma_3b",
    "llama3-8b": "llama3_8b",
    "qwen3-32b": "qwen3_32b",
    "granite-3-8b": "granite_3_8b",
    "granite-3-2b": "granite_3_2b",
    "zamba2-1.2b": "zamba2_1_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    # the paper's own architecture family (Mamba-1)
    "mamba-130m": "mamba_130m",
    "mamba-370m": "mamba_370m",
    "mamba-1.4b": "mamba_1_4b",
    "mamba-2.8b": "mamba_2_8b",
}

ASSIGNED_ARCHS: List[str] = [
    "whisper-medium",
    "qwen3-moe-30b-a3b",
    "granite-moe-1b-a400m",
    "paligemma-3b",
    "llama3-8b",
    "qwen3-32b",
    "granite-3-8b",
    "granite-3-2b",
    "zamba2-1.2b",
    "xlstm-1.3b",
]

MAMBA_ARCHS: List[str] = ["mamba-130m", "mamba-370m", "mamba-1.4b", "mamba-2.8b"]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def dryrun_cells() -> List[tuple]:
    """All (arch_id, shape) cells for the assigned architectures."""
    cells = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, _ = cell_supported(cfg, shape)
            cells.append((arch, shape.name, ok))
    return cells
