from repro.data.synthetic import batches, eval_batches, perplexity, MarkovCorpus, CorpusSpec
