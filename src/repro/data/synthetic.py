"""Synthetic data pipeline (offline container: no Pile download).

A Zipfian bigram Markov language over the model's vocabulary gives data
with real learnable structure (a trained model reaches far-below-unigram
perplexity, so quantization deltas are measurable, which is what the
paper's Tables 2/5/6/9 need).  The generator is deterministic in
(seed, step), so restarts resume mid-stream without duplicating batches
-- the property the fault-tolerant loop relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CorpusSpec:
    vocab_size: int
    branching: int = 16         # candidate successors per token
    zipf_a: float = 1.3
    seed: int = 1234


class MarkovCorpus:
    """Deterministic Zipfian bigram sampler."""

    def __init__(self, spec: CorpusSpec):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        v, b = spec.vocab_size, min(spec.branching, spec.vocab_size)
        # successor table: each token -> b candidate successors + probs
        self.succ = rng.integers(0, v, size=(v, b))
        probs = 1.0 / np.arange(1, b + 1) ** spec.zipf_a
        self.probs = probs / probs.sum()
        self.b = b

    def sample(self, rng: np.random.Generator, batch: int, length: int
               ) -> np.ndarray:
        v = self.spec.vocab_size
        out = np.empty((batch, length + 1), np.int32)
        out[:, 0] = rng.integers(0, v, size=batch)
        choices = rng.choice(self.b, size=(batch, length), p=self.probs)
        for t in range(length):
            out[:, t + 1] = self.succ[out[:, t], choices[:, t]]
        return out


def batches(vocab_size: int, batch: int, seq_len: int, *,
            seed: int = 0, start_step: int = 0,
            num_steps: Optional[int] = None,
            extras: Optional[Dict] = None) -> Iterator[Dict]:
    """Stream of {"tokens", "targets"} (+ modality extras for audio/vlm).

    Batch ``i`` depends only on (seed, i): restart-safe and shardable
    (each data-parallel host can slice its rows).  The corpus *graph*
    (successor table) is fixed by CorpusSpec's own default seed so that
    train/eval/calibration streams with different ``seed`` values sample
    the same language.
    """
    corpus = MarkovCorpus(CorpusSpec(vocab_size))
    step = start_step
    while num_steps is None or step < start_step + num_steps:
        rng = np.random.default_rng((seed << 20) ^ step)
        seq = corpus.sample(rng, batch, seq_len)
        out = {"tokens": jnp.asarray(seq[:, :-1]),
               "targets": jnp.asarray(seq[:, 1:])}
        if extras:
            for k, shape in extras.items():
                out[k] = jnp.asarray(
                    rng.standard_normal((batch,) + shape, np.float32))
        yield out
        step += 1


def eval_batches(vocab_size: int, batch: int, seq_len: int, n: int,
                 seed: int = 10_000, extras: Optional[Dict] = None):
    """Held-out split: same corpus graph, disjoint sampling stream."""
    return list(batches(vocab_size, batch, seq_len, seed=seed,
                        num_steps=n, extras=extras))


def perplexity(loss_values) -> float:
    import math
    return float(math.exp(np.mean([float(v) for v in loss_values])))
