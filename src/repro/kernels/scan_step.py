"""Fused int8 single-token selective-scan step (decode TPOT kernel).

One generation step of the Mamba-1 recurrence (paper Eq. 1):
    h' = exp(dt * A) h + dt * u * B
    y  = <h', C> + D u        (then y *= silu(z) if gated)

All tensor operands arrive int8 with the same per-tensor scales as the
sequence kernel (``selective_scan``); dequantization happens once per
VMEM tile and the update runs in fp32.  Decode is the latency-critical
path (TPOT): at batch B the op reads the (B, D, N) state plus O(B*D)
activations and writes the state back -- purely memory-bound, so the
whole step is fused into a single pass with no intermediate HBM traffic.

Channels (D) tile onto the 128-lane vector unit exactly as in the
sequence kernel; the state block (bd, N) stays resident in VMEM for the
duration of the (single) time step.

``selective_scan_verify`` is the multi-token sibling used by
speculative decoding: it unrolls M = k+1 recurrence steps inside one
kernel launch and writes the state at EVERY step boundary, so rejecting
draft token j is a single O(1) gather of the j-th snapshot -- no
recompute, no KV truncation.  The per-step math is operation-for-
operation identical to ``selective_scan_step``, which is what makes
greedy speculative streams bit-identical to vanilla decode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _kernel(qu_ref, qdt_ref, qA_ref, qB_ref, qC_ref, dres_ref, z_ref,
            h_ref, s_ref, y_ref, hout_ref, *, gated: bool):
    s_u, s_dt, s_A, s_B, s_C = (s_ref[0, 0], s_ref[0, 1], s_ref[0, 2],
                                s_ref[0, 3], s_ref[0, 4])
    u = qu_ref[0].astype(jnp.float32) * s_u           # (bd,)
    dt = qdt_ref[0].astype(jnp.float32) * s_dt        # (bd,)
    a = qA_ref[...].astype(jnp.float32) * s_A         # (bd, N)
    bvec = qB_ref[0].astype(jnp.float32) * s_B        # (N,)
    cvec = qC_ref[0].astype(jnp.float32) * s_C        # (N,)
    h = h_ref[0].astype(jnp.float32)                  # (bd, N)

    da = jnp.exp(dt[:, None] * a)
    h_new = da * h + (dt * u)[:, None] * bvec[None, :]
    y = jnp.sum(h_new * cvec[None, :], axis=-1)
    y = y + dres_ref[...].astype(jnp.float32) * u
    if gated:
        z = z_ref[0].astype(jnp.float32)
        y = y * (z * jax.nn.sigmoid(z))
    y_ref[0] = y.astype(y_ref.dtype)
    hout_ref[0] = h_new.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "out_dtype",
                                             "interpret"))
def selective_scan_step(qu: jax.Array, qdt: jax.Array, qA: jax.Array,
                        qB: jax.Array, qC: jax.Array, scales: jax.Array,
                        D: jax.Array, h: jax.Array,
                        z: Optional[jax.Array] = None, *,
                        block_d: int = 256, out_dtype=jnp.float32,
                        interpret: Optional[bool] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Quantized single-token scan step.

    qu, qdt: (B, D) int8;  qA: (D, N) int8;  qB, qC: (B, N) int8;
    scales: (5,) fp32 = (s_u, s_dt, s_A, s_B, s_C);  D: (D,) fp32;
    h: (B, D, N) fp32 running state;  z: optional (B, D) fp gate.
    Returns (y (B, D) out_dtype, h_new (B, D, N) fp32).
    interpret=None auto-detects: native on TPU, interpret elsewhere.
    """
    bsz, d = qu.shape
    n = qA.shape[-1]
    gated = z is not None

    bd = min(block_d, d)
    dp = -(-d // bd) * bd
    pad_d = ((0, 0), (0, dp - d))
    qu_p = jnp.pad(qu, pad_d)
    qdt_p = jnp.pad(qdt, pad_d)
    qA_p = jnp.pad(qA, ((0, dp - d), (0, 0)))
    d_p = jnp.pad(D.astype(jnp.float32), (0, dp - d))
    z_p = (jnp.pad(z, pad_d) if gated
           else jnp.zeros((bsz, dp), jnp.float32))
    h_p = jnp.pad(h.astype(jnp.float32), ((0, 0), (0, dp - d), (0, 0)))
    s = scales.astype(jnp.float32).reshape(1, 5)

    y, h_new = pl.pallas_call(
        functools.partial(_kernel, gated=gated),
        grid=(bsz, dp // bd),
        in_specs=[
            pl.BlockSpec((1, bd), lambda b, j: (b, j)),       # qu
            pl.BlockSpec((1, bd), lambda b, j: (b, j)),       # qdt
            pl.BlockSpec((bd, n), lambda b, j: (j, 0)),       # qA
            pl.BlockSpec((1, n), lambda b, j: (b, 0)),        # qB
            pl.BlockSpec((1, n), lambda b, j: (b, 0)),        # qC
            pl.BlockSpec((bd,), lambda b, j: (j,)),           # D
            pl.BlockSpec((1, bd), lambda b, j: (b, j)),       # z
            pl.BlockSpec((1, bd, n), lambda b, j: (b, j, 0)),  # h
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scales
        ],
        out_specs=[
            pl.BlockSpec((1, bd), lambda b, j: (b, j)),
            pl.BlockSpec((1, bd, n), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, dp), out_dtype),
            jax.ShapeDtypeStruct((bsz, dp, n), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qu_p, qdt_p, qA_p, qB, qC, d_p, z_p, h_p, s)
    return y[:, :d], h_new[:, :d]


def _verify_kernel(qu_ref, qdt_ref, qA_ref, qB_ref, qC_ref, dres_ref,
                   z_ref, h_ref, s_ref, y_ref, hsteps_ref, *,
                   gated: bool, nsteps: int):
    s_u, s_dt, s_A, s_B, s_C = (s_ref[0, 0], s_ref[0, 1], s_ref[0, 2],
                                s_ref[0, 3], s_ref[0, 4])
    a = qA_ref[...].astype(jnp.float32) * s_A         # (bd, N)
    dres = dres_ref[...].astype(jnp.float32)          # (bd,)
    h = h_ref[0].astype(jnp.float32)                  # (bd, N)
    for i in range(nsteps):                           # static unroll
        u = qu_ref[0, i].astype(jnp.float32) * s_u    # (bd,)
        dt = qdt_ref[0, i].astype(jnp.float32) * s_dt
        bvec = qB_ref[0, i].astype(jnp.float32) * s_B  # (N,)
        cvec = qC_ref[0, i].astype(jnp.float32) * s_C
        da = jnp.exp(dt[:, None] * a)
        h = da * h + (dt * u)[:, None] * bvec[None, :]
        y = jnp.sum(h * cvec[None, :], axis=-1)
        y = y + dres * u
        if gated:
            z = z_ref[0, i].astype(jnp.float32)
            y = y * (z * jax.nn.sigmoid(z))
        y_ref[0, i] = y.astype(y_ref.dtype)
        hsteps_ref[0, i] = h.astype(hsteps_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "out_dtype",
                                             "interpret"))
def selective_scan_verify(qu: jax.Array, qdt: jax.Array, qA: jax.Array,
                          qB: jax.Array, qC: jax.Array,
                          scales: jax.Array, D: jax.Array, h: jax.Array,
                          z: Optional[jax.Array] = None, *,
                          block_d: int = 256, out_dtype=jnp.float32,
                          interpret: Optional[bool] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """Quantized M-token verify step (speculative decode).

    qu, qdt: (B, M, D) int8;  qA: (D, N) int8;  qB, qC: (B, M, N) int8;
    scales: (5,) fp32 = (s_u, s_dt, s_A, s_B, s_C);  D: (D,) fp32;
    h: (B, D, N) fp32 state BEFORE the first fed token;
    z: optional (B, M, D) fp gate.
    Returns (y (B, M, D) out_dtype, h_steps (B, M, D, N) fp32) where
    ``h_steps[:, i]`` is the state AFTER consuming fed token i -- the
    rollback snapshots.  One kernel dispatch regardless of M; each step
    runs the exact op sequence of :func:`selective_scan_step`.
    interpret=None auto-detects: native on TPU, interpret elsewhere.
    """
    bsz, m, d = qu.shape
    n = qA.shape[-1]
    gated = z is not None

    bd = min(block_d, d)
    dp = -(-d // bd) * bd
    pad_d = ((0, 0), (0, 0), (0, dp - d))
    qu_p = jnp.pad(qu, pad_d)
    qdt_p = jnp.pad(qdt, pad_d)
    qA_p = jnp.pad(qA, ((0, dp - d), (0, 0)))
    d_p = jnp.pad(D.astype(jnp.float32), (0, dp - d))
    z_p = (jnp.pad(z, pad_d) if gated
           else jnp.zeros((bsz, m, dp), jnp.float32))
    h_p = jnp.pad(h.astype(jnp.float32), ((0, 0), (0, dp - d), (0, 0)))
    s = scales.astype(jnp.float32).reshape(1, 5)

    y, h_steps = pl.pallas_call(
        functools.partial(_verify_kernel, gated=gated, nsteps=m),
        grid=(bsz, dp // bd),
        in_specs=[
            pl.BlockSpec((1, m, bd), lambda b, j: (b, 0, j)),   # qu
            pl.BlockSpec((1, m, bd), lambda b, j: (b, 0, j)),   # qdt
            pl.BlockSpec((bd, n), lambda b, j: (j, 0)),         # qA
            pl.BlockSpec((1, m, n), lambda b, j: (b, 0, 0)),    # qB
            pl.BlockSpec((1, m, n), lambda b, j: (b, 0, 0)),    # qC
            pl.BlockSpec((bd,), lambda b, j: (j,)),             # D
            pl.BlockSpec((1, m, bd), lambda b, j: (b, 0, j)),   # z
            pl.BlockSpec((1, bd, n), lambda b, j: (b, j, 0)),   # h
            pl.BlockSpec(memory_space=pltpu.SMEM),              # scales
        ],
        out_specs=[
            pl.BlockSpec((1, m, bd), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, m, bd, n), lambda b, j: (b, 0, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, m, dp), out_dtype),
            jax.ShapeDtypeStruct((bsz, m, dp, n), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qu_p, qdt_p, qA_p, qB, qC, d_p, z_p, h_p, s)
    return y[:, :, :d], h_steps[:, :, :d]
