"""Quantized selective SSM scan Pallas kernel (the paper's core operator).

Semantics (Mamba-1, paper Eq. 1, ZOH discretization):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * u_t * B_t
    y_t = <h_t, C_t> + D u_t            (then y *= silu(z) if gated)

All tensor operands arrive as int8 with per-tensor scales (paper §4.2:
"the quantized selective SSM takes 8-bit weights and activations as input,
as well as their scaling factors, and outputs half precision y").
Dequantization happens once per VMEM tile; the recurrence runs in fp32.

Hardware adaptation (DESIGN.md §Hardware-adaptation): the CUDA kernel the
paper modifies parallelizes the scan across threads with registers +
shuffles.  On TPU we instead:
  * tile channels (D) onto the 128-lane vector unit, states (N) onto
    sublanes -- each time step is a dense (bd, N) elementwise contraction;
  * chunk the sequence onto the (sequential) Pallas grid, carrying the
    (bd, N) state in VMEM scratch across grid steps -- the TPU grid is
    guaranteed to execute in order, which replaces the CUDA block-level
    carry;
  * the time loop inside a chunk is a fori_loop of vector ops (the op is
    memory-bound: ~O(N) flops per loaded byte, so MXU matmul-ification of
    the intra-chunk part buys nothing once HBM traffic dominates -- see
    EXPERIMENTS.md §Perf for the measurement).

The final state is emitted so serving can switch prefill -> decode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _kernel(qu_ref, qdt_ref, qA_ref, qB_ref, qC_ref, dres_ref, z_ref,
            h0_ref, s_ref, y_ref, hout_ref, h_ref, *,
            chunk: int, gated: bool, has_h0: bool):
    t_idx = pl.program_id(2)
    s_u, s_dt, s_A, s_B, s_C = (s_ref[0, 0], s_ref[0, 1], s_ref[0, 2],
                                s_ref[0, 3], s_ref[0, 4])

    @pl.when(t_idx == 0)
    def _init():
        if has_h0:
            h_ref[...] = h0_ref[0].astype(jnp.float32)
        else:
            h_ref[...] = jnp.zeros_like(h_ref)

    # dequantize this chunk's tiles once
    u = qu_ref[0].astype(jnp.float32) * s_u           # (T, bd)
    dt = qdt_ref[0].astype(jnp.float32) * s_dt        # (T, bd)
    a = qA_ref[...].astype(jnp.float32) * s_A         # (bd, N)
    bmat = qB_ref[0].astype(jnp.float32) * s_B        # (T, N)
    cmat = qC_ref[0].astype(jnp.float32) * s_C        # (T, N)
    dres = dres_ref[...].astype(jnp.float32)          # (bd,)

    def step(i, h):
        dt_i = dt[i][:, None]                         # (bd, 1)
        da = jnp.exp(dt_i * a)                        # (bd, N)
        dbu = (dt[i] * u[i])[:, None] * bmat[i][None, :]
        h = da * h + dbu                              # (bd, N)
        y_i = jnp.sum(h * cmat[i][None, :], axis=-1) + dres * u[i]
        if gated:
            zi = z_ref[0, i, :].astype(jnp.float32)
            y_i = y_i * (zi * jax.nn.sigmoid(zi))
        y_ref[0, i, :] = y_i.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(t_idx == pl.num_programs(2) - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "chunk", "block_d", "out_dtype", "interpret"))
def selective_scan(qu: jax.Array, qdt: jax.Array, qA: jax.Array,
                   qB: jax.Array, qC: jax.Array, scales: jax.Array,
                   D: jax.Array, z: Optional[jax.Array] = None,
                   h0: Optional[jax.Array] = None, *,
                   chunk: int = 128, block_d: int = 256,
                   out_dtype=jnp.float32,
                   interpret: Optional[bool] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Quantized selective scan.

    qu, qdt: (B, L, D) int8;  qA: (D, N) int8;  qB, qC: (B, L, N) int8;
    scales: (5,) fp32 = (s_u, s_dt, s_A, s_B, s_C);  D: (D,) fp32;
    z: optional (B, L, D) fp gate;  h0: optional (B, D, N) fp32.
    Returns (y (B, L, D) out_dtype, h_last (B, D, N) fp32).
    interpret=None auto-detects: native on TPU, interpret elsewhere.
    """
    interpret = resolve_interpret(interpret)
    bsz, L, d = qu.shape
    n = qA.shape[-1]
    gated = z is not None
    has_h0 = h0 is not None

    bd = min(block_d, d)
    dp = -(-d // bd) * bd
    tc = min(chunk, L)
    lp = -(-L // tc) * tc

    pad_ld = ((0, 0), (0, lp - L), (0, dp - d))
    qu_p = jnp.pad(qu, pad_ld)
    qdt_p = jnp.pad(qdt, pad_ld)
    qA_p = jnp.pad(qA, ((0, dp - d), (0, 0)))
    pad_ln = ((0, 0), (0, lp - L), (0, 0))
    qB_p = jnp.pad(qB, pad_ln)
    qC_p = jnp.pad(qC, pad_ln)
    d_p = jnp.pad(D.astype(jnp.float32), (0, dp - d))
    z_p = (jnp.pad(z, pad_ld) if gated
           else jnp.zeros((bsz, lp, dp), jnp.float32))
    h0_p = (jnp.pad(h0.astype(jnp.float32), ((0, 0), (0, dp - d), (0, 0)))
            if has_h0 else jnp.zeros((bsz, dp, n), jnp.float32))
    s = scales.astype(jnp.float32).reshape(1, 5)

    grid = (bsz, dp // bd, lp // tc)
    y, h_last = pl.pallas_call(
        functools.partial(_kernel, chunk=tc, gated=gated, has_h0=has_h0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, bd), lambda b, j, t: (b, t, j)),   # qu
            pl.BlockSpec((1, tc, bd), lambda b, j, t: (b, t, j)),   # qdt
            pl.BlockSpec((bd, n), lambda b, j, t: (j, 0)),          # qA
            pl.BlockSpec((1, tc, n), lambda b, j, t: (b, t, 0)),    # qB
            pl.BlockSpec((1, tc, n), lambda b, j, t: (b, t, 0)),    # qC
            pl.BlockSpec((bd,), lambda b, j, t: (j,)),              # D
            pl.BlockSpec((1, tc, bd), lambda b, j, t: (b, t, j)),   # z
            pl.BlockSpec((1, bd, n), lambda b, j, t: (b, j, 0)),    # h0
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # scales
        ],
        out_specs=[
            pl.BlockSpec((1, tc, bd), lambda b, j, t: (b, t, j)),
            pl.BlockSpec((1, bd, n), lambda b, j, t: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, lp, dp), out_dtype),
            jax.ShapeDtypeStruct((bsz, dp, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(qu_p, qdt_p, qA_p, qB_p, qC_p, d_p, z_p, h0_p, s)
    return y[:, :L, :d], h_last[:, :d]
