"""Shared Pallas execution-mode detection.

Every kernel in this package accepts ``interpret=None`` and resolves it
here: compile natively on TPU, fall back to interpret mode (the kernel
body executed in Python with identical semantics) everywhere else.  This
keeps *direct* imports of the kernel modules honest -- before, only the
``repro.kernels.ops`` wrappers auto-detected, and importing a kernel
module directly would silently run interpret mode on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax


def default_interpret() -> bool:
    """True when Pallas must run in interpret mode (no TPU backend)."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
