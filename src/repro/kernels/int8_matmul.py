"""W8A8 tiled matmul Pallas kernel (paper §4.3 "Projection layers").

TPU adaptation of the CUTLASS INT8 GEMM the paper uses: int8 x int8 tiles
feed the MXU with int32 accumulation in VMEM scratch; the dequant epilogue
(s_x * s_w rescale, optional bias, optional SiLU, optional re-quantization
to int8 for the next fused op) runs once on the final K step, so scaling
factors are fused exactly as in paper Fig. 4.

Block shapes default to (128, 128, 128): MXU-aligned for int8 (min tile
(32, 128)), and 3 live tiles * 128KB << 16MB VMEM, leaving room for
double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _mm_kernel(qx_ref, qw_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
               apply_silu: bool, out_is_int8: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        qx_ref[...], qw_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        s_in = scale_ref[0, 0]       # s_x * s_w
        s_out = scale_ref[0, 1]      # output quant scale (if int8 out)
        y = acc_ref[...].astype(jnp.float32) * s_in
        y = y + bias_ref[...].astype(jnp.float32)
        if apply_silu:
            y = y * jax.nn.sigmoid(y)
        if out_is_int8:
            o_ref[...] = jnp.clip(jnp.round(y / s_out), -128, 127
                                  ).astype(jnp.int8)
        else:
            o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("apply_silu", "out_dtype", "bm", "bn", "bk",
                     "interpret"))
def int8_matmul(qx: jax.Array, qw: jax.Array, s_x: jax.Array,
                s_w: jax.Array, bias: Optional[jax.Array] = None,
                s_out: Optional[jax.Array] = None, *,
                apply_silu: bool = False, out_dtype=jnp.float32,
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: Optional[bool] = None) -> jax.Array:
    """qx (M,K) int8 @ qw (K,N) int8 -> (M,N) out_dtype (or int8 if s_out).

    Pads M/N/K up to block multiples (zero padding is exact for matmul).
    interpret=None auto-detects: native on TPU, interpret elsewhere.
    """
    interpret = resolve_interpret(interpret)
    m, k = qx.shape
    k2, n = qw.shape
    assert k == k2, (qx.shape, qw.shape)
    out_is_int8 = s_out is not None

    mp, np_, kp = (-(-m // bm) * bm), (-(-n // bn) * bn), (-(-k // bk) * bk)
    qx = jnp.pad(qx, ((0, mp - m), (0, kp - k)))
    qw = jnp.pad(qw, ((0, kp - k), (0, np_ - n)))
    bias_f = jnp.zeros((np_,), jnp.float32) if bias is None else jnp.pad(
        bias.astype(jnp.float32), (0, np_ - n))
    scale = jnp.stack([
        jnp.asarray(s_x, jnp.float32) * jnp.asarray(s_w, jnp.float32),
        jnp.asarray(s_out if out_is_int8 else 1.0, jnp.float32),
    ]).reshape(1, 2)

    kern = functools.partial(_mm_kernel, apply_silu=apply_silu,
                             out_is_int8=out_is_int8)
    out = pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (mp, np_), jnp.int8 if out_is_int8 else out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, qw, scale, bias_f)
    return out[:m, :n]
