"""Fused Walsh–Hadamard transform + static int8 quantization kernel
(paper §4.2 "SSM outputs", §3.3).

Hardware adaptation (DESIGN.md §Hardware-adaptation): the reference CUDA
implementation (Dao's fast-hadamard-transform) runs the log n butterfly in
registers with warp shuffles -- there is no TPU analogue of a warp shuffle.
Instead we exploit H_n = H_a (x) H_b (Kronecker): reshape the row to
(a, b), multiply by H_b on the right and H_a on the left -- two small dense
matmuls that map straight onto the MXU.  Cost is O(n(a+b)) = O(n*sqrt(n))
multiplies instead of O(n log n) add/subs, but on the MXU the matmuls are
effectively free at these sizes (a, b <= 128 => a single MXU tile), and no
transpose/shuffle network is needed.

The 1/(sqrt(n) * s_y) output scaling is folded into the second matmul's
epilogue, so quantization adds zero extra passes (paper: "we fuse the
scaling factor s_y in the forward Hadamard transform").
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret
from repro.quant.hadamard import decompose, hadamard_matrix_np


def _split(n: int):
    """n = a * b with a = 2^ceil(p/2), b = 2^floor(p/2) * m (both Hadamard)."""
    p, m = decompose(n)
    pa = (p + 1) // 2
    a = 2 ** pa
    b = (2 ** (p - pa)) * m
    return a, b


def _kernel(y_ref, ha_ref, hb_ref, s_ref, q_ref, *, a: int, b: int):
    rows = y_ref.shape[0]
    y = y_ref[...].astype(jnp.float32).reshape(rows * a, b)
    # right-multiply by H_b^T == H_b (symmetric base matrices are not
    # guaranteed symmetric, so use explicit transpose via dot dims)
    y = jax.lax.dot_general(y, hb_ref[...], (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y.reshape(rows, a, b)
    # left-multiply by H_a: contract the 'a' axis
    y = jax.lax.dot_general(ha_ref[...], y, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # y now (a, rows, b) -> transpose back
    y = jnp.transpose(y, (1, 0, 2)).reshape(rows, a * b)
    q_ref[...] = jnp.clip(jnp.round(y * s_ref[0, 0]), -128, 127
                          ).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hadamard_quant(y: jax.Array, s_y: jax.Array, *, block_rows: int = 256,
                   interpret=None) -> jax.Array:
    """(tokens, n) fp -> (tokens, n) int8 = quant(H_n y / sqrt(n), s_y).

    interpret=None auto-detects: native on TPU, interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    t, n = y.shape
    a, b = _split(n)
    ha = jnp.asarray(hadamard_matrix_np(a, normalized=False))
    hb = jnp.asarray(hadamard_matrix_np(b, normalized=False))
    rows = min(block_rows, t)
    tp = -(-t // rows) * rows
    yp = jnp.pad(y, ((0, tp - t), (0, 0)))
    # fused epilogue scale: 1 / (sqrt(n) * s_y)
    s = (1.0 / (math.sqrt(n) * jnp.asarray(s_y, jnp.float32))).reshape(1, 1)

    q = pl.pallas_call(
        functools.partial(_kernel, a=a, b=b),
        grid=(tp // rows,),
        in_specs=[
            pl.BlockSpec((rows, n), lambda i: (i, 0)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, n), jnp.int8),
        interpret=interpret,
    )(yp, ha, hb, s)
    return q[:t]
