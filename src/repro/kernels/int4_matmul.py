"""W4A8 tiled matmul Pallas kernel (Table 8 low-bit configuration).

Same dataflow as ``int8_matmul`` -- int8 activations, int32 VMEM
accumulation, fused dequant/bias/SiLU/requant epilogue on the last K step
-- but the weight arrives nibble-packed: two int4 values (two's
complement, range [-8, 7]) per int8 byte along the contraction axis, the
layout written by ``repro.quant.recipe.pack_int4``.  The kernel unpacks
each (bk/2, bn) byte tile to a (bk, bn) int8 tile in VMEM right before
the MXU dot, so HBM traffic for weights is halved while the arithmetic
stays the int8 path whose numerics the qdq oracle certifies.

Sign extension happens in int32 (``(p << 28) >> 28`` for the low nibble,
``(p << 24) >> 28`` for the high) -- arithmetic right-shift on a widened
value is well-defined on every backend, unlike int8 bit-twiddling.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _unpack_tile(packed: jax.Array) -> jax.Array:
    """(bk/2, bn) packed bytes -> (bk, bn) int8 in [-8, 7].

    Row 2i comes from byte i's low nibble, row 2i+1 from its high nibble
    (the ``pack_int4`` layout), so the stack/reshape interleaves them back
    into contraction order.
    """
    p32 = packed.astype(jnp.int32)
    lo = (p32 << 28) >> 28
    hi = (p32 << 24) >> 28
    bkp, bn = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * bkp, bn).astype(jnp.int8)


def _mm_kernel(qx_ref, qw4_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
               apply_silu: bool, out_is_int8: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        qx_ref[...], _unpack_tile(qw4_ref[...]), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        s_in = scale_ref[0, 0]       # s_x * s_w
        s_out = scale_ref[0, 1]      # output quant scale (if int8 out)
        y = acc_ref[...].astype(jnp.float32) * s_in
        y = y + bias_ref[...].astype(jnp.float32)
        if apply_silu:
            y = y * jax.nn.sigmoid(y)
        if out_is_int8:
            o_ref[...] = jnp.clip(jnp.round(y / s_out), -128, 127
                                  ).astype(jnp.int8)
        else:
            o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("apply_silu", "out_dtype", "bm", "bn", "bk",
                     "interpret"))
def int4_matmul(qx: jax.Array, qw4: jax.Array, s_x: jax.Array,
                s_w: jax.Array, bias: Optional[jax.Array] = None,
                s_out: Optional[jax.Array] = None, *,
                apply_silu: bool = False, out_dtype=jnp.float32,
                bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: Optional[bool] = None) -> jax.Array:
    """qx (M,K) int8 @ packed qw4 (ceil(K/2),N) -> (M,N) out (int8 if s_out).

    K is recovered from the activation, never stored with the weight (a
    stored constant would not survive ``vmap`` over stacked layers); for
    odd K the pack-time zero nibble multiplies qx's zero pad column, so
    padding stays exact.  interpret=None auto-detects: native on TPU,
    interpret elsewhere.
    """
    interpret = resolve_interpret(interpret)
    if bk % 2:
        raise ValueError(f"bk must be even to split packed tiles, got {bk}")
    m, k = qx.shape
    k2p, n = qw4.shape
    if k2p != -(-k // 2):
        raise ValueError(f"packed rows {k2p} != ceil({k}/2): wrong layout?")
    out_is_int8 = s_out is not None

    mp, np_, kp = (-(-m // bm) * bm), (-(-n // bn) * bn), (-(-k // bk) * bk)
    qx = jnp.pad(qx, ((0, mp - m), (0, kp - k)))
    qw4 = jnp.pad(qw4, ((0, kp // 2 - k2p), (0, np_ - n)))
    bias_f = jnp.zeros((np_,), jnp.float32) if bias is None else jnp.pad(
        bias.astype(jnp.float32), (0, np_ - n))
    scale = jnp.stack([
        jnp.asarray(s_x, jnp.float32) * jnp.asarray(s_w, jnp.float32),
        jnp.asarray(s_out if out_is_int8 else 1.0, jnp.float32),
    ]).reshape(1, 2)

    kern = functools.partial(_mm_kernel, apply_silu=apply_silu,
                             out_is_int8=out_is_int8)
    out = pl.pallas_call(
        kern,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (mp, np_), jnp.int8 if out_is_int8 else out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(qx, qw4, scale, bias_f)
    return out[:m, :n]
