"""Fused int8 causal depthwise conv1d + SiLU + quantization (paper §4.3).

The operator is memory-bound (depthwise conv does W=4 MACs per loaded
element), so the win is keeping everything int8 in HBM and fusing the
SiLU + requantization before the store -- exactly the paper's recipe,
re-tiled for TPU: channels map to the 128-wide lane dimension, sequence to
the sublane dimension, and the W taps become W shifted elementwise FMAs in
VMEM (no im2col, no MXU needed).

Cross-chunk state: the wrapper carries the last W-1 int8 inputs of the
previous chunk (the same tensor the serving engine uses as the conv cache),
prepended via the ``state`` operand.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _kernel(xp_ref, w_ref, b_ref, s_ref, o_ref, *, width: int, L: int,
            apply_silu: bool, out_is_int8: bool):
    s_x, s_w, s_out = s_ref[0, 0], s_ref[0, 1], s_ref[0, 2]
    xp = xp_ref[...].astype(jnp.float32) * s_x        # (1, L+W-1, bd)
    w = w_ref[...].astype(jnp.float32) * s_w          # (W, bd)
    acc = jnp.zeros((1, L, xp.shape[-1]), jnp.float32)
    for k in range(width):                            # W static taps
        acc = acc + xp[:, k:k + L, :] * w[k]
    acc = acc + b_ref[...].astype(jnp.float32)
    if apply_silu:
        acc = acc * jax.nn.sigmoid(acc)
    if out_is_int8:
        o_ref[...] = jnp.clip(jnp.round(acc / s_out), -128, 127
                              ).astype(jnp.int8)
    else:
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "apply_silu", "out_dtype", "block_d", "interpret"))
def causal_conv1d(qx: jax.Array, qw: jax.Array, bias: jax.Array,
                  s_x: jax.Array, s_w: jax.Array,
                  s_out: Optional[jax.Array] = None,
                  state: Optional[jax.Array] = None, *,
                  apply_silu: bool = True, out_dtype=jnp.float32,
                  block_d: int = 256,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """qx (B, L, D) int8 -> (y (B, L, D) int8|fp, new_state (B, W-1, D) int8).

    qw: (W, D) int8 depthwise taps; state: (B, W-1, D) int8 previous tail.
    interpret=None auto-detects: native on TPU, interpret elsewhere.
    """
    interpret = resolve_interpret(interpret)
    bsz, L, d = qx.shape
    width = qw.shape[0]
    out_is_int8 = s_out is not None
    if state is None:
        state = jnp.zeros((bsz, width - 1, d), jnp.int8)
    xp = jnp.concatenate([state, qx], axis=1)         # (B, L+W-1, D)
    new_state = xp[:, -(width - 1):]

    bd = min(block_d, d)
    dp = -(-d // bd) * bd
    xp = jnp.pad(xp, ((0, 0), (0, 0), (0, dp - d)))
    qwp = jnp.pad(qw, ((0, 0), (0, dp - d)))
    bp = jnp.pad(bias.astype(jnp.float32), (0, dp - d))
    scales = jnp.stack([
        jnp.asarray(s_x, jnp.float32), jnp.asarray(s_w, jnp.float32),
        jnp.asarray(s_out if out_is_int8 else 1.0, jnp.float32),
    ]).reshape(1, 3)

    y = pl.pallas_call(
        functools.partial(_kernel, width=width, L=L, apply_silu=apply_silu,
                          out_is_int8=out_is_int8),
        grid=(bsz, dp // bd),
        in_specs=[
            pl.BlockSpec((1, L + width - 1, bd), lambda b, j: (b, 0, j)),
            pl.BlockSpec((width, bd), lambda b, j: (0, j)),
            pl.BlockSpec((bd,), lambda b, j: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, L, bd), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (bsz, L, dp), jnp.int8 if out_is_int8 else out_dtype),
        interpret=interpret,
    )(xp, qwp, bp, scales)
    return y[:, :, :d], new_state
