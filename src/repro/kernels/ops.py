"""Public wrappers around the Pallas kernels (the ``ops.py`` layer).

Every kernel resolves ``interpret=None`` through the shared
``repro.kernels._backend`` logic: native compilation on TPU, interpret
mode (the kernel body executed in Python with identical semantics)
everywhere else -- so importing a kernel module directly is never
silently slow on TPU.  The fallback to the pure-jnp oracles in ``ref``
is selected by ``QuantSpec.backend`` ("qdq") -- that is also what the
dry-run uses, so the roofline HLO reflects the XLA path (see DESIGN.md
§Dry-run-vs-kernels).

The model zoo's quantized kernel backend (``QuantSpec.backend ==
"kernels"``, see ``repro.models.mamba``) calls these wrappers -- always
through the module attribute (``ops.selective_scan``), which keeps the
call sites monkeypatchable for routing tests.
"""
from __future__ import annotations

from repro.kernels import ref
from repro.kernels._backend import default_interpret
from repro.kernels.causal_conv1d import causal_conv1d
from repro.kernels.hadamard_quant import hadamard_quant
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.rmsnorm_quant import rmsnorm_quant
from repro.kernels.scan_step import (selective_scan_step,
                                     selective_scan_verify)
from repro.kernels.selective_scan import selective_scan
from repro.kernels.ssd_scan import ssd_scan


def _interpret() -> bool:
    """Back-compat alias for the shared auto-detection."""
    return default_interpret()


__all__ = [
    "int8_matmul", "int4_matmul", "rmsnorm_quant", "hadamard_quant",
    "causal_conv1d",
    "selective_scan", "selective_scan_step", "selective_scan_verify",
    "ssd_scan", "ref",
]
