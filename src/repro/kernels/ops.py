"""Public jit'd wrappers around the Pallas kernels (the ``ops.py`` layer).

On TPU the kernels compile natively (interpret=False); everywhere else
(this CPU container, unit tests) they run in interpret mode, which executes
the kernel body in Python with identical semantics.  ``use_kernels`` lets
callers (the model zoo, the serving engine) fall back to the pure-jnp
oracles -- that is also what the dry-run uses, so the roofline HLO reflects
the XLA path (see DESIGN.md §Dry-run-vs-kernels).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.causal_conv1d import causal_conv1d as _causal_conv1d
from repro.kernels.hadamard_quant import hadamard_quant as _hadamard_quant
from repro.kernels.int8_matmul import int8_matmul as _int8_matmul
from repro.kernels.rmsnorm_quant import rmsnorm_quant as _rmsnorm_quant
from repro.kernels.selective_scan import selective_scan as _selective_scan
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def int8_matmul(*args, **kwargs):
    return _int8_matmul(*args, interpret=_interpret(), **kwargs)


def rmsnorm_quant(*args, **kwargs):
    return _rmsnorm_quant(*args, interpret=_interpret(), **kwargs)


def hadamard_quant(*args, **kwargs):
    return _hadamard_quant(*args, interpret=_interpret(), **kwargs)


def causal_conv1d(*args, **kwargs):
    return _causal_conv1d(*args, interpret=_interpret(), **kwargs)


def selective_scan(*args, **kwargs):
    return _selective_scan(*args, interpret=_interpret(), **kwargs)


def ssd_scan(*args, **kwargs):
    return _ssd_scan(*args, interpret=_interpret(), **kwargs)


__all__ = [
    "int8_matmul", "rmsnorm_quant", "hadamard_quant", "causal_conv1d",
    "selective_scan", "ssd_scan", "ref",
]
