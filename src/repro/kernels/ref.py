"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer).

Each function is the ground truth the kernels are tested against
(``tests/test_kernels_*.py`` sweeps shapes/dtypes and asserts allclose).
Everything here is also used directly by the model zoo on CPU.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q
from repro.quant.hadamard import had_transform


# ---------------------------------------------------------------------------
# selective scan (Mamba-1, paper Eq. 1)
# ---------------------------------------------------------------------------

def selective_scan_ref(u: jax.Array, dt: jax.Array, A: jax.Array,
                       B: jax.Array, C: jax.Array, D: jax.Array,
                       z: Optional[jax.Array] = None,
                       h0: Optional[jax.Array] = None,
                       return_state: bool = False):
    """Selective SSM scan.

    u:  (batch, L, D)   SSM input x   (paper's sensitive tensor)
    dt: (batch, L, D)   discretization step (post softplus)
    A:  (D, N)          state transition (negative reals)
    B:  (batch, L, N)   input projection  (input-dependent)
    C:  (batch, L, N)   output projection (input-dependent)
    D:  (D,)            residual
    z:  (batch, L, D)   optional gate; output *= silu(z)
    h0: (batch, D, N)   initial state

    Discretization (ZOH on A, Euler on B, as in Mamba):
      h_t = exp(dt_t * A) * h_{t-1} + dt_t * u_t * B_t
      y_t = (h_t . C_t) + D * u_t
    Runs an associative scan over L in fp32.
    """
    b, L, d = u.shape
    n = A.shape[-1]
    dtype = jnp.float32
    u32, dt32 = u.astype(dtype), dt.astype(dtype)
    dA = jnp.exp(dt32[..., None] * A.astype(dtype))              # (b,L,D,N)
    dBu = (dt32 * u32)[..., None] * B.astype(dtype)[:, :, None]  # (b,L,D,N)

    if h0 is not None:
        # absorb the initial state as a virtual step contribution
        dBu = dBu.at[:, 0].add(dA[:, 0] * h0.astype(dtype))

    def combine(a, b):
        # composition of affine maps h -> g*h + v
        ga, va = a
        gb, vb = b
        return ga * gb, gb * va + vb

    gs, hs = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    y = jnp.einsum("bldn,bln->bld", hs, C.astype(dtype))
    y = y + D.astype(dtype) * u32
    if z is not None:
        y = y * jax.nn.silu(z.astype(dtype))
    if return_state:
        return y, hs[:, -1]
    return y


def selective_scan_seq_ref(u: jax.Array, dt: jax.Array, A: jax.Array,
                           B: jax.Array, C: jax.Array, D: jax.Array,
                           z: Optional[jax.Array] = None,
                           h0: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (lax.scan over time) selective scan; always returns
    (y, h_last).

    Same semantics as :func:`selective_scan_ref`, but the recurrence is
    evaluated strictly in time order with the exact fp operations of
    :func:`selective_scan_step_ref` -- so a chunked prefill through this
    path is bitwise-identical to stepping token by token (the property
    the serving engine's prefill->decode handoff relies on).  The Pallas
    kernels are sequential-in-time too, so this is also their oracle
    ordering.
    """
    bsz, L, d = u.shape
    n = A.shape[-1]
    dtype = jnp.float32
    h_init = (h0.astype(dtype) if h0 is not None
              else jnp.zeros((bsz, d, n), dtype))
    a32 = A.astype(dtype)

    d32 = D.astype(dtype)

    def step(h, t):
        u_t, dt_t, b_t, c_t = t
        dA = jnp.exp(dt_t.astype(dtype)[..., None] * a32)
        dBu = (dt_t.astype(dtype) * u_t.astype(dtype))[..., None] * \
            b_t.astype(dtype)[:, None, :]
        h_new = dA * h + dBu
        # elementwise-multiply + sum, NOT einsum: the fused kernel reduces
        # this way, and dot_general's accumulation order differs by ulps --
        # enough to flip a requant tie in the backend-parity contract.
        # The D*u skip term is added HERE, inside the step, for the same
        # reason: the fused kernel adds it per step inside its compiled
        # loop, and the compiler contracts the multiply-add there; adding
        # it outside the scan (eagerly, two roundings) leaves the result
        # an ulp off on roughly a quarter of the elements
        y_t = jnp.sum(h_new * c_t.astype(dtype)[:, None, :], axis=-1) \
            + d32 * u_t.astype(dtype)
        return h_new, y_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (u, dt, B, C))
    h_last, ys = jax.lax.scan(step, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if z is not None:
        y = y * jax.nn.silu(z.astype(dtype))
    return y, h_last


def selective_scan_states_ref(u: jax.Array, dt: jax.Array, A: jax.Array,
                              B: jax.Array, C: jax.Array, D: jax.Array,
                              z: Optional[jax.Array] = None,
                              h0: Optional[jax.Array] = None
                              ) -> Tuple[jax.Array, jax.Array]:
    """Sequential scan that keeps EVERY intermediate state.

    Same per-step fp operations as :func:`selective_scan_seq_ref` (so
    bitwise-identical outputs), but returns (y (b, L, D),
    h_steps (b, L, D, N)) where ``h_steps[:, t]`` is the state after
    consuming token t.  This is the oracle for the speculative-decode
    verify path: rolling back to draft position j is a gather of
    ``h_steps[:, j]``.  Only call with small L (k+1 speculative steps)
    -- the stacked states are L times the decode state.
    """
    bsz, L, d = u.shape
    n = A.shape[-1]
    dtype = jnp.float32
    h_init = (h0.astype(dtype) if h0 is not None
              else jnp.zeros((bsz, d, n), dtype))
    a32 = A.astype(dtype)

    d32 = D.astype(dtype)

    def step(h, t):
        u_t, dt_t, b_t, c_t = t
        dA = jnp.exp(dt_t.astype(dtype)[..., None] * a32)
        dBu = (dt_t.astype(dtype) * u_t.astype(dtype))[..., None] * \
            b_t.astype(dtype)[:, None, :]
        h_new = dA * h + dBu
        # same reduction form and in-step D*u placement as
        # selective_scan_seq_ref / the fused kernel
        y_t = jnp.sum(h_new * c_t.astype(dtype)[:, None, :], axis=-1) \
            + d32 * u_t.astype(dtype)
        return h_new, (y_t, h_new)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (u, dt, B, C))
    _, (ys, hs) = jax.lax.scan(step, h_init, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if z is not None:
        y = y * jax.nn.silu(z.astype(dtype))
    return y, jnp.moveaxis(hs, 0, 1)


def selective_scan_verify_ref(qu: jax.Array, qdt: jax.Array,
                              qA: jax.Array, qB: jax.Array,
                              qC: jax.Array, scales: jax.Array,
                              D: jax.Array, h: jax.Array,
                              z: Optional[jax.Array] = None
                              ) -> Tuple[jax.Array, jax.Array]:
    """Oracle for the fused multi-token verify kernel.

    Mirrors ``kernels.scan_step.selective_scan_verify``: int8 operands
    with a (5,) per-tensor scale vector (s_u, s_dt, s_A, s_B, s_C),
    M sequential recurrence steps from state ``h``, gate applied as
    z*sigmoid(z).  Returns (y (B, M, D), h_steps (B, M, D, N)).
    """
    s = jnp.asarray(scales, jnp.float32)
    u = qu.astype(jnp.float32) * s[0]
    dt = qdt.astype(jnp.float32) * s[1]
    A = qA.astype(jnp.float32) * s[2]
    B = qB.astype(jnp.float32) * s[3]
    C = qC.astype(jnp.float32) * s[4]
    return selective_scan_states_ref(u, dt, A, B, C, D, z=z, h0=h)


def selective_scan_step_ref(h: jax.Array, u: jax.Array, dt: jax.Array,
                            A: jax.Array, B: jax.Array, C: jax.Array,
                            D: jax.Array, z: Optional[jax.Array] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Single recurrent step (generation).  h: (batch, D, N); u/dt/z: (batch, D);
    B/C: (batch, N).  Returns (y, h_new)."""
    dtype = jnp.float32
    dA = jnp.exp(dt.astype(dtype)[..., None] * A.astype(dtype))
    dBu = (dt.astype(dtype) * u.astype(dtype))[..., None] * \
        B.astype(dtype)[:, None, :]
    h_new = dA * h.astype(dtype) + dBu
    # same reduction form as selective_scan_seq_ref / the fused kernel
    y = jnp.sum(h_new * C.astype(dtype)[:, None, :], axis=-1)
    y = y + D.astype(dtype) * u.astype(dtype)
    if z is not None:
        y = y * jax.nn.silu(z.astype(dtype))
    return y, h_new


def selective_scan_quant_ref(qu, qdt, qA, qB, qC, scales: dict, D, z=None,
                             h0=None, return_state: bool = False):
    """Quantized-selective-scan oracle: dequantize int8 inputs with their
    per-tensor scales (paper §4.2), then run the fp32 scan."""
    u = Q.dequantize(qu, scales["u"])
    dt = Q.dequantize(qdt, scales["dt"])
    A = Q.dequantize(qA, scales["A"])
    B = Q.dequantize(qB, scales["B"])
    C = Q.dequantize(qC, scales["C"])
    return selective_scan_ref(u, dt, A, B, C, D, z=z, h0=h0,
                              return_state=return_state)


# ---------------------------------------------------------------------------
# fused Hadamard transform + static quantization (paper §4.2 "SSM outputs")
# ---------------------------------------------------------------------------

def hadamard_quant_ref(y: jax.Array, s_y: jax.Array) -> jax.Array:
    """y -> clamp(round((H_n y / sqrt(n)) / s_y)) as int8 over last axis."""
    yh = had_transform(y.astype(jnp.float32), normalized=True)
    return Q.quantize(yh, jnp.asarray(s_y, jnp.float32))


# ---------------------------------------------------------------------------
# fused causal conv1d + SiLU + quantization (paper §4.3)
# ---------------------------------------------------------------------------

def causal_conv1d_ref(qx: jax.Array, qw: jax.Array, bias: jax.Array,
                      s_x: jax.Array, s_w: jax.Array,
                      s_out: Optional[jax.Array] = None,
                      state: Optional[jax.Array] = None,
                      apply_silu: bool = True):
    """Depthwise causal conv over L with int8 input/weights.

    qx: (batch, L, D) int8; qw: (W, D) int8; bias: (D,) fp32.
    state: (batch, W-1, D) int8 tail of the previous chunk (or None = zeros).
    Output int8 (if s_out) or fp32; plus the new state tail.
    """
    w = qw.astype(jnp.float32) * s_w
    x = qx.astype(jnp.float32) * s_x
    bsz, L, d = x.shape
    width = qw.shape[0]
    if state is None:
        pad = jnp.zeros((bsz, width - 1, d), x.dtype)
    else:
        pad = state.astype(jnp.float32) * s_x
    xp = jnp.concatenate([pad, x], axis=1)                  # (b, L+W-1, D)
    y = sum(xp[:, k:k + L] * w[k] for k in range(width)) + bias
    if apply_silu:
        y = jax.nn.silu(y)
    new_state = jnp.concatenate(
        [pad, qx.astype(jnp.float32) * s_x], axis=1)[:, -(width - 1):]
    new_state_q = Q.quantize(new_state, jnp.asarray(s_x, jnp.float32))
    if s_out is not None:
        return Q.quantize(y, jnp.asarray(s_out, jnp.float32)), new_state_q
    return y, new_state_q


# ---------------------------------------------------------------------------
# int8 matmul with fused dequant epilogue (paper §4.3 projection layers)
# ---------------------------------------------------------------------------

def int8_matmul_ref(qx: jax.Array, qw: jax.Array, s_x: jax.Array,
                    s_w: jax.Array, bias: Optional[jax.Array] = None,
                    out_dtype=jnp.float32) -> jax.Array:
    """(M,K)int8 @ (K,N)int8 -> int32 -> * s_x*s_w (+bias) -> out_dtype."""
    acc = jax.lax.dot_general(
        qx, qw, (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * (jnp.asarray(s_x, jnp.float32) *
                                   jnp.asarray(s_w, jnp.float32))
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# fused residual-add + RMSNorm + static quantization (paper §4.3)
# ---------------------------------------------------------------------------

def rmsnorm_quant_ref(x_out: jax.Array, x_res: jax.Array, w: jax.Array,
                      s_out: jax.Array, eps: float = 1e-5
                      ) -> Tuple[jax.Array, jax.Array]:
    """Returns (int8 input to the next block, fp residual).

    (x_in^{L+1}, x_res^{L+1}) =
        (quant(RMSNorm(x_out^L + x_res^L) / s_out), x_out^L + x_res^L)
    Normalization in fp32 (weights not quantized, paper §4.3).
    """
    r = x_out.astype(jnp.float32) + x_res.astype(jnp.float32)
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return Q.quantize(y, jnp.asarray(s_out, jnp.float32)), r
