"""Quantized chunked SSD (Mamba-2) scan Pallas kernel.

Extends the paper's quantized-scan idea to the Mamba-2 recurrence used by
the Zamba2 backbone (DESIGN.md §Arch-applicability).  Where the Mamba-1
kernel (``selective_scan.py``) is a vector recurrence (A is per
channel-state, so each step is elementwise), Mamba-2's scalar-per-head
decay admits the **state-space dual** form in which everything becomes
MXU matmuls:

  per (batch, head, chunk) with running state S (n, hd):
    scores  = tril( (C B^T) * exp(lcum_i - lcum_j) )     (t,t)  <- MXU
    y_intra = scores @ (dt * x)                          (t,hd) <- MXU
    y_inter = exp(lcum) * (C @ S)                        (t,hd) <- MXU
    S      <- e^{lcum_T} S + B^T @ (e^{lcum_T-lcum} dt x)       <- MXU

The chunk axis is the (sequential) Pallas grid; S lives in VMEM scratch
across grid steps.  Operands arrive int8 with per-tensor scales,
dequantized once per tile; everything accumulates in fp32 (same
quantization contract as the paper's selective-scan kernel).

VMEM at the default (t=128, n<=128, hd<=128): a few (t,t)/(t,hd)/(n,hd)
fp32 tiles ~ 512 KB << 16 MB, MXU-aligned.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _kernel(qx_ref, qdt_ref, qa_ref, qb_ref, qc_ref, dres_ref, s_ref,
            h0_ref, y_ref, hout_ref, state_ref, *, chunk: int,
            has_h0: bool):
    c_idx = pl.program_id(2)
    s_x, s_dt, s_a, s_b, s_c = (s_ref[0, 0], s_ref[0, 1], s_ref[0, 2],
                                s_ref[0, 3], s_ref[0, 4])

    @pl.when(c_idx == 0)
    def _init():
        if has_h0:
            state_ref[...] = h0_ref[0, 0].astype(jnp.float32)
        else:
            state_ref[...] = jnp.zeros_like(state_ref)

    x = qx_ref[0, :, 0, :].astype(jnp.float32) * s_x        # (t, hd)
    dt = qdt_ref[0, :, 0].astype(jnp.float32) * s_dt        # (t,)
    a = qa_ref[0].astype(jnp.float32) * s_a                 # scalar
    bmat = qb_ref[0].astype(jnp.float32) * s_b              # (t, n)
    cmat = qc_ref[0].astype(jnp.float32) * s_c              # (t, n)
    dres = dres_ref[0].astype(jnp.float32)                  # scalar

    la = dt * a                                             # (t,) < 0
    lcum = jnp.cumsum(la)                                   # (t,)

    # intra-chunk: decay-masked (t, t) score matmul
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    decay = lcum[:, None] - lcum[None, :]
    tri = jnp.tril(jnp.ones((x.shape[0], x.shape[0]), bool))
    # mask before exp (upper triangle is positive and can overflow)
    scores = cb * jnp.exp(jnp.where(tri, decay, -1e30))
    dx = dt[:, None] * x                                    # (t, hd)
    y = jax.lax.dot_general(scores, dx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    y += jnp.exp(lcum)[:, None] * jax.lax.dot_general(
        cmat, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y + dres * x).astype(y_ref.dtype)

    # state update: S <- e^{lcum_T} S + B^T @ (e^{lcum_T - lcum} dt x)
    tail = jnp.exp(lcum[-1] - lcum)                         # (t,)
    contrib = jax.lax.dot_general(
        bmat, tail[:, None] * dx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                 # (n, hd)
    state_ref[...] = jnp.exp(lcum[-1]) * state_ref[...] + contrib

    @pl.when(c_idx == pl.num_programs(2) - 1)
    def _emit():
        hout_ref[0, 0] = state_ref[...].astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "out_dtype",
                                             "interpret"))
def ssd_scan(qx: jax.Array, qdt: jax.Array, qa: jax.Array, qb: jax.Array,
             qc: jax.Array, scales: jax.Array, dres: jax.Array,
             h0: Optional[jax.Array] = None, *, chunk: int = 128,
             out_dtype=jnp.float32, interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Quantized Mamba-2 scan.

    qx (B, L, H, hd) int8; qdt (B, L, H) int8; qa (H,) int8;
    qb, qc (B, L, N) int8; scales (5,) fp32 = (s_x, s_dt, s_a, s_b, s_c);
    dres (H,) fp32; h0 optional (B, H, N, hd) fp32.
    Returns (y (B, L, H, hd) out_dtype, h_last (B, H, N, hd) fp32).
    interpret=None auto-detects: native on TPU, interpret elsewhere.
    """
    interpret = resolve_interpret(interpret)
    bsz, L, h, hd = qx.shape
    n = qb.shape[-1]
    has_h0 = h0 is not None
    tc = min(chunk, L)
    lp = -(-L // tc) * tc
    qx_p = jnp.pad(qx, ((0, 0), (0, lp - L), (0, 0), (0, 0)))
    qdt_p = jnp.pad(qdt, ((0, 0), (0, lp - L), (0, 0)))
    qb_p = jnp.pad(qb, ((0, 0), (0, lp - L), (0, 0)))
    qc_p = jnp.pad(qc, ((0, 0), (0, lp - L), (0, 0)))
    h0_p = (h0.astype(jnp.float32) if has_h0
            else jnp.zeros((bsz, h, n, hd), jnp.float32))
    s = scales.astype(jnp.float32).reshape(1, 5)

    grid = (bsz, h, lp // tc)
    y, h_last = pl.pallas_call(
        functools.partial(_kernel, chunk=tc, has_h0=has_h0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, 1, hd), lambda b, j, c: (b, c, j, 0)),
            pl.BlockSpec((1, tc, 1), lambda b, j, c: (b, c, j)),
            pl.BlockSpec((1,), lambda b, j, c: (j,)),
            pl.BlockSpec((1, tc, n), lambda b, j, c: (b, c, 0)),
            pl.BlockSpec((1, tc, n), lambda b, j, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, j, c: (j,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, n, hd), lambda b, j, c: (b, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tc, 1, hd), lambda b, j, c: (b, c, j, 0)),
            pl.BlockSpec((1, 1, n, hd), lambda b, j, c: (b, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, lp, h, hd), out_dtype),
            jax.ShapeDtypeStruct((bsz, h, n, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, hd), jnp.float32)],
        interpret=interpret,
    )(qx_p, qdt_p, qa, qb_p, qc_p, dres, s, h0_p)
    return y[:, :L], h_last
