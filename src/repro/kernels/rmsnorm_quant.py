"""Fused residual-add + RMSNorm + static quantization kernel (paper §4.3).

One pass over the residual stream: r = x_out + x_res is computed once,
normalized in fp32 (norm weights stay half/full precision per the paper),
and the int8 activation for the next block is emitted alongside the fp
residual -- two outputs, zero extra HBM round-trips.

Rows are tiled (block_rows x d_model); d_model stays whole in VMEM because
the mean-square reduction spans it.  For d_model <= 8192 fp32 that is
<= 32KB * block_rows -- far under VMEM with block_rows = 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._backend import resolve_interpret


def _kernel(x_ref, res_ref, w_ref, s_ref, q_ref, r_ref, *, eps: float):
    r = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    var = jnp.mean(r * r, axis=-1, keepdims=True)
    y = r * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    q_ref[...] = jnp.clip(jnp.round(y / s_ref[0, 0]), -128, 127
                          ).astype(jnp.int8)
    r_ref[...] = r.astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_quant(x_out: jax.Array, x_res: jax.Array, w: jax.Array,
                  s_out: jax.Array, *, eps: float = 1e-5,
                  block_rows: int = 256, interpret=None):
    """(tokens, d) x 2 -> (int8 (tokens, d), fp32 residual (tokens, d)).

    interpret=None auto-detects: native on TPU, interpret elsewhere."""
    interpret = resolve_interpret(interpret)
    t, d = x_out.shape
    rows = min(block_rows, t)
    tp = -(-t // rows) * rows
    pad = ((0, tp - t), (0, 0))
    xo = jnp.pad(x_out, pad)
    xr = jnp.pad(x_res, pad)
    s = jnp.asarray(s_out, jnp.float32).reshape(1, 1)

    q, r = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(tp // rows,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, d), jnp.int8),
            jax.ShapeDtypeStruct((tp, d), jnp.float32),
        ],
        interpret=interpret,
    )(xo, xr, w, s)
    return q[:t], r[:t]
