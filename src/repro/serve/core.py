"""EngineCore: prefill/decode execution over the slotted decode state.

No request lifecycle lives here.  The core knows slots, device state,
and per-slot sampling arrays; WHO occupies a slot is the Scheduler's
business (``repro.serve.scheduler``) and streams/metrics live in the
``LLMEngine`` (``repro.serve.engine``).

Execution details carried over from the pre-PR-4 engine:

Prefill: for families with a sequence prefill path (recurrent state +
h0/h_last carry -- see ``repro.models.prefill_step``) the prompt is fed
in chunks of ``prefill_chunk`` tokens, one dispatch per chunk, against a
batch-1 slice of the slot's state -- O(num_chunks) dispatches instead of
O(prompt_len) full-batch decode steps.  Other families fall back to the
per-token decode path, so quantized execution (Quamba qctx) stays
identical between prefill and generation either way.

Decode-loop host overhead: per-slot bookkeeping lives in host numpy
mirrors; the device-side token/sampling tensors are refreshed only when
slot membership changes, and each step issues exactly one device_get
(the sampled tokens).  Per-slot PRNG keys evolve functionally on device
inside the jitted step, so heterogeneous per-request seeds cost no
extra host syncs.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_decode_state, prefill_step, \
    supports_seq_prefill
from repro.models.model import merge_slot, reset_slot, slice_slot, \
    write_slot
from repro.quant.recipe import prefill_chunk_safe
from repro.serve.params import SamplingParams
from repro.serve.sampler import sample_batched


class EngineCore:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, qctx=None, seed: int = 0,
                 cache_dtype=None, prefill_chunk: int = 128,
                 shard: Optional[bool] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.params = params
        self.cfg = cfg
        self.qctx = qctx
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        if cache_dtype is None:
            # QuantSpec.quantize_kv_cache flows through the qctx: int8
            # attention caches with per-entry scales (see models.attention)
            spec = qctx.get("spec") if isinstance(qctx, dict) else None
            kv8 = spec is not None and getattr(spec, "quantize_kv_cache",
                                               False)
            cache_dtype = jnp.int8 if kv8 else jnp.float32
        self.cache_dtype = jnp.dtype(cache_dtype)
        self.state = init_decode_state(cfg, max_batch, max_len,
                                       cache_dtype=cache_dtype)
        # data-parallel slot sharding: with >1 device the decode slots
        # spread over a host mesh's data axis (repro.dist.sharding rules)
        # and the weights replicate -- each device decodes its share of
        # the batch.  shard=None auto-enables when divisible; shard=True
        # insists; shard=False keeps everything single-device.
        self.mesh = None
        n_dev = len(jax.devices())
        if shard is None:
            shard = n_dev > 1 and max_batch % n_dev == 0
        if shard:
            from repro.dist.sharding import (decode_state_shardings,
                                             replicate_shardings)
            from repro.launch.mesh import make_host_mesh
            if max_batch % n_dev != 0:
                raise ValueError(
                    f"shard=True needs max_batch ({max_batch}) divisible "
                    f"by the device count ({n_dev})")
            self.mesh = make_host_mesh()
            st_sh = decode_state_shardings(
                jax.eval_shape(lambda: self.state), self.mesh, cfg)
            self.state = jax.device_put(self.state, st_sh)
            self.params = jax.device_put(
                params, replicate_shardings(
                    jax.eval_shape(lambda: params), self.mesh))
        # `truncate` is static: the all-greedy/plain-temperature batch
        # (the common case) compiles a variant with no top-k/top-p
        # masking in the hot loop -- at most two compiled versions
        self._step_fn = jax.jit(self._one_step,
                                static_argnames="truncate")
        # chunked prefill requires a sequence path AND chunk-invariant
        # quantization scales (see recipe.prefill_chunk_safe): per-call
        # scales only match per-token stepping when fed token by token
        spec_m = qctx.get("spec") if isinstance(qctx, dict) else None
        self._prefill_fn = (jax.jit(self._one_prefill)
                            if supports_seq_prefill(cfg)
                            and prefill_chunk_safe(spec_m) else None)
        # host mirrors of the per-slot decode inputs; the device copies
        # are only rebuilt when a slot joins or leaves (``_dirty``)
        self._next_host = np.zeros((max_batch,), np.int32)
        self._temps_host = np.zeros((max_batch,), np.float32)
        self._topk_host = np.zeros((max_batch,), np.int32)
        self._topp_host = np.ones((max_batch,), np.float32)
        self._next_dev = jnp.zeros((max_batch,), jnp.int32)
        self._temps_dev = jnp.zeros((max_batch,), jnp.float32)
        self._topk_dev = jnp.zeros((max_batch,), jnp.int32)
        self._topp_dev = jnp.ones((max_batch,), jnp.float32)
        self._dirty = False
        self._truncate = False       # any live slot using top-k/top-p?
        # per-slot PRNG keys live on device and evolve inside the jitted
        # step; a slot's key row is replaced at seat() time only
        self._base_key = jax.random.PRNGKey(seed)
        self._keys_dev = jax.random.split(self._base_key, max_batch)
        # dispatch accounting (benchmarks / tests)
        self.counters: Dict[str, int] = {"prefill_dispatches": 0,
                                         "decode_steps": 0,
                                         "prefix_restores": 0}

    # -- jitted cores -----------------------------------------------------
    def _one_step(self, params, state, tokens, keys, temps, top_k, top_p,
                  truncate):
        logits, new_state = decode_step(params, self.cfg, state, tokens,
                                        qctx=self.qctx)
        ks = jax.vmap(jax.random.split)(keys)          # (B, 2, 2)
        toks = sample_batched(ks[:, 1], logits, temps, top_k, top_p,
                              truncate=truncate)
        return toks, ks[:, 0], new_state

    def _one_prefill(self, params, slot_state, tokens):
        _, new_state = prefill_step(params, self.cfg, slot_state, tokens,
                                    qctx=self.qctx)
        return new_state

    # -- slot management --------------------------------------------------
    @staticmethod
    def _chunk_plan(n: int, chunk: int) -> List[int]:
        """Split ``n`` prompt tokens into full ``chunk``-sized pieces plus
        a power-of-two binary decomposition of the remainder, so the
        jitted prefill compiles at most log2(chunk)+2 distinct shapes
        regardless of the prompt-length mix (vs one compile per distinct
        remainder length)."""
        sizes = [chunk] * (n // chunk)
        rem = n % chunk
        while rem:
            p = 1 << (rem.bit_length() - 1)
            sizes.append(p)
            rem -= p
        return sizes

    def seat(self, i: int, prompt: Sequence[int], sp: SamplingParams,
             salt: int, *, prefix_state: Optional[Dict] = None,
             prefix_len: int = 0, on_prefix=None) -> None:
        """Reset slot ``i``, install ``sp``'s sampling arrays and PRNG
        key, and prefill the prompt (leaving the last prompt token as
        the slot's next decode input).  ``salt`` derives the slot key
        when ``sp.seed`` is None (the engine passes a monotonically
        increasing admission index, so streams stay deterministic).

        Prefix-cache integration (``repro.serve.cache``):
        ``prefix_state`` is a batch-1 state tree covering
        ``prompt[:prefix_len]`` -- it is restored with one device-side
        ``write_slot`` and prefill resumes from ``prefix_len`` (a full
        hit, ``prefix_len == len(prompt) - 1``, skips prefill
        entirely).  ``on_prefix(consumed, slot_state)`` is called with
        the batch-1 state after each prefill chunk so the engine can
        snapshot intermediate prefixes without an extra copy."""
        if prefix_state is not None:
            self.restore_slot(i, prefix_state)
        else:
            prefix_len = 0
            self.state = reset_slot(self.cfg, self.state, i)
        self._temps_host[i] = sp.effective_temperature
        # greedy rows take argmax whatever the masks say -- store the
        # disabled values so a greedy request never flips the batch
        # onto the truncating (two-argsort) step variant
        self._topk_host[i] = 0 if sp.is_greedy else sp.top_k
        self._topp_host[i] = 1.0 if sp.is_greedy else sp.top_p
        key = (jax.random.PRNGKey(sp.seed) if sp.seed is not None
               else jax.random.fold_in(self._base_key, salt))
        self._keys_dev = self._keys_dev.at[i].set(key)
        self._dirty = True
        self._prefill(i, prompt, start=prefix_len, on_prefix=on_prefix)

    # -- prefix-cache state movement (device-side; jax arrays are
    # immutable so a snapshot is a tree of references, not a copy) ------
    def snapshot_slot(self, i: int) -> Dict:
        """Slot ``i``'s decode state as a standalone batch-1 tree."""
        return slice_slot(self.cfg, self.state, i)

    def restore_slot(self, i: int, slot_state: Dict) -> None:
        """Overwrite slot ``i`` with a ``snapshot_slot``/prefill tree
        (covers every state leaf incl. ``pos``, so no reset needed)."""
        self.state = write_slot(self.cfg, self.state, slot_state, i)
        self.counters["prefix_restores"] += 1

    # device <-> host state movement: the prefix cache's spill tier
    # evicts cold snapshots to host RAM instead of dropping them, so
    # the device byte budget stops competing with decode slots for HBM.
    # The policy (what to move when) lives in ``repro.serve.cache``;
    # the mechanism lives here with the rest of the device-state code.
    @staticmethod
    def tree_to_host(tree: Dict) -> Dict:
        """Materialize a state tree as host numpy arrays (one blocking
        ``device_get`` per spill; leaves keep dtype and layout)."""
        return jax.device_get(tree)

    @staticmethod
    def tree_to_device(tree: Dict) -> Dict:
        """Promote a host tree back onto the default device.  jax
        arrays are immutable, so the single promoted tree is shared
        copy-on-write across however many concurrent ``restore_slot``
        calls hit the same prefix -- no per-restore copies."""
        return jax.device_put(tree)

    def clear_slot(self, i: int) -> None:
        """Reset slot ``i``'s sampling arrays after eviction (its state
        is re-initialised at the next seat)."""
        self._temps_host[i] = 0.0
        self._topk_host[i] = 0
        self._topp_host[i] = 1.0
        self._dirty = True

    def _set_next(self, i: int, tok: int) -> None:
        self._next_host[i] = tok
        self._dirty = True

    def _prefill(self, i: int, prompt: Sequence[int], start: int = 0,
                 on_prefix=None) -> None:
        """Advance slot ``i``'s state over ``prompt[start:-1]``.

        ``on_prefix(consumed, slot_state)``: after each chunk (and once
        at the end of the per-token path) reports the batch-1 state
        covering ``prompt[:consumed]`` -- the prefix-cache snapshot
        hook.  ``consumed`` is an absolute prompt offset, so a resumed
        prefill (``start > 0``) extends the cached prefix chain."""
        toks = list(prompt[start:-1])
        consumed = start
        if toks and self._prefill_fn is not None:
            # chunked sequence prefill on a batch-1 slice of the state:
            # O(num_chunks) dispatches, none of them full-batch
            slot_state = slice_slot(self.cfg, self.state, i)
            c0 = 0
            for size in self._chunk_plan(len(toks), self.prefill_chunk):
                chunk = jnp.asarray([toks[c0:c0 + size]], jnp.int32)
                c0 += size
                slot_state = self._prefill_fn(self.params, slot_state,
                                              chunk)
                self.counters["prefill_dispatches"] += 1
                consumed = start + c0
                if on_prefix is not None:
                    on_prefix(consumed, slot_state)
            self.state = write_slot(self.cfg, self.state, slot_state, i)
        elif toks:
            # fallback: per-token decode dispatches (attention families);
            # the sampled token is discarded -- only slot i's state moves
            for t in toks:
                tok = self._next_dev.at[i].set(t)
                # truncate=False: the sampled token is discarded here,
                # so never pay the top-k/top-p masking during prefill
                _, _, new_state = self._step_fn(
                    self.params, self.state, tok, self._keys_dev,
                    self._temps_dev, self._topk_dev, self._topp_dev,
                    truncate=False)
                self.counters["prefill_dispatches"] += 1
                self.state = merge_slot(self.cfg, self.state, new_state,
                                        i)
                consumed += 1
            if on_prefix is not None:
                # one snapshot at the full prefix (slicing per token
                # would double the host work of the fallback path)
                on_prefix(consumed, slice_slot(self.cfg, self.state, i))
        self._set_next(i, prompt[-1])

    # -- decode -----------------------------------------------------------
    def _sync_device_inputs(self) -> None:
        if self._dirty:
            self._next_dev = jnp.asarray(self._next_host)
            self._temps_dev = jnp.asarray(self._temps_host)
            self._topk_dev = jnp.asarray(self._topk_host)
            self._topp_dev = jnp.asarray(self._topp_host)
            self._truncate = bool((self._topk_host > 0).any()
                                  or (self._topp_host < 1.0).any())
            self._dirty = False

    def decode(self) -> np.ndarray:
        """One batched decode dispatch; returns the sampled tokens for
        ALL slots as a host array (stale values in free slots are
        harmless -- their state is reset at the next seat)."""
        self._sync_device_inputs()
        toks, self._keys_dev, self.state = self._step_fn(
            self.params, self.state, self._next_dev, self._keys_dev,
            self._temps_dev, self._topk_dev, self._topp_dev,
            truncate=self._truncate)
        self.counters["decode_steps"] += 1
        toks_host = np.asarray(jax.device_get(toks))
        # sampled tokens feed the next step directly (no per-slot device
        # updates)
        self._next_dev = toks
        self._next_host[:] = toks_host
        return toks_host
