"""EngineCore: prefill/decode execution over the slotted decode state.

No request lifecycle lives here.  The core knows slots, device state,
and per-slot sampling arrays; WHO occupies a slot is the Scheduler's
business (``repro.serve.scheduler``) and streams/metrics live in the
``LLMEngine`` (``repro.serve.engine``).

Execution details carried over from the pre-PR-4 engine:

Prefill: for families with a sequence prefill path (recurrent state +
h0/h_last carry -- see ``repro.models.prefill_step``) the prompt is fed
in chunks of ``prefill_chunk`` tokens, one dispatch per chunk, against a
batch-1 slice of the slot's state -- O(num_chunks) dispatches instead of
O(prompt_len) full-batch decode steps.  Other families fall back to the
per-token decode path, so quantized execution (Quamba qctx) stays
identical between prefill and generation either way.

Decode-loop host overhead: per-slot bookkeeping lives in host numpy
mirrors; the device-side token/sampling tensors are refreshed only when
slot membership changes, and each step issues exactly one device_get
(the sampled tokens).  Per-slot PRNG keys evolve functionally on device
inside the jitted step, so heterogeneous per-request seeds cost no
extra host syncs.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_decode_state, prefill_step, \
    select_scan_state, select_verify_state, supports_seq_prefill, \
    supports_verify, verify_step
from repro.models.model import merge_slot, reset_slot, slice_slot, \
    write_slot
from repro.quant.recipe import prefill_chunk_safe
from repro.serve.params import SamplingParams
from repro.serve.sampler import apply_top_k_top_p, sample_batched
from repro.serve.spec import SpecConfig, resolve_draft, spec_acceptance

# per-slot draft PRNG keys fork off the slot key with a fixed salt, so
# draft sampling never consumes (or perturbs) the target's key stream
_DRAFT_KEY_SALT = 0x5bec


class EngineCore:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, qctx=None, seed: int = 0,
                 cache_dtype=None, prefill_chunk: int = 128,
                 shard: Optional[bool] = None,
                 speculative: Optional[SpecConfig] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.params = params
        self.cfg = cfg
        self.qctx = qctx
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        if cache_dtype is None:
            # QuantSpec.quantize_kv_cache flows through the qctx: int8
            # attention caches with per-entry scales (see models.attention)
            spec = qctx.get("spec") if isinstance(qctx, dict) else None
            kv8 = spec is not None and getattr(spec, "quantize_kv_cache",
                                               False)
            cache_dtype = jnp.int8 if kv8 else jnp.float32
        self.cache_dtype = jnp.dtype(cache_dtype)
        self.state = init_decode_state(cfg, max_batch, max_len,
                                       cache_dtype=cache_dtype)
        # data-parallel slot sharding: with >1 device the decode slots
        # spread over a host mesh's data axis (repro.dist.sharding rules)
        # and the weights replicate -- each device decodes its share of
        # the batch.  shard=None auto-enables when divisible; shard=True
        # insists; shard=False keeps everything single-device.
        self.mesh = None
        n_dev = len(jax.devices())
        if shard is None:
            shard = n_dev > 1 and max_batch % n_dev == 0
        if shard:
            from repro.dist.sharding import (decode_state_shardings,
                                             replicate_shardings)
            from repro.launch.mesh import make_host_mesh
            if max_batch % n_dev != 0:
                raise ValueError(
                    f"shard=True needs max_batch ({max_batch}) divisible "
                    f"by the device count ({n_dev})")
            self.mesh = make_host_mesh()
            st_sh = decode_state_shardings(
                jax.eval_shape(lambda: self.state), self.mesh, cfg)
            self.state = jax.device_put(self.state, st_sh)
            self.params = jax.device_put(
                params, replicate_shardings(
                    jax.eval_shape(lambda: params), self.mesh))
        # `truncate` is static: the all-greedy/plain-temperature batch
        # (the common case) compiles a variant with no top-k/top-p
        # masking in the hot loop -- at most two compiled versions
        self._step_fn = jax.jit(self._one_step,
                                static_argnames="truncate")
        # chunked prefill requires a sequence path AND chunk-invariant
        # quantization scales (see recipe.prefill_chunk_safe): per-call
        # scales only match per-token stepping when fed token by token
        spec_m = qctx.get("spec") if isinstance(qctx, dict) else None
        self._prefill_fn = (jax.jit(self._one_prefill)
                            if supports_seq_prefill(cfg)
                            and prefill_chunk_safe(spec_m) else None)
        # host mirrors of the per-slot decode inputs; the device copies
        # are only rebuilt when a slot joins or leaves (``_dirty``)
        self._next_host = np.zeros((max_batch,), np.int32)
        self._temps_host = np.zeros((max_batch,), np.float32)
        self._topk_host = np.zeros((max_batch,), np.int32)
        self._topp_host = np.ones((max_batch,), np.float32)
        self._next_dev = jnp.zeros((max_batch,), jnp.int32)
        self._temps_dev = jnp.zeros((max_batch,), jnp.float32)
        self._topk_dev = jnp.zeros((max_batch,), jnp.int32)
        self._topp_dev = jnp.ones((max_batch,), jnp.float32)
        self._dirty = False
        self._truncate = False       # any live slot using top-k/top-p?
        # per-slot PRNG keys live on device and evolve inside the jitted
        # step; a slot's key row is replaced at seat() time only
        self._base_key = jax.random.PRNGKey(seed)
        self._keys_dev = jax.random.split(self._base_key, max_batch)
        # dispatch accounting (benchmarks / tests)
        self.counters: Dict[str, int] = {"prefill_dispatches": 0,
                                         "decode_steps": 0,
                                         "prefix_restores": 0}
        # speculative decoding (repro.serve.spec): a draft model state
        # rides alongside the target's, same slot layout
        self.spec: Optional[SpecConfig] = speculative
        if speculative is not None:
            self._init_spec(speculative)

    def _init_spec(self, spec: SpecConfig) -> None:
        if not supports_verify(self.cfg):
            raise ValueError(
                "speculative decoding needs a fused multi-token verify "
                f"path; family {self.cfg.family!r} has none "
                "(models.supports_verify)")
        dc, dp, dq, is_self = resolve_draft(spec, self.cfg, self.params,
                                            self.qctx)
        self.draft_cfg, self.draft_params, self.draft_qctx = dc, dp, dq
        self._draft_is_self = is_self
        self.draft_state = init_decode_state(dc, self.max_batch,
                                             self.max_len)
        self._draft_keys = jax.random.split(
            jax.random.fold_in(self._base_key, _DRAFT_KEY_SALT),
            self.max_batch)
        self._spec_fn = jax.jit(self._one_spec_round,
                                static_argnames="truncate")
        dspec = dq.get("spec") if isinstance(dq, dict) else None
        self._draft_prefill_fn = (jax.jit(self._one_draft_prefill)
                                  if supports_seq_prefill(dc)
                                  and prefill_chunk_safe(dspec) else None)
        self._draft_step_fn = jax.jit(self._one_draft_step)
        self.counters.update({"spec_rounds": 0, "spec_dispatches": 0,
                              "drafted_tokens": 0,
                              "accepted_tokens": 0,
                              "rolled_back_tokens": 0,
                              "draft_prefill_dispatches": 0})

    # -- jitted cores -----------------------------------------------------
    def _one_step(self, params, state, tokens, keys, temps, top_k, top_p,
                  truncate):
        logits, new_state = decode_step(params, self.cfg, state, tokens,
                                        qctx=self.qctx)
        ks = jax.vmap(jax.random.split)(keys)          # (B, 2, 2)
        toks = sample_batched(ks[:, 1], logits, temps, top_k, top_p,
                              truncate=truncate)
        return toks, ks[:, 0], new_state

    def _one_prefill(self, params, slot_state, tokens):
        _, new_state = prefill_step(params, self.cfg, slot_state, tokens,
                                    qctx=self.qctx)
        return new_state

    def _one_draft_prefill(self, dparams, slot_state, tokens):
        _, new_state = prefill_step(dparams, self.draft_cfg, slot_state,
                                    tokens, qctx=self.draft_qctx)
        return new_state

    def _one_draft_step(self, dparams, slot_state, tok):
        _, new_state = decode_step(dparams, self.draft_cfg, slot_state,
                                   tok, qctx=self.draft_qctx)
        return new_state

    def _one_spec_round(self, params, dparams, state, dstate, t0, keys,
                        dkeys, temps, top_k, top_p, truncate):
        """One fused speculative round, a single dispatch end to end:
        draft ``k`` tokens (lax.scan of per-token draft steps, sampling
        on device), verify all of them through ``verify_step``'s
        multi-token kernel, run the acceptance math, and roll BOTH
        models back to each row's last accepted position via O(1)
        per-step snapshot selects."""
        k = self.spec.k

        def body(carry, _):
            st, tok, ks = carry
            logits, st = decode_step(dparams, self.draft_cfg, st, tok,
                                     qctx=self.draft_qctx)
            ks2 = jax.vmap(jax.random.split)(ks)
            # q is the exact distribution this sample is drawn from
            # (sample_batched's pipeline); acceptance needs the pair
            scaled = logits / jnp.maximum(temps, 1e-4)[:, None]
            masked = (apply_top_k_top_p(scaled, top_k, top_p)
                      if truncate else scaled)
            q = jax.nn.softmax(masked, axis=-1)
            nxt = jnp.where(
                temps <= 0.0, jnp.argmax(logits, axis=-1),
                jax.vmap(jax.random.categorical)(ks2[:, 1], masked)
            ).astype(jnp.int32)
            return (st, nxt, ks2[:, 0]), (nxt, q, st)

        # k+1 draft steps: the last one advances the draft past its own
        # final token so EVERY rollback target j in [0, k] has a
        # snapshot (the draft never lags the target between rounds)
        (_, _, dkeys), (toks, qs, dsteps) = jax.lax.scan(
            body, (dstate, t0, dkeys), None, length=k + 1)
        drafts = jnp.moveaxis(toks[:k], 0, 1)          # (B, k) d_1..d_k
        qprobs = jnp.moveaxis(qs[:k], 0, 1)            # (B, k, V)

        fed = jnp.concatenate([t0[:, None], drafts], axis=1)
        logits, steps = verify_step(params, self.cfg, state, fed,
                                    qctx=self.qctx)
        n_acc, extra, keys = spec_acceptance(
            logits, drafts, qprobs, keys, temps, top_k, top_p, truncate)
        new_state = select_verify_state(self.cfg, steps, n_acc)
        new_dstate = select_scan_state(self.draft_cfg, dsteps, n_acc)
        return drafts, n_acc, extra, keys, dkeys, new_state, new_dstate

    # -- slot management --------------------------------------------------
    @staticmethod
    def _chunk_plan(n: int, chunk: int) -> List[int]:
        """Split ``n`` prompt tokens into full ``chunk``-sized pieces plus
        a power-of-two binary decomposition of the remainder, so the
        jitted prefill compiles at most log2(chunk)+2 distinct shapes
        regardless of the prompt-length mix (vs one compile per distinct
        remainder length)."""
        sizes = [chunk] * (n // chunk)
        rem = n % chunk
        while rem:
            p = 1 << (rem.bit_length() - 1)
            sizes.append(p)
            rem -= p
        return sizes

    def seat(self, i: int, prompt: Sequence[int], sp: SamplingParams,
             salt: int, *, prefix_state: Optional[Dict] = None,
             prefix_len: int = 0, on_prefix=None) -> None:
        """Reset slot ``i``, install ``sp``'s sampling arrays and PRNG
        key, and prefill the prompt (leaving the last prompt token as
        the slot's next decode input).  ``salt`` derives the slot key
        when ``sp.seed`` is None (the engine passes a monotonically
        increasing admission index, so streams stay deterministic).

        Prefix-cache integration (``repro.serve.cache``):
        ``prefix_state`` is a batch-1 state tree covering
        ``prompt[:prefix_len]`` -- it is restored with one device-side
        ``write_slot`` and prefill resumes from ``prefix_len`` (a full
        hit, ``prefix_len == len(prompt) - 1``, skips prefill
        entirely).  ``on_prefix(consumed, slot_state)`` is called with
        the batch-1 state after each prefill chunk so the engine can
        snapshot intermediate prefixes without an extra copy."""
        if prefix_state is not None:
            self.restore_slot(i, prefix_state)
        else:
            prefix_len = 0
            self.state = reset_slot(self.cfg, self.state, i)
        self._temps_host[i] = sp.effective_temperature
        # greedy rows take argmax whatever the masks say -- store the
        # disabled values so a greedy request never flips the batch
        # onto the truncating (two-argsort) step variant
        self._topk_host[i] = 0 if sp.is_greedy else sp.top_k
        self._topp_host[i] = 1.0 if sp.is_greedy else sp.top_p
        key = (jax.random.PRNGKey(sp.seed) if sp.seed is not None
               else jax.random.fold_in(self._base_key, salt))
        self._keys_dev = self._keys_dev.at[i].set(key)
        if self.spec is not None:
            self._draft_keys = self._draft_keys.at[i].set(
                jax.random.fold_in(key, _DRAFT_KEY_SALT))
        self._dirty = True
        self._prefill(i, prompt, start=prefix_len, on_prefix=on_prefix)
        if self.spec is not None:
            self._seat_draft(i, prompt)

    def _seat_draft(self, i: int, prompt: Sequence[int]) -> None:
        """Bring the draft model's slot ``i`` to the same consumed
        prefix as the target (everything up to, not including, the last
        prompt token).  A "self" draft shares the target's weights and
        state layout, so the just-prefilled target slot IS the draft
        state: one reference-shared slice, no recompute -- and a
        prefix-cache restore on the target transfers to the draft for
        free.  A distinct draft prefills the prompt through its own
        path (chunked when its family and qctx allow)."""
        if self._draft_is_self:
            self.draft_state = write_slot(
                self.draft_cfg, self.draft_state,
                slice_slot(self.cfg, self.state, i), i)
            return
        self.draft_state = reset_slot(self.draft_cfg, self.draft_state, i)
        toks = list(prompt[:-1])
        if not toks:
            return
        slot = slice_slot(self.draft_cfg, self.draft_state, i)
        if self._draft_prefill_fn is not None:
            c0 = 0
            for size in self._chunk_plan(len(toks), self.prefill_chunk):
                chunk = jnp.asarray([toks[c0:c0 + size]], jnp.int32)
                c0 += size
                slot = self._draft_prefill_fn(self.draft_params, slot,
                                              chunk)
                self.counters["draft_prefill_dispatches"] += 1
        else:
            for t in toks:
                slot = self._draft_step_fn(self.draft_params, slot,
                                           jnp.asarray([t], jnp.int32))
                self.counters["draft_prefill_dispatches"] += 1
        self.draft_state = write_slot(self.draft_cfg, self.draft_state,
                                      slot, i)

    # -- prefix-cache state movement (device-side; jax arrays are
    # immutable so a snapshot is a tree of references, not a copy) ------
    def snapshot_slot(self, i: int) -> Dict:
        """Slot ``i``'s decode state as a standalone batch-1 tree."""
        return slice_slot(self.cfg, self.state, i)

    def restore_slot(self, i: int, slot_state: Dict) -> None:
        """Overwrite slot ``i`` with a ``snapshot_slot``/prefill tree
        (covers every state leaf incl. ``pos``, so no reset needed)."""
        self.state = write_slot(self.cfg, self.state, slot_state, i)
        self.counters["prefix_restores"] += 1

    # device <-> host state movement: the prefix cache's spill tier
    # evicts cold snapshots to host RAM instead of dropping them, so
    # the device byte budget stops competing with decode slots for HBM.
    # The policy (what to move when) lives in ``repro.serve.cache``;
    # the mechanism lives here with the rest of the device-state code.
    @staticmethod
    def tree_to_host(tree: Dict) -> Dict:
        """Materialize a state tree as host numpy arrays (one blocking
        ``device_get`` per spill; leaves keep dtype and layout)."""
        return jax.device_get(tree)

    @staticmethod
    def tree_to_device(tree: Dict) -> Dict:
        """Promote a host tree back onto the default device.  jax
        arrays are immutable, so the single promoted tree is shared
        copy-on-write across however many concurrent ``restore_slot``
        calls hit the same prefix -- no per-restore copies."""
        return jax.device_put(tree)

    def clear_slot(self, i: int) -> None:
        """Reset slot ``i``'s sampling arrays after eviction (its state
        is re-initialised at the next seat)."""
        self._temps_host[i] = 0.0
        self._topk_host[i] = 0
        self._topp_host[i] = 1.0
        self._dirty = True

    def _set_next(self, i: int, tok: int) -> None:
        self._next_host[i] = tok
        self._dirty = True

    def _prefill(self, i: int, prompt: Sequence[int], start: int = 0,
                 on_prefix=None) -> None:
        """Advance slot ``i``'s state over ``prompt[start:-1]``.

        ``on_prefix(consumed, slot_state)``: after each chunk (and once
        at the end of the per-token path) reports the batch-1 state
        covering ``prompt[:consumed]`` -- the prefix-cache snapshot
        hook.  ``consumed`` is an absolute prompt offset, so a resumed
        prefill (``start > 0``) extends the cached prefix chain."""
        toks = list(prompt[start:-1])
        consumed = start
        if toks and self._prefill_fn is not None:
            # chunked sequence prefill on a batch-1 slice of the state:
            # O(num_chunks) dispatches, none of them full-batch
            slot_state = slice_slot(self.cfg, self.state, i)
            c0 = 0
            for size in self._chunk_plan(len(toks), self.prefill_chunk):
                chunk = jnp.asarray([toks[c0:c0 + size]], jnp.int32)
                c0 += size
                slot_state = self._prefill_fn(self.params, slot_state,
                                              chunk)
                self.counters["prefill_dispatches"] += 1
                consumed = start + c0
                if on_prefix is not None:
                    on_prefix(consumed, slot_state)
            self.state = write_slot(self.cfg, self.state, slot_state, i)
        elif toks:
            # fallback: per-token decode dispatches (attention families);
            # the sampled token is discarded -- only slot i's state moves
            for t in toks:
                tok = self._next_dev.at[i].set(t)
                # truncate=False: the sampled token is discarded here,
                # so never pay the top-k/top-p masking during prefill
                _, _, new_state = self._step_fn(
                    self.params, self.state, tok, self._keys_dev,
                    self._temps_dev, self._topk_dev, self._topp_dev,
                    truncate=False)
                self.counters["prefill_dispatches"] += 1
                self.state = merge_slot(self.cfg, self.state, new_state,
                                        i)
                consumed += 1
            if on_prefix is not None:
                # one snapshot at the full prefix (slicing per token
                # would double the host work of the fallback path)
                on_prefix(consumed, slice_slot(self.cfg, self.state, i))
        self._set_next(i, prompt[-1])

    # -- decode -----------------------------------------------------------
    def _sync_device_inputs(self) -> None:
        if self._dirty:
            self._next_dev = jnp.asarray(self._next_host)
            self._temps_dev = jnp.asarray(self._temps_host)
            self._topk_dev = jnp.asarray(self._topk_host)
            self._topp_dev = jnp.asarray(self._topp_host)
            self._truncate = bool((self._topk_host > 0).any()
                                  or (self._topp_host < 1.0).any())
            self._dirty = False

    def decode(self) -> np.ndarray:
        """One batched decode dispatch; returns the sampled tokens for
        ALL slots as a host array (stale values in free slots are
        harmless -- their state is reset at the next seat)."""
        self._sync_device_inputs()
        toks, self._keys_dev, self.state = self._step_fn(
            self.params, self.state, self._next_dev, self._keys_dev,
            self._temps_dev, self._topk_dev, self._topp_dev,
            truncate=self._truncate)
        self.counters["decode_steps"] += 1
        toks_host = np.asarray(jax.device_get(toks))
        # sampled tokens feed the next step directly (no per-slot device
        # updates)
        self._next_dev = toks
        self._next_host[:] = toks_host
        return toks_host

    def decode_spec(self, live_slots: Sequence[int]
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One fused speculative round for ALL slots (one dispatch).

        Returns host arrays ``(drafts (B, k), n_acc (B,), extra (B,))``:
        slot ``i`` commits ``drafts[i, :n_acc[i]]`` followed by
        ``extra[i]`` -- always ``n_acc[i] + 1`` tokens.  Greedy slots
        accept a draft token iff it equals the target argmax, so their
        streams are bit-identical to vanilla :meth:`decode`; sampled
        slots use Leviathan rejection sampling over the same processed
        distributions ``sample_batched`` draws from, so their streams
        are distribution-identical.  ``live_slots`` scopes the
        acceptance counters to occupied slots (free slots still compute
        -- their results are discarded like vanilla decode's)."""
        self._sync_device_inputs()
        k = self.spec.k
        (drafts, n_acc, extra, self._keys_dev, self._draft_keys,
         self.state, self.draft_state) = self._spec_fn(
            self.params, self.draft_params, self.state, self.draft_state,
            self._next_dev, self._keys_dev, self._draft_keys,
            self._temps_dev, self._topk_dev, self._topp_dev,
            truncate=self._truncate)
        self.counters["decode_steps"] += 1
        self.counters["spec_rounds"] += 1
        # the whole round -- k+1 draft steps, verify, acceptance,
        # rollback -- is ONE _spec_fn invocation; this counter is the
        # contract (test_spec_decode pins dispatches == rounds)
        self.counters["spec_dispatches"] += 1
        drafts_h, n_h, extra_h = (
            np.asarray(a) for a in jax.device_get((drafts, n_acc, extra)))
        for i in live_slots:
            self.counters["drafted_tokens"] += k
            self.counters["accepted_tokens"] += int(n_h[i])
            self.counters["rolled_back_tokens"] += k - int(n_h[i])
        self._next_dev = extra
        self._next_host[:] = extra_h
        return drafts_h, n_h, extra_h
