"""EnginePump: a background stepping driver for ``LLMEngine``.

Today's engine is pumped by its consumers: iterating a
``RequestStream`` calls ``engine.step()`` until the stream yields, so
the engine only advances at one client's consumption pace.  That is
fine for a single caller but it breaks open-loop load generation -- an
arrival schedule cannot be honored when submitting a request does not
make it run until somebody polls.

``EnginePump`` decouples stepping from consumption: a daemon thread
steps the engine whenever it has work and parks on a condition
variable when it does not.  Producers (``add_request`` / ``cancel``)
and any other engine access go through the pump's lock, so the engine
itself stays single-threaded -- exactly one thread is ever inside
``step()``, jax dispatch included.

Streams still work while the pump runs: the pump replaces each
request's pull-pump with a blocking wait on the same condition, so a
consumer iterating a stream sleeps until the pump thread delivers the
next token instead of stepping the engine from a second thread.

The pump also records a per-step timeline -- ``(start, duration,
occupancy)`` samples -- which is what the loadgen report integrates
into time-weighted occupancy (idle wall time counts as zero, unlike
the engine's per-step occupancy series).

Usage::

    with EnginePump(engine) as pump:
        st = pump.add_request(prompt, SamplingParams(...))
        ...                      # arrivals paced in real time
        pump.drain(timeout=30)   # block until idle
    report = engine.metrics_json()
"""
from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Tuple

from repro.serve.engine import LLMEngine
from repro.serve.request import RequestState


class EnginePump:
    """Background stepping driver (see module docstring).

    ``idle_wait_s`` bounds how long the pump thread parks between
    wakeup checks when the engine is empty; submissions notify the
    condition, so the practical wakeup latency is the notify, not the
    timeout.
    """

    def __init__(self, engine: LLMEngine, *,
                 clock: Callable[[], float] = time.perf_counter,
                 idle_wait_s: float = 0.02):
        self.engine = engine
        self._clock = clock
        self._idle_wait_s = idle_wait_s
        # RLock: on_token callbacks fired from inside step() may call
        # back into engine.cancel() on the pump thread
        self._work = threading.Condition(threading.RLock())
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.steps = 0
        # (step start, step duration, occupancy after admission) --
        # the loadgen report integrates these over wall time
        self.samples: List[Tuple[float, float, float]] = []

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "EnginePump":
        if self._thread is not None:
            raise RuntimeError("pump already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="engine-pump", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        if self._thread is None:
            return
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._thread.join(timeout)
        if self._thread.is_alive():      # pragma: no cover - watchdog
            raise RuntimeError("pump thread did not stop")
        self._thread = None

    def __enter__(self) -> "EnginePump":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _loop(self) -> None:
        while True:
            with self._work:
                if self._stop:
                    return
                if not self.engine.has_unfinished():
                    self._work.wait(self._idle_wait_s)
                    continue
                t0 = self._clock()
                self.engine.step()
                dur = self._clock() - t0
                occ = self.engine.metrics.occupancy_series
                self.samples.append((t0, dur, occ[-1] if occ else 0.0))
                self.steps += 1
                # wake drain() and any stream consumers
                self._work.notify_all()

    # -- producer side (all engine access goes through the lock) ----------
    def add_request(self, prompt, params=None, **kw) -> RequestState:
        """Thread-safe ``engine.add_request``; the returned state's
        stream blocks on the pump instead of stepping the engine."""
        with self._work:
            st = self.engine.add_request(prompt, params, **kw)
            st.stream._pump = self._stream_wait
            self._work.notify_all()
            return st

    def cancel(self, request_id: str) -> bool:
        with self._work:
            return self.engine.cancel(request_id)

    def run_locked(self, fn: Callable[[], object]):
        """Run ``fn()`` under the pump lock -- e.g. submit-and-cancel
        atomically so the pump thread cannot decode a token in
        between (deterministic cancel-while-queued)."""
        with self._work:
            out = fn()
            self._work.notify_all()
            return out

    def metrics_json(self):
        with self._work:
            return self.engine.metrics_json()

    # -- consumers ---------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the engine has no unfinished work; True when it
        drained, False on timeout (work still pending)."""
        deadline = None if timeout is None else self._clock() + timeout
        with self._work:
            while self.engine.has_unfinished():
                if self._stop:
                    return not self.engine.has_unfinished()
                wait = self._idle_wait_s
                if deadline is not None:
                    wait = min(wait, deadline - self._clock())
                    if wait <= 0:
                        return False
                self._work.wait(wait)
            return True

    def _stream_wait(self) -> bool:
        """Installed as the pull-pump of streams submitted through the
        pump: park until the pump thread makes progress.  Returns False
        only when the pump is stopped (the stream can then never be
        fed, matching the RequestStream stall contract)."""
        with self._work:
            if self._stop:
                return False
            self._work.wait(self._idle_wait_s)
            return True
