"""Pluggable request scheduling over decode slots.

The Scheduler owns WHO runs WHERE: the waiting queue, the slot table,
admission of queued requests into free slots, eviction of finished
ones, and cancellation.  The ``EngineCore`` owns WHAT runs (device
state and dispatches) and never sees a queue; the ``LLMEngine`` wires
the two together and keeps metrics/streams.

Policies override ``_pick`` (which waiting request takes the next free
slot).  ``FCFSScheduler`` is the default; ``PriorityScheduler`` serves
higher ``Request.priority`` first with FCFS tie-breaking.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Optional, Tuple, Type, Union

from repro.serve.request import RequestState, RequestStatus


class Scheduler:
    """Base admission/eviction/cancellation bookkeeping (policy-free)."""

    def __init__(self, max_batch: int):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.slots: List[Optional[RequestState]] = [None] * max_batch
        self.waiting: Deque[RequestState] = deque()

    # -- policy hook ------------------------------------------------------
    def _pick(self) -> RequestState:
        raise NotImplementedError

    # -- queue ------------------------------------------------------------
    def add(self, state: RequestState) -> None:
        state.status = RequestStatus.QUEUED
        self.waiting.append(state)

    @property
    def queue_depth(self) -> int:
        return len(self.waiting)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    def live(self) -> List[Tuple[int, RequestState]]:
        return [(i, s) for i, s in enumerate(self.slots) if s is not None]

    def outstanding(self) -> List[str]:
        """Request ids still queued or seated.  The loadgen drain check
        (and ``LLMEngine.run``'s step-budget diagnostics) use this to
        name exactly which requests a truncated run left behind; an
        empty list == the slot table and queue are both clean."""
        return ([s.request_id for s in self.waiting]
                + [s.request_id for s in self.slots if s is not None])

    # -- admission / eviction --------------------------------------------
    def schedule(self) -> List[Tuple[int, RequestState]]:
        """Fill free slots from the queue (policy order); returns the
        admissions made this call as ``(slot, state)`` pairs."""
        admitted: List[Tuple[int, RequestState]] = []
        for i in range(self.max_batch):
            if self.slots[i] is None and self.waiting:
                state = self._pick()
                state.slot = i
                self.slots[i] = state
                admitted.append((i, state))
        return admitted

    def release(self, state: RequestState) -> int:
        """Evict ``state`` from its slot (finish, length, or cancel);
        returns the freed slot index so the engine can clear the core."""
        i = state.slot
        if i is None or self.slots[i] is not state:
            raise ValueError(
                f"request {state.request_id} does not hold a slot")
        self.slots[i] = None
        state.slot = None
        return i

    # -- cancellation -----------------------------------------------------
    def cancel(self, request_id: str) -> Optional[RequestState]:
        """Locate a request by id.  Queued requests are dequeued here;
        in-flight ones are returned still holding their slot (the
        caller releases + clears the core).  Unknown/finished -> None.
        """
        for idx, state in enumerate(self.waiting):
            if state.request_id == request_id:
                del self.waiting[idx]
                return state
        for state in self.slots:
            if state is not None and state.request_id == request_id:
                return state
        return None


class FCFSScheduler(Scheduler):
    """First come, first served (the default policy)."""

    def _pick(self) -> RequestState:
        return self.waiting.popleft()


class PriorityScheduler(Scheduler):
    """Highest ``Request.priority`` first; FCFS within a priority."""

    def _pick(self) -> RequestState:
        best = max(range(len(self.waiting)),
                   key=lambda i: self.waiting[i].request.priority)
        state = self.waiting[best]
        del self.waiting[best]
        return state


class CacheAwareScheduler(Scheduler):
    """Longest cached prompt prefix first; FCFS within equal matches.

    Requests whose prefix is already in the engine's ``StateCache``
    skip (part of) their prefill, so admitting them first minimises the
    time their slot is occupied before decoding starts -- hits free
    slots fastest, which drains the queue fastest.  ``cached_len`` is
    the match length the engine recorded at ``add_request`` time (0
    when the prefix cache is off, making this policy degrade to FCFS).
    """

    def _pick(self) -> RequestState:
        best = max(range(len(self.waiting)),
                   key=lambda i: self.waiting[i].cached_len)
        state = self.waiting[best]
        del self.waiting[best]
        return state


SCHEDULERS = {"fcfs": FCFSScheduler, "priority": PriorityScheduler,
              "cache-aware": CacheAwareScheduler}


def make_scheduler(policy: Union[str, Scheduler, Type[Scheduler], None],
                   max_batch: int) -> Scheduler:
    """Resolve a policy name / class / ready instance to a Scheduler."""
    if policy is None:
        policy = "fcfs"
    if isinstance(policy, Scheduler):
        if policy.max_batch != max_batch:
            raise ValueError(
                f"scheduler was built for max_batch={policy.max_batch}, "
                f"engine has max_batch={max_batch}")
        return policy
    if isinstance(policy, type) and issubclass(policy, Scheduler):
        return policy(max_batch)
    if isinstance(policy, str):
        if policy not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler policy {policy!r}; "
                f"available: {sorted(SCHEDULERS)}")
        return SCHEDULERS[policy](max_batch)
    raise TypeError(f"cannot build a scheduler from {policy!r}")
