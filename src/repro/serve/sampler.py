"""Token samplers for the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(key: jax.Array, logits: jax.Array, temperature) -> jax.Array:
    """Greedy when temperature <= 0 (per-row), else temperature sampling.

    logits (B, V); temperature scalar or (B,).
    """
    temps = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32),
                             logits.shape[:1])
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(temps, 1e-4)[:, None]
    sampled = jax.random.categorical(key, scaled, axis=-1)
    return jnp.where(temps <= 0.0, greedy, sampled).astype(jnp.int32)
