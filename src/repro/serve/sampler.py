"""Batched token samplers: greedy / temperature / top-k / top-p.

``sample_batched`` takes PER-ROW parameter arrays so a single jitted
dispatch serves a continuous batch of heterogeneous requests -- each
decode slot carries its own ``SamplingParams`` and its own PRNG key.
All truncation happens on the temperature-scaled logits; ``top_k=0``
and ``top_p=1.0`` disable the respective mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_top_k_top_p(logits: jax.Array, top_k: jax.Array,
                      top_p: jax.Array) -> jax.Array:
    """Mask logits outside the per-row top-k / nucleus-p set to -inf.

    logits (B, V); ``top_k`` int (B,), 0 or >= V disables; ``top_p``
    float (B,) in (0, 1], 1.0 disables.  The nucleus keeps the smallest
    prefix of the probability-sorted vocabulary whose mass reaches
    ``top_p`` (a token stays while the mass BEFORE it is < p).  The
    highest-probability token always survives, so no row is ever
    all -inf.
    """
    v = logits.shape[-1]
    top_k = jnp.asarray(top_k, jnp.int32)
    top_p = jnp.asarray(top_p, jnp.float32)
    k_eff = jnp.where((top_k <= 0) | (top_k > v), v, top_k)
    order = jnp.argsort(-logits, axis=-1)               # descending
    ranked = jnp.take_along_axis(logits, order, axis=-1)
    ranks = jnp.arange(v)[None, :]
    keep = ranks < k_eff[:, None]
    probs = jax.nn.softmax(ranked, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < top_p[:, None]
    keep |= ranks == 0
    ranked = jnp.where(keep, ranked, -jnp.inf)
    inv = jnp.argsort(order, axis=-1)                   # undo the sort
    return jnp.take_along_axis(ranked, inv, axis=-1)


def sample_batched(keys: jax.Array, logits: jax.Array, temps: jax.Array,
                   top_k: jax.Array, top_p: jax.Array,
                   truncate: bool = True) -> jax.Array:
    """One token per row from per-row sampling configs.

    ``keys`` (B, 2) uint32 -- one PRNG key per slot, so request sample
    streams are independent of batch composition.  Rows with
    ``temps <= 0`` take the argmax; the rest sample from the
    temperature-scaled, top-k/top-p-truncated distribution.

    ``truncate`` must be a PYTHON bool (jit-static): False skips the
    top-k/top-p masking work entirely -- callers that know every row
    has truncation disabled (the all-greedy/plain-temperature hot path)
    avoid two (B, V) argsorts per decoded token.
    """
    temps = jnp.asarray(temps, jnp.float32)
    greedy_tok = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(temps, 1e-4)[:, None]
    masked = apply_top_k_top_p(scaled, top_k, top_p) if truncate \
        else scaled
    sampled = jax.vmap(jax.random.categorical)(keys, masked)
    return jnp.where(temps <= 0.0, greedy_tok, sampled).astype(jnp.int32)


def sample(key: jax.Array, logits: jax.Array, temperature) -> jax.Array:
    """Pre-PR-4 shim: greedy when temperature <= 0 (per-row), else
    plain temperature sampling.  New callers use ``sample_batched``.

    logits (B, V); temperature scalar or (B,).
    """
    b = logits.shape[0]
    temps = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (b,))
    keys = jax.random.split(key, b)
    return sample_batched(keys, logits, temps,
                          jnp.zeros((b,), jnp.int32),
                          jnp.ones((b,), jnp.float32), truncate=False)
