"""Batched serving engine with continuous batching over decode slots.

The engine owns a fixed-capacity decode state (the model's KV/SSM state
for ``max_batch`` slots).  Requests join free slots; every ``step()``
decodes one token for all live slots; finished sequences free their slot
immediately so queued requests start without waiting for the batch to
drain (continuous batching).

Prefill: for families with a sequence prefill path (recurrent state +
h0/h_last carry -- see ``repro.models.prefill_step``) the prompt is fed
in chunks of ``prefill_chunk`` tokens, one dispatch per chunk, against a
batch-1 slice of the slot's state -- O(num_chunks) dispatches instead of
O(prompt_len) full-batch decode steps.  Other families fall back to the
per-token decode path, so quantized execution (Quamba qctx) stays
identical between prefill and generation either way.

Decode-loop host overhead: per-slot bookkeeping lives in host numpy
mirrors; the device-side token/temperature tensors are refreshed only
when slot membership changes, and each step issues exactly one
device_get (the sampled tokens).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_decode_state, prefill_step, \
    supports_seq_prefill
from repro.models.model import merge_slot, reset_slot, slice_slot, \
    write_slot
from repro.quant.recipe import prefill_chunk_safe
from repro.serve.sampler import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, qctx=None, seed: int = 0,
                 cache_dtype=None, prefill_chunk: int = 128,
                 shard: Optional[bool] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.params = params
        self.cfg = cfg
        self.qctx = qctx
        self.max_batch = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        if cache_dtype is None:
            # QuantSpec.quantize_kv_cache flows through the qctx: int8
            # attention caches with per-entry scales (see models.attention)
            spec = qctx.get("spec") if isinstance(qctx, dict) else None
            kv8 = spec is not None and getattr(spec, "quantize_kv_cache",
                                               False)
            cache_dtype = jnp.int8 if kv8 else jnp.float32
        self.cache_dtype = jnp.dtype(cache_dtype)
        self.state = init_decode_state(cfg, max_batch, max_len,
                                       cache_dtype=cache_dtype)
        # data-parallel slot sharding: with >1 device the decode slots
        # spread over a host mesh's data axis (repro.dist.sharding rules)
        # and the weights replicate -- each device decodes its share of
        # the batch.  shard=None auto-enables when divisible; shard=True
        # insists; shard=False keeps everything single-device.
        self.mesh = None
        n_dev = len(jax.devices())
        if shard is None:
            shard = n_dev > 1 and max_batch % n_dev == 0
        if shard:
            from repro.dist.sharding import (decode_state_shardings,
                                             replicate_shardings)
            from repro.launch.mesh import make_host_mesh
            if max_batch % n_dev != 0:
                raise ValueError(
                    f"shard=True needs max_batch ({max_batch}) divisible "
                    f"by the device count ({n_dev})")
            self.mesh = make_host_mesh()
            st_sh = decode_state_shardings(
                jax.eval_shape(lambda: self.state), self.mesh, cfg)
            self.state = jax.device_put(self.state, st_sh)
            self.params = jax.device_put(
                params, replicate_shardings(
                    jax.eval_shape(lambda: params), self.mesh))
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        # slot-local positions (the global state["pos"] advances for all
        # slots; per-slot bookkeeping is host-side)
        self._step_fn = jax.jit(self._one_step)
        # chunked prefill requires a sequence path AND chunk-invariant
        # quantization scales (see recipe.prefill_chunk_safe): per-call
        # scales only match per-token stepping when fed token by token
        spec_m = qctx.get("spec") if isinstance(qctx, dict) else None
        self._prefill_fn = (jax.jit(self._one_prefill)
                            if supports_seq_prefill(cfg)
                            and prefill_chunk_safe(spec_m) else None)
        # host mirrors of the per-slot decode inputs; the device copies
        # are only rebuilt when a slot joins or leaves (``_dirty``)
        self._next_host = np.zeros((max_batch,), np.int32)
        self._temps_host = np.zeros((max_batch,), np.float32)
        self._next_dev = jnp.zeros((max_batch,), jnp.int32)
        self._temps_dev = jnp.zeros((max_batch,), jnp.float32)
        self._dirty = False
        # dispatch accounting (benchmarks / tests)
        self.counters: Dict[str, int] = {"prefill_dispatches": 0,
                                         "decode_steps": 0}

    # -- jitted cores -----------------------------------------------------
    def _one_step(self, params, state, tokens, key, temps):
        logits, new_state = decode_step(params, self.cfg, state, tokens,
                                        qctx=self.qctx)
        toks = sample(key, logits, temps)
        return toks, logits, new_state

    def _one_prefill(self, params, slot_state, tokens):
        _, new_state = prefill_step(params, self.cfg, slot_state, tokens,
                                    qctx=self.qctx)
        return new_state

    # -- API --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError(
                f"request {req.uid} has an empty prompt; every request "
                "needs at least one prompt token")
        self.queue.append(req)

    def _set_next(self, i: int, tok: int) -> None:
        self._next_host[i] = tok
        self._dirty = True

    @staticmethod
    def _chunk_plan(n: int, chunk: int) -> List[int]:
        """Split ``n`` prompt tokens into full ``chunk``-sized pieces plus
        a power-of-two binary decomposition of the remainder, so the
        jitted prefill compiles at most log2(chunk)+2 distinct shapes
        regardless of the prompt-length mix (vs one compile per distinct
        remainder length)."""
        sizes = [chunk] * (n // chunk)
        rem = n % chunk
        while rem:
            p = 1 << (rem.bit_length() - 1)
            sizes.append(p)
            rem -= p
        return sizes

    def _prefill(self, i: int, req: Request) -> None:
        """Advance slot ``i``'s state over ``req.prompt[:-1]``."""
        toks = req.prompt[:-1]
        if toks and self._prefill_fn is not None:
            # chunked sequence prefill on a batch-1 slice of the state:
            # O(num_chunks) dispatches, none of them full-batch
            slot_state = slice_slot(self.cfg, self.state, i)
            c0 = 0
            for size in self._chunk_plan(len(toks), self.prefill_chunk):
                chunk = jnp.asarray([toks[c0:c0 + size]], jnp.int32)
                c0 += size
                slot_state = self._prefill_fn(self.params, slot_state,
                                              chunk)
                self.counters["prefill_dispatches"] += 1
            self.state = write_slot(self.cfg, self.state, slot_state, i)
        else:
            # fallback: per-token decode dispatches (attention families)
            for t in toks:
                tok = self._next_dev.at[i].set(t)
                self.key, k = jax.random.split(self.key)
                _, _, new_state = self._step_fn(
                    self.params, self.state, tok, k, self._temps_dev)
                self.counters["prefill_dispatches"] += 1
                # only slot i's state advances during its prefill
                self.state = merge_slot(self.cfg, self.state, new_state,
                                        i)
        self._set_next(i, req.prompt[-1])

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.state = reset_slot(self.cfg, self.state, i)
                self._temps_host[i] = req.temperature
                self._dirty = True
                self._prefill(i, req)

    def _sync_device_inputs(self) -> None:
        if self._dirty:
            self._next_dev = jnp.asarray(self._next_host)
            self._temps_dev = jnp.asarray(self._temps_host)
            self._dirty = False

    def step(self) -> None:
        """Decode one token for all live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return
        self._sync_device_inputs()
        self.key, k = jax.random.split(self.key)
        toks, _, self.state = self._step_fn(
            self.params, self.state, self._next_dev, k, self._temps_dev)
        self.counters["decode_steps"] += 1
        toks_host = np.asarray(jax.device_get(toks))
        # sampled tokens feed the next step directly (no per-slot device
        # updates); freed slots keep a stale token, which is harmless --
        # their state is reset at the next admit
        self._next_dev = toks
        self._next_host[:] = toks_host
        for i in live:
            req = self.slots[i]
            tok = int(toks_host[i])
            req.output.append(tok)
            if (len(req.output) >= req.max_new_tokens or
                    (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                self.slots[i] = None       # free slot -> continuous batching
                self._temps_host[i] = 0.0
                self._dirty = True

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()


def generate(params, cfg: ModelConfig, prompts: List[List[int]], *,
             max_new_tokens: int = 32, temperature: float = 0.0,
             qctx=None, max_len: int = 2048,
             prefill_chunk: int = 128) -> List[List[int]]:
    """Convenience batch generation through the engine."""
    if not prompts:
        raise ValueError("prompts is empty: pass at least one prompt")
    for i, p in enumerate(prompts):
        if not p:
            raise ValueError(
                f"prompts[{i}] is empty; every prompt needs at least one "
                "token")
    eng = Engine(params, cfg, max_batch=min(8, len(prompts)),
                 max_len=max_len, qctx=qctx, prefill_chunk=prefill_chunk)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new_tokens,
                    temperature=temperature)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.output for r in reqs]
