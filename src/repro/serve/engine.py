"""Batched serving engine with continuous batching over decode slots.

The engine owns a fixed-capacity decode state (the model's KV/SSM state
for ``max_batch`` slots).  Requests join free slots; every ``step()``
decodes one token for all live slots; finished sequences free their slot
immediately so queued requests start without waiting for the batch to
drain (continuous batching).  Prefill runs through the same decode path
(a lax.scan over prompt tokens), so quantized execution (Quamba qctx) is
identical between prefill and generation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_decode_state
from repro.models.model import merge_slot, reset_slot
from repro.serve.sampler import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, qctx=None, seed: int = 0,
                 cache_dtype=None):
        self.params = params
        self.cfg = cfg
        self.qctx = qctx
        self.max_batch = max_batch
        self.max_len = max_len
        if cache_dtype is None:
            # QuantSpec.quantize_kv_cache flows through the qctx: int8
            # attention caches with per-entry scales (see models.attention)
            spec = qctx.get("spec") if isinstance(qctx, dict) else None
            kv8 = spec is not None and getattr(spec, "quantize_kv_cache",
                                               False)
            cache_dtype = jnp.int8 if kv8 else jnp.float32
        self.cache_dtype = jnp.dtype(cache_dtype)
        self.state = init_decode_state(cfg, max_batch, max_len,
                                       cache_dtype=cache_dtype)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        # slot-local positions (the global state["pos"] advances for all
        # slots; per-slot bookkeeping is host-side)
        self._step_fn = jax.jit(self._one_step)
        self._next_tokens = jnp.zeros((max_batch,), jnp.int32)

    # -- jitted core ------------------------------------------------------
    def _one_step(self, params, state, tokens, key, temps):
        logits, new_state = decode_step(params, self.cfg, state, tokens,
                                        qctx=self.qctx)
        toks = sample(key, logits, temps)
        return toks, logits, new_state

    # -- API --------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.state = reset_slot(self.cfg, self.state, i)
                # prefill: feed prompt tokens through the decode path for
                # this slot (other slots get pad token but their state is
                # masked by position bookkeeping at this scale of engine).
                for t in req.prompt[:-1]:
                    tok = self._next_tokens.at[i].set(t)
                    self.key, k = jax.random.split(self.key)
                    _, _, new_state = self._step_fn(
                        self.params, self.state, tok, k,
                        jnp.zeros((self.max_batch,)))
                    # only slot i's state advances during its prefill
                    self.state = merge_slot(self.cfg, self.state,
                                            new_state, i)
                self._next_tokens = self._next_tokens.at[i].set(
                    req.prompt[-1])

    def step(self) -> None:
        """Decode one token for all live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return
        self.key, k = jax.random.split(self.key)
        temps = jnp.asarray([
            (self.slots[i].temperature if self.slots[i] else 0.0)
            for i in range(self.max_batch)], jnp.float32)
        toks, _, self.state = self._step_fn(
            self.params, self.state, self._next_tokens, k, temps)
        toks_host = jax.device_get(toks)
        for i in live:
            req = self.slots[i]
            tok = int(toks_host[i])
            req.output.append(tok)
            if (len(req.output) >= req.max_new_tokens or
                    (req.eos_id is not None and tok == req.eos_id)):
                req.done = True
                self.slots[i] = None       # free slot -> continuous batching
            else:
                self._next_tokens = self._next_tokens.at[i].set(tok)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()


def generate(params, cfg: ModelConfig, prompts: List[List[int]], *,
             max_new_tokens: int = 32, temperature: float = 0.0,
             qctx=None, max_len: int = 2048) -> List[List[int]]:
    """Convenience batch generation through the engine."""
    eng = Engine(params, cfg, max_batch=min(8, len(prompts)),
                 max_len=max_len, qctx=qctx)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new_tokens,
                    temperature=temperature)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return [r.output for r in reqs]
