"""Request-centric serving engine (continuous batching over decode slots).

Layering::

    LLMEngine  -- request lifecycle, streams, metrics
      Scheduler   (repro.serve.scheduler)  WHO runs WHERE: queue, slots,
                                           admission/eviction/cancel
      EngineCore  (repro.serve.core)       WHAT runs: device state,
                                           prefill/decode dispatches
      StateCache  (repro.serve.cache)      prompt prefixes -> slot state
      Metrics     (repro.serve.metrics)    TTFT/TPOT/queue/occupancy

Requests enter via ``add_request(prompt, SamplingParams(...))`` and move
QUEUED -> PREFILLING -> DECODING -> FINISHED(stop | length | cancelled).
Every ``step()`` decodes one token for all live slots and returns
``RequestOutput`` snapshots; finished sequences free their slot
immediately so queued requests start without waiting for the batch to
drain.  Tokens stream incrementally through each request's
``RequestStream`` (iterating a stream pumps the engine).

``prefix_cache_mb`` enables prefix state caching: prefilled prompt
prefixes are snapshotted (O(1) recurrent state per sequence -- the SSM
advantage) and requests sharing a cached prefix restore it instead of
re-prefilling; a full hit admits straight to DECODING with zero prefill
dispatches.  The default scheduler becomes cache-aware (hits first)
when the cache is on; pass ``scheduler=`` explicitly to override.
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, List, Optional, Sequence, Union

from repro.configs.base import ModelConfig
from repro.serve.cache import StateCache
from repro.serve.core import EngineCore
from repro.serve.metrics import Metrics, REQUEST_CAP, evict_finished
from repro.serve.params import SamplingParams
from repro.serve.request import (FinishReason, Request, RequestOutput,
                                 RequestState, RequestStatus,
                                 RequestStream)
from repro.serve.scheduler import Scheduler, make_scheduler
from repro.serve.spec import SpecConfig


class StepBudgetExhausted(RuntimeError):
    """``LLMEngine.run`` ran out of steps with requests unfinished.

    A load-generated run that quietly truncates invalidates its SLO
    report, so exhaustion raises by default (``on_exhaust="warn"``
    downgrades it); either way ``metrics_json()["engine"]
    ["run_budget_exhausted"]`` records the event.  The engine is left
    in a consistent state -- calling ``run`` again resumes."""


class LLMEngine:
    def __init__(self, params, cfg: ModelConfig, *, max_batch: int = 8,
                 max_len: int = 2048, qctx=None, seed: int = 0,
                 cache_dtype=None, prefill_chunk: int = 128,
                 shard: Optional[bool] = None,
                 scheduler: Union[str, Scheduler, None] = None,
                 prefix_cache_mb: Optional[float] = None,
                 prefix_cache_spill_mb: Optional[float] = None,
                 speculative: Optional[SpecConfig] = None,
                 clock=time.monotonic):
        self.core = EngineCore(params, cfg, max_batch=max_batch,
                               max_len=max_len, qctx=qctx, seed=seed,
                               cache_dtype=cache_dtype,
                               prefill_chunk=prefill_chunk, shard=shard,
                               speculative=speculative)
        self.prefix_cache: Optional[StateCache] = None
        if prefix_cache_mb is not None and prefix_cache_mb > 0:
            spill_mb = prefix_cache_spill_mb or 0
            self.prefix_cache = StateCache(
                byte_budget=int(prefix_cache_mb * (1 << 20)),
                spill_byte_budget=int(spill_mb * (1 << 20)),
                to_host=self.core.tree_to_host,
                to_device=self.core.tree_to_device)
        elif prefix_cache_spill_mb:
            raise ValueError(
                "prefix_cache_spill_mb needs prefix_cache_mb > 0: the "
                "spill tier extends the device cache, it cannot replace "
                "it")
        if scheduler is None:
            scheduler = ("cache-aware" if self.prefix_cache is not None
                         else "fcfs")
        self.scheduler = make_scheduler(scheduler, max_batch)
        self.metrics = Metrics(clock=clock)
        self._states: Dict[str, RequestState] = {}
        self._admitted = 0          # PRNG salt for seedless requests

    # -- convenience views (also the QuantizedModel.engine() surface) -----
    @property
    def cfg(self) -> ModelConfig:
        return self.core.cfg

    @property
    def max_batch(self) -> int:
        return self.core.max_batch

    @property
    def state(self):
        return self.core.state

    @property
    def cache_dtype(self):
        return self.core.cache_dtype

    @property
    def mesh(self):
        return self.core.mesh

    @property
    def counters(self) -> Dict[str, int]:
        return self.core.counters

    @property
    def _prefill_fn(self):
        return self.core._prefill_fn

    _chunk_plan = staticmethod(EngineCore._chunk_plan)

    # -- request lifecycle -------------------------------------------------
    def add_request(self, prompt, params: Optional[SamplingParams] = None,
                    *, request_id: Optional[str] = None, priority: int = 0,
                    on_token=None) -> RequestState:
        """Queue a request; returns its live ``RequestState`` (whose
        ``.stream`` delivers tokens incrementally and whose
        ``.token_ids`` accumulate).  ``prompt`` is a token-id sequence
        or a ready ``Request``."""
        if isinstance(prompt, Request):
            if (params is not None or request_id is not None
                    or priority != 0):
                raise ValueError(
                    "pass sampling params / request_id / priority on "
                    "the Request itself when submitting a ready "
                    "Request object")
            req = prompt
        else:
            req = Request(list(prompt), params, request_id=request_id,
                          priority=priority)
        if req.request_id in self._states:
            raise ValueError(
                f"duplicate request_id {req.request_id!r}")
        state = RequestState(request=req)
        state.stream = RequestStream(req.request_id, pump=self._pump,
                                     on_token=on_token)
        if self.prefix_cache is not None:
            # admission-ordering hint only (no counters, no LRU bump);
            # the authoritative match happens at seat time
            state.cached_len = self.prefix_cache.peek_len(req.prompt)
        self._states[req.request_id] = state
        self.scheduler.add(state)
        state.arrival_time = self.metrics.on_submit(
            req.request_id, len(req.prompt), req.priority)
        return state

    def request_state(self, request_id: str) -> RequestState:
        return self._states[request_id]

    def stream(self, request_id: str) -> RequestStream:
        return self._states[request_id].stream

    def cancel(self, request_id: str) -> bool:
        """Cancel a queued or in-flight request; returns False for
        unknown/already-finished ids.  A queued request never touches a
        slot; an in-flight one is evicted at this step boundary and
        keeps the tokens produced so far."""
        state = self.scheduler.cancel(request_id)
        if state is None:
            return False
        if state.slot is not None:
            slot = self.scheduler.release(state)
            self.core.clear_slot(slot)
        self._finish(state, FinishReason.CANCELLED)
        return True

    def _finish(self, state: RequestState, reason: FinishReason) -> None:
        state.status = RequestStatus.FINISHED
        state.finish_reason = reason
        state.request.done = True
        state.finish_time = self.metrics.on_finish(state.request_id,
                                                   reason.value)
        state.stream.close()
        evict_finished(self._states, REQUEST_CAP,
                       lambda st: st.finished)

    # -- stepping ----------------------------------------------------------
    def step(self) -> List[RequestOutput]:
        """Admit queued requests into free slots (scheduler policy),
        prefill them, then decode one token for every live slot.  With
        nothing queued and nothing live this is a strict no-op: no
        dispatch, no counters, no metrics samples."""
        for slot, state in self.scheduler.schedule():
            prompt = state.request.prompt
            entry = None
            on_prefix = None
            if self.prefix_cache is not None:
                entry = self.prefix_cache.lookup(prompt)

                def on_prefix(consumed, tree, _p=tuple(prompt)):
                    self.prefix_cache.insert(_p[:consumed], tree)
            k = len(entry.tokens) if entry is not None else 0
            state.cached_len = k
            # seat() is synchronous, so PREFILLING is never observable
            # from outside this loop; a full hit (whole prompt head
            # cached, k == len(prompt) - 1) restores the snapshot and
            # reaches DECODING with zero prefill dispatches
            state.status = RequestStatus.PREFILLING
            state.scheduled_time = self.metrics.on_schedule(
                state.request_id, cached_tokens=k)
            self.core.seat(slot, prompt, state.request.params,
                           self._admitted,
                           prefix_state=(entry.state if entry is not None
                                         else None),
                           prefix_len=k, on_prefix=on_prefix)
            self._admitted += 1
            state.status = RequestStatus.DECODING
        live = self.scheduler.live()
        if not live:
            return []
        if self.core.spec is not None:
            return self._spec_step(live)
        toks = self.core.decode()
        self.metrics.on_step(self.scheduler.queue_depth, len(live),
                             self.core.max_batch)
        outputs: List[RequestOutput] = []
        for slot, state in live:
            if state.finished:
                # cancelled reentrantly by an earlier slot's on_token
                # callback this very step: its token is dropped
                continue
            emitted = self._emit(state, int(toks[slot]))
            outputs.append(state.snapshot(emitted))
        return outputs

    def _emit(self, state: RequestState, tok: int) -> tuple:
        """Deliver one decoded token to a request: stream it, update
        metrics, and apply the stop / max_tokens finish rules.  Returns
        the tokens actually committed (empty when a reentrant cancel
        from an earlier stream callback already finished the request
        this step)."""
        if state.finished:
            return ()
        state.request.output.append(tok)
        t = self.metrics.on_token(state.request_id)
        if state.first_token_time is None:
            state.first_token_time = t
        state.stream.put(tok)          # may reenter cancel()
        if state.finished:
            return (tok,)
        sp = state.request.params
        reason = None
        if tok in sp.stop_token_ids:
            reason = FinishReason.STOP
        elif len(state.request.output) >= sp.max_tokens:
            reason = FinishReason.LENGTH
        if reason is not None:
            freed = self.scheduler.release(state)
            self.core.clear_slot(freed)
            self._finish(state, reason)
        return (tok,)

    def _spec_step(self, live) -> List[RequestOutput]:
        """One speculative round: every live slot commits between 1 and
        ``k + 1`` tokens (its accepted draft prefix plus the
        replacement/bonus token).  A stop token, max_tokens, or a
        reentrant cancel inside the block drops the block's remaining
        tokens -- the slot is released at that boundary, exactly as a
        vanilla step would at its single token."""
        k = self.core.spec.k
        drafts, n_acc, extra = self.core.decode_spec(
            [slot for slot, _ in live])
        self.metrics.on_step(self.scheduler.queue_depth, len(live),
                             self.core.max_batch)
        outputs: List[RequestOutput] = []
        for slot, state in live:
            if state.finished:
                continue
            n = int(n_acc[slot])
            block = [int(t) for t in drafts[slot, :n]] + [int(extra[slot])]
            emitted: List[int] = []
            for tok in block:
                out = self._emit(state, tok)
                emitted.extend(out)
                if state.finished or not out:
                    break
            self.metrics.on_spec_round(state.request_id, drafted=k,
                                       accepted=n)
            outputs.append(state.snapshot(tuple(emitted)))
        return outputs

    def has_unfinished(self) -> bool:
        return self.scheduler.has_work

    def run(self, max_steps: int = 10_000, *,
            on_exhaust: str = "raise") -> None:
        """Step until drained, or until ``max_steps`` is spent.  A
        budget exhausted with requests still unfinished raises
        :class:`StepBudgetExhausted` (``on_exhaust="warn"`` downgrades
        to a warning) -- silent truncation would invalidate any
        latency/SLO numbers derived from the run.  The engine stays
        consistent either way; ``run`` again to resume."""
        if on_exhaust not in ("raise", "warn"):
            raise ValueError(
                f"on_exhaust must be 'raise' or 'warn', got "
                f"{on_exhaust!r}")
        for _ in range(max_steps):
            if not self.has_unfinished():
                return
            self.step()
        if not self.has_unfinished():
            return
        self.metrics.run_budget_exhausted += 1
        left = self.scheduler.outstanding()
        msg = (f"run(max_steps={max_steps}) exhausted its step budget "
               f"with {len(left)} request(s) unfinished "
               f"({', '.join(left[:8])}{'...' if len(left) > 8 else ''}); "
               "results are truncated -- raise max_steps or call run() "
               "again to resume")
        if on_exhaust == "raise":
            raise StepBudgetExhausted(msg)
        warnings.warn(msg, RuntimeWarning, stacklevel=2)

    def _pump(self) -> bool:
        """Stream-iteration driver: advance the engine once if it still
        has work; False tells the stream it can never be fed again."""
        if not self.has_unfinished():
            return False
        self.step()
        return True

    # -- metrics -----------------------------------------------------------
    def metrics_json(self) -> Dict:
        """Per-request TTFT/TPOT/queue-time + engine tokens/s,
        occupancy, queue-depth series, dispatch counts, and (when the
        prefix cache is on) its hit-rate/bytes/TTFT-split, as one
        JSON-safe dict.  With speculative decoding on, a
        ``spec_decode`` section carries the acceptance rate, the
        drafted/accepted/rolled-back token counters, and the
        per-request tokens-per-round speedup distribution."""
        spec = None
        if self.core.spec is not None:
            c = self.core.counters
            spec = {
                "k": self.core.spec.k,
                "draft": ("self" if self.core._draft_is_self
                          else self.core.draft_cfg.name),
                "rounds": c["spec_rounds"],
                # one fused dispatch per round (k+1 draft steps +
                # verify + acceptance + rollback); drafted-per-dispatch
                # is the batching win over k separate draft dispatches
                "dispatches": c["spec_dispatches"],
                "drafted_tokens_per_dispatch": (
                    c["drafted_tokens"] / c["spec_dispatches"]
                    if c["spec_dispatches"] else None),
                "drafted_tokens": c["drafted_tokens"],
                "accepted_tokens": c["accepted_tokens"],
                "rolled_back_tokens": c["rolled_back_tokens"],
                "acceptance_rate": (c["accepted_tokens"]
                                    / c["drafted_tokens"]
                                    if c["drafted_tokens"] else None),
            }
        return self.metrics.to_json(
            extra_counters=self.core.counters,
            prefix_cache=(self.prefix_cache.stats()
                          if self.prefix_cache is not None else None),
            spec_decode=spec)


def generate(params, cfg: ModelConfig, prompts: Sequence[Sequence[int]],
             *, max_new_tokens: int = 32, temperature: float = 0.0,
             qctx=None, max_len: int = 2048,
             prefill_chunk: int = 128) -> List[List[int]]:
    """Convenience batch generation through the engine."""
    if not prompts:
        raise ValueError("prompts is empty: pass at least one prompt")
    for i, p in enumerate(prompts):
        if not p:
            raise ValueError(
                f"prompts[{i}] is empty; every prompt needs at least one "
                "token")
    eng = LLMEngine(params, cfg, max_batch=min(8, len(prompts)),
                    max_len=max_len, qctx=qctx,
                    prefill_chunk=prefill_chunk)
    sp = SamplingParams(temperature=temperature,
                        max_tokens=max_new_tokens)
    states = [eng.add_request(list(p), sp) for p in prompts]
    eng.run()
    return [list(s.token_ids) for s in states]
