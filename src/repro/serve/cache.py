"""Prefix state caching: prompt prefixes -> per-slot decode state.

Selective-scan models carry O(1) recurrent state per sequence (SSM
hidden state + conv taps + position), so the ENTIRE effect of a prompt
prefix on future decoding is one small state tree -- unlike a KV cache
it does not grow with the prefix length.  ``StateCache`` exploits this:
after a slot prefills a prompt, the engine snapshots the slot's state
under the consumed token prefix; a later request whose prompt starts
with a cached prefix restores the snapshot (one device-side state copy)
and skips the matched part of its prefill entirely.  A shared system
prompt or few-shot template turns from O(prefix) prefill dispatches
into a dictionary lookup.

Design:

* **Keys** are token prefixes, indexed by ``(length, rolling hash)``.
  Lookup computes the prompt's rolling prefix hashes once (O(n)) and
  probes cached lengths longest-first, so the match is the LONGEST
  cached prefix; the stored token tuple is compared on every probe, so
  a hash collision can never restore the wrong state.
* **Values** are batch-1 decode-state trees from ``slice_slot`` --
  int8 or fp leaves exactly as the artifact's backend laid them out.
  jax arrays are immutable, so a snapshot is a tree of references, not
  a copy; eviction just drops the references.
* **Copy-on-write snapshot sharing** is a hard contract, not an
  accident of implementation: ``insert`` stores the caller's tree by
  reference, ``lookup`` returns THE cached tree (never a copy), and
  every consumer (``EngineCore.restore_slot`` -> ``write_slot``) reads
  it into a fresh batched state without touching the original.  N
  concurrent requests restoring the same cached prefix therefore share
  ONE set of device buffers -- zero per-restore copies, one
  ``device_put`` total even when the entry has to be promoted from the
  spill tier first.  The flip side binds callers: cached trees are
  read-only; advancing a restored slot must build new arrays (which
  every jax op does) rather than mutate leaves in place.
* **Eviction** is LRU under a byte budget (plus an entry-count cap).
  ``lookup`` refreshes recency; inserting past the budget evicts the
  least recently used entries.
* **Spill tier** (``spill_byte_budget > 0``): LRU-evicted entries are
  moved to host RAM (one ``device_get``) instead of dropped, so the
  device byte budget stops competing with decode slots for HBM.  A
  lookup that matches a spilled prefix *promotes* it back to the
  device tier (one ``device_put``); the promoted tree is immutable, so
  concurrent restores of the same prefix share it copy-on-write.  The
  host tier is itself LRU under its own byte budget; overflow there is
  a true drop.  Tree movement is injectable (``to_host`` /
  ``to_device``) and defaults to ``EngineCore.tree_to_host`` /
  ``tree_to_device`` semantics (plain ``jax.device_get`` /
  ``device_put``), which keeps the cache model-agnostic and the spill
  tier unit-testable on numpy trees.
* **Metrics**: hits (full/partial), misses, tokens reused, bytes in
  use, insert/evict counts, and the spill tier's
  spills/spilled_bytes/promotions counters -- exported via
  :meth:`stats` into the engine's ``metrics_json()['prefix_cache']``
  section.

The cache itself is model-agnostic (it never inspects the trees beyond
byte accounting); correctness of restore-then-resume is the engine's
contract: state after ``k`` prompt tokens is identical however those
``k`` tokens were chunked (sequential-scan prefill, chunk-invariant
scales -- see ``repro.quant.recipe.prefill_chunk_safe``).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

# budget accounting shares the roofline model's leaf-bytes definition
# (int8 leaves count 1 byte/elem, so an int8-KV snapshot is accounted
# at its real footprint)
from repro.dist.roofline import count_bytes as tree_nbytes

# polynomial rolling hash: h_k = (h_{k-1} * BASE + tok + 1) mod MOD.
# MOD is a Mersenne prime (2^61 - 1) so collisions across equal-length
# prefixes are ~2^-61; equality of the stored token tuple is still
# checked on every probe, so collisions cost a miss, never wrong state.
_HASH_BASE = 1_000_003
_HASH_MOD = (1 << 61) - 1


def rolling_hashes(tokens: Sequence[int]) -> List[int]:
    """``out[k]`` = hash of ``tokens[:k]`` (``out[0]`` = empty prefix)."""
    out = [0]
    h = 0
    for t in tokens:
        h = (h * _HASH_BASE + int(t) + 1) % _HASH_MOD
        out.append(h)
    return out


def prefix_hash(tokens: Sequence[int]) -> int:
    return rolling_hashes(tokens)[-1]


@dataclasses.dataclass
class CacheEntry:
    """One cached prefix: the tokens it covers and the state after them."""

    tokens: Tuple[int, ...]
    state: Dict                 # batch-1 decode-state tree (device refs)
    nbytes: int
    hits: int = 0


class StateCache:
    """LRU prefix -> decode-state cache under a byte budget.

    ``byte_budget`` bounds the summed leaf bytes of all entries; 0 (or
    negative) disables insertion entirely (every lookup misses), which
    lets callers keep one code path for cache-on/cache-off.

    ``spill_byte_budget`` > 0 turns on the host-RAM spill tier:
    device-tier LRU evictions move to host memory instead of dropping,
    and a lookup that matches a spilled prefix promotes it back (see
    the module docstring).  ``to_host`` / ``to_device`` override how
    trees cross the boundary (tests inject counters; the engine passes
    ``EngineCore.tree_to_host`` / ``tree_to_device``).
    """

    def __init__(self, byte_budget: int, max_entries: int = 1024,
                 spill_byte_budget: int = 0,
                 to_host: Optional[Callable[[Dict], Dict]] = None,
                 to_device: Optional[Callable[[Dict], Dict]] = None):
        self.byte_budget = int(byte_budget)
        self.max_entries = int(max_entries)
        self.spill_byte_budget = int(spill_byte_budget)
        self._to_host = to_host if to_host is not None else jax.device_get
        self._to_device = (to_device if to_device is not None
                           else jax.device_put)
        self._entries: "OrderedDict[Tuple[int, int], CacheEntry]" = \
            OrderedDict()
        self._len_counts: Dict[int, int] = {}   # prefix length -> #entries
        self.bytes_in_use = 0
        # host (spill) tier: same key scheme, numpy-leaved trees
        self._host: "OrderedDict[Tuple[int, int], CacheEntry]" = \
            OrderedDict()
        self._host_len_counts: Dict[int, int] = {}
        self.host_bytes_in_use = 0
        # counters (exported via stats())
        self.hits = 0               # full hits: whole prompt head cached
        self.partial_hits = 0       # matched a shorter prefix
        self.misses = 0
        self.inserted = 0
        self.evicted = 0
        self.rejected = 0           # single entry larger than the budget
        self.tokens_reused = 0      # prefill tokens skipped via restores
        self.spills = 0             # device evictions moved to host RAM
        self.spilled_bytes = 0      # cumulative bytes spilled
        self.promotions = 0         # host hits moved back to the device
        self.promoted_bytes = 0     # cumulative bytes promoted
        self.host_evicted = 0       # true drops out of the host tier

    # -- queries ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, tokens: Sequence[int]) -> bool:
        key = (len(tokens), prefix_hash(tokens))
        for tier in (self._entries, self._host):
            e = tier.get(key)
            if e is not None and e.tokens == tuple(tokens):
                return True
        return False

    def _candidate_lengths(self, limit: int) -> List[int]:
        lens = set(self._len_counts)
        lens.update(self._host_len_counts)
        return sorted((n for n in lens if n <= limit), reverse=True)

    def peek_len(self, prompt: Sequence[int]) -> int:
        """Length of the longest cached prefix usable for ``prompt``
        (at most ``len(prompt) - 1`` -- the last prompt token is always
        fed as the first decode input).  No counters, no LRU bump, no
        promotion: the scheduler calls this to order admissions without
        perturbing the cache."""
        e, _ = self._match(prompt)
        return len(e.tokens) if e is not None else 0

    def _match(self, prompt: Sequence[int]
               ) -> Tuple[Optional[CacheEntry], bool]:
        """Longest usable prefix across BOTH tiers -> ``(entry,
        is_spilled)``.  At equal length the device tier wins (no
        promotion cost)."""
        limit = len(prompt) - 1
        if limit <= 0 or not (self._entries or self._host):
            return None, False
        hs = rolling_hashes(prompt[:limit])
        for n in self._candidate_lengths(limit):
            e = self._entries.get((n, hs[n]))
            if e is not None and e.tokens == tuple(prompt[:n]):
                return e, False
            e = self._host.get((n, hs[n]))
            if e is not None and e.tokens == tuple(prompt[:n]):
                return e, True
        return None, False

    def lookup(self, prompt: Sequence[int]) -> Optional[CacheEntry]:
        """Longest-prefix-match for ``prompt`` with accounting: bumps
        LRU recency and the hit/miss counters.  Returns the entry (its
        ``.tokens`` tell the caller how much prefill to skip) or None.
        A *full* hit covers ``len(prompt) - 1`` tokens: the request can
        go straight to decoding.  A match in the spill tier is promoted
        back to the device tier first, so the returned ``.state`` is
        always device-resident.

        Copy-on-write: the returned ``.state`` is the cached tree
        itself, by reference -- repeated lookups of the same prefix
        hand out the SAME leaves, concurrent restores share them, and
        a promotion pays its one ``device_put`` only once.  Callers
        must treat the tree as read-only (see the module docstring)."""
        e, spilled = self._match(prompt)
        if e is None:
            self.misses += 1
            return None
        if spilled:
            e = self._promote(e)
        key = (len(e.tokens), prefix_hash(e.tokens))
        self._entries.move_to_end(key)
        e.hits += 1
        self.tokens_reused += len(e.tokens)
        if len(e.tokens) == len(prompt) - 1:
            self.hits += 1
        else:
            self.partial_hits += 1
        return e

    def _promote(self, host_e: CacheEntry) -> CacheEntry:
        """Move a spilled entry back to the device tier (one
        ``device_put``); the device tier may evict -- and re-spill --
        its own LRU to make room."""
        key = (len(host_e.tokens), prefix_hash(host_e.tokens))
        self._host_drop(key)
        e = CacheEntry(tokens=host_e.tokens,
                       state=self._to_device(host_e.state),
                       nbytes=host_e.nbytes, hits=host_e.hits)
        self.promotions += 1
        self.promoted_bytes += e.nbytes
        self._admit(key, e)
        return e

    # -- mutation ---------------------------------------------------------
    def insert(self, tokens: Sequence[int], state: Dict) -> bool:
        """Cache ``state`` as the decode state after ``tokens``.
        Refreshes recency if the prefix is already cached.  Returns
        True when a NEW entry was stored."""
        tokens = tuple(int(t) for t in tokens)
        if not tokens or self.byte_budget <= 0:
            return False
        key = (len(tokens), prefix_hash(tokens))
        prev = self._entries.get(key)
        if prev is not None and prev.tokens == tokens:
            self._entries.move_to_end(key)
            return False
        nbytes = tree_nbytes(state)
        if nbytes > self.byte_budget:
            self.rejected += 1
            return False
        if prev is not None:        # same-length hash collision: replace
            self._drop(key)
        if key in self._host:       # fresh device copy supersedes a
            self._host_drop(key)    # stale (or colliding) spilled one
        self._admit(key, CacheEntry(tokens=tokens, state=state,
                                    nbytes=nbytes))
        self.inserted += 1
        return True

    def _admit(self, key: Tuple[int, int], e: CacheEntry) -> None:
        """Store ``e`` in the device tier and run LRU eviction; each
        eviction spills to the host tier when one is configured."""
        self._entries[key] = e
        n = len(e.tokens)
        self._len_counts[n] = self._len_counts.get(n, 0) + 1
        self.bytes_in_use += e.nbytes
        while (self.bytes_in_use > self.byte_budget
               or len(self._entries) > self.max_entries):
            oldest = next(iter(self._entries))
            dropped = self._drop(oldest)
            self.evicted += 1
            self._spill(oldest, dropped)

    def _spill(self, key: Tuple[int, int], e: CacheEntry) -> None:
        if self.spill_byte_budget <= 0 or e.nbytes > self.spill_byte_budget:
            return
        if key in self._host:       # same-length hash collision: replace
            self._host_drop(key)
        self._host[key] = CacheEntry(tokens=e.tokens,
                                     state=self._to_host(e.state),
                                     nbytes=e.nbytes, hits=e.hits)
        n = len(e.tokens)
        self._host_len_counts[n] = self._host_len_counts.get(n, 0) + 1
        self.host_bytes_in_use += e.nbytes
        self.spills += 1
        self.spilled_bytes += e.nbytes
        while (self.host_bytes_in_use > self.spill_byte_budget
               or len(self._host) > self.max_entries):
            stale = next(iter(self._host))
            self._host_drop(stale)
            self.host_evicted += 1

    def _drop(self, key: Tuple[int, int]) -> CacheEntry:
        e = self._entries.pop(key)
        self.bytes_in_use -= e.nbytes
        n = len(e.tokens)
        self._len_counts[n] -= 1
        if not self._len_counts[n]:
            del self._len_counts[n]
        return e

    def _host_drop(self, key: Tuple[int, int]) -> CacheEntry:
        e = self._host.pop(key)
        self.host_bytes_in_use -= e.nbytes
        n = len(e.tokens)
        self._host_len_counts[n] -= 1
        if not self._host_len_counts[n]:
            del self._host_len_counts[n]
        return e

    def clear(self) -> None:
        self._entries.clear()
        self._len_counts.clear()
        self.bytes_in_use = 0
        self._host.clear()
        self._host_len_counts.clear()
        self.host_bytes_in_use = 0

    # -- metrics ----------------------------------------------------------
    def stats(self) -> Dict:
        """JSON-safe counters (feeds ``metrics_json()['prefix_cache']``
        and the ``serve.prefix_cache`` section of BENCH_PR.json)."""
        lookups = self.hits + self.partial_hits + self.misses
        return {
            "entries": len(self._entries),
            "bytes_in_use": self.bytes_in_use,
            "byte_budget": self.byte_budget,
            "hits": self.hits,
            "partial_hits": self.partial_hits,
            "misses": self.misses,
            "hit_rate": ((self.hits + self.partial_hits) / lookups
                         if lookups else None),
            "full_hit_rate": (self.hits / lookups if lookups else None),
            "tokens_reused": self.tokens_reused,
            "inserted": self.inserted,
            "evicted": self.evicted,
            "rejected": self.rejected,
            # spill tier (all zero / empty when spill_byte_budget == 0)
            "spill_enabled": self.spill_byte_budget > 0,
            "spill_byte_budget": self.spill_byte_budget,
            "host_entries": len(self._host),
            "host_bytes_in_use": self.host_bytes_in_use,
            "spills": self.spills,
            "spilled_bytes": self.spilled_bytes,
            "promotions": self.promotions,
            "promoted_bytes": self.promoted_bytes,
            "host_evicted": self.host_evicted,
        }
