"""Request lifecycle: ``Request`` -> ``RequestState`` -> ``RequestOutput``.

A ``Request`` is what a caller submits (prompt + ``SamplingParams`` +
identity/priority).  The engine wraps it in a ``RequestState`` that
tracks the QUEUED -> PREFILLING -> DECODING -> FINISHED(stop | length |
cancelled) lifecycle plus the timestamps the metrics recorder turns
into TTFT/TPOT/queue-time.  Each ``step()`` yields ``RequestOutput``
snapshots, and every request owns a ``RequestStream`` for incremental
token delivery (pull iteration or an ``on_token`` callback).

The pre-PR-4 legacy surface (``Engine.submit`` + ``Request(uid=,
max_new_tokens=, temperature=, eos_id=)``) was removed in PR 5 once the
last in-repo users migrated; the mutable ``output``/``done`` fields
remain as the canonical accumulating token list and finish flag.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from collections import deque
from typing import Callable, List, Optional, Tuple

from repro.serve.params import SamplingParams


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"


class FinishReason(enum.Enum):
    STOP = "stop"            # hit a stop token (included in the output)
    LENGTH = "length"        # produced max_tokens
    CANCELLED = "cancelled"  # cancelled while queued or in flight


_REQUEST_IDS = itertools.count()


@dataclasses.dataclass
class Request:
    """A unit of work for the engine.

    ``Request(prompt, SamplingParams(...), request_id=...,
    priority=...)``; ``params=None`` means greedy with the
    ``SamplingParams`` defaults.  ``priority``: higher values are
    served first under the priority scheduling policy (FCFS breaks
    ties).
    """

    prompt: List[int]
    params: Optional[SamplingParams] = None
    request_id: Optional[str] = None
    priority: int = 0
    # engine-written: the canonical accumulating token list + finish flag
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    def __post_init__(self) -> None:
        self.prompt = [int(t) for t in self.prompt]
        if self.params is None:
            self.params = SamplingParams()
        if self.request_id is None:
            self.request_id = f"req-{next(_REQUEST_IDS)}"
        if not self.prompt:
            raise ValueError(
                f"request {self.request_id} has an empty prompt; every "
                "request needs at least one prompt token")


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Per-step snapshot of one request's progress."""

    request_id: str
    new_token_ids: Tuple[int, ...]
    token_ids: Tuple[int, ...]
    status: RequestStatus
    finish_reason: Optional[FinishReason] = None

    @property
    def finished(self) -> bool:
        return self.status is RequestStatus.FINISHED


class RequestStream:
    """Incremental token delivery for one request.

    The engine is synchronous, so PULL iteration drives it: each
    ``__next__`` pumps ``engine.step()`` until this request yields a
    token or finishes.  ``drain()`` is the non-blocking variant
    (everything buffered so far), and ``on_token`` is the push-style
    callback, invoked as each token is decoded.
    """

    def __init__(self, request_id: str,
                 pump: Optional[Callable[[], bool]] = None,
                 on_token: Optional[Callable[[int], None]] = None):
        self.request_id = request_id
        self._buf: deque = deque()
        self._closed = False
        self._pump = pump
        self._on_token = on_token

    # -- engine side ------------------------------------------------------
    def put(self, token: int) -> None:
        if self._closed:                   # late token after a cancel
            return
        self._buf.append(token)
        if self._on_token is not None:
            self._on_token(token)

    def close(self) -> None:
        self._closed = True

    # -- consumer side ----------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def drain(self) -> List[int]:
        out = list(self._buf)
        self._buf.clear()
        return out

    def __iter__(self) -> "RequestStream":
        return self

    def __next__(self) -> int:
        while True:
            if self._buf:
                return self._buf.popleft()
            if self._closed:
                raise StopIteration
            if self._pump is None or not self._pump():
                raise RuntimeError(
                    f"stream for {self.request_id} stalled: the engine "
                    "has no work left but the request never finished")


@dataclasses.dataclass
class RequestState:
    """Engine-side lifecycle record for one request."""

    request: Request
    status: RequestStatus = RequestStatus.QUEUED
    slot: Optional[int] = None
    finish_reason: Optional[FinishReason] = None
    stream: Optional[RequestStream] = None
    # prompt tokens covered by the prefix cache: the add_request-time
    # estimate drives cache-aware admission ordering; re-resolved at
    # seat time (entries may be evicted while the request queues)
    cached_len: int = 0
    # timestamps from the engine clock (metrics derives TTFT/TPOT)
    arrival_time: float = 0.0
    scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None

    @property
    def request_id(self) -> str:
        return self.request.request_id

    @property
    def params(self) -> SamplingParams:
        return self.request.params

    @property
    def prompt(self) -> List[int]:
        return self.request.prompt

    @property
    def token_ids(self) -> List[int]:
        return self.request.output

    @property
    def finished(self) -> bool:
        return self.status is RequestStatus.FINISHED

    def snapshot(self, new_tokens: Tuple[int, ...] = ()) -> RequestOutput:
        return RequestOutput(request_id=self.request_id,
                             new_token_ids=tuple(new_tokens),
                             token_ids=tuple(self.request.output),
                             status=self.status,
                             finish_reason=self.finish_reason)
