"""Speculative draft-and-verify decoding for selective-scan models.

A small *draft* model proposes ``k`` tokens per round; the target model
checks all of them in ONE multi-token dispatch (``models.verify_step``
riding the fused ``kernels.scan_step.selective_scan_verify`` Pallas
kernel, which emits the recurrent state at every step boundary).
Rejection is where SSMs shine: rolling back to the last accepted
position is a single O(1) per-step snapshot select
(``select_verify_state``), not a KV-cache truncation -- the same
state-is-tiny property the prefix cache exploits.

Acceptance is ``SamplingParams``-exact:

* **Greedy rows** (``temperature == 0``) accept a draft token iff it
  equals the target argmax, and the replacement/bonus token IS the
  target argmax -- so speculative greedy streams are *bit-identical* to
  vanilla decode (``verify_step`` runs ``decode_step``'s exact per-token
  ops).
* **Sampled rows** run Leviathan-style rejection sampling: draft token
  ``d ~ q`` is accepted with probability ``min(1, p(d)/q(d))``; on
  rejection the replacement is drawn from the residual
  ``norm(max(p - q, 0))``, and a full accept earns a bonus token from
  the last verified distribution.  Both ``p`` and ``q`` are the SAME
  processed distributions ``sample_batched`` draws from (temperature
  scaling + top-k/top-p masking + softmax), so the emitted stream is
  *distribution-identical* to vanilla decoding -- token by token, for
  any acceptance rate.

The per-round bookkeeping (per-slot draft state, counters, multi-token
emission) lives in ``repro.serve.core.EngineCore`` / ``LLMEngine``;
this module holds the config surface and the sampling math.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.serve.sampler import apply_top_k_top_p


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for ``LLMEngine(speculative=...)``.

    draft: which model proposes tokens --

      * ``"self"``: the target model drafts for itself.  Acceptance is
        1.0 by construction, so every round turns ``k + 1`` sequential
        decode dispatches into one fused draft-scan + one verify
        dispatch: pure dispatch-overhead amortization (the regime CPU
        smoke runs and small models live in).
      * an architecture name (e.g. ``"mamba-130m"``): resolved via the
        config registry.  When it names the *target's own* config it
        degenerates to ``"self"``; otherwise ``draft_params`` must
        carry the draft weights (the engine never loads checkpoints).
      * a ``ModelConfig``: explicit draft config, ``draft_params``
        required.

    k: draft tokens per round (>= 1).  Each round emits between 1 and
    ``k + 1`` tokens per slot; higher ``k`` pays off only while the
    acceptance rate stays high (see docs/serving.md).

    draft_params / draft_qctx: weights and quantization context for a
    distinct draft model.  ``draft_qctx=None`` with a distinct draft
    runs it in floating point; a "self" draft inherits the target qctx
    so both sides share the int8 kernel path.
    """

    draft: Union[str, ModelConfig] = "self"
    k: int = 4
    draft_params: Optional[dict] = None
    draft_qctx: Optional[dict] = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")


def resolve_draft(spec: SpecConfig, cfg: ModelConfig, params, qctx
                  ) -> Tuple[ModelConfig, dict, Optional[dict], bool]:
    """Resolve ``spec.draft`` against the target model.

    Returns ``(draft_cfg, draft_params, draft_qctx, is_self)``;
    ``is_self`` means the draft shares the target's weights AND state
    layout, so the engine can seed draft slots by reference from the
    target's prefilled state (no draft prefill at all).
    """
    d = spec.draft
    if isinstance(d, str):
        if d == "self" or d == cfg.name:
            if spec.draft_params is not None:
                raise ValueError(
                    f"draft {d!r} resolves to the target model itself; "
                    "draft_params must be None (the target's weights are "
                    "used)")
            dq = (qctx if spec.draft_qctx is None else spec.draft_qctx)
            return cfg, params, dq, True
        if spec.draft_params is None:
            raise ValueError(
                f"draft {d!r} names a different model than the target "
                f"({cfg.name!r}); pass SpecConfig(draft_params=...) with "
                "its weights -- the engine never loads checkpoints")
        from repro.configs.registry import get_config
        dc = get_config(d)
    else:
        dc = d
        if spec.draft_params is None:
            raise ValueError(
                "SpecConfig with a ModelConfig draft needs draft_params")
    if dc.vocab_size != cfg.vocab_size:
        raise ValueError(
            f"draft vocab ({dc.vocab_size}) must match the target vocab "
            f"({cfg.vocab_size}): acceptance compares distributions "
            "token id by token id")
    return dc, spec.draft_params, spec.draft_qctx, False


def processed_probs(logits: jax.Array, temps: jax.Array,
                    top_k: jax.Array, top_p: jax.Array,
                    truncate: bool) -> jax.Array:
    """The distribution ``sample_batched`` actually draws from.

    logits: (B, V) raw model logits; returns (B, V) probabilities after
    temperature scaling and (when ``truncate``) top-k/top-p masking --
    the exact pipeline in ``repro.serve.sampler``, so acceptance tests
    p and q on the same footing as vanilla sampling.
    """
    scaled = logits / jnp.maximum(temps, 1e-4)[:, None]
    if truncate:
        scaled = apply_top_k_top_p(scaled, top_k, top_p)
    return jax.nn.softmax(scaled, axis=-1)


def spec_acceptance(logits: jax.Array, drafts: jax.Array,
                    qprobs: jax.Array, keys: jax.Array, temps: jax.Array,
                    top_k: jax.Array, top_p: jax.Array, truncate: bool
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One verify round's acceptance decision, fully batched.

    logits: (B, k+1, V) target logits over the fed tokens
    ``[t0, d_1..d_k]`` (``logits[:, i]`` = distribution after consuming
    fed token ``i``); drafts: (B, k); qprobs: (B, k, V) the PROCESSED
    draft distributions each ``d_{i+1}`` was sampled from.

    Returns ``(n_acc, extra, new_keys)``: row ``b`` commits
    ``drafts[b, :n_acc[b]]`` followed by ``extra[b]`` -- the residual
    replacement at the first rejection, or the bonus token after a full
    accept.  Always ``n_acc + 1`` tokens per row per round.
    """
    b, m, v = logits.shape
    k = m - 1
    rows = jnp.arange(b)
    greedy_tok = jnp.argmax(logits, axis=-1)                  # (B, M)
    flat = processed_probs(
        logits.reshape(b * m, v), jnp.repeat(temps, m),
        jnp.repeat(top_k, m), jnp.repeat(top_p, m), truncate)
    p = flat.reshape(b, m, v)                                 # (B, M, V)

    ks = jax.vmap(lambda key: jax.random.split(key, 3))(keys)
    new_keys = ks[:, 0]
    u = jax.vmap(lambda key: jax.random.uniform(key, (k,)))(ks[:, 1])

    p_d = jnp.take_along_axis(p[:, :k], drafts[..., None],
                              axis=-1)[..., 0]                # (B, k)
    q_d = jnp.take_along_axis(qprobs, drafts[..., None],
                              axis=-1)[..., 0]
    # u < p/q without the division; u in [0, 1) so p == q always accepts
    acc_sample = u * q_d < p_d
    acc_greedy = drafts == greedy_tok[:, :k]
    acc = jnp.where((temps <= 0.0)[:, None], acc_greedy, acc_sample)
    # number of leading accepts: cumprod turns the first reject into 0s
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)

    # replacement (reject at j < k: residual of p_j vs q_j) and bonus
    # (full accept: plain sample from p_k) unify via q_k := 0
    p_j = p[rows, n_acc]                                      # (B, V)
    q_pad = jnp.concatenate(
        [qprobs, jnp.zeros((b, 1, v), qprobs.dtype)], axis=1)
    resid = jnp.maximum(p_j - q_pad[rows, n_acc], 0.0)
    rsum = jnp.sum(resid, axis=-1, keepdims=True)
    # p == q exactly leaves an empty residual; rejection then had
    # probability 0, so the fallback to p_j is unreachable in
    # distribution (it only guards the sampler against NaNs)
    resid = jnp.where(rsum > 0.0, resid / rsum, p_j)
    resid_logits = jnp.where(resid > 0.0, jnp.log(resid), -jnp.inf)
    extra_sampled = jax.vmap(jax.random.categorical)(ks[:, 2],
                                                     resid_logits)
    extra = jnp.where(temps <= 0.0, greedy_tok[rows, n_acc],
                      extra_sampled).astype(jnp.int32)
    return n_acc.astype(jnp.int32), extra, new_keys
