"""Disaggregated serving workers: one engine per role per process.

A :class:`Worker` owns one engine -- an ``EngineCore`` for
``role="prefill"``, a full ``LLMEngine`` (plus an ``EnginePump`` for
standalone realtime use) for ``role="decode"`` -- and runs it either
in-process (``mode="thread"``, the deterministic test mode) or in its
own OS process (``mode="process"``, ``multiprocessing`` spawn).  The
frontend (``repro.serve.disagg.frontend``) talks to both through the
same synchronous command surface, so the process boundary is a
deployment knob, not an API.

Roles:

* **prefill** -- a batch-1 ``EngineCore`` that turns a prompt into a
  wire snapshot: seat the prompt (chunked sequence prefill), slice the
  slot, ``transport.pack_snapshot`` it.  An optional local
  ``StateCache`` dedupes shared prompt prefixes across requests, so a
  hot system prompt is prefilled once per prefill worker, not once per
  request.
* **decode** -- a full ``LLMEngine`` with its prefix cache on.  The
  cache IS the admission mechanism: :meth:`_DecodeServer.admit` unpacks
  the snapshot, inserts it under ``prompt[:-1]``, and queues the
  request; at the next ``step()`` the engine's own seat path full-hits
  and the request reaches DECODING with zero prefill dispatches (the
  ``prefix_restores`` counter is the proof).  If the entry was evicted
  in between, the engine just prefills locally -- slower, never wrong.

Process isolation: the child process is spawned fresh, and
``_worker_main`` forces its device set (``XLA_FLAGS
--xla_force_host_platform_device_count=N``) *before* the first jax
device query, so each worker owns its own XLA backend -- the
process-mode analogue of pinning a worker to a mesh slice.  Params and
qctx cross the boundary once, as host numpy trees; after that the wire
carries only prompts, sampling params, snapshots, and token events.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.disagg.transport import pack_snapshot, unpack_snapshot

ROLES = ("prefill", "decode")


class WorkerError(RuntimeError):
    """A worker call failed (remote traceback in the message) or the
    worker process died / timed out."""


def _host_tree(tree):
    """Copy a params/qctx pytree to host numpy leaves so it pickles
    across the spawn boundary.  Non-array leaves (QuantSpec, scalars,
    strings) pass through untouched."""
    import jax

    def leaf(x):
        if isinstance(x, (np.ndarray, np.generic)):
            return x
        if isinstance(x, jax.Array):
            return np.asarray(jax.device_get(x))
        return x

    return jax.tree_util.tree_map(leaf, tree)


@dataclasses.dataclass
class WorkerSpec:
    """Everything a worker needs to build its engine (picklable once
    ``params``/``qctx`` are host trees -- see :func:`_host_tree`)."""

    role: str
    cfg: Any                      # ModelConfig (plain dataclass)
    params: Any
    qctx: Any = None
    seed: int = 0
    max_len: int = 2048
    prefill_chunk: int = 128
    max_batch: int = 8            # decode role only
    prefix_cache_mb: float = 64.0
    # process mode: the child forces this many host devices before its
    # first jax device query (its private "mesh slice"); <= 0 inherits
    host_devices: int = 1

    def __post_init__(self):
        if self.role not in ROLES:
            raise ValueError(
                f"role must be one of {ROLES}, got {self.role!r}")


# -- in-worker servers ----------------------------------------------------

class _PrefillServer:
    """prompt tokens -> packed prefix-state snapshot (batch-1 core)."""

    def __init__(self, spec: WorkerSpec):
        from repro.serve.cache import StateCache
        from repro.serve.core import EngineCore
        self.core = EngineCore(spec.params, spec.cfg, max_batch=1,
                               max_len=spec.max_len, qctx=spec.qctx,
                               seed=spec.seed,
                               prefill_chunk=spec.prefill_chunk,
                               shard=False)
        self.cache = None
        if spec.prefix_cache_mb and spec.prefix_cache_mb > 0:
            self.cache = StateCache(
                byte_budget=int(spec.prefix_cache_mb * (1 << 20)),
                to_host=self.core.tree_to_host,
                to_device=self.core.tree_to_device)
        self.requests = 0
        self.busy_s = 0.0

    def prefill(self, prompt: Sequence[int]) -> Dict:
        """Run (the uncached part of) the prompt's prefill and return
        the wire snapshot covering ``prompt[:-1]``."""
        from repro.serve.params import SamplingParams
        prompt = [int(t) for t in prompt]
        if len(prompt) < 2:
            raise ValueError(
                "prefill worker needs >= 2 prompt tokens (a snapshot "
                "covers prompt[:-1]); route shorter prompts directly "
                "to a decode worker")
        t0 = time.perf_counter()
        entry = self.cache.lookup(prompt) if self.cache is not None \
            else None
        k = len(entry.tokens) if entry is not None else 0
        on_prefix = None
        if self.cache is not None:
            def on_prefix(consumed, tree, _p=tuple(prompt)):
                self.cache.insert(_p[:consumed], tree)
        # sampling params are irrelevant here: the slot's state after
        # the prompt does not depend on them, and this core never
        # decodes -- greedy defaults keep the seat cheap
        self.core.seat(0, prompt, SamplingParams(), 0,
                       prefix_state=(entry.state if entry is not None
                                     else None),
                       prefix_len=k, on_prefix=on_prefix)
        blob = pack_snapshot(self.core.snapshot_slot(0))
        self.requests += 1
        self.busy_s += time.perf_counter() - t0
        return {"snapshot": blob, "cached": k, "nbytes": len(blob)}

    def counters(self) -> Dict[str, int]:
        return dict(self.core.counters)

    def stats(self) -> Dict:
        return {"requests": self.requests, "busy_s": self.busy_s,
                "counters": dict(self.core.counters),
                "cache": (self.cache.stats() if self.cache is not None
                          else None)}

    def close(self) -> None:
        pass


class _DecodeServer:
    """Snapshot-admitted continuous-batching engine (full LLMEngine)."""

    def __init__(self, spec: WorkerSpec):
        from repro.serve.engine import LLMEngine
        from repro.serve.pump import EnginePump
        cache_mb = spec.prefix_cache_mb if spec.prefix_cache_mb else 64.0
        if cache_mb <= 0:
            raise ValueError(
                "decode workers need prefix_cache_mb > 0: the prefix "
                "cache is how shipped snapshots enter the engine")
        self.engine = LLMEngine(spec.params, spec.cfg,
                                max_batch=spec.max_batch,
                                max_len=spec.max_len, qctx=spec.qctx,
                                seed=spec.seed,
                                prefill_chunk=spec.prefill_chunk,
                                shard=False, prefix_cache_mb=cache_mb)
        self.pump = EnginePump(self.engine)
        self._pumping = False

    def admit(self, request_id: str, prompt: Sequence[int], params,
              snapshot: Optional[bytes]) -> bool:
        """Queue a request, pre-seeding the prefix cache from the wire
        snapshot so the seat path full-hits.  Returns True when the
        snapshot entered the cache (False: duplicate prefix already
        cached, or no snapshot -- either way the request is queued and
        will decode correctly)."""
        import jax
        prompt = [int(t) for t in prompt]
        inserted = False
        if snapshot is not None:
            tree = jax.device_put(unpack_snapshot(snapshot))
            inserted = self.engine.prefix_cache.insert(prompt[:-1], tree)
        if self._pumping:
            self.pump.add_request(prompt, params, request_id=request_id)
        else:
            self.engine.add_request(prompt, params,
                                    request_id=request_id)
        return inserted

    def step(self) -> List[Tuple[str, List[int], bool, Optional[str]]]:
        """One engine step; token/finish events as picklable tuples
        ``(request_id, new_tokens, finished, finish_reason)``."""
        if self._pumping:
            raise RuntimeError("step() conflicts with a running pump; "
                               "stop_pump() first")
        return [(o.request_id, [int(t) for t in o.new_token_ids],
                 o.finished,
                 o.finish_reason.value if o.finish_reason else None)
                for o in self.engine.step()]

    def cancel(self, request_id: str) -> bool:
        if self._pumping:
            return self.pump.cancel(request_id)
        return self.engine.cancel(request_id)

    def occupancy(self) -> Dict[str, int]:
        return {"live": len(self.engine.scheduler.live()),
                "queued": self.engine.scheduler.queue_depth,
                "max_batch": self.engine.max_batch}

    def has_unfinished(self) -> bool:
        return self.engine.has_unfinished()

    def counters(self) -> Dict[str, int]:
        return dict(self.engine.core.counters)

    def metrics(self) -> Dict:
        if self._pumping:
            return self.pump.metrics_json()
        return self.engine.metrics_json()

    def stats(self) -> Dict:
        occ = list(self.engine.metrics.occupancy_series)
        return {"occupancy_mean": (sum(occ) / len(occ) if occ else None),
                "counters": dict(self.engine.core.counters),
                "cache": self.engine.prefix_cache.stats()}

    # standalone realtime use: the worker's own background stepper
    # (the frontend's deterministic step() path never starts it)
    def start_pump(self) -> None:
        if not self._pumping:
            self.pump.start()
            self._pumping = True

    def stop_pump(self) -> None:
        if self._pumping:
            self.pump.stop()
            self._pumping = False

    def drain(self, timeout: Optional[float] = None) -> bool:
        if self._pumping:
            return self.pump.drain(timeout)
        while self.engine.has_unfinished():
            self.engine.step()
        return True

    def close(self) -> None:
        self.stop_pump()


def _make_server(spec: WorkerSpec):
    return (_PrefillServer(spec) if spec.role == "prefill"
            else _DecodeServer(spec))


# -- process plumbing ------------------------------------------------------

def _worker_main(conn, spec: WorkerSpec) -> None:  # pragma: no cover -
    # child-process body: covered by the cross-process tests, invisible
    # to the parent's coverage tracer
    if spec.host_devices and spec.host_devices > 0:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{spec.host_devices}").strip()
    try:
        server = _make_server(spec)
    except Exception as e:
        conn.send(("err", f"{type(e).__name__}: {e}\n"
                   f"{traceback.format_exc()}"))
        conn.close()
        return
    conn.send(("ready", spec.role))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break
        if msg[0] == "close":
            try:
                server.close()
            finally:
                conn.send(("ok", None))
            break
        _, method, args, kw = msg
        try:
            conn.send(("ok", getattr(server, method)(*args, **kw)))
        except Exception as e:
            conn.send(("err", f"{type(e).__name__}: {e}\n"
                       f"{traceback.format_exc()}"))
    conn.close()


class Worker:
    """One role-pinned engine behind a synchronous command surface.

    ``mode="thread"`` builds the server in-process (shared jax backend,
    params shared by reference -- the deterministic test mode);
    ``mode="process"`` spawns it into its own interpreter + XLA backend
    with host-tree params.  All calls are serialized per worker.
    """

    _TIMEOUT_S = 600.0

    def __init__(self, spec: WorkerSpec, *, mode: str = "thread",
                 name: Optional[str] = None):
        if mode not in ("thread", "process"):
            raise ValueError(
                f"mode must be 'thread' or 'process', got {mode!r}")
        self.spec = spec
        self.role = spec.role
        self.mode = mode
        self.name = name or f"{spec.role}-worker"
        self._closed = False
        self._lock = threading.Lock()
        if mode == "thread":
            self._server = _make_server(spec)
            self._proc = None
            self._conn = None
        else:
            spec = dataclasses.replace(spec,
                                       params=_host_tree(spec.params),
                                       qctx=_host_tree(spec.qctx))
            ctx = mp.get_context("spawn")
            self._conn, child = ctx.Pipe()
            self._proc = ctx.Process(target=_worker_main,
                                     args=(child, spec),
                                     name=self.name, daemon=True)
            self._proc.start()
            child.close()
            kind, detail = self._recv()
            if kind != "ready":
                self._proc.join(5)
                raise WorkerError(
                    f"{self.name} failed to start: {detail}")
            self._server = None

    def _recv(self):
        if not self._conn.poll(self._TIMEOUT_S):
            raise WorkerError(
                f"{self.name} timed out after {self._TIMEOUT_S}s")
        try:
            return self._conn.recv()
        except (EOFError, OSError) as e:
            raise WorkerError(f"{self.name} died mid-call: {e}")

    def call(self, method: str, *args, **kw):
        """Invoke ``method`` on the worker's server, wherever it lives."""
        with self._lock:
            if self._closed:
                raise WorkerError(f"{self.name} is closed")
            if self._server is not None:
                return getattr(self._server, method)(*args, **kw)
            self._conn.send(("call", method, args, kw))
            kind, value = self._recv()
            if kind == "err":
                raise WorkerError(f"{self.name}.{method} failed: {value}")
            return value

    # convenience wrappers (the frontend's whole vocabulary)
    def prefill(self, prompt) -> Dict:
        return self.call("prefill", prompt)

    def admit(self, request_id, prompt, params, snapshot) -> bool:
        return self.call("admit", request_id, prompt, params, snapshot)

    def step(self) -> List[Tuple[str, List[int], bool, Optional[str]]]:
        return self.call("step")

    def cancel(self, request_id: str) -> bool:
        return self.call("cancel", request_id)

    def occupancy(self) -> Dict[str, int]:
        return self.call("occupancy")

    def has_unfinished(self) -> bool:
        return self.call("has_unfinished")

    def counters(self) -> Dict[str, int]:
        return self.call("counters")

    def stats(self) -> Dict:
        return self.call("stats")

    def metrics(self) -> Dict:
        return self.call("metrics")

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if self._server is not None:
                self._server.close()
                return
            try:
                self._conn.send(("close",))
                if self._conn.poll(10.0):
                    self._conn.recv()
            except (BrokenPipeError, OSError):
                pass
            finally:
                self._conn.close()
                self._proc.join(10)
                if self._proc.is_alive():   # pragma: no cover - watchdog
                    self._proc.terminate()
                    self._proc.join(5)

    def __enter__(self) -> "Worker":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
