"""Snapshot transport: prefix-state trees <-> crc-checked wire bytes.

The disaggregated serving split ships a finished prompt's decode state
(SSM recurrent state, conv taps, position -- exactly the batch-1 tree
``EngineCore.snapshot_slot`` produces and the PR-5 ``StateCache``
stores) from a prefill worker to a decode worker.  This module is the
wire format: one self-describing binary blob per snapshot.

The layout reuses ``repro.train.checkpoint``'s key-path tree encoding
(``tree-v1``: each leaf records its DictKey/SequenceKey path as a list
of ``{"k": name}`` / ``{"i": index}`` steps) so the same code that
rebuilds a checkpoint rebuilds a snapshot -- only the container
differs: a checkpoint is a directory of ``.npy`` files, a snapshot is
a single in-memory buffer::

    magic  b"rpds1\\n"
    u32    manifest length (little-endian)
    bytes  manifest JSON  {"format": "snapshot-v1", "leaves": [
               {"path": [...], "shape": [...], "dtype": "...",
                "offset": ..., "nbytes": ..., "crc32": ...}, ...]}
    bytes  concatenated C-order leaf buffers

Every leaf carries a crc32 (same discipline as ``checkpoint.save``);
``unpack_snapshot`` verifies all of them plus the header framing and
raises :class:`SnapshotCorruption` on any mismatch, so a torn or
bit-flipped transfer can never be restored into a slot.  Leaves come
back as host numpy arrays in the stored dtype (int8 KV entries stay
int8, packed w4 qdata stays packed) -- the receiving worker's
``device_put`` happens at restore time, shared copy-on-write like any
other cached snapshot.
"""
from __future__ import annotations

import json
import struct
import zlib
from typing import Dict, List

import numpy as np
import jax

from repro.train.checkpoint import _encode_keypath, _insert_at, _listify

MAGIC = b"rpds1\n"
FORMAT = "snapshot-v1"
_LEN = struct.Struct("<I")


class SnapshotCorruption(IOError):
    """The wire bytes fail framing or crc verification."""


def pack_snapshot(tree) -> bytes:
    """Serialize a decode-state pytree into one self-describing blob.

    Accepts device or host trees (leaves are pulled to host with one
    ``device_get``); dict keys must be strings and tuple nodes come
    back as lists, exactly like ``checkpoint.save_tree``.
    """
    flat = jax.tree_util.tree_flatten_with_path(jax.device_get(tree))[0]
    leaves: List[Dict] = []
    bufs: List[bytes] = []
    offset = 0
    for keypath, leaf in flat:
        # tobytes() serializes in C order whatever the input layout;
        # no ascontiguousarray (it would promote 0-d leaves to (1,))
        arr = np.asarray(leaf)
        raw = arr.tobytes()
        leaves.append({
            "path": _encode_keypath(keypath),
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "offset": offset, "nbytes": len(raw),
            "crc32": zlib.crc32(raw),
        })
        bufs.append(raw)
        offset += len(raw)
    manifest = json.dumps({"format": FORMAT,
                           "leaves": leaves}).encode("utf-8")
    return b"".join([MAGIC, _LEN.pack(len(manifest)), manifest] + bufs)


def _manifest(data: bytes) -> Dict:
    if not data.startswith(MAGIC):
        raise SnapshotCorruption(
            f"bad snapshot magic {data[:len(MAGIC)]!r} (want {MAGIC!r})")
    hdr_end = len(MAGIC) + _LEN.size
    if len(data) < hdr_end:
        raise SnapshotCorruption("truncated snapshot header")
    (mlen,) = _LEN.unpack(data[len(MAGIC):hdr_end])
    if len(data) < hdr_end + mlen:
        raise SnapshotCorruption("truncated snapshot manifest")
    try:
        manifest = json.loads(data[hdr_end:hdr_end + mlen])
    except ValueError as e:
        raise SnapshotCorruption(f"unreadable snapshot manifest: {e}")
    if manifest.get("format") != FORMAT:
        raise SnapshotCorruption(
            f"unsupported snapshot format {manifest.get('format')!r} "
            f"(this build reads {FORMAT!r})")
    manifest["_payload"] = hdr_end + mlen
    return manifest


def unpack_snapshot(data: bytes):
    """Rebuild the pytree from :func:`pack_snapshot` bytes.

    Verifies the framing and every leaf's crc32; raises
    :class:`SnapshotCorruption` rather than returning a damaged tree.
    Leaves are host numpy arrays (dtype/shape as stored).
    """
    manifest = _manifest(data)
    base = manifest.pop("_payload")
    root: Dict = {}
    empty = True
    for meta in manifest["leaves"]:
        lo = base + meta["offset"]
        hi = lo + meta["nbytes"]
        if hi > len(data):
            raise SnapshotCorruption(
                f"truncated snapshot payload (leaf at {meta['path']!r})")
        raw = data[lo:hi]
        if zlib.crc32(raw) != meta["crc32"]:
            raise SnapshotCorruption(
                f"snapshot corruption in leaf {meta['path']!r} "
                "(crc32 mismatch)")
        arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        if not meta["path"]:
            return arr                    # bare-leaf tree
        _insert_at(root, meta["path"], arr)
        empty = False
    return _listify(root) if not empty else {}


def snapshot_equal(a, b) -> bool:
    """Structural + bitwise equality of two state trees (test helper;
    also the cross-process restore-equality check)."""
    fa = jax.tree_util.tree_flatten_with_path(a)
    fb = jax.tree_util.tree_flatten_with_path(b)
    if [p for p, _ in fa[0]] != [p for p, _ in fb[0]]:
        return False
    for (_, la), (_, lb) in zip(fa[0], fb[0]):
        xa, xb = np.asarray(jax.device_get(la)), \
            np.asarray(jax.device_get(lb))
        if xa.dtype != xb.dtype or xa.shape != xb.shape:
            return False
        if xa.tobytes() != xb.tobytes():
            return False
    return True
