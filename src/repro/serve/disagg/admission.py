"""Roofline-informed admission for disaggregated serving.

Decode on a selective SSM is memory-bound (per the PR-3 roofline:
every token re-reads the weights plus one O(1) state tree per
sequence), while prefill is dispatch-bound (few big chunked dispatches
whose wall clock is dominated by launch overhead at serving sizes).
The two knobs that matter therefore fall straight out of
``repro.dist.roofline``'s ceilings:

* ``max_batch`` (decode workers) -- batching amortizes the weight read
  across sequences, so decode throughput rises with B until the
  compute ceiling crosses the memory ceiling; past that knee extra
  slots only add latency.  :func:`plan_decode` solves for the knee
  analytically (``2*N*B / peak == (W + B*S) / hbm_bw``).
* ``prefill_chunk`` (prefill workers) -- a chunk is one dispatch; the
  chunk is big enough exactly when its compute time covers the
  per-dispatch launch overhead, so the prefill loop stops being
  launch-bound.  :func:`plan_decode` picks the smallest power of two
  that does.

The static plan seeds the worker pools; the
:class:`AdmissionController` then consumes the loadgen-style feedback
the frontend already measures (per-role occupancy + queue depth) and
nudges the prefill:decode worker *ratio*: a deep queue with idle
decode slots means admissions are prefill-starved (shift a worker to
prefill); saturated decode slots with an idle prefill pool means the
opposite.  The controller only recommends -- the frontend/launcher
decides when (or whether) to resize pools.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.dist import roofline

# conservative per-dispatch launch overhead for the chunk sizing; real
# values range ~10-100 us (XLA:CPU/TPU) -- callers override per part
DISPATCH_OVERHEAD_S = 50e-6


@dataclasses.dataclass(frozen=True)
class RooflinePlan:
    """One (arch, mesh) cell's admission limits and their provenance."""

    max_batch: int
    prefill_chunk: int
    decode_step_s: float          # modeled step time AT max_batch
    decode_tokens_per_s: float    # max_batch / decode_step_s
    bottleneck: str               # at max_batch: "compute" | "memory"
    n_params: int
    weight_bytes: int
    state_bytes_per_seq: int
    terms: Dict[str, float]       # roofline_terms at max_batch

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["terms"] = {k: v for k, v in self.terms.items()
                      if isinstance(v, (int, float, str))}
        return d


def _pow2_at_most(n: int) -> int:
    return 1 << (max(1, n).bit_length() - 1)


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def plan_decode(cfg, *, n_params: Optional[int] = None,
                weight_bytes: Optional[int] = None,
                state_bytes_per_seq: Optional[int] = None,
                quantized: bool = True, n_devices: int = 1,
                peak_flops: float = roofline.PEAK_FLOPS,
                hbm_bw: float = roofline.HBM_BW,
                dispatch_overhead_s: float = DISPATCH_OVERHEAD_S,
                max_batch_cap: int = 64,
                max_chunk_cap: int = 1024) -> RooflinePlan:
    """Pick ``max_batch``/``prefill_chunk`` from the decode ceilings.

    ``n_params`` defaults to ``models.param_count(cfg)``;
    ``weight_bytes`` to 1 byte/param when ``quantized`` (the int8
    deployment this repo serves) else 4; ``state_bytes_per_seq`` to
    the mamba-family recurrent tree (``n_layers * (d_inner * d_state +
    (conv_width - 1) * d_inner)`` fp32 floats).  ``n_devices`` models a
    data-parallel mesh slice: weights replicate, the batch splits, so
    the per-chip memory term reads the full weights but only B/n
    states.
    """
    if n_params is None:
        from repro.models import param_count
        n_params = param_count(cfg)
    if weight_bytes is None:
        weight_bytes = n_params * (1 if quantized else 4)
    if state_bytes_per_seq is None:
        di, ds, w = cfg.d_inner, cfg.d_state, cfg.conv_width
        state_bytes_per_seq = cfg.n_layers * (di * ds + (w - 1) * di) * 4

    def terms_at(batch: int) -> Dict:
        per_chip = max(1, batch // n_devices) if n_devices > 1 else batch
        cost = {"flops": 2.0 * n_params * per_chip,
                "bytes accessed": float(weight_bytes
                                        + per_chip * state_bytes_per_seq)}
        return roofline.roofline_terms(cost, {"total": 0, "count": 0},
                                       peak_flops=peak_flops,
                                       hbm_bw=hbm_bw)

    # the roofline knee: smallest B where the compute ceiling overtakes
    # the memory ceiling -- 2*N*B/peak >= (W + B*S)/bw.  Past it the
    # step slows linearly in B and batching stops paying.
    denom = 2.0 * n_params / peak_flops - state_bytes_per_seq / hbm_bw
    if denom <= 0:
        # state reads dominate compute at ANY batch (tiny model): the
        # memory term never crosses, so take the cap
        knee = max_batch_cap
    else:
        knee = int(weight_bytes / hbm_bw / denom)
    max_batch = _pow2_at_most(min(max(1, knee), max_batch_cap))
    max_batch *= max(1, n_devices)        # mesh slice: B splits over n
    max_batch = min(max_batch, max_batch_cap)

    # prefill chunk: one dispatch computes 2*N*chunk flops; the chunk
    # stops being launch-bound when that covers the dispatch overhead
    need = dispatch_overhead_s * peak_flops / (2.0 * n_params)
    prefill_chunk = min(_pow2_at_least(max(1, int(need))), max_chunk_cap)

    t = terms_at(max_batch)
    step_s = max(t["step_s"], 1e-12)
    return RooflinePlan(
        max_batch=max_batch, prefill_chunk=prefill_chunk,
        decode_step_s=step_s,
        decode_tokens_per_s=max_batch / step_s,
        bottleneck=t["bottleneck"], n_params=int(n_params),
        weight_bytes=int(weight_bytes),
        state_bytes_per_seq=int(state_bytes_per_seq),
        terms={k: t[k] for k in ("compute_s", "memory_s", "step_s",
                                 "bottleneck", "arithmetic_intensity")})


class AdmissionController:
    """Occupancy/goodput feedback -> prefill:decode ratio nudges.

    The frontend calls :meth:`observe` once per step with what it
    already measures; :meth:`suggest_workers` returns the worker split
    the evidence currently supports.  The rule is deliberately dumb
    and hysteretic (a single EWMA per signal, one-step nudges) -- the
    point is the *direction*, the static :class:`RooflinePlan` sets
    the magnitudes.
    """

    def __init__(self, plan: RooflinePlan, *, prefill_workers: int,
                 decode_workers: int, ewma: float = 0.2,
                 high: float = 0.85, low: float = 0.25):
        if prefill_workers < 1 or decode_workers < 1:
            raise ValueError("need >= 1 worker per role")
        if not 0 < ewma <= 1 or not 0 <= low < high <= 1:
            raise ValueError(f"bad controller constants "
                             f"(ewma={ewma}, low={low}, high={high})")
        self.plan = plan
        self.prefill_workers = prefill_workers
        self.decode_workers = decode_workers
        self._ewma = ewma
        self._high, self._low = high, low
        self.prefill_busy = 0.0       # EWMA, fraction of step wall time
        self.decode_occupancy = 0.0   # EWMA, live / total slots
        self.queue_pressure = 0.0     # EWMA, queued / total slots
        self.observations = 0

    def observe(self, *, queue_depth: int, prefill_busy: float,
                decode_occupancy: float) -> None:
        a = self._ewma
        slots = max(1, self.decode_workers * self.plan.max_batch)
        for name, x in (("prefill_busy", prefill_busy),
                        ("decode_occupancy", decode_occupancy),
                        ("queue_pressure", min(1.0, queue_depth / slots))):
            setattr(self, name,
                    (1 - a) * getattr(self, name) + a * float(x))
        self.observations += 1

    def suggest_workers(self) -> Dict[str, int]:
        """The (prefill, decode) split the current EWMAs support.

        Total worker count is preserved; a pool never drops below 1.
        A saturated prefill pool feeding idle decode slots wants a
        decode->prefill shift (admissions are prefill-starved); the
        mirror image wants the opposite.  Anything else keeps the
        current split.
        """
        p, d = self.prefill_workers, self.decode_workers
        starved = (self.prefill_busy > self._high
                   and self.queue_pressure > self._low
                   and self.decode_occupancy < self._high)
        flooded = (self.decode_occupancy > self._high
                   and self.prefill_busy < self._low)
        if starved and d > 1:
            p, d = p + 1, d - 1
        elif flooded and p > 1:
            p, d = p - 1, d + 1
        return {"prefill": p, "decode": d}

    def to_json(self) -> Dict:
        return {
            "prefill_workers": self.prefill_workers,
            "decode_workers": self.decode_workers,
            "prefill_busy": self.prefill_busy,
            "decode_occupancy": self.decode_occupancy,
            "queue_pressure": self.queue_pressure,
            "observations": self.observations,
            "suggested": self.suggest_workers(),
            "plan": self.plan.to_json(),
        }
