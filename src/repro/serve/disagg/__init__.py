"""Disaggregated prefill/decode serving (``repro.serve.disagg``).

Prefill is dispatch-bound, decode is memory-bound (the PR-3 roofline
ceilings); running both in one process makes each request's prefill
stall every other request's decode step.  This package splits them:
prefill workers turn prompts into O(1) prefix-state snapshots
(``transport``), decode workers admit the snapshots through their
prefix cache (``worker``), a :class:`DisaggEngine` keeps the familiar
single-engine API over the pools (``frontend``), and the roofline
model sizes the knobs (``admission``).
"""
from repro.serve.disagg.admission import (AdmissionController,
                                          RooflinePlan, plan_decode)
from repro.serve.disagg.frontend import DisaggEngine, generate_disagg
from repro.serve.disagg.transport import (SnapshotCorruption,
                                          pack_snapshot, snapshot_equal,
                                          unpack_snapshot)
from repro.serve.disagg.worker import Worker, WorkerError, WorkerSpec

__all__ = [
    "AdmissionController", "DisaggEngine", "RooflinePlan",
    "SnapshotCorruption", "Worker", "WorkerError", "WorkerSpec",
    "generate_disagg", "pack_snapshot", "plan_decode", "snapshot_equal",
    "unpack_snapshot",
]
