"""DisaggEngine: the single-engine surface over split worker pools.

``DisaggEngine`` speaks the exact ``LLMEngine`` dialect --
``add_request`` / ``cancel`` / ``step`` / ``has_unfinished`` / ``run``
/ ``metrics_json``, plus the ``cfg`` / ``core`` / ``scheduler`` /
``metrics`` views the loadgen runner and ``EnginePump`` read -- so
``loadgen.run()`` and ``launch/serve.py`` accept one unchanged.  Under
the surface each ``step()`` runs the disaggregated pipeline:

1. **Admit**: pop queued requests while a decode worker has a free
   slot.  Each prompt goes to a prefill worker (round-robin), comes
   back as a packed prefix-state snapshot (``transport``), and is
   shipped to the least-loaded decode worker, whose prefix cache turns
   it into a zero-prefill seat.  One-token prompts have no prefix to
   ship and go to a decode worker directly.
2. **Decode**: step every decode worker with live requests and relay
   its token/finish events into the frontend's streams and metrics --
   the same stop/length/reentrant-cancel semantics as ``LLMEngine``
   (the worker applies the finish rules; the frontend owns streams).
3. **Observe**: feed queue depth and per-role occupancy to the
   :class:`~repro.serve.disagg.admission.AdmissionController`.

Determinism: token streams are bit-identical to a single-process
``LLMEngine`` for greedy requests and for requests with an explicit
``SamplingParams.seed`` (the slot PRNG key is then
``PRNGKey(seed)`` in both worlds; loadgen traces always set per-event
seeds).  Seed*less* sampled requests draw from
``fold_in(base_key, admission_index)`` and the admission index depends
on which worker a request lands on -- correct sampling, but not
reproducible across topologies; pin seeds when you need replay.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.serve.disagg.admission import AdmissionController, \
    RooflinePlan, plan_decode
from repro.serve.disagg.worker import Worker, WorkerSpec
from repro.serve.engine import StepBudgetExhausted
from repro.serve.metrics import Metrics, REQUEST_CAP, evict_finished, \
    stats_ms
from repro.serve.params import SamplingParams
from repro.serve.request import (FinishReason, Request, RequestOutput,
                                 RequestState, RequestStatus,
                                 RequestStream)

_TRANSFER_SAMPLE_CAP = 4096


class _CoreView:
    """The ``engine.core`` attributes external callers read."""

    def __init__(self, max_len: int, max_batch: int):
        self.max_len = max_len
        self.max_batch = max_batch


class _SchedulerView:
    """The ``engine.scheduler`` surface the loadgen runner reads."""

    def __init__(self, owner: "DisaggEngine"):
        self._owner = owner

    @property
    def queue_depth(self) -> int:
        return len(self._owner._queue)

    @property
    def has_work(self) -> bool:
        return self._owner.has_unfinished()

    def outstanding(self) -> List[str]:
        return ([st.request_id for st in self._owner._queue]
                + [rid for rids in self._owner._assigned
                   for rid in rids])


class DisaggEngine:
    """Disaggregated prefill/decode serving behind the LLMEngine API."""

    def __init__(self, params, cfg: ModelConfig, *,
                 prefill_workers: int = 1, decode_workers: int = 1,
                 max_batch: Optional[int] = None, max_len: int = 2048,
                 qctx=None, seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 mode: str = "thread", host_devices: int = 1,
                 prefix_cache_mb: float = 64.0,
                 plan: Optional[RooflinePlan] = None,
                 clock=time.monotonic):
        if prefill_workers < 1 or decode_workers < 1:
            raise ValueError(
                f"need >= 1 worker per role, got prefill="
                f"{prefill_workers} decode={decode_workers}")
        if plan is None:
            plan = plan_decode(cfg)
        # the plan models datacenter parts; clamp the derived knobs to
        # the single-host defaults the rest of the repo uses unless the
        # caller sized them explicitly
        if max_batch is None:
            max_batch = min(plan.max_batch, 8)
        if prefill_chunk is None:
            prefill_chunk = min(plan.prefill_chunk, 128)
        self.plan = plan
        self.controller = AdmissionController(
            plan, prefill_workers=prefill_workers,
            decode_workers=decode_workers)
        self.mode = mode
        self._cfg = cfg
        self.max_batch = max_batch
        self.core = _CoreView(max_len, max_batch * decode_workers)
        self.scheduler = _SchedulerView(self)
        self.metrics = Metrics(clock=clock)
        self._clock = clock

        def spec(role: str) -> WorkerSpec:
            return WorkerSpec(role=role, cfg=cfg, params=params,
                              qctx=qctx, seed=seed, max_len=max_len,
                              prefill_chunk=prefill_chunk,
                              max_batch=max_batch,
                              prefix_cache_mb=prefix_cache_mb,
                              host_devices=host_devices)

        self._closed = False
        self.prefill_pool: List[Worker] = []
        self.decode_pool: List[Worker] = []
        try:
            for i in range(prefill_workers):
                self.prefill_pool.append(Worker(
                    spec("prefill"), mode=mode, name=f"prefill-{i}"))
            for i in range(decode_workers):
                self.decode_pool.append(Worker(
                    spec("decode"), mode=mode, name=f"decode-{i}"))
        except BaseException:
            self.close()
            raise
        self._states: Dict[str, RequestState] = {}
        self._queue: Deque[RequestState] = deque()
        # rid -> decode worker index, and the inverse live sets (local
        # mirrors; kept exact by the finish/cancel events, so admission
        # never needs a worker round-trip to count free slots)
        self._where: Dict[str, int] = {}
        self._assigned: List[set] = [set()
                                     for _ in range(decode_workers)]
        self._next_prefill = 0          # round-robin cursor
        # transport accounting
        self.transfers = 0
        self.transfer_bytes = 0
        self.direct_admits = 0
        self._transfer_s: Deque[float] = deque(
            maxlen=_TRANSFER_SAMPLE_CAP)
        self._t0: Optional[float] = None

    # -- LLMEngine-compatible views ---------------------------------------
    @property
    def cfg(self) -> ModelConfig:
        return self._cfg

    # -- request lifecycle -------------------------------------------------
    def add_request(self, prompt, params: Optional[SamplingParams] = None,
                    *, request_id: Optional[str] = None,
                    priority: int = 0, on_token=None) -> RequestState:
        """Queue a request (same contract as ``LLMEngine.add_request``:
        returns the live ``RequestState`` whose stream delivers tokens
        incrementally)."""
        if isinstance(prompt, Request):
            if (params is not None or request_id is not None
                    or priority != 0):
                raise ValueError(
                    "pass sampling params / request_id / priority on "
                    "the Request itself when submitting a ready "
                    "Request object")
            req = prompt
        else:
            req = Request(list(prompt), params, request_id=request_id,
                          priority=priority)
        if req.request_id in self._states:
            raise ValueError(f"duplicate request_id {req.request_id!r}")
        state = RequestState(request=req)
        state.stream = RequestStream(req.request_id, pump=self._pump,
                                     on_token=on_token)
        self._states[req.request_id] = state
        self._queue.append(state)
        state.arrival_time = self.metrics.on_submit(
            req.request_id, len(req.prompt), req.priority)
        return state

    def request_state(self, request_id: str) -> RequestState:
        return self._states[request_id]

    def stream(self, request_id: str) -> RequestStream:
        return self._states[request_id].stream

    def cancel(self, request_id: str) -> bool:
        """Cancel a queued or in-flight request (tokens so far are
        kept); False for unknown/finished ids."""
        state = self._states.get(request_id)
        if state is None or state.finished:
            return False
        if state in self._queue:
            self._queue.remove(state)
        w = self._where.pop(request_id, None)
        if w is not None:
            self._assigned[w].discard(request_id)
            self.decode_pool[w].cancel(request_id)
        self._finish(state, FinishReason.CANCELLED)
        return True

    def _finish(self, state: RequestState,
                reason: FinishReason) -> None:
        state.status = RequestStatus.FINISHED
        state.finish_reason = reason
        state.request.done = True
        state.finish_time = self.metrics.on_finish(state.request_id,
                                                   reason.value)
        state.stream.close()
        evict_finished(self._states, REQUEST_CAP,
                       lambda st: st.finished)

    # -- stepping ----------------------------------------------------------
    def _least_loaded(self) -> Optional[int]:
        free = [(len(self._assigned[i]), i)
                for i in range(len(self.decode_pool))
                if len(self._assigned[i]) < self.max_batch]
        return min(free)[1] if free else None

    def _admit_one(self, state: RequestState) -> None:
        w = self._least_loaded()
        prompt = state.request.prompt
        cached = 0
        if len(prompt) >= 2:
            pw = self.prefill_pool[self._next_prefill]
            self._next_prefill = ((self._next_prefill + 1)
                                  % len(self.prefill_pool))
            out = pw.prefill(prompt)
            cached = out["cached"]
            t0 = self._clock()
            self.decode_pool[w].admit(state.request_id, prompt,
                                      state.request.params,
                                      out["snapshot"])
            self._transfer_s.append(self._clock() - t0)
            self.transfers += 1
            self.transfer_bytes += out["nbytes"]
        else:
            # one-token prompt: the snapshot would cover zero tokens
            self.decode_pool[w].admit(state.request_id, prompt,
                                      state.request.params, None)
            self.direct_admits += 1
        self._where[state.request_id] = w
        self._assigned[w].add(state.request_id)
        state.scheduled_time = self.metrics.on_schedule(
            state.request_id, cached_tokens=cached)
        state.status = RequestStatus.DECODING

    def _deliver(self, state: RequestState, tok: int) -> bool:
        """One token into a request's stream/metrics; False when a
        reentrant cancel already finished it (token dropped)."""
        if state.finished:
            return False
        state.request.output.append(tok)
        t = self.metrics.on_token(state.request_id)
        if state.first_token_time is None:
            state.first_token_time = t
        state.stream.put(tok)          # may reenter cancel()
        return True

    def step(self) -> List[RequestOutput]:
        """Admit + decode one round across the worker pools.  With
        nothing queued and nothing live this is a strict no-op, exactly
        like ``LLMEngine.step``."""
        if self._t0 is None:
            self._t0 = self._clock()
        while self._queue and self._least_loaded() is not None:
            self._admit_one(self._queue.popleft())
        live_total = sum(len(s) for s in self._assigned)
        if live_total == 0:
            return []
        outputs: List[RequestOutput] = []
        for w, worker in enumerate(self.decode_pool):
            if not self._assigned[w]:
                continue
            for rid, toks, finished, reason in worker.step():
                state = self._states.get(rid)
                if state is None or state.finished:
                    # cancelled reentrantly by an earlier stream
                    # callback this very step: its tokens are dropped
                    continue
                emitted = [t for t in toks if self._deliver(state, t)]
                if finished and not state.finished:
                    self._assigned[w].discard(rid)
                    self._where.pop(rid, None)
                    self._finish(state, FinishReason(reason))
                outputs.append(state.snapshot(tuple(emitted)))
        self.metrics.on_step(len(self._queue), live_total,
                             self.core.max_batch)
        self.controller.observe(
            queue_depth=len(self._queue),
            prefill_busy=self._prefill_busy_fraction(),
            decode_occupancy=live_total / self.core.max_batch)
        return outputs

    def has_unfinished(self) -> bool:
        return bool(self._queue) or any(self._assigned)

    def run(self, max_steps: int = 10_000, *,
            on_exhaust: str = "raise") -> None:
        """Step until drained (``LLMEngine.run`` semantics, including
        :class:`StepBudgetExhausted` on a spent budget)."""
        if on_exhaust not in ("raise", "warn"):
            raise ValueError(f"on_exhaust must be 'raise' or 'warn', "
                             f"got {on_exhaust!r}")
        for _ in range(max_steps):
            if not self.has_unfinished():
                return
            self.step()
        if not self.has_unfinished():
            return
        self.metrics.run_budget_exhausted += 1
        left = self.scheduler.outstanding()
        msg = (f"run(max_steps={max_steps}) exhausted its step budget "
               f"with {len(left)} request(s) unfinished")
        if on_exhaust == "raise":
            raise StepBudgetExhausted(msg)
        import warnings
        warnings.warn(msg, RuntimeWarning, stacklevel=2)

    def _pump(self) -> bool:
        if not self.has_unfinished():
            return False
        self.step()
        return True

    # -- metrics -----------------------------------------------------------
    def _prefill_busy_fraction(self) -> float:
        if self._t0 is None:
            return 0.0
        elapsed = max(self._clock() - self._t0, 1e-9)
        busy = sum(w.call("stats")["busy_s"] for w in self.prefill_pool)
        return min(1.0, busy / (elapsed * len(self.prefill_pool)))

    def metrics_json(self) -> Dict:
        """The frontend's own request metrics (the authoritative TTFT/
        TPOT/queue numbers -- they include the transfer cost) with the
        per-worker dispatch counters merged in, plus a ``disagg``
        section: transfer bytes/latency, per-role occupancy, and the
        admission controller's view."""
        merged: Dict[str, int] = {}
        pf_stats = [w.stats() for w in self.prefill_pool]
        dc_stats = [w.stats() for w in self.decode_pool]
        for s in pf_stats + dc_stats:
            for k, v in s["counters"].items():
                merged[k] = merged.get(k, 0) + int(v)
        out = self.metrics.to_json(extra_counters=merged)
        occ = list(self.metrics.occupancy_series)
        out["disagg"] = {
            "mode": self.mode,
            "prefill": {
                "workers": len(self.prefill_pool),
                "requests": sum(s["requests"] for s in pf_stats),
                "busy_s": sum(s["busy_s"] for s in pf_stats),
                "occupancy": self._prefill_busy_fraction(),
                "dispatches": sum(
                    s["counters"].get("prefill_dispatches", 0)
                    for s in pf_stats),
                "cache": [s["cache"] for s in pf_stats],
            },
            "decode": {
                "workers": len(self.decode_pool),
                "slots_per_worker": self.max_batch,
                "occupancy_mean": (sum(occ) / len(occ) if occ
                                   else None),
                "snapshot_restores": sum(
                    s["counters"].get("prefix_restores", 0)
                    for s in dc_stats),
                "fallback_prefill_dispatches": sum(
                    s["counters"].get("prefill_dispatches", 0)
                    for s in dc_stats),
                "per_worker_occupancy": [s["occupancy_mean"]
                                         for s in dc_stats],
            },
            "transport": {
                "transfers": self.transfers,
                "bytes": self.transfer_bytes,
                "direct_admits": self.direct_admits,
                "latency_ms": stats_ms(list(self._transfer_s)),
            },
            "admission": self.controller.to_json(),
        }
        return out

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for w in getattr(self, "prefill_pool", []) + \
                getattr(self, "decode_pool", []):
            try:
                w.close()
            except Exception:       # pragma: no cover - best effort
                pass

    def __enter__(self) -> "DisaggEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def generate_disagg(params, cfg: ModelConfig,
                    prompts: Sequence[Sequence[int]], *,
                    max_new_tokens: int = 32, temperature: float = 0.0,
                    qctx=None, max_len: int = 2048,
                    prefill_workers: int = 1, decode_workers: int = 1,
                    mode: str = "thread") -> List[List[int]]:
    """Convenience batch generation through a DisaggEngine (the disagg
    twin of ``repro.serve.engine.generate``)."""
    if not prompts:
        raise ValueError("prompts is empty: pass at least one prompt")
    with DisaggEngine(params, cfg, max_batch=min(8, len(prompts)),
                      max_len=max_len, qctx=qctx,
                      prefill_workers=prefill_workers,
                      decode_workers=decode_workers, mode=mode) as eng:
        sp = SamplingParams(temperature=temperature,
                            max_tokens=max_new_tokens)
        states = [eng.add_request(list(p), sp) for p in prompts]
        eng.run()
        return [list(s.token_ids) for s in states]
