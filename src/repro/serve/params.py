"""Frozen per-request sampling parameters (the request-centric API).

``SamplingParams`` travels WITH a request instead of living on the
engine: every slot in a continuous decode batch can run its own
temperature / top-k / top-p / seed, and the batched sampler
(``repro.serve.sampler.sample_batched``) consumes the per-slot arrays
the engine core builds from these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Immutable sampling configuration for one request.

    ``temperature <= 0`` (or ``greedy=True``) selects greedy argmax.
    ``top_k=0`` and ``top_p=1.0`` disable the respective truncation;
    both act on the temperature-scaled distribution.  ``seed`` pins the
    request's sample stream independently of engine state (two requests
    with the same seed and prompt draw identical tokens, whatever else
    the batch is doing).  ``stop_token_ids`` finish the request
    INCLUSIVE of the stop token, matching the legacy ``eos_id``
    semantics.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    greedy: Optional[bool] = None      # None -> derived from temperature
    seed: Optional[int] = None
    max_tokens: int = 32
    stop_token_ids: Tuple[int, ...] = ()

    def __post_init__(self) -> None:
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens < 1:
            raise ValueError(
                f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.greedy is False and self.temperature <= 0.0:
            raise ValueError(
                "greedy=False needs temperature > 0 to sample from")
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))

    @property
    def is_greedy(self) -> bool:
        if self.greedy is not None:
            return self.greedy
        return self.temperature <= 0.0

    @property
    def effective_temperature(self) -> float:
        """What the sampler sees: 0.0 encodes greedy per-row."""
        return 0.0 if self.is_greedy else self.temperature

    def describe(self) -> str:  # pragma: no cover - cosmetic
        mode = "greedy" if self.is_greedy else f"T={self.temperature:g}"
        return (f"SamplingParams({mode}, top_k={self.top_k}, "
                f"top_p={self.top_p:g}, max_tokens={self.max_tokens})")


GREEDY = SamplingParams()
