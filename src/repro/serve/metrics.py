"""Per-request and engine-level serving metrics.

Definitions (all from the engine's injectable clock, seconds):

  queue_time = first scheduled - arrival (time spent QUEUED)
  TTFT       = first decoded token - arrival (queue + prefill + 1 step)
  TPOT       = mean inter-token time after the first token

Engine-level: decode steps, tokens/s (counted from the FIRST submission
to the last decoded token, so queue + prefill wall time is included --
it is a serving-throughput number, not a decode-loop number), mean slot
occupancy, queue-depth and occupancy series (one sample per non-idle
step, bounded), request counts, and the core's dispatch counters.  ``to_json`` emits plain
finite floats so the result can go straight into ``BENCH_PR.json`` and
the CI perf gate.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

# everything here is bounded so a long-lived engine cannot grow without
# limit: the step series keep the most recent _SERIES_CAP samples, and
# per-request records evict the OLDEST FINISHED entries beyond
# _REQUEST_CAP (live requests are never evicted).  Both are far beyond
# any benchmark/test horizon in this repo.
_SERIES_CAP = 4096
REQUEST_CAP = 4096


def evict_finished(records: Dict, cap: int, is_finished) -> None:
    """Drop the oldest FINISHED entries of an insertion-ordered dict
    until it fits ``cap`` (live entries are never dropped).  Shared by
    the metrics recorder and the engine's request-state table so the
    two retention policies cannot drift apart."""
    excess = len(records) - cap
    if excess <= 0:
        return
    stale = [k for k, v in records.items() if is_finished(v)][:excess]
    for k in stale:
        del records[k]


@dataclasses.dataclass
class RequestMetrics:
    """Raw timestamps/counts for one request; derived values lazily."""

    prompt_len: int = 0
    priority: int = 0
    cached_tokens: int = 0        # prompt tokens restored from the
    arrival_time: float = 0.0     # prefix cache instead of prefilled
    scheduled_time: Optional[float] = None
    first_token_time: Optional[float] = None
    last_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    generated: int = 0
    finish_reason: Optional[str] = None
    # speculative decoding (zero when the engine runs vanilla decode)
    spec_rounds: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0

    @property
    def spec_speedup(self) -> Optional[float]:
        """Tokens committed per dispatch round (vanilla decode == 1.0):
        the per-request speculative speedup in the dispatch-bound
        regime."""
        if self.spec_rounds == 0:
            return None
        return self.generated / self.spec_rounds

    @property
    def queue_time_s(self) -> Optional[float]:
        if self.scheduled_time is None:
            return None
        return self.scheduled_time - self.arrival_time

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot_s(self) -> Optional[float]:
        if (self.first_token_time is None
                or self.last_token_time is None or self.generated < 2):
            return None
        return ((self.last_token_time - self.first_token_time)
                / (self.generated - 1))

    def to_dict(self) -> Dict:
        def ms(v):
            return None if v is None else v * 1e3
        return {
            "prompt_len": self.prompt_len,
            "priority": self.priority,
            "cached_tokens": self.cached_tokens,
            "generated": self.generated,
            "finish_reason": self.finish_reason,
            "queue_time_ms": ms(self.queue_time_s),
            "ttft_ms": ms(self.ttft_s),
            "tpot_ms": ms(self.tpot_s),
            "spec_rounds": self.spec_rounds,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_speedup": self.spec_speedup,
        }


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Linear-interpolation percentile over pre-sorted values."""
    if not sorted_vals:
        return float("nan")
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = q * (len(sorted_vals) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


def _stats(vals: List[float]) -> Optional[Dict[str, float]]:
    """mean/p50/p95/p99/max/n summary of raw (unitless) samples."""
    vals = sorted(vals)
    if not vals:
        return None
    return {
        "mean": sum(vals) / len(vals),
        "p50": _percentile(vals, 0.50),
        "p95": _percentile(vals, 0.95),
        "p99": _percentile(vals, 0.99),
        "max": vals[-1],
        "n": len(vals),
    }


def _stats_ms(vals_s: List[float]) -> Optional[Dict[str, float]]:
    return _stats([v * 1e3 for v in vals_s])


def stats_ms(vals_s: List[float]) -> Optional[Dict[str, float]]:
    """Public alias: mean/p50/p95/p99/max/n summary (ms) of a list of
    second-valued samples -- the loadgen report uses the same shape as
    the engine summaries so BENCH_PR.json stays uniform."""
    return _stats_ms(vals_s)


class Metrics:
    """Event recorder the ``LLMEngine`` drives; query any time."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self.requests: Dict[str, RequestMetrics] = {}
        self.decode_steps = 0
        self.tokens_generated = 0
        self.requests_submitted = 0
        self.requests_finished = 0
        self.requests_cancelled = 0
        # times LLMEngine.run() exhausted its step budget with requests
        # still unfinished (a truncated run invalidates SLO numbers, so
        # it is surfaced here even when the caller downgraded the raise
        # to a warning)
        self.run_budget_exhausted = 0
        self.queue_depth_series: Deque[int] = deque(maxlen=_SERIES_CAP)
        self.occupancy_series: Deque[float] = deque(maxlen=_SERIES_CAP)
        self._start_time: Optional[float] = None
        self._last_token_time: Optional[float] = None

    def now(self) -> float:
        return self._clock()

    # -- request events ---------------------------------------------------
    def on_submit(self, request_id: str, prompt_len: int,
                  priority: int = 0) -> float:
        t = self.now()
        if self._start_time is None:
            self._start_time = t
        self.requests[request_id] = RequestMetrics(
            prompt_len=prompt_len, priority=priority, arrival_time=t)
        self.requests_submitted += 1
        return t

    def on_schedule(self, request_id: str,
                    cached_tokens: int = 0) -> float:
        t = self.now()
        m = self.requests[request_id]
        m.scheduled_time = t
        m.cached_tokens = cached_tokens
        return t

    def on_token(self, request_id: str) -> float:
        t = self.now()
        m = self.requests[request_id]
        if m.first_token_time is None:
            m.first_token_time = t
        m.last_token_time = t
        m.generated += 1
        self.tokens_generated += 1
        self._last_token_time = t
        return t

    def on_finish(self, request_id: str, reason: str) -> float:
        t = self.now()
        m = self.requests[request_id]
        m.finish_time = t
        m.finish_reason = reason
        self.requests_finished += 1
        if reason == "cancelled":
            self.requests_cancelled += 1
        evict_finished(self.requests, REQUEST_CAP,
                       lambda rm: rm.finish_time is not None)
        return t

    def on_spec_round(self, request_id: str, drafted: int,
                      accepted: int) -> None:
        """Record one speculative round for a request (``drafted`` =
        the round's k, ``accepted`` = draft tokens that survived
        verification; the committed tokens themselves flow through
        ``on_token`` as usual)."""
        m = self.requests.get(request_id)
        if m is None:
            return
        m.spec_rounds += 1
        m.spec_drafted += drafted
        m.spec_accepted += accepted

    # -- engine events ----------------------------------------------------
    def on_step(self, queue_depth: int, live: int, max_batch: int) -> None:
        self.decode_steps += 1
        self.queue_depth_series.append(queue_depth)
        self.occupancy_series.append(live / max_batch)

    # -- queries ----------------------------------------------------------
    def request(self, request_id: str) -> Dict:
        return self.requests[request_id].to_dict()

    def to_json(self, extra_counters: Optional[Dict[str, int]] = None,
                prefix_cache: Optional[Dict] = None,
                spec_decode: Optional[Dict] = None) -> Dict:
        """One JSON-safe dict: per-request, summary, engine sections --
        plus a ``prefix_cache`` section (hit-rate/bytes from the
        ``StateCache`` counters passed in, TTFT split by whether the
        request's prefix was cached) when ``prefix_cache`` stats are
        provided, and a ``spec_decode`` section (acceptance rate,
        drafted/accepted/rolled-back counters from the engine plus the
        per-request tokens-per-round speedup distribution) when
        ``spec_decode`` counters are provided."""
        elapsed = None
        if (self._start_time is not None
                and self._last_token_time is not None):
            elapsed = self._last_token_time - self._start_time
        occ = list(self.occupancy_series)
        engine = {
            "decode_steps": self.decode_steps,
            "tokens_generated": self.tokens_generated,
            "requests_submitted": self.requests_submitted,
            "requests_finished": self.requests_finished,
            "requests_cancelled": self.requests_cancelled,
            "run_budget_exhausted": self.run_budget_exhausted,
            "tokens_per_s": (self.tokens_generated / elapsed
                             if elapsed and elapsed > 0 else None),
            "occupancy_mean": (sum(occ) / len(occ) if occ else None),
            "queue_depth_series": list(self.queue_depth_series),
            "occupancy_series": occ,
        }
        if extra_counters:
            engine.update({k: int(v) for k, v in extra_counters.items()})
        ms = self.requests.values()
        summary = {
            "ttft_ms": _stats_ms([m.ttft_s for m in ms
                                  if m.ttft_s is not None]),
            "tpot_ms": _stats_ms([m.tpot_s for m in ms
                                  if m.tpot_s is not None]),
            "queue_time_ms": _stats_ms([m.queue_time_s for m in ms
                                        if m.queue_time_s is not None]),
        }
        out = {
            "requests": {rid: m.to_dict()
                         for rid, m in self.requests.items()},
            "summary": summary,
            "engine": engine,
        }
        if prefix_cache is not None:
            # TTFT split: a hit request restored >= 1 prompt tokens from
            # the cache; the gap between the two is the cache's win
            out["prefix_cache"] = dict(
                prefix_cache,
                ttft_ms_hit=_stats_ms([m.ttft_s for m in ms
                                       if m.ttft_s is not None
                                       and m.cached_tokens > 0]),
                ttft_ms_miss=_stats_ms([m.ttft_s for m in ms
                                        if m.ttft_s is not None
                                        and m.cached_tokens == 0]),
            )
        if spec_decode is not None:
            # per_request_speedup is tokens-per-dispatch-round, so 1.0
            # is vanilla decode and k+1 is a fully-accepted round
            out["spec_decode"] = dict(
                spec_decode,
                per_request_speedup=_stats(
                    [m.spec_speedup for m in ms
                     if m.spec_speedup is not None]),
            )
        return out

    def dump(self, path: str,
             extra_counters: Optional[Dict[str, int]] = None,
             prefix_cache: Optional[Dict] = None,
             spec_decode: Optional[Dict] = None) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(extra_counters, prefix_cache,
                                   spec_decode), f,
                      indent=1, sort_keys=True)
        return path
