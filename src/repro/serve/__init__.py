from repro.serve.engine import Engine, Request, generate
from repro.serve.sampler import sample
