from repro.serve.cache import CacheEntry, StateCache
from repro.serve.core import EngineCore
from repro.serve.disagg import DisaggEngine, SnapshotCorruption
from repro.serve.engine import LLMEngine, StepBudgetExhausted, generate
from repro.serve.metrics import Metrics, RequestMetrics
from repro.serve.params import SamplingParams
from repro.serve.pump import EnginePump
from repro.serve.request import (FinishReason, Request, RequestOutput,
                                 RequestState, RequestStatus,
                                 RequestStream)
from repro.serve.sampler import apply_top_k_top_p, sample, sample_batched
from repro.serve.scheduler import (CacheAwareScheduler, FCFSScheduler,
                                   PriorityScheduler, Scheduler,
                                   make_scheduler)
from repro.serve.spec import SpecConfig

__all__ = [
    "CacheEntry", "StateCache",
    "DisaggEngine", "SnapshotCorruption",
    "EngineCore", "LLMEngine", "StepBudgetExhausted", "generate",
    "Metrics", "RequestMetrics", "SamplingParams",
    "EnginePump",
    "FinishReason", "Request", "RequestOutput", "RequestState",
    "RequestStatus", "RequestStream",
    "apply_top_k_top_p", "sample", "sample_batched",
    "CacheAwareScheduler", "FCFSScheduler", "PriorityScheduler",
    "Scheduler", "make_scheduler",
    "SpecConfig",
]
