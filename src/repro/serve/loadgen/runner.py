"""Trace replay against a live engine + the SLO report.

``run(engine, trace)`` replays a :class:`Trace` and returns one
JSON-safe report: tail latency (p50/p95/p99 TTFT/TPOT), goodput,
time-weighted occupancy, the per-request token streams, and the SLO
verdict.  Two pump modes:

* ``pump="async"`` -- the real serving shape: an :class:`EnginePump`
  steps the engine from a background thread while this thread paces
  arrivals, so the open-loop schedule is honored (the engine decodes
  *between* arrivals).
* ``pump="sync"`` -- the consumer-pumped control: arrivals are paced
  on the same wall schedule but nothing steps the engine until the
  last request is in; then a step-drain loop runs it dry.  This is
  exactly what today's pull-pumped streams do under load, and it is
  fully deterministic (admission order == trace order), which makes it
  the replay mode: two sync runs of the same trace produce identical
  token streams AND identical schedules.

Occupancy is TIME-weighted -- ``sum(occupancy * step_duration)`` over
the wall window from the first submission to the last step -- so wall
time the engine spends idle while requests are arriving counts as
zero.  Per-step means would flatter the sync control (it only steps
with full queues); the time-weighted number is what capacity planning
actually cares about, and it is the async pump's win:
``steps_before_last_arrival`` is 0 for sync by construction and > 0
for async whenever there is any decode overlap.

Cancellation replay: ``cancel_after_tokens=0`` cancels at submission
(atomically with it under the async pump, via ``run_locked``);
``k > 0`` cancels from the request's own ``on_token`` callback the
moment the k-th token lands, which the engine supports reentrantly
from inside ``step()``.  Both are token-deterministic, never
wall-clock races.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.serve.engine import LLMEngine
from repro.serve.loadgen.trace import Trace, TraceEvent, validate_prompts
from repro.serve.metrics import stats_ms
from repro.serve.pump import EnginePump

_DRAIN_STEP_CAP = 200_000


@dataclasses.dataclass(frozen=True)
class SLO:
    """Latency/goodput objectives for one loadgen run (milliseconds).

    ``ttft_ms``/``tpot_ms`` are PER-REQUEST bounds: a finished request
    is "good" (counts toward goodput) only when it meets them.  The
    ``*_p95``/``*_p99`` fields gate the report's tail percentiles;
    ``check`` returns the list of violations (empty == pass).
    """

    ttft_ms: Optional[float] = None
    tpot_ms: Optional[float] = None
    ttft_p95_ms: Optional[float] = None
    ttft_p99_ms: Optional[float] = None
    tpot_p95_ms: Optional[float] = None

    def good(self, ttft_ms: Optional[float],
             tpot_ms: Optional[float]) -> bool:
        if (self.ttft_ms is not None
                and (ttft_ms is None or ttft_ms > self.ttft_ms)):
            return False
        if (self.tpot_ms is not None and tpot_ms is not None
                and tpot_ms > self.tpot_ms):
            return False
        return True

    def check(self, report: Dict) -> List[str]:
        out = []
        for section, pct, bound in (
                ("ttft_ms", "p95", self.ttft_p95_ms),
                ("ttft_ms", "p99", self.ttft_p99_ms),
                ("tpot_ms", "p95", self.tpot_p95_ms)):
            if bound is None:
                continue
            stats = report.get(section)
            got = stats.get(pct) if stats else None
            if got is None or got > bound:
                out.append(f"{section}.{pct} = "
                           f"{'n/a' if got is None else f'{got:.2f}'} ms "
                           f"> SLO {bound:.2f} ms")
        return out

    def to_json(self) -> Dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


def _cancel_hook(engine: LLMEngine, event: TraceEvent):
    """``on_token`` callback cancelling after the k-th token (k >= 1).
    Fires from inside ``step()`` -- the engine handles the reentry."""
    k = event.cancel_after_tokens
    if not k:
        return None
    seen = {"n": 0}

    def on_token(_tok: int) -> None:
        seen["n"] += 1
        if seen["n"] == k:
            engine.cancel(event.request_id)
    return on_token


def run(engine: LLMEngine, trace: Trace, slo: Optional[SLO] = None, *,
        pump: str = "async", time_scale: float = 1.0,
        drain_timeout_s: float = 300.0, warmup: bool = True,
        clock: Callable[[], float] = time.perf_counter,
        sleep: Callable[[float], None] = time.sleep) -> Dict:
    """Replay ``trace`` against ``engine`` and report (see module doc).

    ``time_scale`` compresses/stretches the arrival schedule
    (``0`` = submit as fast as possible); ``warmup`` runs one tiny
    request to absorb jit compilation before the pacing clock starts.
    """
    if pump not in ("async", "sync"):
        raise ValueError(f"pump must be 'async' or 'sync', got {pump!r}")
    if time_scale < 0:
        raise ValueError(f"time_scale must be >= 0, got {time_scale}")
    if not trace.events:
        raise ValueError("trace has no events")
    validate_prompts(trace, engine.cfg.vocab_size, engine.core.max_len)

    if warmup:
        wst = engine.add_request(
            list(trace.events[0].prompt[:4]) or [0],
            request_id="loadgen-warmup")
        while not wst.finished:
            engine.step()

    states: Dict[str, object] = {}
    samples: List = []          # (step start, duration, occupancy)
    submit_lag_s: List[float] = []
    t_start = clock()
    last_submit = t_start

    def _submit(add, cancel, locked, event: TraceEvent):
        nonlocal last_submit
        due = t_start + event.t * time_scale
        while True:
            wait = due - clock()
            if wait <= 0:
                break
            sleep(wait)
        submit_lag_s.append(max(0.0, clock() - due))

        def _go():
            st = add(list(event.prompt), event.sampling_params(),
                     request_id=event.request_id,
                     priority=event.priority,
                     on_token=_cancel_hook(engine, event))
            if event.cancel_after_tokens == 0:
                cancel(event.request_id)
            return st
        states[event.request_id] = locked(_go)
        last_submit = clock()

    if pump == "async":
        with EnginePump(engine, clock=clock) as ep:
            for ev in trace.events:
                _submit(ep.add_request, ep.cancel, ep.run_locked, ev)
            if not ep.drain(timeout=drain_timeout_s):
                raise RuntimeError(
                    f"loadgen drain timed out after {drain_timeout_s}s "
                    f"with {engine.scheduler.outstanding()!r} "
                    "outstanding")
            samples = list(ep.samples)
    else:
        for ev in trace.events:
            _submit(engine.add_request, engine.cancel, lambda f: f(), ev)
        steps = 0
        while engine.has_unfinished():
            if steps >= _DRAIN_STEP_CAP:
                raise RuntimeError(
                    f"sync drain exceeded {_DRAIN_STEP_CAP} steps with "
                    f"{engine.scheduler.outstanding()!r} outstanding")
            t0 = clock()
            engine.step()
            occ = engine.metrics.occupancy_series
            samples.append((t0, clock() - t0, occ[-1] if occ else 0.0))
            steps += 1
    t_end = clock()

    return _report(engine, trace, slo, states, samples,
                   pump=pump, time_scale=time_scale,
                   window=(t_start, last_submit, t_end),
                   submit_lag_s=submit_lag_s)


def _report(engine: LLMEngine, trace: Trace, slo: Optional[SLO],
            states: Dict, samples: List, *, pump: str,
            time_scale: float, window, submit_lag_s) -> Dict:
    t_start, last_submit, t_end = window
    recs = {e.request_id: engine.metrics.requests[e.request_id]
            for e in trace.events}

    busy = sum(occ * dur for _, dur, occ in samples)
    span = max(t_end - t_start,
               max((t0 + dur for t0, dur, _ in samples),
                   default=t_start) - t_start)

    good_requests = good_tokens = 0
    for rid, m in recs.items():
        if m.finish_reason not in ("stop", "length"):
            continue
        d = m.to_dict()
        if slo is None or slo.good(d["ttft_ms"], d["tpot_ms"]):
            good_requests += 1
            good_tokens += m.generated

    scheduled = [rid for rid, m in recs.items()
                 if m.scheduled_time is not None]
    scheduled.sort(key=lambda rid: recs[rid].scheduled_time)

    report = {
        "trace": {"name": trace.name, "seed": trace.seed,
                  "n_requests": len(trace),
                  "n_cancelled": trace.n_cancelled,
                  "span_s": trace.span_s},
        "pump": pump,
        "time_scale": time_scale,
        "wall_s": t_end - t_start,
        "ttft_ms": stats_ms([m.ttft_s for m in recs.values()
                             if m.ttft_s is not None]),
        "tpot_ms": stats_ms([m.tpot_s for m in recs.values()
                             if m.tpot_s is not None]),
        "queue_time_ms": stats_ms([m.queue_time_s for m in recs.values()
                                   if m.queue_time_s is not None]),
        "submit_lag_ms": stats_ms(submit_lag_s),
        "goodput_requests": good_requests,
        "goodput_tokens": good_tokens,
        "goodput_rps": (good_requests / (t_end - t_start)
                        if t_end > t_start else None),
        "completed": sum(1 for m in recs.values()
                         if m.finish_reason in ("stop", "length")),
        "cancelled": sum(1 for m in recs.values()
                         if m.finish_reason == "cancelled"),
        "steps": len(samples),
        "steps_before_last_arrival": sum(
            1 for t0, _, _ in samples if t0 < last_submit),
        "occupancy_mean": busy / span if span > 0 else None,
        "schedule": scheduled,
        "token_streams": {rid: list(states[rid].token_ids)
                          for rid in recs},
    }
    if slo is not None:
        violations = slo.check(report)
        report["slo"] = {"objectives": slo.to_json(),
                         "violations": violations,
                         "ok": not violations}
    return report
