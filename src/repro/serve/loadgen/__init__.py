"""repro.serve.loadgen -- trace-driven traffic simulation.

Build a deterministic arrival trace from seeded workload models
(:class:`WorkloadMix`), replay it against an :class:`LLMEngine`
through the async :class:`EnginePump` (or the sync consumer-pumped
control), and get back a tail-latency / goodput / occupancy report
gated by an :class:`SLO`::

    from repro.serve.loadgen import (SharedPrefixChat, RAGLongPrompt,
                                     BurstyArrivals, WorkloadMix,
                                     SLO, run)
    mix = WorkloadMix([(3, SharedPrefixChat()), (1, RAGLongPrompt())],
                      cancel_fraction=0.1)
    trace = mix.build(n_requests=64, vocab_size=cfg.vocab_size, seed=0)
    trace.save("trace.json")          # replay later, bit-identically
    report = run(engine, trace, SLO(ttft_p99_ms=500.0))
"""
from repro.serve.loadgen.runner import SLO, run
from repro.serve.loadgen.trace import (TRACE_VERSION, Trace, TraceEvent,
                                       validate_prompts)
from repro.serve.loadgen.workloads import (BurstyArrivals,
                                           ClusteredArrivals,
                                           RAGLongPrompt,
                                           SharedPrefixChat,
                                           UniformArrivals, WorkloadMix)

__all__ = [
    "SLO", "run",
    "TRACE_VERSION", "Trace", "TraceEvent", "validate_prompts",
    "BurstyArrivals", "ClusteredArrivals", "RAGLongPrompt",
    "SharedPrefixChat", "UniformArrivals", "WorkloadMix",
]
