"""Replayable traffic traces: the loadgen's on-disk interchange format.

A trace is a list of arrival events, each carrying everything the
engine needs to reproduce the request exactly: the prompt, the full
``SamplingParams`` surface (including an explicit per-request PRNG
seed -- seedless requests would derive their key from the admission
order, which an async replay does not fix), a priority, and an
optional deterministic cancellation point.

Cancellation is expressed in *tokens*, not wall time:
``cancel_after_tokens=k`` cancels the request the moment its ``k``-th
token is delivered (``k=0`` cancels at submission, before any token
can be decoded).  Wall-time cancels would race the scheduler and make
two replays disagree on how many tokens a cancelled request produced;
token-count cancels make the cancelled stream bit-reproducible.

The JSON schema (``version`` 1) is flat and self-describing::

    {"version": 1, "name": ..., "seed": ..., "meta": {...},
     "events": [{"t": 0.013, "request_id": "chat-0",
                 "prompt": [...], "max_tokens": 8,
                 "temperature": 0.8, "top_k": 20, "top_p": 0.95,
                 "seed": 1234, "stop_token_ids": [], "priority": 0,
                 "cancel_after_tokens": null, "workload": "chat"}]}

``Trace.save``/``Trace.load`` round-trip it; two builds of the same
``WorkloadMix`` with the same seed serialize to identical JSON.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.serve.params import SamplingParams

TRACE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One request arrival at trace time ``t`` (seconds from start)."""

    t: float
    request_id: str
    prompt: Tuple[int, ...]
    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    stop_token_ids: Tuple[int, ...] = ()
    priority: int = 0
    cancel_after_tokens: Optional[int] = None
    workload: str = ""

    def sampling_params(self) -> SamplingParams:
        return SamplingParams(temperature=self.temperature,
                              top_k=self.top_k, top_p=self.top_p,
                              seed=self.seed,
                              max_tokens=self.max_tokens,
                              stop_token_ids=tuple(self.stop_token_ids))

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["prompt"] = list(self.prompt)
        d["stop_token_ids"] = list(self.stop_token_ids)
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "TraceEvent":
        d = dict(d)
        d["prompt"] = tuple(int(t) for t in d["prompt"])
        d["stop_token_ids"] = tuple(int(t)
                                    for t in d.get("stop_token_ids", ()))
        return cls(**d)


@dataclasses.dataclass
class Trace:
    """An ordered arrival schedule plus its provenance."""

    events: List[TraceEvent]
    seed: int = 0
    name: str = "trace"
    meta: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.events = sorted(self.events, key=lambda e: e.t)
        seen = set()
        for e in self.events:
            if e.t < 0:
                raise ValueError(
                    f"event {e.request_id} has negative time {e.t}")
            if e.request_id in seen:
                raise ValueError(
                    f"duplicate request_id {e.request_id!r} in trace")
            seen.add(e.request_id)

    def __len__(self) -> int:
        return len(self.events)

    @property
    def span_s(self) -> float:
        """Arrival window: time of the last arrival."""
        return self.events[-1].t if self.events else 0.0

    @property
    def n_cancelled(self) -> int:
        return sum(1 for e in self.events
                   if e.cancel_after_tokens is not None)

    # -- serialization -----------------------------------------------------
    def to_json(self) -> Dict:
        return {"version": TRACE_VERSION, "name": self.name,
                "seed": self.seed, "meta": self.meta,
                "events": [e.to_json() for e in self.events]}

    @classmethod
    def from_json(cls, d: Dict) -> "Trace":
        v = d.get("version")
        if v != TRACE_VERSION:
            raise ValueError(
                f"unsupported trace version {v!r} "
                f"(this build reads version {TRACE_VERSION})")
        return cls(events=[TraceEvent.from_json(e) for e in d["events"]],
                   seed=int(d.get("seed", 0)),
                   name=d.get("name", "trace"),
                   meta=dict(d.get("meta", {})))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(json.load(f))


def validate_prompts(trace: Trace, vocab_size: int,
                     max_len: Optional[int] = None) -> None:
    """Fail fast (before any device work) when a trace does not fit
    the engine it is about to be replayed on."""
    for e in trace.events:
        if not e.prompt:
            raise ValueError(f"event {e.request_id} has an empty prompt")
        bad = [t for t in e.prompt if not 0 <= t < vocab_size]
        if bad:
            raise ValueError(
                f"event {e.request_id} has out-of-vocab tokens "
                f"{bad[:4]} (vocab_size={vocab_size})")
        if max_len is not None and len(e.prompt) + e.max_tokens > max_len:
            raise ValueError(
                f"event {e.request_id} needs "
                f"{len(e.prompt) + e.max_tokens} positions but the "
                f"engine's max_len is {max_len}")
