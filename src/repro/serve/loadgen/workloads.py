"""Deterministic, seeded workload models for the load generator.

Every generator draws from a ``random.Random`` threaded through
``WorkloadMix.build`` -- no wall-clock entropy anywhere, so the same
``(mix, seed, n_requests)`` always builds the identical trace and a
saved trace replays exactly.

Components:

* :class:`SharedPrefixChat` -- chat traffic against a pool of shared
  system prompts / few-shot templates.  Prefix popularity is
  Zipf-distributed (rank ``r`` drawn with weight ``1 / r**zipf_a``),
  the realistic shape for prefix-cache stress: a couple of hot
  prefixes dominate while a long tail of cold ones forces eviction.
* :class:`RAGLongPrompt` -- retrieval-augmented requests: long, mostly
  unique prompts (the pasted-context shape) with short completions.
  These are prefill-heavy and cache-hostile by design.
* :class:`BurstyArrivals` -- open-loop arrival process: Poisson gaps
  whose rate switches between a base and a burst level via on/off
  phases with exponentially distributed durations (a standard
  Markov-modulated Poisson process).  Bursts are what make tail
  latency diverge from the mean, which is the whole point of gating
  p95/p99 instead of means.

``WorkloadMix`` composes weighted components, sprinkles deterministic
mid-flight cancellations (``cancel_fraction`` of requests get a
``cancel_after_tokens`` point), and emits a :class:`Trace`.
"""
from __future__ import annotations

import bisect
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.loadgen.trace import Trace, TraceEvent


def _span(rng: random.Random, lo_hi: Tuple[int, int]) -> int:
    lo, hi = lo_hi
    if lo > hi:
        raise ValueError(f"range ({lo}, {hi}) has lo > hi")
    return rng.randint(lo, hi)


class SharedPrefixChat:
    """Zipf-reused shared prompt heads + short unique suffixes."""

    name = "chat"

    def __init__(self, *, n_prefixes: int = 8, prefix_len: int = 32,
                 zipf_a: float = 1.2,
                 suffix_len: Tuple[int, int] = (2, 6),
                 max_tokens: Tuple[int, int] = (4, 12),
                 sampled_fraction: float = 0.5):
        if n_prefixes < 1 or prefix_len < 1:
            raise ValueError("need at least one prefix of length >= 1")
        self.n_prefixes = n_prefixes
        self.prefix_len = prefix_len
        self.zipf_a = zipf_a
        self.suffix_len = suffix_len
        self.max_tokens = max_tokens
        self.sampled_fraction = sampled_fraction
        self._prefixes: List[List[int]] = []
        self._cum: List[float] = []

    def prepare(self, rng: random.Random, vocab_size: int) -> None:
        self._prefixes = [[rng.randrange(vocab_size)
                           for _ in range(self.prefix_len)]
                          for _ in range(self.n_prefixes)]
        weights = [1.0 / (r + 1) ** self.zipf_a
                   for r in range(self.n_prefixes)]
        total = sum(weights)
        acc, self._cum = 0.0, []
        for w in weights:
            acc += w / total
            self._cum.append(acc)

    def sample(self, rng: random.Random, vocab_size: int) -> Dict:
        idx = min(bisect.bisect_left(self._cum, rng.random()),
                  self.n_prefixes - 1)
        suffix = [rng.randrange(vocab_size)
                  for _ in range(_span(rng, self.suffix_len))]
        sampled = rng.random() < self.sampled_fraction
        return {
            "prompt": tuple(self._prefixes[idx] + suffix),
            "max_tokens": _span(rng, self.max_tokens),
            "temperature": 0.8 if sampled else 0.0,
            "top_k": 20 if sampled else 0,
            "top_p": 0.95 if sampled else 1.0,
        }


class RAGLongPrompt:
    """Long unique prompts, short outputs (prefill-dominated)."""

    name = "rag"

    def __init__(self, *, prompt_len: Tuple[int, int] = (48, 128),
                 max_tokens: Tuple[int, int] = (2, 6),
                 sampled_fraction: float = 0.2):
        self.prompt_len = prompt_len
        self.max_tokens = max_tokens
        self.sampled_fraction = sampled_fraction

    def prepare(self, rng: random.Random, vocab_size: int) -> None:
        del rng, vocab_size          # stateless: nothing to materialize

    def sample(self, rng: random.Random, vocab_size: int) -> Dict:
        n = _span(rng, self.prompt_len)
        sampled = rng.random() < self.sampled_fraction
        return {
            "prompt": tuple(rng.randrange(vocab_size) for _ in range(n)),
            "max_tokens": _span(rng, self.max_tokens),
            "temperature": 0.7 if sampled else 0.0,
            "top_k": 0,
            "top_p": 0.9 if sampled else 1.0,
        }


class BurstyArrivals:
    """Markov-modulated Poisson arrivals: base rate with burst phases.

    ``rate`` / ``burst_rate`` are requests per second; ``off_s`` /
    ``on_s`` are the MEAN durations of the base and burst phases
    (exponentially distributed).  ``burst_rate=rate`` degrades to a
    plain Poisson process.
    """

    def __init__(self, *, rate: float = 20.0, burst_rate: float = 80.0,
                 on_s: float = 0.1, off_s: float = 0.2):
        if rate <= 0 or burst_rate <= 0:
            raise ValueError("arrival rates must be > 0")
        if on_s <= 0 or off_s <= 0:
            raise ValueError("phase durations must be > 0")
        self.rate = rate
        self.burst_rate = burst_rate
        self.on_s = on_s
        self.off_s = off_s

    def times(self, rng: random.Random, n: int) -> List[float]:
        out: List[float] = []
        t = 0.0
        bursting = False
        phase_end = rng.expovariate(1.0 / self.off_s)
        while len(out) < n:
            gap = rng.expovariate(self.burst_rate if bursting
                                  else self.rate)
            t += gap
            while t >= phase_end:
                bursting = not bursting
                phase_end += rng.expovariate(
                    1.0 / (self.on_s if bursting else self.off_s))
            out.append(t)
        return out


class ClusteredArrivals:
    """``n_clusters`` near-simultaneous bursts, ``gap_s`` apart.

    The adversarial shape for a consumer-pumped engine: each burst
    fills the batch, then nothing arrives while it drains.  A
    background pump decodes each burst during the following gap; the
    sync control cannot start until the last burst has landed, which
    is exactly the time-weighted-occupancy separation the loadgen
    benchmark measures.  Deterministic (no rng draw).
    """

    def __init__(self, *, n_clusters: int = 4, gap_s: float = 1.0,
                 spread_s: float = 0.005):
        if n_clusters < 1 or gap_s < 0 or spread_s < 0:
            raise ValueError("need n_clusters >= 1 and non-negative "
                             "gap_s / spread_s")
        self.n_clusters = n_clusters
        self.gap_s = gap_s
        self.spread_s = spread_s

    def times(self, rng: random.Random, n: int) -> List[float]:
        del rng
        per = max(1, (n + self.n_clusters - 1) // self.n_clusters)
        return [(i // per) * self.gap_s + (i % per) * self.spread_s
                for i in range(n)]


class UniformArrivals:
    """Evenly spaced arrivals over ``span_s`` (a smoke-test pacing)."""

    def __init__(self, *, span_s: float = 0.5):
        if span_s < 0:
            raise ValueError("span_s must be >= 0")
        self.span_s = span_s

    def times(self, rng: random.Random, n: int) -> List[float]:
        del rng
        if n <= 1:
            return [0.0] * n
        return [i * self.span_s / (n - 1) for i in range(n)]


class WorkloadMix:
    """Weighted composition of workload components -> :class:`Trace`.

    ``components`` is ``[(weight, component), ...]``;
    ``cancel_fraction`` of the generated requests receive a
    deterministic ``cancel_after_tokens`` drawn from
    ``cancel_after_tokens`` (0 = cancel at submission -- exercises
    cancel-while-queued; larger values cancel mid-decode).
    """

    def __init__(self, components: Sequence[Tuple[float, object]], *,
                 cancel_fraction: float = 0.0,
                 cancel_after_tokens: Tuple[int, int] = (0, 3)):
        if not components:
            raise ValueError("WorkloadMix needs at least one component")
        if any(w <= 0 for w, _ in components):
            raise ValueError("component weights must be > 0")
        if not 0.0 <= cancel_fraction <= 1.0:
            raise ValueError(
                f"cancel_fraction must be in [0, 1], got "
                f"{cancel_fraction}")
        self.components = list(components)
        self.cancel_fraction = cancel_fraction
        self.cancel_after_tokens = cancel_after_tokens
        total = sum(w for w, _ in components)
        acc, self._cum = 0.0, []
        for w, _ in components:
            acc += w / total
            self._cum.append(acc)

    def build(self, *, n_requests: int, vocab_size: int, seed: int = 0,
              arrivals: Optional[object] = None,
              name: str = "mix") -> Trace:
        """Generate a fully replayable trace.  Every request carries an
        explicit SamplingParams seed, so the replayed token streams do
        not depend on admission order (the engine's seedless fallback
        would tie them to it)."""
        if n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        rng = random.Random(seed)
        arrivals = arrivals if arrivals is not None else BurstyArrivals()
        times = arrivals.times(rng, n_requests)
        for _, comp in self.components:
            comp.prepare(rng, vocab_size)
        events: List[TraceEvent] = []
        counts: Dict[str, int] = {}
        for i, t in enumerate(times):
            ci = min(bisect.bisect_left(self._cum, rng.random()),
                     len(self.components) - 1)
            comp = self.components[ci][1]
            fields = comp.sample(rng, vocab_size)
            cancel = None
            if rng.random() < self.cancel_fraction:
                cancel = _span(rng, self.cancel_after_tokens)
            counts[comp.name] = counts.get(comp.name, 0) + 1
            events.append(TraceEvent(
                t=round(t, 6),
                request_id=f"{comp.name}-{i}",
                seed=rng.randrange(1 << 31),
                cancel_after_tokens=cancel,
                workload=comp.name,
                **fields))
        return Trace(events=events, seed=seed, name=name,
                     meta={"n_requests": n_requests,
                           "vocab_size": vocab_size,
                           "arrivals": type(arrivals).__name__,
                           "cancel_fraction": self.cancel_fraction,
                           "component_counts": counts})
