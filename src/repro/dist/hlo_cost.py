"""Trip-count-aware HLO-text cost analysis.

``Compiled.cost_analysis()`` walks the HLO graph but counts each
while-loop *body once*, which makes it useless for scanned-layer models:
a 48-layer Mamba stack compiled with ``lax.scan`` reports 1/48th of the
real flops.  XLA *does* annotate while ops with
``backend_config={"known_trip_count":{"n":...}}`` after trip-count
analysis, so the fix is mechanical: parse the HLO text, walk the call
graph from ENTRY, and multiply every while body (and the collectives
inside it -- one fire per scanned layer) by its trip count.

``analyze(hlo_text)`` returns::

    {"flops":             dot/conv + elementwise flops, trip-multiplied,
     "transcendentals":   tanh/exp/log/... element counts,
     "bytes accessed":    slice-aware operand+output bytes,
     "collective_bytes":  output bytes of collective ops,
     "collective_count":  number of collective fires,
     "collective_by_type": {op_name: bytes},
     "bytes_by_op":       {op_name: bytes}}

Byte accounting is *slice-aware*: a ``dynamic-slice`` (and a fusion
whose parameter is consumed only by slices -- the stacked-weight gather
inside every scan body) charges the slice, not the full operand.  This
matches what a chip actually moves per trip.

The parser is deliberately tolerant: unknown operands, exotic ops and
partial HLO snippets cost 0 rather than raising.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# shape / dtype parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_dims(shape_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All (dtype, dims) array shapes in a type string (tuples give >1)."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _numel(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _type_bytes(shape_str: str) -> float:
    return sum(_DTYPE_BYTES[dt] * _numel(dims)
               for dt, dims in _shape_dims(shape_str))


def _type_elems(shape_str: str) -> int:
    return sum(_numel(dims) for _, dims in _shape_dims(shape_str))


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-~]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# "%name = <type> <op>(...)" -- the non-greedy type group also captures
# tuple types like "(s32[], f32[64]{0})"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-~]+)\s*=\s*(.*?)\s+([a-z][\w\-]*)\(")

_FREE_OPS = frozenset((
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    "rng-get-and-update-state",
    # async completion halves: the matching -start op already carried
    # the full cost (counting -done too would double-charge the buffer)
    "all-reduce-done", "all-gather-done", "reduce-scatter-done",
    "all-to-all-done", "collective-permute-done", "copy-done",
    "send-done", "recv-done",
))

_TRANSCENDENTAL = frozenset((
    "tanh", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "rsqrt", "sqrt", "power", "sine", "cosine", "logistic", "erf", "atan2",
    "cbrt", "tan",
))

_ELEMENTWISE = frozenset((
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "negate", "abs", "select", "compare", "and", "or", "xor", "not",
    "clamp", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "sign", "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "remainder", "convert",
))

_COLLECTIVES = frozenset((
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-reduce-start", "all-gather-start", "collective-permute-start",
))

_SLICE_OPS = frozenset(("dynamic-slice", "slice", "gather"))

_TRIP_RE = re.compile(r"known_trip_count[^0-9]*?(\d+)")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-~]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


class _Instr:
    __slots__ = ("name", "type_str", "op", "operands", "attrs", "line")

    def __init__(self, name, type_str, op, operands, attrs, line):
        self.name = name
        self.type_str = type_str
        self.op = op
        self.operands = operands
        self.attrs = attrs
        self.line = line


def _split_operands(text: str) -> List[str]:
    """Split an operand list on top-level commas."""
    out, depth, cur = [], 0, []
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return [o for o in out if o]


def _operand_split(line: str, op: str) -> Tuple[str, str]:
    """(operand_text, attr_text) for an instruction line."""
    start = line.find(op + "(")
    if start < 0:
        return "", ""
    i = start + len(op)
    depth = 0
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                return line[i + 1:j], line[j + 1:]
    return line[i + 1:], ""


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[_Instr]],
                                           Optional[str]]:
    comps: Dict[str, List[_Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line.strip())
        if m and line.strip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, type_str, op = im.group(1), im.group(2), im.group(3)
        opnds, attrs = _operand_split(line, op)
        comps[cur].append(
            _Instr(name, type_str, op, _split_operands(opnds), attrs,
                   line))
    return comps, entry


def _operand_type(operand: str, symbols: Dict[str, str]) -> Optional[str]:
    """Type string of an operand ref ('f32[2,4]{1,0} %x' or '%x')."""
    operand = operand.strip()
    m = re.match(r"^(.*?)\s*%([\w.\-~]+)$", operand)
    if m:
        if m.group(1):
            return m.group(1)
        return symbols.get(m.group(2))
    if operand.startswith("%"):
        return symbols.get(operand[1:])
    # bare typed literal (rare)
    return operand if _SHAPE_RE.search(operand) else None


def _dot_flops(ins: _Instr, symbols: Dict[str, str]) -> float:
    out_elems = _type_elems(ins.type_str)
    if not ins.operands:
        return 0.0
    lhs_t = _operand_type(ins.operands[0], symbols)
    contract = 1
    if lhs_t:
        shapes = _shape_dims(lhs_t)
        if shapes:
            dims = shapes[0][1]
            m = _CONTRACT_RE.search(ins.attrs)
            if m:
                for i in (int(x) for x in m.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(ins: _Instr, symbols: Dict[str, str]) -> float:
    """2 * output_elems * kernel_spatial * in_features / groups (approx)."""
    out_elems = _type_elems(ins.type_str)
    if len(ins.operands) < 2:
        return 2.0 * out_elems
    rhs_t = _operand_type(ins.operands[1], symbols)
    k = 1
    if rhs_t:
        shapes = _shape_dims(rhs_t)
        if shapes and shapes[0][1]:
            dims = shapes[0][1]
            # kernel = spatial... x in_features x out_features; drop the
            # largest dim as out_features (heuristic on text alone)
            k = _numel(dims) // max(dims)
    gm = re.search(r"feature_group_count=(\d+)", ins.attrs)
    groups = int(gm.group(1)) if gm else 1
    return 2.0 * out_elems * max(1, k // max(1, groups))


def _slice_aware_operand_bytes(ins: _Instr, symbols: Dict[str, str],
                               comp: Optional[List[_Instr]]) -> float:
    """Operand bytes for a fusion/call, charging sliced params by their
    slice output rather than the full array."""
    total = 0.0
    for idx, opnd in enumerate(ins.operands):
        t = _operand_type(opnd, symbols)
        full = _type_bytes(t) if t else 0.0
        if comp is None:
            total += full
            continue
        params = [i for i in comp if i.op == "parameter"]
        pname = None
        for p in params:
            pm = re.search(r"parameter\((\d+)\)", p.line)
            if pm and int(pm.group(1)) == idx:
                pname = p.name
                break
        if pname is None:
            total += full
            continue
        uses = [i for i in comp
                if any(re.search(r"%" + re.escape(pname) + r"\b", o)
                       for o in i.operands)]
        if uses and all(u.op in _SLICE_OPS for u in uses):
            total += sum(_type_bytes(u.type_str) for u in uses)
        else:
            total += full
    return total


# ---------------------------------------------------------------------------
# the walker
# ---------------------------------------------------------------------------

class _Cost:
    def __init__(self):
        self.flops = 0.0
        self.transcendentals = 0.0
        self.bytes = 0.0
        self.coll_bytes = 0.0
        self.coll_count = 0.0
        self.coll_by_type: Dict[str, float] = {}
        self.bytes_by_op: Dict[str, float] = {}

    def add(self, other: "_Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.transcendentals += mult * other.transcendentals
        self.bytes += mult * other.bytes
        self.coll_bytes += mult * other.coll_bytes
        self.coll_count += mult * other.coll_count
        for k, v in other.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0) + mult * v
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0) + mult * v


def _trip_count(attrs: str) -> int:
    m = _TRIP_RE.search(attrs)
    return int(m.group(1)) if m else 1


def _called(attrs: str) -> List[str]:
    return _CALLED_RE.findall(attrs)


def _comp_cost(comp_name: str, comps: Dict[str, List[_Instr]],
               cache: Dict[str, _Cost], stack: Tuple[str, ...] = ()
               ) -> _Cost:
    if comp_name in cache:
        return cache[comp_name]
    if comp_name in stack or comp_name not in comps:
        return _Cost()
    cost = _Cost()
    instrs = comps[comp_name]
    symbols = {i.name: i.type_str for i in instrs}
    stack = stack + (comp_name,)
    for ins in instrs:
        op = ins.op
        if op in _FREE_OPS:
            continue
        out_bytes = _type_bytes(ins.type_str)

        if op == "while":
            trips = _trip_count(ins.attrs)
            for callee in _called(ins.attrs):
                cost.add(_comp_cost(callee, comps, cache, stack), trips)
            continue
        if op == "call":
            for callee in _CALLED_RE.findall(ins.attrs):
                cost.add(_comp_cost(callee, comps, cache, stack))
            continue
        if op == "conditional":
            sub = [_comp_cost(c, comps, cache, stack)
                   for c in _called(ins.attrs)]
            if sub:  # charge the most expensive branch
                cost.add(max(sub, key=lambda c: c.flops + c.bytes))
            continue
        if op == "fusion":
            callee = None
            cm = re.search(r"calls=%?([\w.\-~]+)", ins.attrs)
            if cm:
                callee = cm.group(1)
            inner = (_comp_cost(callee, comps, cache, stack)
                     if callee else _Cost())
            # flops/collectives from the fused body; bytes from the
            # call-site boundary (slice-aware), since internal values
            # never touch memory
            cost.flops += inner.flops
            cost.transcendentals += inner.transcendentals
            cost.coll_bytes += inner.coll_bytes
            cost.coll_count += inner.coll_count
            for k, v in inner.coll_by_type.items():
                cost.coll_by_type[k] = cost.coll_by_type.get(k, 0) + v
            b = out_bytes + _slice_aware_operand_bytes(
                ins, symbols, comps.get(callee))
            cost.bytes += b
            cost.bytes_by_op["fusion"] = \
                cost.bytes_by_op.get("fusion", 0) + b
            continue

        if op in _COLLECTIVES:
            cost.coll_bytes += out_bytes
            cost.coll_count += 1
            cost.coll_by_type[op] = \
                cost.coll_by_type.get(op, 0) + out_bytes
            cost.bytes += 2 * out_bytes
            cost.bytes_by_op[op] = \
                cost.bytes_by_op.get(op, 0) + 2 * out_bytes
            continue

        # dataflow bytes: output + operands (slices charge the slice)
        if op in _SLICE_OPS or op == "dynamic-update-slice":
            if op == "dynamic-update-slice":
                upd_t = (_operand_type(ins.operands[1], symbols)
                         if len(ins.operands) > 1 else None)
                b = 2 * (_type_bytes(upd_t) if upd_t else out_bytes)
            else:
                b = 2 * out_bytes
        else:
            b = out_bytes
            for opnd in ins.operands:
                t = _operand_type(opnd, symbols)
                if t:
                    b += _type_bytes(t)
        cost.bytes += b
        cost.bytes_by_op[op] = cost.bytes_by_op.get(op, 0) + b

        # flops
        if op == "dot":
            cost.flops += _dot_flops(ins, symbols)
        elif op == "convolution":
            cost.flops += _conv_flops(ins, symbols)
        elif op in _TRANSCENDENTAL:
            cost.transcendentals += _type_elems(ins.type_str)
        elif op in _ELEMENTWISE:
            cost.flops += _type_elems(ins.type_str)
        elif op in ("reduce", "reduce-window"):
            # ~1 flop per input element consumed
            in_elems = 0
            for opnd in ins.operands:
                t = _operand_type(opnd, symbols)
                if t:
                    in_elems += _type_elems(t)
            cost.flops += in_elems
    cache[comp_name] = cost
    return cost


def analyze(hlo_text: str) -> Dict[str, float]:
    """Parse HLO text and return trip-count-aware totals (see module
    docstring for the key set)."""
    comps, entry = _parse_computations(hlo_text)
    if entry is None:
        # fall back: treat the last computation as the root
        entry = next(reversed(comps), None)
    cost = _comp_cost(entry, comps, {}) if entry else _Cost()
    return {
        "flops": cost.flops,
        "transcendentals": cost.transcendentals,
        "bytes accessed": cost.bytes,
        "collective_bytes": cost.coll_bytes,
        "collective_count": int(round(cost.coll_count)),
        "collective_by_type": dict(cost.coll_by_type),
        "bytes_by_op": dict(cost.bytes_by_op),
    }


def xla_cost_dict(compiled) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions (older
    CPU backends return a one-element list of dicts)."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c)
