"""Sharding rules: pytree path + shape -> PartitionSpec on the mesh.

One function, ``param_spec``, maps every parameter (and quantized-weight)
leaf of every ``ASSIGNED_ARCHS`` family onto the production mesh axes:

  model   -- tensor parallelism.  Column-parallel weights (in/up/qkv
             projections, routers, conv channels, expert dim of MoE
             stacks) shard their output dim; row-parallel weights
             (out/down projections) shard their contraction dim; the
             embedding shards its vocab rows (falling back to the
             feature dim for the odd vocab sizes -- 49155, 51865 --
             that 16 does not divide).
  data    -- with ``fsdp=True``, one additional dim of every leaf is
             sharded over the data axis (ZeRO-style); optimizer moments
             and fp32 masters follow their parameter's spec.
  pod     -- a second, slower data axis; only batch/gradient traffic
             crosses it, so params never take the 'pod' axis.

Every assignment is divisibility-guarded: a dim only gets a mesh axis
when the axis size divides it, so the rules are total over arbitrary
(including scaled-down) shapes.  Leading stacked-layer axes (the
``lax.scan`` dims of ``layers`` / ``enc_layers`` / ``m_blocks`` /
``s_blocks``) are never sharded -- they are loop dims, not data dims.

The mesh argument only needs ``.shape`` (axis -> size mapping) and
``.axis_names``; the pytree helpers below additionally need a real
``jax.sharding.Mesh`` to build ``NamedSharding`` leaves.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.launch.mesh import dp_axes  # single source for the dp rule

# column-parallel: shard the LAST dim (projection output) on 'model'
_COL_PARALLEL = frozenset((
    "in_proj", "x_proj", "dt_proj", "wq", "wk", "wv", "wi", "mlp_wi",
    "up_proj", "up", "w_in", "w_gates", "router", "qkv", "lm_head",
))
# row-parallel: shard the FIRST kernel dim (contraction) on 'model'
_ROW_PARALLEL = frozenset((
    "out_proj", "out_proj_had", "wo", "mlp_wo", "down_proj",
    "down_proj_had", "down",
))
# depthwise conv taps (width, channels): channels ride 'model'
_CONV = frozenset(("conv_w",))

# sections whose params carry leading stacked-layer axes (scan dims)
_STACKED_1 = frozenset(("layers", "enc_layers", "s_blocks"))
_STACKED_2 = frozenset(("m_blocks",))

# smallest dim worth FSDP-sharding (below this the all-gather latency
# dwarfs the memory saving)
_FSDP_MIN_DIM = 128


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, str):
            names.append(p)
        elif hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        else:
            names.append(str(p))
    return tuple(names)


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, (tuple, list)):
        return int(np.prod([_axis_size(mesh, a) for a in axis]))
    return int(dict(mesh.shape)[axis])


def _has_axis(mesh, axis: str) -> bool:
    return axis in tuple(mesh.axis_names)


def _divides(mesh, axis, dim: int) -> bool:
    size = _axis_size(mesh, axis)
    return size > 1 and dim % size == 0


def _n_stacked(names: Tuple[str, ...]) -> int:
    if any(n in _STACKED_2 for n in names):
        return 2
    if any(n in _STACKED_1 for n in names):
        return 1
    return 0


def _dp_axis_for(mesh, dim: int):
    """Largest data-parallel axis combination that divides ``dim``."""
    dp = dp_axes(mesh)
    candidates = [dp] if len(dp) > 1 else []
    candidates += [(a,) for a in dp]
    for axes in candidates:
        size = _axis_size(mesh, axes)
        if size > 1 and dim % size == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def param_spec(path, shape, mesh, cfg, fsdp: bool = False):
    """PartitionSpec for one parameter leaf.

    path: pytree path (jax key entries or plain strings) from the params
    root; shape: the leaf shape; mesh: mesh (or any object with
    ``.shape``/``.axis_names``); cfg: the ModelConfig (reserved for
    family-specific refinements); fsdp: additionally shard one dim over
    the 'data' axis.
    """
    from jax.sharding import PartitionSpec

    names = _path_names(path)
    name = names[-1] if names else ""
    ndim = len(shape)
    spec = [None] * ndim
    if ndim == 0:
        return PartitionSpec()

    lead = min(_n_stacked(names), ndim - 1)
    kernel = list(range(lead, ndim))  # dims that belong to the weight

    model = "model" if _has_axis(mesh, "model") else None

    def assign(dim_idx, axis) -> bool:
        if axis is None or spec[dim_idx] is not None:
            return False
        if not _divides(mesh, axis, shape[dim_idx]):
            return False
        spec[dim_idx] = axis
        return True

    # ---- model (tensor-parallel) axis --------------------------------
    if model is not None and len(kernel) >= 1:
        if name == "embed":
            # vocab rows first; odd vocabs fall back to the feature dim
            assign(kernel[0], model) or (
                len(kernel) > 1 and assign(kernel[-1], model))
        elif name in _CONV and len(kernel) >= 2:
            assign(kernel[-1], model)
        elif "moe" in names and name in ("wi", "wo") and len(kernel) >= 3:
            # expert parallelism: experts ride the model axis
            assign(kernel[0], model)
        elif name in _COL_PARALLEL and len(kernel) >= 2:
            assign(kernel[-1], model)
        elif name in _ROW_PARALLEL and len(kernel) >= 2:
            assign(kernel[0], model)

    # ---- fsdp (ZeRO) data axis ---------------------------------------
    if fsdp and _has_axis(mesh, "data"):
        # first unassigned kernel dim that the data axis divides
        for i in sorted(kernel, key=lambda i: -shape[i]):
            if shape[i] < _FSDP_MIN_DIM:
                continue
            if assign(i, "data"):
                break

    return PartitionSpec(*spec)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def _named(mesh, spec):
    from jax.sharding import NamedSharding

    return NamedSharding(mesh, spec)


def replicate_shardings(tree, mesh):
    """Fully-replicated NamedSharding for every leaf."""
    import jax
    from jax.sharding import PartitionSpec

    return jax.tree.map(lambda _: _named(mesh, PartitionSpec()), tree)


def param_shardings(tree, mesh, cfg, fsdp: bool = False):
    """NamedSharding pytree for a params (or qw) tree via
    ``param_spec``."""
    import jax

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _named(
            mesh, param_spec(path, leaf.shape, mesh, cfg, fsdp=fsdp)),
        tree)


def train_state_shardings(state, mesh, cfg, fsdp: bool = False):
    """Shardings for ``init_train_state`` trees: params by rule;
    optimizer moments / fp32 master / error-feedback state mirror their
    parameter's spec (sharded at least as much -- ZeRO); the step
    counter is replicated."""
    import jax
    from jax.sharding import PartitionSpec

    out: Dict = {}
    for key, sub in state.items():
        if key == "opt":
            opt: Dict = {}
            for k, v in sub.items():
                if k == "step":
                    opt[k] = _named(mesh, PartitionSpec())
                else:  # m / v / master mirror the params tree
                    opt[k] = param_shardings(v, mesh, cfg, fsdp=fsdp)
            out[key] = opt
        elif key in ("params", "err"):
            out[key] = param_shardings(sub, mesh, cfg, fsdp=fsdp)
        else:
            out[key] = replicate_shardings(sub, mesh)
    return out


def batch_shardings(batch, mesh):
    """Data-parallel batch sharding: dim 0 of every leaf over the
    data (+pod) axes when divisible, replicated otherwise."""
    import jax
    from jax.sharding import PartitionSpec

    def one(leaf):
        if not leaf.shape:
            return _named(mesh, PartitionSpec())
        axis = _dp_axis_for(mesh, leaf.shape[0])
        return _named(
            mesh, PartitionSpec(axis, *([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(one, batch)


def decode_state_shardings(state, mesh, cfg):
    """Shard the decode state's batch (slot) dim over the data axes.

    The batch axis of each top-level entry comes from the model zoo
    (``repro.models.decode_state_batch_axes``); KV caches, SSM/conv
    states and per-slot positions all shard the same way, so a serving
    engine's slots spread across data-parallel devices.  Entries (or
    batch sizes) the data axes do not divide stay replicated.
    """
    import jax
    from jax.sharding import PartitionSpec
    from repro.models import decode_state_batch_axes

    axes_map = decode_state_batch_axes(cfg)
    out = {}
    for key, sub in state.items():
        axis = axes_map.get(key)
        if axis is None:
            out[key] = replicate_shardings(sub, mesh)
            continue

        def one(leaf, axis=axis):
            if len(leaf.shape) <= axis:
                return _named(mesh, PartitionSpec())
            dp = _dp_axis_for(mesh, leaf.shape[axis])
            spec = [None] * len(leaf.shape)
            spec[axis] = dp
            return _named(mesh, PartitionSpec(*spec))

        out[key] = jax.tree.map(one, sub)
    return out


def qdata_shardings(qdata, mesh, cfg):
    """Shardings for quantized artifacts ({"scales", "qw"} trees): int8
    weights follow the same tensor-parallel rules as their fp parents
    (the qw tree mirrors the param tree's section names); scales are
    scalars / per-channel vectors and stay replicated."""
    out = {}
    for key, sub in qdata.items():
        if key == "qw":
            out[key] = param_shardings(sub, mesh, cfg)
        else:
            out[key] = replicate_shardings(sub, mesh)
    return out
