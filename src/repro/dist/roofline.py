"""Roofline terms for a compiled step on one chip of a mesh.

The model is the standard three-ceiling roofline: a step cannot finish
faster than its compute time (flops / peak), its memory time (bytes /
HBM bandwidth), or its collective time (collective bytes / interconnect
bandwidth).  Quamba's whole pitch lives in the memory term: int8 halves
the bytes a chip must move per decoded token, so for the memory-bound
SSM scan the roofline -- not peak flops -- decides throughput.

Default chip constants are TPU v5e: 197 TFLOP/s bf16 peak, 819 GB/s
HBM, and a conservative 50 GB/s per-link ICI budget for collectives.
Pass overrides for other parts (e.g. ``peak_flops=394e12`` for int8).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

# TPU v5e chip constants (per chip)
PEAK_FLOPS = 197e12      # bf16 FLOP/s (int8 is 2x)
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s collective budget per chip

INT8_PEAK_FLOPS = 394e12


def count_params(tree) -> int:
    """Total element count of a param pytree (arrays or
    ShapeDtypeStructs)."""
    import jax

    return int(sum(int(np.prod(leaf.shape)) if leaf.shape else 1
                   for leaf in jax.tree.leaves(tree)))


def count_bytes(tree) -> int:
    """Total byte size of a pytree (arrays or ShapeDtypeStructs)."""
    import jax

    return int(sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        if leaf.shape else np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(tree)))


def roofline_terms(cost: Dict[str, float], coll: Dict[str, float], *,
                   model_flops: Optional[float] = None,
                   peak_flops: float = PEAK_FLOPS,
                   hbm_bw: float = HBM_BW,
                   coll_bw: float = ICI_BW) -> Dict[str, object]:
    """Derive roofline terms from parsed per-chip cost.

    cost: {"flops", "bytes accessed"} (trip-count-aware totals,
          e.g. from ``repro.dist.hlo_cost.analyze``)
    coll: {"total": collective bytes, "count": collective fires}
    model_flops: the *useful* model flops per chip (6ND train /
          2ND inference); sets useful_flops_ratio and mfu_bound.

    Returns compute_s / memory_s / collective_s (the three ceilings),
    step_s (their max), bottleneck ("compute"|"memory"|"collective"),
    arithmetic intensity, and -- when model_flops is given --
    useful_flops_ratio (model flops / executed flops, <1 under remat)
    and mfu_bound (the MFU the bottleneck ceiling allows).
    """
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    coll_bytes = float(coll.get("total", 0.0))
    coll_count = int(coll.get("count", 0))

    compute_s = flops / peak_flops
    memory_s = bytes_acc / hbm_bw
    collective_s = coll_bytes / coll_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = terms[bottleneck]

    out: Dict[str, object] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "step_s": step_s,
        # alias consumed by benchmarks/roofline_report.py: the step time
        # the three ceilings jointly allow is a LOWER bound
        "step_lower_bound_s": step_s,
        "bottleneck": bottleneck,
        "arithmetic_intensity": flops / bytes_acc if bytes_acc else 0.0,
        "collective_count": coll_count,
    }
    if model_flops is not None and flops > 0:
        out["useful_flops_ratio"] = model_flops / flops
        out["mfu_bound"] = ((model_flops / peak_flops) / step_s
                            if step_s > 0 else 0.0)
    return out
