"""repro.dist -- sharding rules + trip-count-aware roofline analysis.

This package is how the repo reasons about *placement* (how a model's
params, optimizer state, activations and decode state are laid out on a
device mesh) and *cost* (what a compiled step actually moves and
computes, including the scan bodies XLA's ``cost_analysis()`` counts
only once).

Quick usage
-----------

Sharding a train state onto a mesh::

    import jax
    from repro.configs import get_config
    from repro.dist.sharding import (batch_shardings,
                                     train_state_shardings)
    from repro.launch.mesh import make_host_mesh, use_mesh
    from repro.train.step import init_train_state

    cfg = get_config("mamba-130m")
    mesh = make_host_mesh()                  # (data, model) over devices
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    st_sh = train_state_shardings(jax.eval_shape(lambda: state), mesh,
                                  cfg, fsdp=True)
    state = jax.device_put(state, st_sh)

Costing a compiled step (trip-count aware)::

    from repro.dist import hlo_cost, roofline

    compiled = jax.jit(step).lower(state, batch).compile()
    parsed = hlo_cost.analyze(compiled.as_text())
    # parsed["flops"] / parsed["bytes accessed"] multiply while-loop
    # bodies by their known_trip_count; parsed["collective_bytes"] /
    # ["collective_count"] cover all-reduce/all-gather/... including
    # collectives fired once per scanned layer.
    terms = roofline.roofline_terms(
        {"flops": parsed["flops"],
         "bytes accessed": parsed["bytes accessed"]},
        {"total": parsed["collective_bytes"],
         "count": parsed["collective_count"]},
        model_flops=2 * roofline.count_params(params) * tokens)
    # terms: compute_s / memory_s / collective_s, bottleneck,
    # useful_flops_ratio, mfu_bound

End-to-end evidence for every (arch, shape) cell comes from the dry-run
launcher (``python -m repro.launch.dryrun --arch mamba-130m --shape
decode_small --scale-down --mesh 2x4 --variants fp,bf16,quamba,kv8``),
which lowers + compiles on the chosen mesh and emits one JSON line per
cell with memory, cost and roofline terms.  See ROADMAP.md
"Distributed execution" for how to read the output.
"""
from repro.dist import hlo_cost, roofline
from repro.dist.sharding import (
    batch_shardings, decode_state_shardings, param_shardings, param_spec,
    qdata_shardings, train_state_shardings,
)

__all__ = [
    "hlo_cost", "roofline",
    "param_spec", "param_shardings", "train_state_shardings",
    "batch_shardings", "decode_state_shardings", "qdata_shardings",
]
