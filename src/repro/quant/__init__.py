from repro.quant.quantizers import (
    quantize, dequantize, qdq, symmetric_scale, percentile_scale,
    dynamic_qdq, log2_qdq, per_channel_scale, quant_error,
)
from repro.quant.hadamard import (
    hadamard_matrix, fwht, had_transform, fold_hadamard_into_weight,
)
from repro.quant.observers import (
    observe, observe_none, merge_stats, stats_scale, PERCENTILES,
)
from repro.quant.recipe import (
    QuantSpec, PRESETS, get_spec, quantize_weight, pack_int4, unpack_int4,
    kernel_backend_fallback_reason, uses_kernel_backend,
    BackendFallbackWarning,
)
from repro.quant.calibrate import run_calibration
from repro.quant.sitemap import (
    SiteMap, register_site_map, get_site_map, registered_families,
)
