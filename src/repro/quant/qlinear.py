"""Quantized linear application.

Two execution paths:

* ``apply_int8``  -- true integer arithmetic: int8 x int8 -> int32
  accumulation (``preferred_element_type=int32``), then one fused rescale.
  This is what the TPU deployment uses (the MXU has an int8 mode); the CPU
  backend executes the same graph bit-exactly.

* ``apply_qdq``   -- fake-quant simulation (dequantize first, fp matmul).
  Used inside numerics experiments where we sweep methods; identical to
  the integer path up to fp accumulation order.

Weights arrive as the ``{"qw", "s_w", ...}`` pytree from
``repro.quant.recipe.quantize_weight``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q
from repro.quant.recipe import unpack_int4


def _stored_qw(x: jax.Array, qlin: dict) -> jax.Array:
    """The integer weight, unpacking the int4 nibble layout if present.

    ``{"qw4", ...}`` stores two 4-bit values per byte along the
    contraction axis (PR 8); K is recovered from the activation's last
    dim -- never stored, so the dict stays vmap/scan-transparent.
    """
    if "qw" in qlin:
        return qlin["qw"]
    return unpack_int4(qlin["qw4"], x.shape[-1])


def apply_int8(x: jax.Array, s_x: jax.Array, qlin: dict,
               out_dtype=jnp.bfloat16) -> jax.Array:
    """y = (quant(x) @ qw) * s_x * s_w  (+ bias), int32 accumulation.

    x is floating point; it is statically quantized with the calibrated
    scale ``s_x`` (all scaling factors fused into one epilogue multiply,
    paper Fig. 4).
    """
    qx = Q.quantize(x, jnp.asarray(s_x, x.dtype))
    acc = jax.lax.dot_general(
        qx, _stored_qw(x, qlin),
        dimension_numbers=(((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    s_w = qlin["s_w"]
    scale = (jnp.asarray(s_x, jnp.float32) * s_w.astype(jnp.float32))
    y = acc.astype(jnp.float32) * scale
    if "b" in qlin and qlin["b"] is not None:
        y = y + qlin["b"].astype(jnp.float32)
    return y.astype(out_dtype)


def apply_qdq(x: jax.Array, s_x: Optional[jax.Array], qlin: dict,
              out_dtype=None) -> jax.Array:
    """Fake-quant path on the integer grid.

    The matmul runs on the *grid values* (int8/int4 magnitudes held in
    float32) with the scales applied once afterwards -- products and
    64-4096-term sums of |q| <= 127 integers are exact in float32
    (< 2^24), so the result is bit-identical to ``apply_int8`` and the
    int8/int4 kernels, not merely close: pre-scaling the operands
    (``(s_x q_x) @ (s_w q_w)``) re-rounds every partial product, and the
    accumulated ulp noise flips activation requants that land on
    rounding ties, which is exactly what backend-parity tests compare.

    The rounding is the straight-through variant so the op stays the QAT
    training surrogate: since the scalar ``s_x`` factors out of the
    matmul, ``(round_ste(clip(x/s)) @ q_w) * (s_x s_w)`` has exactly the
    clipped-STE / LSQ gradients of ``qdq(x, s_x) @ (q_w s_w)``.
    """
    out_dtype = out_dtype or x.dtype
    w = _stored_qw(x, qlin).astype(jnp.float32)
    s_w = qlin["s_w"].astype(jnp.float32)
    if s_x is not None:
        z = jnp.clip(x / jnp.asarray(s_x, x.dtype), Q.INT8_MIN, Q.INT8_MAX)
        qx = Q.round_ste(z).astype(jnp.float32)
        y = (qx @ w) * (jnp.asarray(s_x, jnp.float32) * s_w)
    else:
        y = x.astype(jnp.float32) @ (w * s_w)
    if "b" in qlin and qlin["b"] is not None:
        y = y + qlin["b"].astype(jnp.float32)
    return y.astype(out_dtype)
