"""Quantized linear application.

Two execution paths:

* ``apply_int8``  -- true integer arithmetic: int8 x int8 -> int32
  accumulation (``preferred_element_type=int32``), then one fused rescale.
  This is what the TPU deployment uses (the MXU has an int8 mode); the CPU
  backend executes the same graph bit-exactly.

* ``apply_qdq``   -- fake-quant simulation (dequantize first, fp matmul).
  Used inside numerics experiments where we sweep methods; identical to
  the integer path up to fp accumulation order.

Weights arrive as the ``{"qw", "s_w", ...}`` pytree from
``repro.quant.recipe.quantize_weight``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q
from repro.quant.recipe import unpack_int4


def _stored_qw(x: jax.Array, qlin: dict) -> jax.Array:
    """The integer weight, unpacking the int4 nibble layout if present.

    ``{"qw4", ...}`` stores two 4-bit values per byte along the
    contraction axis (PR 8); K is recovered from the activation's last
    dim -- never stored, so the dict stays vmap/scan-transparent.
    """
    if "qw" in qlin:
        return qlin["qw"]
    return unpack_int4(qlin["qw4"], x.shape[-1])


def apply_int8(x: jax.Array, s_x: jax.Array, qlin: dict,
               out_dtype=jnp.bfloat16) -> jax.Array:
    """y = (quant(x) @ qw) * s_x * s_w  (+ bias), int32 accumulation.

    x is floating point; it is statically quantized with the calibrated
    scale ``s_x`` (all scaling factors fused into one epilogue multiply,
    paper Fig. 4).
    """
    qx = Q.quantize(x, jnp.asarray(s_x, x.dtype))
    acc = jax.lax.dot_general(
        qx, _stored_qw(x, qlin),
        dimension_numbers=(((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    s_w = qlin["s_w"]
    scale = (jnp.asarray(s_x, jnp.float32) * s_w.astype(jnp.float32))
    y = acc.astype(jnp.float32) * scale
    if "b" in qlin and qlin["b"] is not None:
        y = y + qlin["b"].astype(jnp.float32)
    return y.astype(out_dtype)


def apply_qdq(x: jax.Array, s_x: Optional[jax.Array], qlin: dict,
              out_dtype=None) -> jax.Array:
    """Fake-quant path: x is (optionally) fake-quantized, weights dequantized."""
    out_dtype = out_dtype or x.dtype
    if s_x is not None:
        x = Q.qdq(x, jnp.asarray(s_x, x.dtype))
    w = _stored_qw(x, qlin).astype(x.dtype) * qlin["s_w"].astype(x.dtype)
    y = x @ w
    if "b" in qlin and qlin["b"] is not None:
        y = y + qlin["b"].astype(x.dtype)
    return y.astype(out_dtype)
