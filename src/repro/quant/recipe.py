"""Quamba recipe driver: QuantSpec + generic weight/activation helpers.

The architecture-specific wiring (which site gets the percentile clip,
where the Hadamard rotation is folded) lives in ``repro.models.quantize``;
this module holds the architecture-independent pieces:

  * ``QuantSpec``        -- which method / bit-widths / knobs
  * ``quantize_weight``  -- per-tensor (or per-channel) int8/int4 weights
  * ``QLinear`` params   -- {"qw", "s_w", "b"} pytree consumed by qlinear
  * method presets reproducing the paper's baselines (Tables 2/3/5/9)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q
from repro.quant.hadamard import fold_hadamard_into_weight


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Configuration of one quantization run.

    method:
      quamba       -- paper: static W8A8, percentile clip on SSM input x,
                      Hadamard-rotated SSM output (H folded into W_out)
      static       -- naive static per-tensor W8A8 (paper baseline)
      dynamic      -- scales recomputed per tensor per step (paper baseline)
      smoothquant  -- SmQ-SSM: per-channel smoothing folded into weights
      quarot       -- QuaRot-SSM: Hadamard on every linear input + output
      in_per       -- ablation: percentile clip only (Table 5 "+ In Per.")
      out_had      -- ablation: Hadamard only    (Table 5 "+ Out Had.")
    """

    method: str = "quamba"
    w_bits: int = 8
    a_bits: int = 8
    percentile: float = 99.999          # paper §4.2 p
    smooth_alpha: float = 0.5           # SmoothQuant alpha
    per_channel_w: bool = False         # beyond-paper: per-channel weights
    quantize_kv_cache: bool = False     # beyond-paper: int8 KV cache
    input_quant: str = "sym_percentile"  # Table 9 variants:
    # sym_percentile | sym_minmax | asym_percentile | log2 | dynamic
    backend: str = "qdq"                # execution backend:
    # qdq     -- fake-quant simulation over the fp reference ops (oracle)
    # kernels -- activations quantized once to int8 and fed to the Pallas
    #            kernels (int8 matmul / conv / scan / hadamard / rmsnorm);
    #            the paper's deployed dataflow.  Falls back to qdq where
    #            unsupported (dynamic scales, non-8-bit, quarot).

    @property
    def use_percentile(self) -> bool:
        return self.method in ("quamba", "in_per", "quarot")

    @property
    def use_hadamard(self) -> bool:
        return self.method in ("quamba", "out_had", "quarot")

    @property
    def x_percentile(self) -> float:
        return self.percentile if self.use_percentile else 100.0

    def validate(self) -> None:
        # explicit raises (bare asserts are stripped under ``python -O``)
        methods = ("quamba", "static", "dynamic", "smoothquant", "quarot",
                   "in_per", "out_had")
        if self.method not in methods:
            raise ValueError(
                f"unknown quantization method {self.method!r}; "
                f"expected one of {methods}")
        if self.w_bits not in (4, 8):
            raise ValueError(f"w_bits must be 4 or 8, got {self.w_bits}")
        if self.a_bits not in (4, 8):
            raise ValueError(f"a_bits must be 4 or 8, got {self.a_bits}")
        if self.backend not in ("qdq", "kernels"):
            raise ValueError(
                f"backend must be 'qdq' or 'kernels', got {self.backend!r}")


PRESETS = {
    "fp": None,
    "quamba": QuantSpec(method="quamba"),
    "static": QuantSpec(method="static"),
    "dynamic": QuantSpec(method="dynamic"),
    "smoothquant": QuantSpec(method="smoothquant"),
    "quarot": QuantSpec(method="quarot"),
    "in_per": QuantSpec(method="in_per"),
    "out_had": QuantSpec(method="out_had"),
    "quamba-w4a8": QuantSpec(method="quamba", w_bits=4),
    "quamba-pc": QuantSpec(method="quamba", per_channel_w=True),
    "quamba-kv8": QuantSpec(method="quamba", quantize_kv_cache=True),
    "quamba-kernels": QuantSpec(method="quamba", backend="kernels"),
}


# static-scale methods the int8 kernel backend can execute directly;
# everything else (dynamic scales, the rotate-back of quarot) keeps the
# qdq oracle path even when backend="kernels" is requested.
KERNEL_BACKEND_METHODS = ("quamba", "static", "in_per", "out_had",
                          "smoothquant")


def uses_kernel_backend(spec: Optional["QuantSpec"]) -> bool:
    """True when ``spec`` selects the int8 Pallas-kernel execution path."""
    return (spec is not None
            and getattr(spec, "backend", "qdq") == "kernels"
            and spec.method in KERNEL_BACKEND_METHODS
            and spec.w_bits == 8 and spec.a_bits == 8
            and not spec.per_channel_w
            and spec.input_quant in ("sym_percentile", "sym_minmax"))


def prefill_chunk_safe(spec: Optional["QuantSpec"]) -> bool:
    """True when quantization scales are independent of the activation
    batch, so a chunked sequence prefill reproduces per-token stepping.

    The "dynamic" method and the per-call input_quant variants (dynamic
    scale, log2's per-tensor amax, asym_percentile's mean-derived zero
    point) compute statistics over whatever tensor they see -- one chunk
    vs one token gives different scales, so those specs must prefill
    token by token."""
    if spec is None:
        return True
    return (spec.method != "dynamic"
            and spec.input_quant in ("sym_percentile", "sym_minmax"))


def get_spec(name: str) -> Optional[QuantSpec]:
    if name not in PRESETS:
        raise KeyError(f"unknown quant preset {name!r}: {sorted(PRESETS)}")
    spec = PRESETS[name]
    if spec is not None:
        spec.validate()
    return spec


# ---------------------------------------------------------------------------
# weights
# ---------------------------------------------------------------------------

def quantize_weight(w: jax.Array, spec: QuantSpec, *,
                    fold_hadamard_axis: Optional[int] = None,
                    out_axis: int = -1) -> dict:
    """Quantize one weight matrix to a QLinear params dict.

    fold_hadamard_axis: if set, fold the normalized Hadamard rotation into
    this (input) axis before quantizing -- this is the W_out^H = H W_out
    fusion of paper §4.2 that makes the rotated output quantization free at
    inference time.
    """
    if fold_hadamard_axis is not None:
        w = fold_hadamard_into_weight(w, axis=fold_hadamard_axis)
    if spec.per_channel_w:
        axis = out_axis % w.ndim
        s_w = Q.per_channel_scale(w, axis=axis, bits=spec.w_bits)
    else:
        s_w = Q.symmetric_scale(w, bits=spec.w_bits)
    qw = Q.quantize(w, s_w, bits=spec.w_bits)
    return {"qw": qw, "s_w": jnp.asarray(s_w, jnp.float32)}


def dequantize_weight(qlin: dict, dtype=jnp.float32) -> jax.Array:
    return qlin["qw"].astype(dtype) * qlin["s_w"].astype(dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def act_qdq(x: jax.Array, scale: jax.Array, spec: QuantSpec) -> jax.Array:
    """Static fake-quant of an activation with a calibrated scale."""
    return Q.qdq(x, jnp.asarray(scale, x.dtype), bits=spec.a_bits)


def ssm_input_qdq(x: jax.Array, scale: jax.Array, spec: QuantSpec
                  ) -> jax.Array:
    """Quantize the SSM input x per the configured Table-9 variant.

    The static symmetric-percentile path (the paper's choice) uses the
    pre-calibrated percentile scale.  The alternatives reproduce §F.
    """
    kind = spec.input_quant
    if kind in ("sym_percentile", "sym_minmax"):
        return Q.qdq(x, jnp.asarray(scale, x.dtype), bits=spec.a_bits)
    if kind == "dynamic":
        return Q.dynamic_qdq(x, bits=spec.a_bits)
    if kind == "log2":
        return Q.log2_qdq(x, bits=spec.a_bits)
    if kind == "asym_percentile":
        # static scale, dynamic zero-point estimate from clip range
        s = jnp.asarray(scale, x.dtype)
        zp = jnp.round(-jnp.mean(x) / s)
        return Q.qdq_asymmetric(x, s, zp, bits=spec.a_bits)
    raise ValueError(f"unknown input_quant {kind!r}")
