"""Quamba recipe driver: QuantSpec + generic weight/activation helpers.

The architecture-specific wiring (which site gets the percentile clip,
where the Hadamard rotation is folded) lives in ``repro.models.quantize``;
this module holds the architecture-independent pieces:

  * ``QuantSpec``        -- which method / bit-widths / knobs
  * ``quantize_weight``  -- per-tensor (or per-channel) int8/int4 weights
  * ``QLinear`` params   -- {"qw", "s_w", "b"} pytree consumed by qlinear
  * method presets reproducing the paper's baselines (Tables 2/3/5/9)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q
from repro.quant.hadamard import fold_hadamard_into_weight


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Configuration of one quantization run.

    method:
      quamba       -- paper: static W8A8, percentile clip on SSM input x,
                      Hadamard-rotated SSM output (H folded into W_out)
      static       -- naive static per-tensor W8A8 (paper baseline)
      dynamic      -- scales recomputed per tensor per step (paper baseline)
      smoothquant  -- SmQ-SSM: per-channel smoothing folded into weights
      quarot       -- QuaRot-SSM: Hadamard on every linear input + output
      in_per       -- ablation: percentile clip only (Table 5 "+ In Per.")
      out_had      -- ablation: Hadamard only    (Table 5 "+ Out Had.")
    """

    method: str = "quamba"
    w_bits: int = 8
    a_bits: int = 8
    percentile: float = 99.999          # paper §4.2 p
    smooth_alpha: float = 0.5           # SmoothQuant alpha
    per_channel_w: bool = False         # beyond-paper: per-channel weights
    quantize_kv_cache: bool = False     # beyond-paper: int8 KV cache
    input_quant: str = "sym_percentile"  # Table 9 variants:
    # sym_percentile | sym_minmax | asym_percentile | log2 | dynamic
    soft_edge: float = 0.0              # Quamba-SE soft-edge activation
    # policy: blend the percentile clip toward the calibrated abs-max,
    # s = (1-lambda) * s_pct + lambda * s_amax.  0.0 keeps the paper's
    # hard percentile clip; 1.0 degenerates to plain min-max.
    backend: str = "qdq"                # execution backend:
    # qdq     -- fake-quant simulation over the fp reference ops (oracle)
    # kernels -- activations quantized once to int8 and fed to the Pallas
    #            kernels (int8/int4 matmul / conv / scan / hadamard /
    #            rmsnorm); the paper's deployed dataflow.  Falls back to
    #            qdq where unsupported (dynamic scales, quarot, ...).

    @property
    def use_percentile(self) -> bool:
        return self.method in ("quamba", "in_per", "quarot")

    @property
    def use_hadamard(self) -> bool:
        return self.method in ("quamba", "out_had", "quarot")

    @property
    def x_percentile(self) -> float:
        return self.percentile if self.use_percentile else 100.0

    def validate(self) -> None:
        # explicit raises (bare asserts are stripped under ``python -O``)
        methods = ("quamba", "static", "dynamic", "smoothquant", "quarot",
                   "in_per", "out_had")
        if self.method not in methods:
            raise ValueError(
                f"unknown quantization method {self.method!r}; "
                f"expected one of {methods}")
        if self.w_bits not in (4, 8):
            raise ValueError(f"w_bits must be 4 or 8, got {self.w_bits}")
        if self.a_bits not in (4, 8):
            raise ValueError(f"a_bits must be 4 or 8, got {self.a_bits}")
        if self.backend not in ("qdq", "kernels"):
            raise ValueError(
                f"backend must be 'qdq' or 'kernels', got {self.backend!r}")
        if not 0.0 <= self.soft_edge <= 1.0:
            raise ValueError(
                f"soft_edge must be in [0, 1], got {self.soft_edge}")


PRESETS = {
    "fp": None,
    "quamba": QuantSpec(method="quamba"),
    "static": QuantSpec(method="static"),
    "dynamic": QuantSpec(method="dynamic"),
    "smoothquant": QuantSpec(method="smoothquant"),
    "quarot": QuantSpec(method="quarot"),
    "in_per": QuantSpec(method="in_per"),
    "out_had": QuantSpec(method="out_had"),
    "quamba-w4a8": QuantSpec(method="quamba", w_bits=4),
    "quamba-w4a8-se": QuantSpec(method="quamba", w_bits=4, soft_edge=0.25),
    # sub-8-bit activations: accuracy-credible only after a QAT recovery
    # pass (Quantizer.finetune); runs on the qdq oracle -- the int8
    # kernels cannot consume int4 activations (see fallback reasons)
    "quamba-w4a4": QuantSpec(method="quamba", w_bits=4, a_bits=4,
                             soft_edge=0.25),
    "quamba-pc": QuantSpec(method="quamba", per_channel_w=True),
    "quamba-kv8": QuantSpec(method="quamba", quantize_kv_cache=True),
    "quamba-kernels": QuantSpec(method="quamba", backend="kernels"),
}


# static-scale methods the int8 kernel backend can execute directly;
# everything else (dynamic scales, the rotate-back of quarot) keeps the
# qdq oracle path even when backend="kernels" is requested.
KERNEL_BACKEND_METHODS = ("quamba", "static", "in_per", "out_had",
                          "smoothquant")


class BackendFallbackWarning(UserWarning):
    """Raised (once per process per reason) when ``backend="kernels"`` was
    requested but execution falls back to the qdq oracle.  Structured:
    ``.requested`` / ``.effective`` / ``.reason`` are machine-readable,
    mirroring the ``describe()`` fields of the artifact."""

    def __init__(self, requested: str, effective: str, reason: str):
        self.requested = requested
        self.effective = effective
        self.reason = reason
        super().__init__(
            f"backend={requested!r} requested but executing on "
            f"{effective!r}: {reason}")


def uses_kernel_backend(spec: Optional["QuantSpec"]) -> bool:
    """True when ``spec`` selects the Pallas-kernel execution path.

    w_bits=8 routes matmul sites to ``int8_matmul``; w_bits=4 routes them
    to ``int4_matmul`` (nibble-packed weights).  Activations must be int8
    either way -- the kernels quantize them once with static scales.
    """
    return kernel_backend_fallback_reason(spec) is None


def kernel_backend_fallback_reason(spec: Optional["QuantSpec"]
                                   ) -> Optional[str]:
    """Why ``backend="kernels"`` cannot be honored, or None if it can.

    The reasons mirror the fallback rules documented in README.md; the
    string is surfaced verbatim in the one-shot ``BackendFallbackWarning``
    and in ``QuantizedModel.describe()``.
    """
    if spec is None:
        return "fp spec has no quantized data"
    if getattr(spec, "backend", "qdq") != "kernels":
        return "backend='qdq' requested"
    if spec.method not in KERNEL_BACKEND_METHODS:
        return (f"method {spec.method!r} needs per-call scales or a "
                "rotate-back the int8 kernels cannot express")
    if spec.w_bits not in (4, 8):
        return f"w_bits={spec.w_bits} has no kernel (only 4 and 8)"
    if spec.a_bits != 8:
        return f"a_bits={spec.a_bits}: kernels consume int8 activations"
    if spec.per_channel_w:
        return "per-channel weight scales (kernels fuse per-tensor scales)"
    if spec.input_quant not in ("sym_percentile", "sym_minmax"):
        return (f"input_quant={spec.input_quant!r} recomputes scales "
                "per call")
    return None


def prefill_chunk_safe(spec: Optional["QuantSpec"]) -> bool:
    """True when quantization scales are independent of the activation
    batch, so a chunked sequence prefill reproduces per-token stepping.

    The "dynamic" method and the per-call input_quant variants (dynamic
    scale, log2's per-tensor amax, asym_percentile's mean-derived zero
    point) compute statistics over whatever tensor they see -- one chunk
    vs one token gives different scales, so those specs must prefill
    token by token."""
    if spec is None:
        return True
    return (spec.method != "dynamic"
            and spec.input_quant in ("sym_percentile", "sym_minmax"))


def get_spec(name: str) -> Optional[QuantSpec]:
    if name not in PRESETS:
        raise KeyError(f"unknown quant preset {name!r}: {sorted(PRESETS)}")
    spec = PRESETS[name]
    if spec is not None:
        spec.validate()
    return spec


# ---------------------------------------------------------------------------
# weights
# ---------------------------------------------------------------------------

def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 values (int8 storage, range [-8, 7]) two per byte.

    Packing runs along axis 0 -- the contraction axis of a (K, N) weight:
    byte ``i`` holds row ``2i`` in its low nibble and row ``2i+1`` in its
    high nibble (two's complement).  Odd K is zero-padded; a zero row
    contributes nothing to a matmul, so consumers recover K from the
    activation's last dim rather than a stored constant (which would not
    survive ``jax.vmap`` over stacked layers).
    """
    k = q.shape[0]
    if k % 2:
        q = jnp.pad(q, ((0, 1),) + ((0, 0),) * (q.ndim - 1))
    lo = q[0::2].astype(jnp.int32) & 0xF
    hi = q[1::2].astype(jnp.int32) & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, k: Optional[int] = None) -> jax.Array:
    """Inverse of :func:`pack_int4`: (ceil(K/2), ...) bytes -> (K, ...) int8.

    ``k`` drops the zero pad row of an odd-K weight (None keeps it --
    harmless for matmuls, where the matching activation column is absent).
    Nibbles are sign-extended via int32 shifts (arithmetic >> on a widened
    value is well-defined everywhere; bit-twiddling int8 directly is not).
    """
    p32 = packed.astype(jnp.int32)
    lo = (p32 << 28) >> 28
    hi = (p32 << 24) >> 28
    q = jnp.stack([lo, hi], axis=1).reshape((-1,) + packed.shape[1:])
    return q[:k].astype(jnp.int8)


def quantize_weight(w: jax.Array, spec: QuantSpec, *,
                    fold_hadamard_axis: Optional[int] = None,
                    out_axis: int = -1, storage: str = "auto",
                    ste: bool = False) -> dict:
    """Quantize one weight matrix to a QLinear params dict.

    fold_hadamard_axis: if set, fold the normalized Hadamard rotation into
    this (input) axis before quantizing -- this is the W_out^H = H W_out
    fusion of paper §4.2 that makes the rotated output quantization free at
    inference time.

    storage: "auto" packs 4-bit weights two-nibbles-per-byte along the
    contraction axis (``{"qw4", "s_w"}``, consumed by ``int4_matmul``);
    "int8" keeps one value per byte regardless of w_bits (conv taps, whose
    kernel reads int8 -- the values still sit on the 4-bit grid).

    ste: QAT mode.  ``qw`` is returned as *float* grid values produced by
    a straight-through round (same numbers an int cast would store, so the
    dequantized forward is bit-identical), never nibble-packed, with the
    scale frozen via stop_gradient -- so ``jax.grad`` of a loss through
    ``qw * s_w`` reaches the underlying fp weight with the clipped-STE
    surrogate.
    """
    if storage not in ("auto", "int8"):
        raise ValueError(f"storage must be 'auto' or 'int8', got {storage!r}")
    if fold_hadamard_axis is not None:
        w = fold_hadamard_into_weight(w, axis=fold_hadamard_axis)
    if spec.per_channel_w:
        axis = out_axis % w.ndim
        s_w = Q.per_channel_scale(w, axis=axis, bits=spec.w_bits)
    else:
        s_w = Q.symmetric_scale(w, bits=spec.w_bits)
    if ste:
        s_w = jax.lax.stop_gradient(s_w)
        qmax = 2.0 ** (spec.w_bits - 1) - 1.0
        qw = Q.round_ste(jnp.clip(w / s_w, -qmax - 1.0, qmax))
        return {"qw": qw, "s_w": jnp.asarray(s_w, jnp.float32)}
    qw = Q.quantize(w, s_w, bits=spec.w_bits)
    if storage == "auto" and spec.w_bits == 4:
        return {"qw4": pack_int4(qw), "s_w": jnp.asarray(s_w, jnp.float32)}
    return {"qw": qw, "s_w": jnp.asarray(s_w, jnp.float32)}


def dequantize_weight(qlin: dict, dtype=jnp.float32, k: Optional[int] = None
                      ) -> jax.Array:
    qw = qlin["qw"] if "qw" in qlin else unpack_int4(qlin["qw4"], k)
    return qw.astype(dtype) * qlin["s_w"].astype(dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def soft_edge_blend(s_pct: jax.Array, s_amax: jax.Array,
                    lam: float) -> jax.Array:
    """Quamba-SE soft edge: blend the hard percentile clip toward the
    calibrated abs-max, ``s = (1 - lam) * s_pct + lam * s_amax``.

    lam=0 keeps the paper's percentile clip, lam=1 degenerates to plain
    min-max; any lam in between lands between the two endpoint scales.
    """
    return (1.0 - lam) * s_pct + lam * s_amax


def act_qdq(x: jax.Array, scale: jax.Array, spec: QuantSpec) -> jax.Array:
    """Static fake-quant of an activation with a calibrated scale."""
    return Q.qdq(x, jnp.asarray(scale, x.dtype), bits=spec.a_bits)


def ssm_input_qdq(x: jax.Array, scale: jax.Array, spec: QuantSpec
                  ) -> jax.Array:
    """Quantize the SSM input x per the configured Table-9 variant.

    The static symmetric-percentile path (the paper's choice) uses the
    pre-calibrated percentile scale.  The alternatives reproduce §F.
    """
    kind = spec.input_quant
    if kind in ("sym_percentile", "sym_minmax"):
        return Q.qdq(x, jnp.asarray(scale, x.dtype), bits=spec.a_bits)
    if kind == "dynamic":
        return Q.dynamic_qdq(x, bits=spec.a_bits)
    if kind == "log2":
        return Q.log2_qdq(x, bits=spec.a_bits)
    if kind == "asym_percentile":
        # static scale, dynamic zero-point estimate from clip range
        s = jnp.asarray(scale, x.dtype)
        zp = jnp.round(-jnp.mean(x) / s)
        return Q.qdq_asymmetric(x, s, zp, bits=spec.a_bits)
    raise ValueError(f"unknown input_quant {kind!r}")
