"""Walsh–Hadamard transforms (paper §3.3, §4.2).

Quamba removes the massive outliers in the SSM output ``y`` by rotating it
into an outlier-free basis: ``y_H = H_n @ y`` with a (scaled) Hadamard
matrix, quantizing there, and folding the inverse rotation into the output
projection ``W_out`` (compute-invariance: W_out^T y == (H W_out)^T (H y)/n).

We provide:
  * ``hadamard_matrix(n)``       -- explicit (normalized) H_n for n = 2^p*m,
                                    m in {1, 12, 20} (Sloane's library bases)
  * ``fwht(x)``                  -- O(n log n) fast transform over the last
                                    axis (pure jnp; the TPU Pallas kernel in
                                    ``repro.kernels`` uses a matmul (kron)
                                    decomposition instead, which maps to the
                                    MXU -- see DESIGN.md §Hardware-adaptation)
  * ``had_transform(x)``         -- normalized transform for any supported n
"""
from __future__ import annotations

import functools
import math

import numpy as np
import jax
import jax.numpy as jnp

def _paley_type1(q: int) -> np.ndarray:
    """Paley-I Hadamard matrix of order q+1 for prime q == 3 (mod 4)."""
    residues = {(i * i) % q for i in range(1, q)}

    def chi(a: int) -> int:
        a %= q
        return 0 if a == 0 else (1 if a in residues else -1)

    jac = np.array([[chi(j - i) for j in range(q)] for i in range(q)],
                   dtype=np.float32)
    s = np.zeros((q + 1, q + 1), dtype=np.float32)
    s[0, 1:] = 1.0
    s[1:, 0] = -1.0
    s[1:, 1:] = jac
    return s + np.eye(q + 1, dtype=np.float32)


def _base_matrix(m: int) -> np.ndarray:
    """Hadamard bases of order 1, 12 (Paley q=11), 20 (Paley q=19)."""
    if m == 1:
        return np.ones((1, 1), dtype=np.float32)
    h = _paley_type1({12: 11, 20: 19}[m])
    assert np.allclose(h @ h.T, m * np.eye(m)), f"H_{m} base is not Hadamard"
    return h


def decompose(n: int):
    """Factor n = 2^p * m with m in {1, 12, 20}; raise if impossible."""
    for m in (1, 12, 20):
        if n % m == 0:
            rest = n // m
            if rest & (rest - 1) == 0:  # power of two
                return int(math.log2(rest)), m
    raise ValueError(f"no Hadamard decomposition for n={n}")


@functools.lru_cache(maxsize=32)
def hadamard_matrix_np(n: int, normalized: bool = True) -> np.ndarray:
    """Dense H_n (numpy, cached). normalized -> H/sqrt(n), orthonormal."""
    p, m = decompose(n)
    h = _base_matrix(m)
    h2 = np.array([[1.0, 1.0], [1.0, -1.0]], dtype=np.float32)
    for _ in range(p):
        h = np.kron(h2, h)
    if normalized:
        h = h / np.sqrt(n)
    return h


def hadamard_matrix(n: int, normalized: bool = True,
                    dtype=jnp.float32) -> jax.Array:
    return jnp.asarray(hadamard_matrix_np(n, normalized), dtype)


def fwht(x: jax.Array) -> jax.Array:
    """Fast Walsh–Hadamard transform over the last axis (unnormalized).

    Supports n = 2^p * m with m in {1, 12, 20}: the power-of-two part uses
    log2 butterfly stages; the base part is one small dense matmul.
    """
    n = x.shape[-1]
    p, m = decompose(n)
    orig_shape = x.shape
    x = x.reshape(-1, n)
    if m != 1:
        base = jnp.asarray(_base_matrix(m), x.dtype)
        x = x.reshape(-1, 2 ** p, m) @ base.T
        x = x.reshape(-1, n)
    # butterfly over the 2^p part
    for s in range(p):
        x = x.reshape(-1, 2 ** (p - s - 1), 2, (2 ** s) * m)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
    return x.reshape(orig_shape)


def had_transform(x: jax.Array, normalized: bool = True) -> jax.Array:
    """Normalized WHT over the last axis: x -> (1/sqrt(n)) H_n x."""
    y = fwht(x)
    if normalized:
        y = y * (1.0 / jnp.sqrt(jnp.asarray(x.shape[-1], x.dtype)))
    return y


def had_transform_t(x: jax.Array, normalized: bool = True) -> jax.Array:
    """Inverse (transpose) transform: x -> (1/sqrt(n)) H_n^T x.

    For pure 2^p sizes H is symmetric and this equals ``had_transform``;
    the Paley bases (12, 20) are not symmetric, so the inverse applies the
    dense transpose explicitly.
    """
    n = x.shape[-1]
    _, m = decompose(n)
    if m == 1:
        return had_transform(x, normalized)
    h = jnp.asarray(hadamard_matrix_np(n, normalized), x.dtype)
    return x @ h  # (H^T x)^T = x^T H


def fold_hadamard_into_weight(w: jax.Array, axis: int = 0) -> jax.Array:
    """Fold the (normalized) Hadamard rotation into a weight matrix.

    With y' = H y (H orthonormal), compute-invariance requires replacing
    W_out (applied as y @ W_out, contraction over ``axis``) by H @ W_out so
    that (H y) @ (H W) == y @ W.
    """
    n = w.shape[axis]
    w_moved = jnp.moveaxis(w, axis, 0)
    out = had_transform(w_moved.reshape(n, -1).T).T.reshape(w_moved.shape)
    return jnp.moveaxis(out, 0, axis)
