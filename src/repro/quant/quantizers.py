"""Symmetric / asymmetric / log2 uniform quantizers (paper §3.2, §F).

All functions are pure-jnp and jit-safe.  The *static* path takes a
pre-calibrated scale; the *dynamic* path computes the scale from the tensor
itself (paper Table 9 "dynamic" baseline).

Conventions
-----------
``quantize(x, s)``   -> int8 tensor  (clamp(round(x/s)))
``dequantize(q, s)`` -> float tensor (q * s)
``qdq(x, s)``        -> fake-quant round-trip (used inside fp simulations of
                        integer ops where true int arithmetic is awkward;
                        numerically identical to int arithmetic up to fp
                        accumulation order)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

INT8_MIN, INT8_MAX = -128, 127


def symmetric_scale(x: jax.Array, bits: int = 8) -> jax.Array:
    """Per-tensor symmetric scale from the absolute max (Eq. 2)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = jnp.max(jnp.abs(x))
    return jnp.maximum(amax, 1e-8) / qmax


def percentile_scale(x: jax.Array, p: float = 99.999, bits: int = 8
                     ) -> jax.Array:
    """Percentile-max scale (paper §4.2): clip the top (100-p)% outliers.

    This is Quamba's treatment for the SSM input ``x``: the outliers are
    numerically small (<10) but skew the per-tensor quantization step; a
    99.999th-percentile max restores precision for the bulk of the values.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    amax = jnp.percentile(jnp.abs(x).astype(jnp.float32).reshape(-1), p)
    return jnp.maximum(amax, 1e-8) / qmax


def asymmetric_qparams(x: jax.Array, bits: int = 8
                       ) -> Tuple[jax.Array, jax.Array]:
    """(scale, zero_point) for asymmetric quantization (paper Table 9)."""
    lo, hi = jnp.min(x), jnp.max(x)
    qmin, qmax = -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(hi - lo, 1e-8) / (qmax - qmin)
    zp = jnp.round(qmin - lo / scale)
    return scale, zp


def quantize(x: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    qmin = -(2 ** (bits - 1))
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), qmin, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int16)


def dequantize(q: jax.Array, scale: jax.Array,
               dtype: jnp.dtype = jnp.float32) -> jax.Array:
    return q.astype(dtype) * jnp.asarray(scale, dtype)


def round_ste(x: jax.Array) -> jax.Array:
    """round() whose gradient is the straight-through identity.

    Forward value is exactly ``jnp.round(x)``; under ``jax.grad`` the
    rounding is treated as the identity (d/dx = 1), which is the STE
    surrogate QAT trains through."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def qdq(x: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    """Fake-quant round trip in the input dtype.

    Composed as clip -> straight-through round -> rescale so the op is
    differentiable: w.r.t. ``x`` the gradient is the clipped STE (1 inside
    the representable range, 0 where the value saturates); w.r.t. ``scale``
    it is the LSQ-style gradient (round(z) - z inside the range, +/-qmax at
    saturation).  The forward value is bit-identical to the integer
    round trip ``dequantize(quantize(x, s), s)`` -- for integer clip bounds
    round(clip(z)) == clip(round(z)) -- so PTQ inference numerics are
    unchanged and QAT can reuse this exact op as its training surrogate.
    """
    qmin = -(2.0 ** (bits - 1))
    qmax = 2.0 ** (bits - 1) - 1.0
    z = round_ste(jnp.clip(x / scale, qmin, qmax))
    return z.astype(x.dtype) * jnp.asarray(scale, x.dtype)


def qdq_asymmetric(x: jax.Array, scale: jax.Array, zp: jax.Array,
                   bits: int = 8) -> jax.Array:
    """Asymmetric fake-quant, STE-composed like :func:`qdq` (``zp`` must be
    integer-valued, as :func:`asymmetric_qparams` produces)."""
    qmin = -(2.0 ** (bits - 1))
    qmax = 2.0 ** (bits - 1) - 1.0
    q = jnp.clip(round_ste(x / scale) + zp, qmin, qmax)
    return ((q - zp) * scale).astype(x.dtype)


def dynamic_qdq(x: jax.Array, bits: int = 8) -> jax.Array:
    """Dynamic per-tensor symmetric fake quant (paper Table 9 'dynamic')."""
    return qdq(x, symmetric_scale(x, bits), bits)


def log2_qdq(x: jax.Array, bits: int = 8) -> jax.Array:
    """Log2 (power-of-two) quantization (paper §F).

    Maps |x| to the nearest power of two with a (2^(bits-1)-1)-level
    exponent range anchored at the tensor max; preserves small values much
    better than uniform quantization under outliers.
    """
    levels = 2 ** (bits - 1) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    sign = jnp.sign(x)
    mag = jnp.abs(x) / amax                       # (0, 1]
    e = jnp.clip(jnp.round(-jnp.log2(jnp.maximum(mag, 2.0 ** -levels))),
                 0, levels - 1)
    out = sign * amax * (2.0 ** -e)
    return jnp.where(mag < 2.0 ** -(levels - 1), jnp.zeros_like(x),
                     out).astype(x.dtype)


def per_channel_scale(w: jax.Array, axis: int = 0, bits: int = 8
                      ) -> jax.Array:
    """Per-output-channel symmetric weight scale (beyond-paper option)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    red = tuple(i for i in range(w.ndim) if i != axis)
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quant_error(x: jax.Array, xq: jax.Array) -> jax.Array:
    """Mean absolute quantization error (used in Fig. 2/5 style analyses)."""
    return jnp.mean(jnp.abs(x.astype(jnp.float32) - xq.astype(jnp.float32)))
