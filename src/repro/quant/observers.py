"""Calibration observers (paper §5.1 "Quantization setup").

During calibration the model's forward pass emits, for every named
activation site, a small summary ``{"amax": scalar, "p": vector}`` holding
the absolute max and a fixed ladder of percentiles of |x|.  Summaries from
different calibration batches are merged with an elementwise max (a
conservative upper envelope, matching the paper's "absolute maximum value
observed from the calibration set").

Sites inside a ``lax.scan`` over layers come back stacked with a leading
layer axis -- scales then stay per-layer, which is what the scanned
quantized forward consumes.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

# percentile ladder; index with PCT_INDEX[p]
PERCENTILES = (99.0, 99.9, 99.99, 99.999, 100.0)
PCT_INDEX = {p: i for i, p in enumerate(PERCENTILES)}


def observe(x: jax.Array) -> Dict[str, jax.Array]:
    """Summary statistics of one activation tensor.

    cmax (per-channel abs-max over the last axis) feeds SmoothQuant's
    smoothing factors; amax/percentiles feed the per-tensor static scales.
    """
    ax = jnp.abs(x).astype(jnp.float32)
    flat = ax.reshape(-1)
    return {
        "amax": jnp.max(flat),
        "p": jnp.percentile(flat, jnp.asarray(PERCENTILES)),
        "cmax": jnp.max(ax.reshape(-1, x.shape[-1]), axis=0),
    }


def observe_none(d: int) -> Dict[str, jax.Array]:
    """Placeholder with the same pytree structure as ``observe``."""
    return {
        "amax": jnp.zeros((), jnp.float32),
        "p": jnp.zeros((len(PERCENTILES),), jnp.float32),
        "cmax": jnp.zeros((d,), jnp.float32),
    }


def merge_stats(a, b):
    """Elementwise-max merge of two stats pytrees (same structure)."""
    return jax.tree.map(jnp.maximum, a, b)


def stats_scale(entry: Dict[str, jax.Array], *, percentile: float = 100.0,
                bits: int = 8) -> jax.Array:
    """Static scale from a calibrated summary.

    percentile == 100 -> plain abs-max scale (Eq. 2); otherwise the
    percentile-max scale of paper §4.2 (used for the SSM input ``x``).
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    if percentile >= 100.0:
        amax = entry["amax"]
    else:
        amax = entry["p"][..., PCT_INDEX[percentile]]
    return jnp.maximum(amax, 1e-8) / qmax
