"""Declarative quantization site maps + the generic walker.

The paper's recipe assigns, per architecture family, a set of *quant
sites*: which activation gets a static per-tensor scale (and whether the
scale comes from the percentile max of §4.2), which weight is quantized
(and whether the Hadamard rotation of §4.2 is folded in first), which
weights are fake-quantized in place (fused int8 conv, §4.3; MoE experts,
Table 4), and where SmoothQuant-style per-channel factors are folded.

Instead of hard-coding that assignment in an ``if/elif`` over families,
each family registers a :class:`SiteMap` -- pure data -- and a single
generic :func:`quantize_with_site_map` interprets it.  New architectures
add a registration (see ``repro.models.quantize``), not a new branch.

Site vocabulary
---------------
``ScaleSite``      static activation scale from a calibrated stats entry
``ComputedScale``  scale derived from a parameter (e.g. A from A_log)
``AliasScale``     reuse of an already-computed scale under a new name
                   (linear-input scales share the producing site's scale)
``WeightSite``     int8/int4 weight for a quantized linear
``FakeQuantSite``  in-place weight fake-quant (conv kernels, MoE experts)
``SmoothFold``     SmoothQuant per-channel factors folded into a
                   (norm, linear) pair -- only active for that method
``Group``          nested sub-block (attn / mlp / moe) whose scales and
                   weights live under a sub-key of the block's dicts
``Section``        one top-level parameter collection (``layers``,
                   ``shared``, ``enc_layers``, ...) plus its stacking
                   layout and stats transform
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q
from repro.quant import recipe as qrecipe
from repro.quant.baselines import fold_smoothing, smoothquant_factors
from repro.quant.observers import stats_scale

# percentile policy of a ScaleSite
PCT_NEVER = "never"                 # plain abs-max scale (Eq. 2)
PCT_X = "x"                         # spec.x_percentile (SSM input, §4.2)
PCT_X_UNLESS_QUAROT = "x_unless_quarot"  # rotated-input path keeps minmax


@dataclasses.dataclass(frozen=True)
class ScaleSite:
    name: str
    stat: Optional[str] = None      # stats entry; defaults to ``name``
    percentile: str = PCT_NEVER
    trainable: bool = True          # QAT: scale may be learned (and the
    # fake-quant it feeds passes the clipped-STE gradient); False pins the
    # calibrated value with stop_gradient under ste=True


@dataclasses.dataclass(frozen=True)
class ComputedScale:
    name: str
    fn: str                         # key into _COMPUTED_SCALE_FNS
    param: str


@dataclasses.dataclass(frozen=True)
class AliasScale:
    name: str
    of: str


@dataclasses.dataclass(frozen=True)
class WeightSite:
    name: str
    param: Optional[str] = None     # param entry; defaults to ``name``
    fold_hadamard: bool = False     # W^H = H W fusion of §4.2
    dtype: str = "auto"             # storage: "auto" nibble-packs 4-bit
    # weights ({"qw4", "s_w"}, fed to int4_matmul); "int8" pins one value
    # per byte (conv taps -- the int8 conv kernel reads them directly,
    # values still on the w_bits grid)
    trainable: bool = True          # QAT: STE passes gradient to the fp
    # weight; False freezes the site (stop_gradient) under ste=True


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """A derived tensor quantized once at quantize time with an
    already-computed scale site (e.g. A = -exp(A_log) with the
    ``ComputedScale`` "A") -- consumed by the int8 kernel backend so the
    hot path never recomputes/requantizes static data."""

    name: str                       # output key in the qw dict
    fn: str                         # key into _COMPUTED_TENSOR_FNS
    param: str
    scale: str                      # scale site supplying the step size


@dataclasses.dataclass(frozen=True)
class FakeQuantSite:
    param: str
    per_expert: bool = False        # MoE: one scale per (layer, expert)
    trainable: bool = True          # QAT: STE on the in-place fake-quant


@dataclasses.dataclass(frozen=True)
class SmoothFold:
    kind: str                       # key into _SMOOTH_KINDS
    norm: str                       # norm param folded by 1/s
    weights: Tuple[str, ...]        # linear params folded by s
    stat: str                       # stats entry supplying cmax
    subtree: Optional[str] = None   # weights live under p[subtree]
    produces: Optional[str] = None  # scale name replaced by the fold


@dataclasses.dataclass(frozen=True)
class Group:
    name: str                       # output key in scales/qw dicts
    subtree: Optional[str]          # param sub-dict holding the weights
    scales: Tuple = ()
    weights: Tuple[WeightSite, ...] = ()
    fakequant: Tuple[FakeQuantSite, ...] = ()


@dataclasses.dataclass(frozen=True)
class BlockSites:
    """All quant sites of one block type (flat and/or grouped)."""

    scales: Tuple = ()
    weights: Tuple[WeightSite, ...] = ()
    computed: Tuple[QuantizedTensor, ...] = ()
    fakequant: Tuple[FakeQuantSite, ...] = ()
    smooth: Optional[SmoothFold] = None
    groups: Tuple[Group, ...] = ()


@dataclasses.dataclass(frozen=True)
class Section:
    """One top-level parameter collection walked by the generic pass."""

    params_key: str
    block: BlockSites
    stats_key: Optional[str] = None       # defaults to params_key
    layout: str = "stacked"               # stacked | single | grouped
    stats_transform: str = "identity"     # identity | hybrid_flatten | max0


@dataclasses.dataclass(frozen=True)
class SiteMap:
    family: str
    sections: Tuple[Section, ...]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, SiteMap] = {}


def register_site_map(site_map: SiteMap, *families: str) -> SiteMap:
    """Register ``site_map`` under its family (plus optional aliases)."""
    for fam in families or (site_map.family,):
        _REGISTRY[fam] = site_map
    return site_map


def get_site_map(family: str) -> SiteMap:
    # site maps are registered at import of the model zoo's quantize module
    import repro.models.quantize  # noqa: F401  (registration side effect)
    if family not in _REGISTRY:
        raise KeyError(
            f"no quantization site map registered for family {family!r}; "
            f"registered: {registered_families()}")
    return _REGISTRY[family]


def registered_families() -> Tuple[str, ...]:
    import repro.models.quantize  # noqa: F401
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# site interpreters
# ---------------------------------------------------------------------------

_COMPUTED_SCALE_FNS = {
    # scale of the dequantized A = -exp(A_log) used by the int8 scan
    "neg_exp_symmetric": lambda a: Q.symmetric_scale(-jnp.exp(a)),
}

_COMPUTED_TENSOR_FNS = {
    "neg_exp": lambda a: -jnp.exp(a),
}


def _percentile_of(spec: qrecipe.QuantSpec, mode: str) -> float:
    if mode == PCT_NEVER:
        return 100.0
    if mode == PCT_X:
        return spec.x_percentile
    if mode == PCT_X_UNLESS_QUAROT:
        return 100.0 if spec.method == "quarot" else spec.x_percentile
    raise ValueError(f"unknown percentile policy {mode!r}")


def _qw(w, spec, fold_had: bool = False, stacked: bool = True,
        storage: str = "auto", ste: bool = False):
    fn = lambda wi: qrecipe.quantize_weight(
        wi, spec, fold_hadamard_axis=0 if fold_had else None,
        storage=storage, ste=ste)
    return jax.vmap(fn)(w) if stacked else fn(w)


def _wqdq(w, spec):
    s = jax.lax.stop_gradient(Q.symmetric_scale(w, bits=spec.w_bits))
    return Q.qdq(w, s, bits=spec.w_bits)


def _wqdq_experts(w, spec):
    flat = w.reshape((-1,) + w.shape[-2:])
    out = jax.vmap(lambda wi: _wqdq(wi, spec))(flat)
    return out.reshape(w.shape)


def _smooth_norm_linear(fold: SmoothFold, p, stats_l, spec, stacked):
    """Fold s into (norm, linear); the folded input's scale is recomputed
    from the smoothed channel maxima (SmQ-SSM, paper §5.3)."""
    (weight,) = fold.weights

    def fold_one(norm, w_in, cmax_in):
        s1 = smoothquant_factors(cmax_in, w_in, spec.smooth_alpha)
        norm, w_in = fold_smoothing(norm, w_in, s1)
        new_amax = jnp.max(cmax_in / s1)
        return norm, w_in, jnp.maximum(new_amax, 1e-8) / 127.0

    run = jax.vmap(fold_one) if stacked else fold_one
    p[fold.norm], p[weight], s = run(
        p[fold.norm], p[weight], stats_l[fold.stat]["cmax"])
    return {fold.produces: s} if fold.produces else {}


def _smooth_norm_qkv(fold: SmoothFold, p, stats_l, spec, stacked):
    """Fold s into (norm, wq/wk/wv) -- the attention-input smoothing of
    the SmoothQuant baseline on transformer blocks."""
    wq_name, wk_name, wv_name = fold.weights

    def fold_one(ln1, wq, wk, wv, cmax):
        s = smoothquant_factors(cmax, wq, spec.smooth_alpha)
        ln1 = ln1 / s
        shape = (-1, 1)
        return (ln1, wq * s.reshape(shape), wk * s.reshape(shape),
                wv * s.reshape(shape))

    run = jax.vmap(fold_one) if stacked else fold_one
    sub = dict(p[fold.subtree]) if fold.subtree else p
    p[fold.norm], sub[wq_name], sub[wk_name], sub[wv_name] = run(
        p[fold.norm], sub[wq_name], sub[wk_name], sub[wv_name],
        stats_l[fold.stat]["cmax"])
    if fold.subtree:
        p[fold.subtree] = sub
    return {}


_SMOOTH_KINDS = {
    "norm_linear": _smooth_norm_linear,
    "norm_qkv": _smooth_norm_qkv,
}


def _scale_sites(sites, stats_l, spec, p, stacked, pre: Dict,
                 ste: bool = False, overrides: Optional[Dict] = None
                 ) -> Dict:
    """Interpret a tuple of scale sites (aliases resolve last).

    ``overrides`` maps base ScaleSite names to replacement scale arrays
    (QAT-learned scales); SmoothFold-produced scales keep precedence.
    Under ``ste`` a non-trainable site's scale is stop_gradiented so the
    clipped-STE fake-quant it feeds cannot move it.
    """
    scales: Dict = {}
    for site in sites:
        if isinstance(site, ScaleSite):
            if site.name in pre:            # produced by a SmoothFold
                scales[site.name] = pre[site.name]
                continue
            if overrides is not None and site.name in overrides:
                s = overrides[site.name]
            else:
                stat = site.stat or site.name
                pct = _percentile_of(spec, site.percentile)
                s = stats_scale(stats_l[stat], percentile=pct)
                if spec.soft_edge > 0.0 and pct < 100.0:
                    # Quamba-SE soft edge: instead of the hard percentile
                    # clip, pull the scale toward the observed abs-max so
                    # rare outliers are softly covered -- the accuracy
                    # hedge the W4A8 preset leans on (PAPERS.md,
                    # Quamba-SE).
                    s_max = stats_scale(stats_l[stat], percentile=100.0)
                    s = qrecipe.soft_edge_blend(s, s_max, spec.soft_edge)
            if ste and not site.trainable:
                s = jax.lax.stop_gradient(s)
            scales[site.name] = s
        elif isinstance(site, ComputedScale):
            fn = _COMPUTED_SCALE_FNS[site.fn]
            arr = p[site.param]
            scales[site.name] = jax.vmap(fn)(arr) if stacked else fn(arr)
    for site in sites:
        if isinstance(site, AliasScale):
            scales[site.name] = scales[site.of]
    return scales


def _weight_sites(sites, p_src, spec, stacked, ste: bool = False) -> Dict:
    qw: Dict = {}
    for site in sites:
        param = site.param or site.name
        lin = _qw(p_src[param], spec,
                  fold_had=site.fold_hadamard, stacked=stacked,
                  storage=site.dtype, ste=ste)
        if ste and not site.trainable:
            lin = jax.tree.map(jax.lax.stop_gradient, lin)
        qw[site.name] = lin
    return qw


def _computed_sites(sites, p_src, scales, stacked) -> Dict:
    qw: Dict = {}
    for site in sites:
        fn = _COMPUTED_TENSOR_FNS[site.fn]
        one = lambda arr, s, fn=fn: {"qw": Q.quantize(fn(arr), s)}
        run = jax.vmap(one) if stacked else one
        qw[site.name] = run(p_src[site.param], scales[site.scale])
    return qw


def _fakequant_sites(sites, p_dst, spec, stacked, ste: bool = False) -> None:
    for site in sites:
        w = p_dst[site.param]
        if site.per_expert:
            out = _wqdq_experts(w, spec)
        elif stacked:
            out = jax.vmap(lambda wi: _wqdq(wi, spec))(w)
        else:
            out = _wqdq(w, spec)
        if ste and not site.trainable:
            out = jax.lax.stop_gradient(out)
        p_dst[site.param] = out


def quantize_block(block: BlockSites, params_l, stats_l,
                   spec: qrecipe.QuantSpec, stacked: bool = True,
                   ste: bool = False, overrides: Optional[Dict] = None):
    """Interpret one block's sites -> (new params, scales, qw)."""
    p = dict(params_l)
    ov = overrides or {}
    pre: Dict = {}
    if block.smooth is not None and spec.method == "smoothquant":
        pre = _SMOOTH_KINDS[block.smooth.kind](
            block.smooth, p, stats_l, spec, stacked)

    scales = _scale_sites(block.scales, stats_l, spec, p, stacked, pre,
                          ste=ste, overrides=ov)
    qw = _weight_sites(block.weights, p, spec, stacked, ste=ste)
    qw.update(_computed_sites(block.computed, p, scales, stacked))
    _fakequant_sites(block.fakequant, p, spec, stacked, ste=ste)

    for grp in block.groups:
        src = p[grp.subtree] if grp.subtree else p
        grp_ov = ov.get(grp.name) if isinstance(ov.get(grp.name), dict) \
            else None
        scales[grp.name] = _scale_sites(grp.scales, stats_l, spec, src,
                                        stacked, pre, ste=ste,
                                        overrides=grp_ov)
        qw[grp.name] = _weight_sites(grp.weights, src, spec, stacked,
                                     ste=ste)
        if grp.fakequant:
            sub = dict(src) if grp.subtree else p
            _fakequant_sites(grp.fakequant, sub, spec, stacked, ste=ste)
            if grp.subtree:
                p[grp.subtree] = sub
    return p, scales, qw


# ---------------------------------------------------------------------------
# section layouts / stats transforms
# ---------------------------------------------------------------------------

def _stats_for(section: Section, stats: Dict):
    key = section.stats_key or section.params_key
    kind = section.stats_transform
    if kind == "identity":
        return stats[key]
    if kind == "hybrid_flatten":
        # group-scanned stats come back (groups, per, ...); flatten to
        # match the stacked params, then append the flat tail if present
        flat = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), stats[key])
        if "tail" in stats:
            flat = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                flat, stats["tail"])
        return flat
    if kind == "max0":
        # shared-block stats are stacked over invocations; reduce to one
        # conservative scale set
        return jax.tree.map(lambda a: jnp.max(a, axis=0), stats[key])
    raise ValueError(f"unknown stats_transform {kind!r}")


def _quantize_section(section: Section, params, stats, spec,
                      ste: bool = False,
                      overrides: Optional[Dict] = None):
    p_sec = params[section.params_key]
    s_sec = _stats_for(section, stats)
    if section.layout == "stacked":
        return quantize_block(section.block, p_sec, s_sec, spec,
                              stacked=True, ste=ste, overrides=overrides)
    if section.layout == "single":
        return quantize_block(section.block, p_sec, s_sec, spec,
                              stacked=False, ste=ste, overrides=overrides)
    if section.layout == "grouped":
        # (groups, per, ...) leading dims: flatten, quantize, reshape back
        g, per = jax.tree.leaves(p_sec)[0].shape[:2]
        flat = lambda t: jax.tree.map(
            lambda a: a.reshape((g * per,) + a.shape[2:]), t)
        np_, sc, qw = quantize_block(
            section.block, flat(p_sec), flat(s_sec), spec, stacked=True,
            ste=ste, overrides=flat(overrides) if overrides else None)
        back = lambda t: jax.tree.map(
            lambda a: a.reshape((g, per) + a.shape[1:]), t)
        return back(np_), back(sc), back(qw)
    raise ValueError(f"unknown layout {section.layout!r}")


# ---------------------------------------------------------------------------
# generic walker
# ---------------------------------------------------------------------------

def quantize_with_site_map(params: Dict, stats: Dict, cfg,
                           spec: qrecipe.QuantSpec,
                           site_map: Optional[SiteMap] = None, *,
                           ste: bool = False,
                           scale_overrides: Optional[Dict] = None):
    """Walk the family's registered site map -> (new_params, qdata).

    ste=True is the QAT mode: weight sites come back as float
    straight-through grid values (bit-identical dequantized forward, but
    ``jax.grad`` through the qdata reaches the fp weights), non-trainable
    sites are frozen with stop_gradient, and nothing is nibble-packed.

    ``scale_overrides`` replaces the stats-derived scale of base
    ``ScaleSite`` entries (a sub-tree shaped like the ``"scales"`` output
    restricted to those sites, see :func:`trainable_scale_overrides`);
    aliases resolve against the overridden values, so QAT-learned scales
    stay consistent across the sites that share them.
    """
    spec.validate()
    if site_map is None:
        site_map = get_site_map(cfg.family)
    new_params = dict(params)
    scales: Dict = {}
    qw: Dict = {}
    for section in site_map.sections:
        ov = (scale_overrides or {}).get(section.params_key)
        np_, sc, qws = _quantize_section(section, params, stats, spec,
                                         ste=ste, overrides=ov)
        new_params[section.params_key] = np_
        scales[section.params_key] = sc
        qw[section.params_key] = qws
    return new_params, {"scales": scales, "qw": qw}


# ---------------------------------------------------------------------------
# QAT helpers: which scales are learnable, and their initial values
# ---------------------------------------------------------------------------

def _base_scale_names(block: BlockSites) -> Dict:
    """{site_name: None, group_name: {site_name: None}} of the block's
    trainable base ``ScaleSite`` entries (aliases and computed scales
    resolve from these, so only these become QAT state)."""
    names: Dict = {s.name: None for s in block.scales
                   if isinstance(s, ScaleSite) and s.trainable}
    for grp in block.groups:
        sub = {s.name: None for s in grp.scales
               if isinstance(s, ScaleSite) and s.trainable}
        if sub:
            names[grp.name] = sub
    return names


def trainable_scale_overrides(site_map: SiteMap, scales: Dict) -> Dict:
    """Extract the learnable-scale pytree from a PTQ ``qdata["scales"]``.

    The result mirrors the scales structure restricted to trainable base
    ``ScaleSite`` entries; it is the initial value of the QAT scale state
    and the ``scale_overrides`` accepted by :func:`quantize_with_site_map`.
    """
    out: Dict = {}
    for section in site_map.sections:
        sec_scales = scales.get(section.params_key, {})
        sec_out: Dict = {}
        for name, sub in _base_scale_names(section.block).items():
            if isinstance(sub, dict):
                grp_scales = sec_scales.get(name, {})
                grp = {n: grp_scales[n] for n in sub if n in grp_scales}
                if grp:
                    sec_out[name] = grp
            elif name in sec_scales:
                sec_out[name] = sec_scales[name]
        if sec_out:
            out[section.params_key] = sec_out
    return out
