"""Calibration runner (paper §5.1): aggregate activation statistics.

``forward_calib(params, batch) -> (out, stats)`` is supplied by the model
zoo; this runner jits it once and folds the per-batch stats pytrees with an
elementwise max.  512 random calibration sentences in the paper; here the
batch source is any iterable of model inputs.
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional

import jax

from repro.quant.observers import merge_stats


def run_calibration(forward_calib: Callable, params, batches: Iterable,
                    max_batches: Optional[int] = None):
    """Returns the merged stats pytree over the calibration stream."""
    fwd = jax.jit(forward_calib)
    merged = None
    for i, batch in enumerate(batches):
        if max_batches is not None and i >= max_batches:
            break
        _, stats = fwd(params, batch)
        merged = stats if merged is None else merge_stats(merged, stats)
    if merged is None:
        raise ValueError("calibration stream was empty")
    return jax.device_get(merged)
