"""Re-implementations of the paper's baselines (Tables 1-3, §C).

* SmoothQuant (SmQ-SSM): per-channel smoothing factors s_j =
  amax(X_j)^alpha / amax(W_j)^(1-alpha) folded into (prev-op, weight) pairs
  so activations become easier to quantize per-tensor.
* QuaRot-SSM: Hadamard rotations on *every* linear interface (both the
  residual stream and the SSM input), which fixes outliers but costs extra
  transposes/transforms at the SSM input at inference time -- this is the
  overhead Quamba avoids (paper Table 1 discussion, §C).

Model-specific folding (which weight pairs absorb the factors) is wired in
``repro.models.quantize``; the math lives here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def smoothquant_factors(act_cmax: jax.Array, w: jax.Array,
                        alpha: float = 0.5, in_axis: int = 0) -> jax.Array:
    """Per-input-channel smoothing factors (SmoothQuant Eq. 4), alpha=0.5.

    act_cmax: per-channel abs-max of the linear's input activations (from
    calibration).  w: the linear weight; its per-input-channel abs-max is
    reduced over all other axes.
    """
    red = tuple(i for i in range(w.ndim) if i != in_axis % w.ndim)
    w_cmax = jnp.max(jnp.abs(w), axis=red)
    act_cmax = jnp.maximum(act_cmax.astype(jnp.float32), 1e-5)
    w_cmax = jnp.maximum(w_cmax.astype(jnp.float32), 1e-5)
    s = act_cmax ** alpha / w_cmax ** (1.0 - alpha)
    # guard: keep factors in a sane range so the folded weight stays finite
    return jnp.clip(s, 1e-3, 1e3)


def fold_smoothing(w_prev_out: jax.Array, w_next: jax.Array,
                   s: jax.Array, next_in_axis: int = 0):
    """Fold smoothing: prev output channels /= s, next input channels *= s.

    ``w_prev_out`` is whatever produces the activation (an RMSNorm weight
    vector or a previous linear's output channels, broadcast on the last
    axis).  Returns the updated pair.
    """
    w_prev_out = w_prev_out / s.astype(w_prev_out.dtype)
    shape = [1] * w_next.ndim
    shape[next_in_axis % w_next.ndim] = -1
    w_next = w_next * s.reshape(shape).astype(w_next.dtype)
    return w_prev_out, w_next
