"""Theoretical quantization-error bound for LTI SSMs (paper §A, Thm 4.1)
and the empirical HiPPO-materialized simulation behind Figure 5.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.quant import quantizers as Q


def theorem_bound(t: jax.Array, T: float, b: float, eps: float) -> jax.Array:
    """|h[t] - h_bar[t]| <= b * eps * e^{t-T} / (e - 1)   (Theorem 4.1).

    NOTE (documented in DESIGN.md): the paper's unrolling drops the
    undecayed b*eps terms of the last steps, so this expression
    under-counts for every t (at t=1 it is ~b*eps*e^{1-T} while a single
    step already contributes b*eps; at t=T the lag-0 and lag-1
    contributions both arrive with decay factor ~1).  ``corrected_bound``
    below is the tight uniform envelope sum_k e^{-k(k-1)/2} * b * eps
    ~ 2.420 b*eps.  The qualitative claim of the theorem -- the error
    stays bounded as t grows -- is unaffected.
    """
    return b * eps * jnp.exp(t - T) / (jnp.e - 1.0)


CORRECTED_CONSTANT = float(sum(np.exp(-k * (k - 1) / 2.0)
                                for k in range(0, 40)))  # ~2.4202


def corrected_bound(t: jax.Array, T: float, b: float, eps: float
                    ) -> jax.Array:
    """Tight uniform bound: the lag-k contribution to h[t] is damped by
    prod_{i=t-k+1}^{t} e^{i-T} = e^{-(k(T-t) + k(k-1)/2)}, maximized at
    t = T where it is e^{-k(k-1)/2}; summing over k gives the constant
    sum_k e^{-k(k-1)/2} ~ 2.420 (note lag 0 AND lag 1 both arrive with
    decay ~1 -- the term the paper's geometric-series step drops)."""
    return jnp.full_like(jnp.asarray(t, jnp.float32),
                         b * eps * CORRECTED_CONSTANT)


def simulate_theorem_system(steps: int = 100, b: float = 0.7,
                            eps: float = 0.01, seed: int = 0
                            ) -> Dict[str, np.ndarray]:
    """Exact system of Theorem A.1: h[t] = e^{t-T} h[t-1] + b x[t].

    The input perturbation is adversarial (|delta| = eps), so the measured
    error must sit below the analytic bound b*eps*e^{t-T}/(e-1) for every t.
    """
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(steps,)).astype(np.float64)
    delta = eps * np.sign(rng.normal(size=(steps,)))
    h, hq = 0.0, 0.0
    errs = []
    for t in range(1, steps + 1):
        a = np.exp(t - steps)
        h = a * h + b * x[t - 1]
        hq = a * hq + b * (x[t - 1] + delta[t - 1])
        errs.append(abs(h - hq))
    ts = np.arange(1, steps + 1, dtype=np.float64)
    bound = np.asarray(theorem_bound(jnp.asarray(ts), float(steps), b, eps))
    return {"t": ts, "err": np.asarray(errs), "bound": bound}


def hippo_matrices(measure: str, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """HiPPO-LegT / HiPPO-LegS (A, B) materialization (Gu et al. 2020)."""
    if measure == "legt":
        q = np.arange(n, dtype=np.float64)
        r = (2 * q + 1) ** 0.5
        j, i = np.meshgrid(q, q)
        a = r[:, None] * np.where(i < j, (-1.0) ** (i - j), 1.0) * r[None, :]
        b = r[:, None]
        return -a, b
    if measure == "legs":
        q = np.arange(n, dtype=np.float64)
        col, row = np.meshgrid(q, q)
        r = 2 * q + 1
        m = -(np.where(row >= col, r, 0) - np.diag(q))
        t = np.sqrt(np.diag(2 * q + 1))
        a = t @ m @ np.linalg.inv(t)
        b = np.diag(t)[:, None]
        return a, b
    raise ValueError(measure)


def discretize_bilinear(a: np.ndarray, b: np.ndarray, dt: float
                        ) -> Tuple[np.ndarray, np.ndarray]:
    n = a.shape[0]
    eye = np.eye(n)
    inv = np.linalg.inv(eye - dt / 2 * a)
    return inv @ (eye + dt / 2 * a), (inv * dt) @ b


def simulate_quantized_lti(measure: str = "legt", n: int = 4, steps: int = 100,
                           dt: float = 0.05, bits: int = 8, seed: int = 0
                           ) -> Dict[str, np.ndarray]:
    """Reproduce the Figure-5 experiment.

    Runs the discretized HiPPO SSM twice -- with fp input and with int8-
    quantized input -- and reports Mean(|y - y_bar|) per step plus the
    Theorem-4.1 bound evaluated with the empirical (b, eps).
    """
    rng = np.random.default_rng(seed)
    a, b = hippo_matrices(measure, n)
    ad, bd = discretize_bilinear(a, b, dt)
    bd = bd.ravel()
    c = rng.normal(size=(n, n))

    x = rng.normal(size=(steps,)).astype(np.float32)  # 1-D input signal
    s = Q.symmetric_scale(jnp.asarray(x), bits=bits)
    xq = np.asarray(Q.qdq(jnp.asarray(x), s, bits=bits))

    h = np.zeros(n)
    hq = np.zeros(n)
    errs, herrs = [], []
    for t in range(steps):
        h = ad @ h + bd * x[t]
        hq = ad @ hq + bd * xq[t]
        errs.append(np.mean(np.abs(c @ h - c @ hq)))
        herrs.append(np.max(np.abs(h - hq)))

    eps = float(np.max(np.abs(x - xq)))
    b_const = float(np.max(np.abs(bd)))
    ts = np.arange(1, steps + 1, dtype=np.float32)
    bound = np.asarray(theorem_bound(jnp.asarray(ts), float(steps),
                                     b_const * n, eps))
    return {
        "t": ts,
        "output_err": np.asarray(errs, np.float32),
        "state_err": np.asarray(herrs, np.float32),
        "bound": bound,
        "eps": np.float32(eps),
    }
