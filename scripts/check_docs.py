#!/usr/bin/env python
"""Doc-CI: execute every ```python block in README.md and docs/*.md.

    python scripts/check_docs.py [files.md ...]

Documentation code that is never executed rots; this script makes every
fenced ``python`` block a test.  Blocks within one markdown file run
SEQUENTIALLY IN ONE PROCESS sharing a namespace (like a doctest
session), so a later block can use names an earlier block defined.
Each file gets its own subprocess with ``PYTHONPATH=src`` and
``JAX_PLATFORMS=cpu`` (accelerator-plugin probing would add minutes).

Fence rules:
  * ```python        -- executed (the default; keep snippets CPU-sized)
  * ```python no-run -- rendered as python, NOT executed (for
                        illustrative fragments that need real weights,
                        a TPU, or external services)
  * ```bash / ```text / anything else -- ignored

Failures print the markdown file and line number of the offending
block.  Exit code: 0 all green, 1 otherwise.
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from typing import List, Tuple

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_FENCE = re.compile(r"^```(\S+)?(.*)$")


def extract_blocks(text: str) -> List[Tuple[int, str]]:
    """``(start_line, source)`` for every executable python block."""
    blocks: List[Tuple[int, str]] = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE.match(lines[i].strip())
        if m and m.group(1):
            lang = m.group(1).lower()
            info = (m.group(2) or "").strip()
            start = i + 1
            body: List[str] = []
            i += 1
            while i < len(lines) and lines[i].strip() != "```":
                body.append(lines[i])
                i += 1
            if lang == "python" and "no-run" not in info:
                blocks.append((start + 1, "\n".join(body)))
        i += 1
    return blocks


def run_file(path: str, timeout: int) -> bool:
    with open(path) as f:
        blocks = extract_blocks(f.read())
    rel = os.path.relpath(path, REPO)
    if not blocks:
        print(f"check_docs: {rel}: no python blocks")
        return True
    # one shared namespace per file; each block compiled under a label
    # carrying its markdown line so tracebacks point at the doc source
    runner = ["g = {'__name__': '__main__'}"]
    for line, src in blocks:
        runner.append(
            f"exec(compile({src!r}, {f'{rel}:L{line}'!r}, 'exec'), g)")
    env = dict(os.environ,
               PYTHONPATH="src" + (os.pathsep + os.environ["PYTHONPATH"]
                                   if os.environ.get("PYTHONPATH")
                                   else ""),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    r = subprocess.run([sys.executable, "-c", "\n".join(runner)],
                       cwd=REPO, env=env, capture_output=True,
                       text=True, timeout=timeout)
    if r.returncode != 0:
        print(f"check_docs: {rel}: FAILED "
              f"({len(blocks)} blocks)\n{r.stdout[-2000:]}"
              f"{r.stderr[-4000:]}")
        return False
    print(f"check_docs: {rel}: ok ({len(blocks)} blocks)")
    return True


def default_files() -> List[str]:
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        files += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                        if f.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="markdown files (default: README.md docs/*.md)")
    ap.add_argument("--timeout", type=int, default=600,
                    help="per-file timeout, seconds")
    args = ap.parse_args()
    files = args.files or default_files()
    ok = True
    for path in files:
        ok &= run_file(path, args.timeout)
    print("check_docs:", "all docs execute" if ok else "FAILURES above")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
