#!/usr/bin/env bash
# One-command verification: runs the tier-1 test suite exactly as CI does.
#   ./scripts/check.sh            # full suite
#   ./scripts/check.sh tests/test_api.py   # any extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -x -q "$@"
